"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth for kernel sweeps in tests/test_kernels.py and the
semantic reference for the XLA fallbacks in ops.py. They are deliberately
naive (materialize everything, O(S²) attention, sequential scans) — clarity
over speed.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# seeded_axpy: out = w + scale * z,  z = counter-hash N(0,1) stream from seed
# ---------------------------------------------------------------------------

# Trailing dims that are a multiple of one SIMD packet (16 f32 covers both
# AVX2 and AVX-512) vectorize log/cos without scalar tails, so the native-
# shape evaluation is bitwise identical to the kernel's lane-tiled one.
_SIMD_PACKET = 16


def draw_z_ref(shape, seed) -> jnp.ndarray:
    """The canonical z-stream: fmix32 counter hash + Box–Muller, identical to
    the Pallas kernel's in-VMEM generation (bitwise).

    Counters are always flat element indices, so the stream's VALUES are a
    pure function of (seed, index) — but the last ulp of log/cos depends on
    how XLA:CPU vectorizes the evaluating loop. Two regimes:

    * SIMD-exact trailing dim (every real model leaf): counters come from
      per-dim broadcasted_iota and the chain stays purely elementwise in
      the consumer's own shape — it fuses into the consuming axpy (z never
      materializes), shards with the consumer under GSPMD, and compiles
      identically inside lax.scan and standalone jit (the engine bitwise
      invariant). No scalar libm tails, so it is bitwise equal to the
      kernel's lane-tiled evaluation.
    * awkward trailing dim (e.g. [64, 50]): native evaluation has shape-
      dependent scalar libm tails — the historical 1-2 ulp pallas-interpret
      drift. Evaluate on the kernel's canonical [rows, LANE] layout behind
      an optimization barrier so fusion cannot drag the transcendentals
      back into the consumer's iteration space (the barrier materializes z
      for these shapes — the price of bitwise stability off the lane grid).
    """
    from repro.kernels.seeded_axpy import LANE, gaussian_from_counter
    seed = jnp.asarray(seed).astype(jnp.uint32)
    if shape and shape[-1] % _SIMD_PACKET == 0:
        idx = jnp.zeros(shape, jnp.uint32)
        for k in range(len(shape)):
            stride_k = np_prod(shape[k + 1:]) & 0xFFFFFFFF
            idx = idx + jax.lax.broadcasted_iota(
                jnp.uint32, shape, k) * jnp.uint32(stride_k)
        return gaussian_from_counter(idx, seed)
    n = np_prod(shape) if shape else 1
    rows = (n + LANE - 1) // LANE
    idx = (jax.lax.broadcasted_iota(jnp.uint32, (rows, LANE), 0)
           * jnp.uint32(LANE)
           + jax.lax.broadcasted_iota(jnp.uint32, (rows, LANE), 1))
    z = jax.lax.optimization_barrier(gaussian_from_counter(idx, seed))
    return z.reshape(-1)[:n].reshape(shape)


def np_prod(dims) -> int:
    n = 1
    for d in dims:
        n *= int(d)
    return n


def seeded_axpy_ref(w: jnp.ndarray, seed, scale) -> jnp.ndarray:
    """Reference semantics of the fused perturb: deterministic standard-normal
    z from the counter-hash stream, scaled and added in f32."""
    z = draw_z_ref(w.shape, seed)
    return (w.astype(jnp.float32) + jnp.asarray(scale, jnp.float32) * z
            ).astype(w.dtype)


# ---------------------------------------------------------------------------
# attention: causal / local-window / GQA, full-softmax oracle
# ---------------------------------------------------------------------------

def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = True, window: Optional[int] = None,
                  scale: Optional[float] = None) -> jnp.ndarray:
    """q: [B, Hq, Sq, D], k/v: [B, Hkv, Skv, D] (Hq % Hkv == 0).

    window=w restricts key j to q position i with i − w < j ≤ i (local attn).
    Assumes q positions are the LAST Sq positions of the Skv range (so decode
    with a prefix cache works: Sq=1, Skv=cache_len).
    """
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    kf = jnp.repeat(kf, group, axis=1)
    vf = jnp.repeat(vf, group, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", qf, kf)
    q_pos = jnp.arange(sq) + (skv - sq)
    k_pos = jnp.arange(skv)
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    scores = jnp.where(mask[None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vf)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# RG-LRU first-order linear recurrence: h_t = a_t * h_{t-1} + x_t
# ---------------------------------------------------------------------------

def linear_recurrence_ref(a: jnp.ndarray, x: jnp.ndarray,
                          h0: Optional[jnp.ndarray] = None
                          ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """a, x: [B, S, D]; h0: [B, D]. Returns (hs [B,S,D], h_last [B,D])."""
    b, s, d = x.shape
    if h0 is None:
        h0 = jnp.zeros((b, d), dtype=jnp.float32)

    def step(h, inp):
        a_t, x_t = inp
        h = a_t * h + x_t
        return h, h

    a32 = a.astype(jnp.float32).swapaxes(0, 1)   # [S, B, D]
    x32 = x.astype(jnp.float32).swapaxes(0, 1)
    h_last, hs = jax.lax.scan(step, h0.astype(jnp.float32), (a32, x32))
    return hs.swapaxes(0, 1).astype(x.dtype), h_last.astype(x.dtype)


# ---------------------------------------------------------------------------
# Mamba-2 SSD: y_t = C_tᵀ S_t x-state;  S_t = exp(a_t) S_{t-1} + B_t x_tᵀ dt_t
# ---------------------------------------------------------------------------

def ssd_ref(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray,
            c: jnp.ndarray, state0: Optional[jnp.ndarray] = None
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sequential state-space-duality oracle (ngroups = 1).

    x:  [B, S, H, P]   inputs per head
    dt: [B, S, H]      positive step sizes (already softplus'd)
    a:  [H]            negative decay rates (A = -exp(A_log) convention)
    b:  [B, S, N]      input projections (shared across heads, G=1)
    c:  [B, S, N]      output projections
    state0: [B, H, P, N]
    Returns (y [B,S,H,P], state_last [B,H,P,N]).
    """
    B, S, H, P = x.shape
    N = b.shape[-1]
    if state0 is None:
        state0 = jnp.zeros((B, H, P, N), dtype=jnp.float32)

    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    cf = c.astype(jnp.float32)

    def step(state, inp):
        x_t, dt_t, b_t, c_t = inp            # [B,H,P], [B,H], [B,N], [B,N]
        decay = jnp.exp(af[None, :] * dt_t)  # [B,H]
        # state: [B,H,P,N]
        upd = jnp.einsum("bhp,bn,bh->bhpn", x_t, b_t, dt_t)
        state = decay[:, :, None, None] * state + upd
        y_t = jnp.einsum("bhpn,bn->bhp", state, c_t)
        return state, y_t

    xs = (xf.swapaxes(0, 1), dtf.swapaxes(0, 1), bf.swapaxes(0, 1),
          cf.swapaxes(0, 1))
    state_last, ys = jax.lax.scan(step, state0, xs)
    return ys.swapaxes(0, 1).astype(x.dtype), state_last
