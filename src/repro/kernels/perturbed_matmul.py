"""Fused perturbed matmul kernel: out = x @ (w + eps · z(seed)).

The second half of the paper's memory trick (the first is
`seeded_axpy.py`). A naive ZO dual forward materializes the perturbed
parameter tree θ±εz in HBM before each rollout; here the perturbation is
generated *inside the kernel, per weight tile, in VMEM* from the same
counter-hash stream (`gaussian_from_counter`) — the perturbed weights never
exist as a tensor anywhere in the memory hierarchy. HBM sees exactly one
read of `w` per tile, the same traffic as an unperturbed matmul.

Counter layout: element (k, n) of `w` draws counter

    idx = off + k · N + n                 (row-major over the ORIGINAL w)

where `off` is the leaf's base offset into its per-leaf stream (0 for a
whole leaf; `layer · K · N` for a layer sliced out of a scan-stacked
[L, K, N] leaf — see `kernels.ops.PerturbedParam`). This makes the fused
draw bitwise identical to `ref.draw_z_ref` / `seeded_axpy` on the same
leaf: the stream is a pure function of (seed, flat element index),
invariant to tiling, grid shape, and scan slicing.

Grid layout: (m, n, k) with the contraction dim innermost and sequential
("arbitrary" semantics); the f32 accumulator lives in VMEM scratch across
k steps. Padding is carried by zero-filled x columns (0 · (w + εz) = 0
exactly), so no masking is needed in-kernel and the identity-probe
property holds bitwise: x = I returns (w + εz) rows unchanged.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams
from repro.kernels.seeded_axpy import gaussian_from_counter

LANE = 128


def _pmm_kernel(seed_ref, off_ref, eps_ref, x_ref, w_ref, o_ref, acc_ref, *,
                bk: int, bn: int, n_orig: int):
    ki = pl.program_id(2)
    nj = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # in-VMEM z for this (k, n) tile of w: counters are flat row-major
    # indices over the ORIGINAL (unpadded) w, shifted by the leaf offset
    r_iota = jax.lax.broadcasted_iota(jnp.uint32, (bk, bn), 0)
    c_iota = jax.lax.broadcasted_iota(jnp.uint32, (bk, bn), 1)
    k0 = (ki * bk).astype(jnp.uint32)
    n0 = (nj * bn).astype(jnp.uint32)
    idx = off_ref[0] + (k0 + r_iota) * jnp.uint32(n_orig) + (n0 + c_iota)
    z = gaussian_from_counter(idx, seed_ref[0])
    wz = w_ref[...].astype(jnp.float32) + eps_ref[0] * z
    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32), wz,
                            preferred_element_type=jnp.float32)

    @pl.when(ki == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bk", "bn", "interpret"))
def perturbed_matmul_pallas(x: jnp.ndarray, w: jnp.ndarray,
                            seed: jnp.ndarray, off: jnp.ndarray, eps,
                            bm: int = 128, bk: int = 128, bn: int = 128,
                            interpret: bool = False) -> jnp.ndarray:
    """x [M, K] @ (w [K, N] + eps · z(seed, off)) with in-VMEM z generation.

    Args:
      x: [M, K] activations (any float dtype; accumulation is f32).
      w: [K, N] unperturbed weights.
      seed: uint32 scalar — the leaf's stream seed (`zo.leaf_seed`).
      off: uint32 scalar — base flat offset of `w` within its leaf stream
        (0 unless `w` is a slice of a scan-stacked leaf).
      eps: perturbation scale (traced or static scalar; ±μ in the dual
        forward).
      bm/bk/bn: tile sizes (clamped to the padded operand dims).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    out_dtype = x.dtype

    bm = min(bm, max(8, -(-m // 8) * 8))
    bk = min(bk, max(LANE, -(-k // LANE) * LANE))
    bn = min(bn, max(LANE, -(-n // LANE) * LANE))
    mp = -(-m // bm) * bm
    kp = -(-k // bk) * bk
    npad = -(-n // bn) * bn
    # zero-filled x columns kill the padded-K contributions exactly; padded
    # N columns are sliced off below (their z counters are junk by design).
    if (mp, kp) != (m, k):
        x = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    if (kp, npad) != (k, n):
        w = jnp.pad(w, ((0, kp - k), (0, npad - n)))

    out = pl.pallas_call(
        functools.partial(_pmm_kernel, bk=bk, bn=bn, n_orig=n),
        grid=(mp // bm, npad // bn, kp // bk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, npad), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(jnp.asarray([seed]).astype(jnp.uint32),
      jnp.asarray([off]).astype(jnp.uint32),
      jnp.asarray([eps], jnp.float32), x, w)
    return out[:m, :n]
