"""Pallas-TPU API compatibility across jax versions.

jax renamed `pltpu.TPUCompilerParams` (≤ 0.4.x) to `pltpu.CompilerParams`
(≥ 0.5). The fields we use (`dimension_semantics`) are identical in both.
Every kernel imports the alias from here so the version probe lives in
exactly one place.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams",
                         getattr(pltpu, "TPUCompilerParams", None))
if CompilerParams is None:  # fail here, at the version probe, not in kernels
    raise ImportError(
        "jax.experimental.pallas.tpu exposes neither CompilerParams "
        "(jax >= 0.5) nor TPUCompilerParams (jax <= 0.4.x); update "
        "repro/kernels/compat.py for this jax version")
