"""Flash attention (fused online-softmax) Pallas TPU kernel.

Supports causal masking, local windows (RecurrentGemma), and GQA via the
BlockSpec index_map (kv blocks are fetched for head h using h // group — no
jnp.repeat materialization).

Grid layout: (batch·heads, num_q_blocks, num_kv_blocks) with the kv dimension
innermost and sequential ("arbitrary" semantics): the f32 accumulator, running
max m and normalizer l live in VMEM scratch that persists across kv steps.
Causal/window block-level skipping uses pl.when — skipped blocks cost zero
MXU work (the dominant saving for long-sequence causal training).

Block shapes are multiples of (8, 128) so the MXU sees aligned tiles; head_dim
is padded by the wrapper in ops.py if needed.

ZO perturbation fusion: attention itself has no weights, so the fused dual
forward (PairZeroConfig.fused_perturbation) perturbs the QKV/O *projections*
feeding this kernel via kernels/perturbed_matmul.py — the scores/output math
here runs unchanged on already-perturbed activations, and no perturbed weight
tensor is ever materialized for the attention block either.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: Optional[int],
                  blk_q: int, blk_k: int, seq_k: int, sq_offset: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    n_k = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # absolute positions: q rows are the last Sq positions of the kv range
    q_start = qi * blk_q + sq_offset
    k_start = ki * blk_k

    # block-level relevance test (static per (qi, ki) only via traced compare)
    q_last = q_start + blk_q - 1
    k_first = k_start
    relevant = jnp.bool_(True)
    if causal:
        relevant &= k_first <= q_last
    if window is not None:
        # highest q position must still see the *end* of this kv block
        relevant &= (k_start + blk_k - 1) > (q_start - window)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # [blk_q, d]
        k = k_ref[0].astype(jnp.float32)                  # [blk_k, d]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (blk_q, blk_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (blk_q, blk_k), 1)
        mask = jnp.ones((blk_q, blk_k), dtype=bool)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        mask &= k_pos < seq_k                              # tail padding
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                                # [blk_q, 1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                             # [blk_q, blk_k]
        alpha = jnp.exp(m_prev - m_new)                    # [blk_q, 1]
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)                   # [blk_k, d]
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = alpha * acc_ref[...] + pv
        m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "blk_q", "blk_k",
                     "interpret"))
def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           *, causal: bool = True,
                           window: Optional[int] = None,
                           scale: Optional[float] = None,
                           blk_q: int = 128, blk_k: int = 128,
                           interpret: bool = False) -> jnp.ndarray:
    """q: [B, Hq, Sq, D]; k, v: [B, Hkv, Skv, D]. Returns [B, Hq, Sq, D].

    Sq may be smaller than Skv (q rows are the final Sq positions — decode /
    chunked prefill). D must be 128-aligned (ops.py pads otherwise).
    """
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    if scale is None:
        scale = 1.0 / (d ** 0.5)

    blk_q = min(blk_q, max(sq, 8))
    blk_k = min(blk_k, max(skv, 128))
    q_pad = (-sq) % blk_q
    k_pad = (-skv) % blk_k
    if q_pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, q_pad), (0, 0)))
    if k_pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, k_pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, k_pad), (0, 0)))
    sq_p, skv_p = sq + q_pad, skv + k_pad

    qr = q.reshape(b * hq, sq_p, d)
    kr = k.reshape(b * hkv, skv_p, d)
    vr = v.reshape(b * hkv, skv_p, d)
    n_q, n_kv = sq_p // blk_q, skv_p // blk_k

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        blk_q=blk_q, blk_k=blk_k, seq_k=skv, sq_offset=skv - sq)

    out = pl.pallas_call(
        kernel,
        grid=(b * hq, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, blk_q, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, blk_k, d),
                         lambda h, i, j, g=group: (h // g, j, 0)),
            pl.BlockSpec((1, blk_k, d),
                         lambda h, i, j, g=group: (h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, d), jnp.float32),
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, 1), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qr, kr, vr)
    return out[:, :sq].reshape(b, hq, sq, d)
