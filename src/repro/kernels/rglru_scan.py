"""RG-LRU / first-order linear recurrence Pallas TPU kernel.

Computes h_t = a_t ⊙ h_{t-1} + x_t over the sequence with the gate a already
materialized (the RG-LRU gating algebra — exp(−c·softplus(Λ)·σ(r_t)) and the
√(1−a²) input scaling — is cheap elementwise work done by the caller; the
recurrence is the part XLA serializes badly on TPU).

Strategy: grid (B, D/blk_d, S/chunk) with the sequence dimension innermost and
sequential. The carried state h lives in a VMEM scratch row persisting across
chunk steps. Within a chunk the recurrence is an in-VMEM fori_loop over time —
serial in S but each step is a (1, blk_d) VPU op over the channel block, and
the HBM traffic is one read of (a, x) and one write of h per element: the
kernel is bandwidth-bound at exactly its roofline minimum (3 streams).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _rglru_kernel(a_ref, x_ref, h0_ref, o_ref, hlast_ref, state_ref, *,
                  chunk: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        state_ref[...] = h0_ref[0].astype(jnp.float32)

    def step(t, h):
        a_t = a_ref[0, t].astype(jnp.float32)
        x_t = x_ref[0, t].astype(jnp.float32)
        h = a_t * h + x_t
        o_ref[0, t] = h.astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, state_ref[...])
    state_ref[...] = h

    @pl.when(si == pl.num_programs(2) - 1)
    def _fin():
        hlast_ref[0] = h.astype(hlast_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("chunk", "blk_d", "interpret"))
def rglru_scan_pallas(a: jnp.ndarray, x: jnp.ndarray, h0: jnp.ndarray,
                      chunk: int = 256, blk_d: int = 512,
                      interpret: bool = False):
    """a, x: [B, S, D]; h0: [B, D] → (h [B,S,D], h_last [B,D]).

    S must be divisible by `chunk` (callers pad); D by 128 (lane width).
    """
    b, s, d = x.shape
    chunk = min(chunk, s)
    assert s % chunk == 0, "pad S to a multiple of chunk"
    blk_d = min(blk_d, d)
    n_d = (d + blk_d - 1) // blk_d
    n_s = s // chunk

    out, h_last = pl.pallas_call(
        functools.partial(_rglru_kernel, chunk=chunk),
        grid=(b, n_d, n_s),
        in_specs=[
            pl.BlockSpec((1, chunk, blk_d), lambda bi, di, si: (bi, si, di)),
            pl.BlockSpec((1, chunk, blk_d), lambda bi, di, si: (bi, si, di)),
            pl.BlockSpec((1, blk_d), lambda bi, di, si: (bi, di)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, blk_d), lambda bi, di, si: (bi, si, di)),
            pl.BlockSpec((1, blk_d), lambda bi, di, si: (bi, di)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, d), x.dtype),
            jax.ShapeDtypeStruct((b, d), x.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((blk_d,), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, x, h0)
    return out, h_last
