"""Public kernel API: jit'd wrappers that dispatch Pallas ↔ XLA fallbacks.

Dispatch policy (`impl=None` ⇒ auto):
  * TPU backend        → Pallas (Mosaic) kernels.
  * CPU / tests        → XLA fallbacks (semantically identical to ref.py, but
    memory-efficient: chunked attention never materializes the S² matrix).
  * `pallas_interpret` → Pallas kernel body interpreted on CPU (kernel tests).

The XLA fallbacks are not an afterthought — they are what the multi-pod
dry-run compiles (this container has no TPU), so they are written to lower to
the same asymptotic memory/flops shape as the kernels (chunked online softmax,
scan-based recurrences) to keep the roofline honest.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref as kref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.perturbed_matmul import perturbed_matmul_pallas
from repro.kernels.rglru_scan import rglru_scan_pallas
from repro.kernels.seeded_axpy import gaussian_from_counter, seeded_axpy_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas

NEG_INF = -1e30


def _default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "xla"


# ---------------------------------------------------------------------------
# PerturbedParam — lazy w + eps · z(seed)
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
class PerturbedParam:
    """A parameter leaf tagged as "perturbed by eps · z(seed) at offset off".

    The fused dual forward (`zo.tag_perturbed`) replaces every leaf of the
    parameter tree with one of these; consumers in `models/layers.py` then
    either fuse the perturbation into their matmul/gather (z generated
    in-kernel, never stored) or `resolve()` a layer-sized transient. Either
    way no θ-sized perturbed tree ever exists.

    Children (all jax arrays, so the tag survives jit/scan/shard_map):
      w    — the unperturbed leaf, [lead, ...rest];
      seed — per-leaf stream seed (`zo.leaf_seed`), broadcast to [lead];
      off  — base flat offset of each leading-dim slice into the leaf's
             counter stream: off[l] = l · prod(rest), [lead];
      eps  — perturbation scale (±μ), broadcast to [lead].

    Every child carries the leaf's leading dim, so `lax.scan` over a
    scan-stacked tree ([L, ...] leaves) slices a PerturbedParam into valid
    per-layer PerturbedParams (w [...rest], scalar seed/off/eps) whose
    counters continue the whole-leaf stream: z values are bitwise identical
    to perturbing the full leaf with `kernels.seeded_axpy`.
    """

    def __init__(self, w, seed, off, eps):
        self.w = w
        self.seed = seed
        self.off = off
        self.eps = eps

    @property
    def shape(self):
        """Shape of the underlying (unperturbed) leaf."""
        return self.w.shape

    @property
    def dtype(self):
        """Dtype of the underlying (unperturbed) leaf."""
        return self.w.dtype

    @property
    def ndim(self):
        """Rank of the underlying (unperturbed) leaf."""
        return self.w.ndim

    def tree_flatten(self):
        return (self.w, self.seed, self.off, self.eps), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)

    def __repr__(self):
        return (f"PerturbedParam(w={self.w.shape}/{self.w.dtype}, "
                f"seed={self.seed.shape}, off={self.off.shape})")


def _scalar(v):
    """First element of a possibly-broadcast child (post-scan they are 0-d)."""
    v = jnp.asarray(v)
    return v.reshape(-1)[0] if v.ndim else v


def _flat_iota(shape) -> jnp.ndarray:
    """uint32 row-major flat index of every element of `shape` (mod 2³²)."""
    if not shape:
        return jnp.uint32(0)
    idx = jnp.zeros(shape, jnp.uint32)
    stride = 1
    for k in range(len(shape) - 1, -1, -1):
        idx = idx + jax.lax.broadcasted_iota(jnp.uint32, shape, k) \
            * jnp.uint32(stride & 0xFFFFFFFF)
        stride *= shape[k]
    return idx


def perturbed_z(pp: "PerturbedParam") -> jnp.ndarray:
    """Materialize z for a tagged leaf (f32) — same bits as the unfused
    stream `kernels.ref.draw_z_ref(leaf.shape, leaf_seed)` restricted to
    this slice. Used by the XLA fallback and `resolve`; the Pallas path
    generates the same values tile-by-tile in VMEM instead."""
    seed = _scalar(pp.seed)
    off = jnp.asarray(pp.off)
    w = pp.w
    if off.ndim == 0:
        idx = off + _flat_iota(w.shape)
    else:
        lead = off.shape[0]
        rest = w.shape[1:]
        idx = off.reshape((lead,) + (1,) * len(rest)) + _flat_iota(rest)[None]
    return gaussian_from_counter(idx, seed)


def resolve(pp) -> jnp.ndarray:
    """Materialize w + eps · z for one tagged leaf (a layer-sized transient,
    NOT a θ-sized one). Identity on plain arrays, so consumers can call it
    unconditionally on params that may or may not be tagged."""
    if not isinstance(pp, PerturbedParam):
        return pp
    wf = pp.w.astype(jnp.float32)
    return (wf + _scalar(pp.eps) * perturbed_z(pp)).astype(pp.w.dtype)


def perturbed_matmul(x: jnp.ndarray, pp: "PerturbedParam",
                     impl: Optional[str] = None) -> jnp.ndarray:
    """out = x @ (w + eps · z(seed)) for a 2-D tagged leaf; x: [..., K].

    The shared fused entry point of the ZO dual forward: both rollouts
    (eps = +μ and −μ) route every projection through here. Pallas impls
    generate z per weight tile in VMEM (kernels/perturbed_matmul.py); the
    XLA fallback materializes one layer-sized z transient and lets XLA fuse
    generation into the matmul's operand — in neither case does a perturbed
    copy of the full parameter tree exist.
    """
    impl = impl or _default_impl()
    w = pp.w
    assert w.ndim == 2, f"perturbed_matmul wants a 2-D leaf, got {w.shape}"
    if impl == "xla":
        # resolve a layer-sized w+εz transient and run ONE matmul. Under the
        # dual forward's vmap over eps = ±μ (zo.dual_forward mode="fused")
        # only eps is batched — z depends on (seed, off) alone, so XLA
        # materializes each layer's z once and shares it across the two
        # rollouts instead of drawing it per rollout.
        return jnp.einsum("...d,df->...f", x, resolve(pp),
                          preferred_element_type=jnp.float32).astype(x.dtype)
    if impl in ("pallas", "pallas_interpret"):
        batch = x.shape[:-1]
        m = 1
        for b in batch:
            m *= b
        out = perturbed_matmul_pallas(
            x.reshape(m, x.shape[-1]), w, _scalar(pp.seed), _scalar(pp.off),
            _scalar(pp.eps), interpret=(impl == "pallas_interpret"))
        return out.reshape(batch + (w.shape[1],))
    raise ValueError(f"unknown impl: {impl}")


def perturbed_unembed(x: jnp.ndarray, pp: "PerturbedParam") -> jnp.ndarray:
    """Fused lm-head contraction: [.., D] @ (w + εz)[V, D]ᵀ → f32 logits.

    Resolves a table-sized w+εz transient for the contraction; like
    `perturbed_matmul`, z depends only on (seed, off), so the dual
    forward's eps-vmap draws the [V, D] z once for both rollouts. The
    transient is freed after this op — no perturbed copy of the tree
    persists."""
    return jnp.einsum("...d,vd->...v", x, resolve(pp),
                      preferred_element_type=jnp.float32)


def perturbed_gather(pp: "PerturbedParam", tokens: jnp.ndarray
                     ) -> jnp.ndarray:
    """Embedding-table gather of (w + eps · z) rows: z is drawn ONLY for the
    gathered rows (row v, column j uses counter off[v] + j — the same bits
    the row has in the full-table stream), so the fused path never touches
    the [V, D] table beyond the rows the batch actually reads."""
    w, off = pp.w, jnp.asarray(pp.off)
    seed, eps = _scalar(pp.seed), _scalar(pp.eps)
    rows = jnp.take(w, tokens, axis=0).astype(jnp.float32)
    if off.ndim == 0:   # tagged leaf was already sliced — single-row table
        off_t = jnp.broadcast_to(off, tokens.shape)
    else:
        off_t = jnp.take(off, tokens, axis=0)
    d = w.shape[-1]
    idx = off_t[..., None] + jax.lax.broadcasted_iota(
        jnp.uint32, off_t.shape + (d,), off_t.ndim)
    z = gaussian_from_counter(idx, seed)
    return (rows + eps * z).astype(w.dtype)


# ---------------------------------------------------------------------------
# seeded_axpy
# ---------------------------------------------------------------------------

def seeded_axpy(w: jnp.ndarray, seed, scale,
                impl: Optional[str] = None) -> jnp.ndarray:
    """out = w + scale · z(seed); z is the counter-hash N(0,1) stream.

    `seed` is a uint32/int32 scalar (traced or static). All impls produce the
    SAME bits for the same (seed, shape) — backend-portable trajectories.
    """
    impl = impl or _default_impl()
    if impl == "xla":
        # identical stream, generated by fused XLA elementwise ops; z never
        # persists in HBM after fusion.
        return kref.seeded_axpy_ref(w, seed, scale)
    if impl in ("pallas", "pallas_interpret"):
        return seeded_axpy_pallas(w, jnp.asarray(seed).astype(jnp.uint32),
                                  scale,
                                  interpret=(impl == "pallas_interpret"))
    raise ValueError(f"unknown impl: {impl}")


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def _xla_chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           causal: bool, window: Optional[int],
                           scale: float, blk_k: int = 512) -> jnp.ndarray:
    """Online-softmax attention scanning kv chunks — flash semantics in XLA.

    Never materializes [Sq, Skv]; peak transient is [B, H, Sq, blk_k].
    """
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    blk_k = min(blk_k, skv)
    pad = (-skv) % blk_k
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    n_k = (skv + pad) // blk_k
    qf = q.astype(jnp.float32) * scale
    # fold GQA group into q's head dim: [B, Hkv, group, Sq, D]
    qg = qf.reshape(b, hkv, group, sq, d)
    kc = k.reshape(b, hkv, n_k, blk_k, d).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, hkv, n_k, blk_k, d).transpose(2, 0, 1, 3, 4)
    q_pos = jnp.arange(sq) + (skv - sq)

    def body(carry, inp):
        m, l, acc = carry
        ci, k_blk, v_blk = inp
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k_blk.astype(jnp.float32))
        k_pos = ci * blk_k + jnp.arange(blk_k)
        mask = k_pos[None, :] < skv
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = alpha * l + jnp.sum(p, axis=-1)
        acc = alpha[..., None] * acc + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p, v_blk.astype(jnp.float32))
        return (m_new, l, acc), None

    m0 = jnp.full((b, hkv, group, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, group, sq), jnp.float32)
    acc0 = jnp.zeros((b, hkv, group, sq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0), (jnp.arange(n_k), kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, hq, sq, d).astype(q.dtype)


def _attn_layout_hints(q, k, v):
    """Pin the attention layout so GSPMD never splits the head_dim
    contraction (which would all-reduce the full S×S score matrix — the
    dominant pathology for head counts not divisible by the model axis).

    Heads divisible by the model axis → shard heads (classic TP attention).
    Otherwise → shard the QUERY sequence dim (sequence-parallel attention):
    scores come out [B, H, Sq/model, Skv] with k/v gathered — linear bytes
    instead of quadratic."""
    from repro.runtime.sharding import _HINT_MESH, hint
    mesh = _HINT_MESH.get()
    if mesh is None:
        return q, k, v
    model = mesh.shape.get("model", 1)
    hq, hkv, sq = q.shape[1], k.shape[1], q.shape[2]
    if hq % model == 0 and hkv % model == 0:
        q = hint(q, "client", "model", None, None)
        k = hint(k, "client", "model", None, None)
        v = hint(v, "client", "model", None, None)
    elif hq % model == 0:
        # pin q's head layout; let GSPMD place k/v freely (forcing them
        # replicated measurably regressed divisible-kv archs)
        q = hint(q, "client", "model", None, None)
    elif sq % model == 0 and sq > 1:
        # sequence-parallel branch: k/v MUST be pinned replicated-over-model
        # or GSPMD reshards them against the S-sharded q every layer
        q = hint(q, "client", None, "model", None)
        k = hint(k, "client", None, None, None)
        v = hint(v, "client", None, None, None)
    return q, k, v


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
              causal: bool = True, window: Optional[int] = None,
              scale: Optional[float] = None,
              impl: Optional[str] = None) -> jnp.ndarray:
    """Fused attention. q: [B,Hq,Sq,D]; k,v: [B,Hkv,Skv,D] → [B,Hq,Sq,D]."""
    if scale is None:
        scale = 1.0 / (q.shape[-1] ** 0.5)
    impl = impl or _default_impl()
    q, k, v = _attn_layout_hints(q, k, v)
    if impl == "xla":
        if q.shape[2] * k.shape[2] <= 256 * 256:
            return kref.attention_ref(q, k, v, causal=causal, window=window,
                                      scale=scale)
        return _xla_chunked_attention(q, k, v, causal, window, scale)
    if impl == "xla_chunked":
        return _xla_chunked_attention(q, k, v, causal, window, scale)
    if impl == "xla_full":
        # materialized-softmax path: identical FLOPs to chunked/fused, no
        # inner scan — used by roofline probes (lower/compile only)
        return kref.attention_ref(q, k, v, causal=causal, window=window,
                                  scale=scale)
    if impl in ("pallas", "pallas_interpret"):
        d = q.shape[-1]
        pad = (-d) % 128
        sc = scale
        if pad:
            q = jnp.pad(q, ((0, 0), (0, 0), (0, 0), (0, pad)))
            k = jnp.pad(k, ((0, 0), (0, 0), (0, 0), (0, pad)))
            v = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, pad)))
        out = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                     scale=sc,
                                     interpret=(impl == "pallas_interpret"))
        return out[..., :d] if pad else out
    raise ValueError(f"unknown impl: {impl}")


# ---------------------------------------------------------------------------
# linear recurrence (RG-LRU)
# ---------------------------------------------------------------------------

def linear_recurrence(a: jnp.ndarray, x: jnp.ndarray,
                      h0: Optional[jnp.ndarray] = None,
                      impl: Optional[str] = None
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """h_t = a_t ⊙ h_{t-1} + x_t.  a, x: [B,S,D]; h0: [B,D]."""
    impl = impl or _default_impl()
    b, s, d = x.shape
    if h0 is None:
        h0 = jnp.zeros((b, d), dtype=x.dtype)
    if impl == "xla":
        # log-depth associative scan — the production XLA path
        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, b1 * a2 + b2
        a32 = a.astype(jnp.float32)
        x32 = x.astype(jnp.float32)
        # fold h0 into the first input
        x32 = x32.at[:, 0].add(a32[:, 0] * h0.astype(jnp.float32))
        _, hs = jax.lax.associative_scan(combine, (a32, x32), axis=1)
        return hs.astype(x.dtype), hs[:, -1].astype(x.dtype)
    if impl in ("pallas", "pallas_interpret"):
        chunk = 256 if s % 256 == 0 else s
        return rglru_scan_pallas(a, x, h0, chunk=chunk,
                                 interpret=(impl == "pallas_interpret"))
    raise ValueError(f"unknown impl: {impl}")


# ---------------------------------------------------------------------------
# SSD (Mamba-2)
# ---------------------------------------------------------------------------

def ssd(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray,
        c: jnp.ndarray, state0: Optional[jnp.ndarray] = None,
        chunk: int = 128, impl: Optional[str] = None
        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD. Shapes as kref.ssd_ref; state [B,H,P,N]."""
    impl = impl or _default_impl()
    B, S, H, P = x.shape
    N = b.shape[-1]
    if state0 is None:
        state0 = jnp.zeros((B, H, P, N), dtype=jnp.float32)
    if impl == "xla":
        return _xla_chunked_ssd(x, dt, a, b, c, state0, chunk)
    if impl in ("pallas", "pallas_interpret"):
        y, s_last = ssd_scan_pallas(
            x, dt, a, b, c, state0.swapaxes(-1, -2), chunk=chunk,
            interpret=(impl == "pallas_interpret"))
        return y, s_last.swapaxes(-1, -2)
    raise ValueError(f"unknown impl: {impl}")


def _xla_chunked_ssd(x, dt, a, b, c, state0, chunk):
    """Chunked SSD in pure XLA: scan over chunks, dense matmuls within."""
    B, S, H, P = x.shape
    N = b.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, "pad S to a multiple of chunk"
    n_c = S // chunk
    xf = x.astype(jnp.float32).reshape(B, n_c, chunk, H, P)
    dtf = dt.astype(jnp.float32).reshape(B, n_c, chunk, H)
    bf = b.astype(jnp.float32).reshape(B, n_c, chunk, N)
    cf = c.astype(jnp.float32).reshape(B, n_c, chunk, N)
    af = a.astype(jnp.float32)

    ii = jnp.arange(chunk)
    causal = ii[:, None] >= ii[None, :]

    def body(state, inp):
        x_c, dt_c, b_c, c_c = inp           # [B,Q,H,P],[B,Q,H],[B,Q,N]×2
        g = jnp.cumsum(af[None, None] * dt_c, axis=1)        # [B,Q,H]
        xdt = x_c * dt_c[..., None]
        cb = jnp.einsum("bqn,bkn->bqk", c_c, b_c)            # [B,Q,Q]
        decay = jnp.exp(g[:, :, None] - g[:, None])          # [B,Q,Q,H]
        l_mask = jnp.where(causal[None, :, :, None], decay, 0.0)
        y_intra = jnp.einsum("bqk,bqkh,bkhp->bqhp", cb, l_mask, xdt)
        y_inter = jnp.exp(g)[..., None] * jnp.einsum(
            "bhpn,bqn->bqhp", state, c_c)
        g_last = g[:, -1]                                     # [B,H]
        w = jnp.exp(g_last[:, None] - g)[..., None] * b_c[:, :, None]
        state = jnp.exp(g_last)[..., None, None] * state + jnp.einsum(
            "bqhn,bqhp->bhpn", w, xdt)
        return state, y_intra + y_inter

    xs = (xf.swapaxes(0, 1), dtf.swapaxes(0, 1), bf.swapaxes(0, 1),
          cf.swapaxes(0, 1))
    state_last, ys = jax.lax.scan(body, state0.astype(jnp.float32), xs)
    y = ys.swapaxes(0, 1).reshape(B, S, H, P).astype(x.dtype)
    return y, state_last


def ssd_decode_step(state: jnp.ndarray, x_t: jnp.ndarray, dt_t: jnp.ndarray,
                    a: jnp.ndarray, b_t: jnp.ndarray, c_t: jnp.ndarray
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One-token SSD update (decode). state: [B,H,P,N]; x_t: [B,H,P];
    dt_t: [B,H]; b_t/c_t: [B,N] → (y_t [B,H,P], state')."""
    decay = jnp.exp(a[None] * dt_t)                       # [B,H]
    upd = jnp.einsum("bhp,bn,bh->bhpn", x_t.astype(jnp.float32),
                     b_t.astype(jnp.float32), dt_t)
    state = decay[..., None, None] * state + upd
    y = jnp.einsum("bhpn,bn->bhp", state, c_t.astype(jnp.float32))
    return y.astype(x_t.dtype), state
