"""Pallas TPU kernels for the perf-critical compute layers.

Kernels (each with a pure-jnp oracle in ref.py and a jit'd wrapper in ops.py):
  * seeded_axpy      — fused ZO perturb/update with in-VMEM PRNG (the paper's
                       memory trick made TPU-native)
  * perturbed_matmul — x @ (w + εz(seed)): the fused ZO dual forward; z is
                       regenerated per weight tile in VMEM, so perturbed
                       weights never exist in HBM (PairZeroConfig.
                       fused_perturbation)
  * flash_attention  — fused online-softmax attention (causal / window / GQA)
  * rglru_scan       — RG-LRU first-order linear recurrence
  * ssd_scan         — Mamba-2 chunked state-space duality

Bit-identity contract: the seeded z-stream is a pure function of
(leaf seed, flat element index). Every implementation — the Pallas Mosaic
kernel, its CPU interpret mode, the XLA fallback in ref.py, and the fused
per-tile generation in perturbed_matmul — produces the SAME uint32-counter →
Box–Muller draws for the same leaf, independent of tiling, sharding, or scan
slicing. Training trajectories are therefore bitwise portable across
backends, and a base station broadcasting the round seed fully determines
every client's perturbation (the premise of the paper's O(1) uplink and of
the seed-replay attack in repro.privacy).

Adding a kernel: write the Mosaic kernel next to an equal-semantics jnp
oracle in ref.py, dispatch it from ops.py behind `impl=
pallas|pallas_interpret|xla`, and test interpret-vs-ref bitwise (see
docs/kernels.md for the checklist).
"""
from repro.kernels import ops, ref  # noqa: F401
