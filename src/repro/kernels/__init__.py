"""Pallas TPU kernels for the perf-critical compute layers.

Kernels (each with a pure-jnp oracle in ref.py and a jit'd wrapper in ops.py):
  * seeded_axpy     — fused ZO perturb/update with in-VMEM PRNG (the paper's
                      memory trick made TPU-native)
  * flash_attention — fused online-softmax attention (causal / window / GQA)
  * rglru_scan      — RG-LRU first-order linear recurrence
  * ssd_scan        — Mamba-2 chunked state-space duality
"""
from repro.kernels import ops, ref  # noqa: F401
