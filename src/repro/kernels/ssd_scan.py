"""Mamba-2 SSD (state-space duality) chunked Pallas TPU kernel (ngroups = 1).

The SSD insight (arXiv:2405.21060): within a chunk of length Q the recurrence
is a masked attention-like matmul (MXU work); across chunks only the [P, N]
state is carried. This maps perfectly onto a Pallas grid with a sequential
chunk dimension:

  per (batch, head, chunk) step, all in VMEM/f32:
    g        = cumsum(a·dt)                       chunk-local log-decay
    L        = exp(g_i − g_j) · (i ≥ j)           [Q, Q] causal decay mask
    y_intra  = ((C Bᵀ) ⊙ L) (x·dt)                [Q, P] quadratic-in-chunk
    y_inter  = exp(g) ⊙ (C S_prev)                contribution of carried state
    S_new    = exp(g_last − g) scaled Bᵀ(x·dt) + exp(g_last)·S_prev

Chunk = 128 keeps every matmul MXU-shaped ([128,128]×[128,P]) and the whole
working set (few hundred KB) in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, s0_ref,
                y_ref, slast_ref, state_ref, *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0].astype(jnp.float32)          # [Q, P]
    dt = dt_ref[0].astype(jnp.float32)        # [Q, 1]
    a = a_ref[0]                              # [1, 1] (per-head decay rate)
    bmat = b_ref[0].astype(jnp.float32)       # [Q, N]
    cmat = c_ref[0].astype(jnp.float32)       # [Q, N]

    adt = a[0, 0] * dt[:, 0]                  # [Q]  (a < 0)
    g = jnp.cumsum(adt)                       # [Q]  inclusive log-decay
    xdt = x * dt                              # [Q, P]

    # --- intra-chunk (quadratic within chunk) ---
    cb = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [Q, Q]
    gi = g[:, None]
    gj = g[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    # decay from j to i (i ≥ j): exp(g_i − g_j); iota mask gives causality
    l_mask = jnp.where(ii >= jj, jnp.exp(gi - gj), 0.0)
    y_intra = jax.lax.dot_general(cb * l_mask, xdt, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    # --- inter-chunk (carried state) ---
    s_prev = state_ref[...]                   # [N, P]
    cs = jax.lax.dot_general(cmat, s_prev, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [Q, P]
    y_inter = jnp.exp(g)[:, None] * cs

    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    # --- state update ---
    g_last = g[chunk - 1]
    w = jnp.exp(g_last - g)[:, None] * bmat   # [Q, N] decay-to-chunk-end
    s_new = jnp.exp(g_last) * s_prev + jax.lax.dot_general(
        w, xdt, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)   # [N, P]
    state_ref[...] = s_new

    @pl.when(ci == pl.num_programs(2) - 1)
    def _fin():
        slast_ref[0, 0] = s_new.astype(slast_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray,
                    b: jnp.ndarray, c: jnp.ndarray, state0: jnp.ndarray,
                    chunk: int = 128, interpret: bool = False):
    """x: [B,S,H,P], dt: [B,S,H], a: [H], b/c: [B,S,N], state0: [B,H,N,P].

    Returns (y [B,S,H,P], state_last [B,H,N,P]). S must divide by chunk.
    NOTE: state layout here is [N, P] (transposed vs ref.py's [P, N]) to keep
    the MXU contractions layout-friendly; ops.py adapts.
    """
    B, S, H, P = x.shape
    N = b.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, "pad S to a multiple of chunk"
    n_c = S // chunk

    # layout: fold head into batch-like grid dims; broadcast b/c across heads
    xt = x.transpose(0, 2, 1, 3).reshape(B * H, S, P)
    dtt = dt.transpose(0, 2, 1).reshape(B * H, S, 1)
    at = jnp.repeat(a.reshape(1, H), B, axis=0).reshape(B * H, 1, 1)
    s0 = state0.reshape(B * H, 1, N, P)

    y, s_last = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=(B, H, n_c),
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda bi, hi, ci, H=H:
                         (bi * H + hi, ci, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bi, hi, ci, H=H:
                         (bi * H + hi, ci, 0)),
            pl.BlockSpec((1, 1, 1), lambda bi, hi, ci, H=H:
                         (bi * H + hi, 0, 0)),
            pl.BlockSpec((1, chunk, N), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, N), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, 1, N, P), lambda bi, hi, ci, H=H:
                         (bi * H + hi, 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, P), lambda bi, hi, ci, H=H:
                         (bi * H + hi, ci, 0)),
            pl.BlockSpec((1, 1, N, P), lambda bi, hi, ci, H=H:
                         (bi * H + hi, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, S, P), x.dtype),
            jax.ShapeDtypeStruct((B * H, 1, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xt, dtt, at, b, c, s0)
    return (y.reshape(B, H, S, P).transpose(0, 2, 1, 3),
            s_last.reshape(B, H, N, P))
