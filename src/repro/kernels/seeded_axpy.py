"""Fused seeded-perturbation kernel: out = w + scale · z(seed).

This is the paper's memory trick made TPU-native. A naive ZO perturbation
materializes z in HBM (read w, read z, write w': 3d bytes of HBM traffic per
axpy, plus d floats of live memory). Here z is generated *inside VMEM per
tile* from a counter-based hash RNG (murmur3 fmix32 finalizer + Box–Muller),
so HBM sees exactly one read and one write of w — z never exists as a tensor.

Why a counter-based hash instead of the TPU hardware PRNG
(`pltpu.prng_random_bits`): the stream becomes a *pure function of
(seed, element index)* — identical in the Mosaic kernel, the interpret-mode
kernel, the XLA fallback and the pure-jnp oracle (ref.py). That gives
  * bitwise kernel-vs-ref tests (not just statistical ones),
  * backend-independent training trajectories (CPU test == TPU run),
  * exact MeZO chain algebra: w → w+μz → w−μz → restore+update reuses the
    very same z at every step from nothing but the int32 seed.

Counters are element indices, so the stream is also invariant to tiling and
sharding — a resharded or differently-blocked call perturbs identically.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

LANE = 128
DEFAULT_BLOCK = 2048 * LANE  # elements per grid step (1 MiB of f32 in VMEM)

_GOLDEN = 0x9E3779B9
_M1 = 0x7FEB352D
_M2 = 0x846CA68B
_TWO_PI = 6.283185307179586


def fmix32(x: jnp.ndarray) -> jnp.ndarray:
    """murmur3 finalizer — full-avalanche 32-bit bijection (uint32 in/out)."""
    x ^= x >> jnp.uint32(16)
    x *= jnp.uint32(_M1)
    x ^= x >> jnp.uint32(15)
    x *= jnp.uint32(_M2)
    x ^= x >> jnp.uint32(16)
    return x


def _bits_to_unit(bits: jnp.ndarray) -> jnp.ndarray:
    """uint32 → float32 uniform in [2^-24, 1): top 24 bits as mantissa."""
    f = (bits >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(2 ** -24)
    return jnp.maximum(f, jnp.float32(2 ** -24))


def gaussian_from_counter(idx: jnp.ndarray, seed: jnp.ndarray) -> jnp.ndarray:
    """Standard normal z[idx] as a pure function of (seed, element index).

    idx: uint32 element indices (any shape); seed: uint32 scalar.
    Two decorrelated streams (counters 2i, 2i+1) feed Box–Muller.
    """
    base = idx * jnp.uint32(2) + seed * jnp.uint32(_GOLDEN)
    u1 = _bits_to_unit(fmix32(base))
    u2 = _bits_to_unit(fmix32(base + jnp.uint32(1)))
    r = jnp.sqrt(-2.0 * jnp.log(u1))
    return r * jnp.cos(jnp.float32(_TWO_PI) * u2)


def _axpy_kernel(seed_ref, scale_ref, w_ref, o_ref, *, rows_per_block: int):
    tile = pl.program_id(0)
    rows, lanes = w_ref.shape
    row0 = tile * rows_per_block
    r_iota = jax.lax.broadcasted_iota(jnp.uint32, (rows, lanes), 0)
    l_iota = jax.lax.broadcasted_iota(jnp.uint32, (rows, lanes), 1)
    idx = (jnp.uint32(row0) + r_iota) * jnp.uint32(lanes) + l_iota
    z = gaussian_from_counter(idx, seed_ref[0])
    w = w_ref[...].astype(jnp.float32)
    o_ref[...] = (w + scale_ref[0] * z).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def seeded_axpy_pallas(w: jnp.ndarray, seed: jnp.ndarray, scale,
                       block: int = DEFAULT_BLOCK,
                       interpret: bool = False) -> jnp.ndarray:
    """out = w + scale * z(seed), flattened-and-tiled over a 1D grid.

    Args:
      w: any-shape array (flattened internally; padded to the lane width).
      seed: uint32/int32 scalar (fold leaf/round indices in *before* calling).
      scale: traced or static scalar.
    """
    orig_shape, orig_dtype = w.shape, w.dtype
    n = w.size
    padded = max(((n + block - 1) // block) * block, 8 * LANE)
    flat = jnp.ravel(w)
    if padded != n:
        flat = jnp.pad(flat, (0, padded - n))
    grid = max(padded // block, 1)
    rows_per_block = padded // grid // LANE
    mat = flat.reshape(grid * rows_per_block, LANE)

    out = pl.pallas_call(
        functools.partial(_axpy_kernel, rows_per_block=rows_per_block),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((rows_per_block, LANE), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((rows_per_block, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(mat.shape, orig_dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(jnp.asarray([seed]).astype(jnp.uint32),
      jnp.asarray([scale], jnp.float32), mat)
    return out.reshape(-1)[:n].reshape(orig_shape)
