"""Trilemma ledger: one JSONL record per round, all three axes at once.

`MetricsSink` is a round hook (duck-typed against `fedsim.RoundHook`, so
this module never imports the driver) that streams one machine-readable
record per executed round:

  communication — bits this round and cumulative, from the run Transport's
    `payload_bits` with the realized survival mask (K_eff) and any defense
    payload/feedback adjustments, via the SAME `transport.uplink_bits_total`
    expression the driver uses, so the final row equals
    `RunResult.uplink_bits` exactly;
  privacy — the Eq.-16 cost charged this round, the cumulative ledger
    (bit-identical to `PrivacyAccountant.spent`: the identical float64
    left fold), and the closed-form ε it implies (`epsilon_for_budget`);
  memory — the run's `peak_bytes` watermark so far (repro.obs.memory);
  plus loss, K_eff, the desync view (`k_sync`: surviving clients whose
  scalar rode the current round seed; `stale_frac`: the stale share of
  K_eff, 0.0 when desync is off), and wall-clock seconds since the sink
  started.

Line 1 is a header record carrying `schema: "trilemma_ledger/v2"` (v2
added the k_sync/stale_frac columns) and the run's static facts; every
later line is one round. tools/check_trace.py validates the schema and
cross-checks the final row against the run summary in CI.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, Optional

from repro.core import dp
from repro.core import transport as tp


class MetricsSink:
    """Round hook streaming the per-round trilemma ledger to a JSONL file.

    Implements the `RoundHook` surface (`cadence`/`on_start`/`on_round`/
    `on_boundary`/`close`) without subclassing it — the driver only
    type-checks `CheckpointHook`, and staying import-free of `fedsim`
    keeps obs a leaf package. cadence 0: the sink never realigns chunk
    boundaries, so attaching it cannot change compiled chunk shapes.
    """

    cadence = 0
    SCHEMA = "trilemma_ledger/v2"

    def __init__(self, path: str):
        self.path = path
        self._f = None
        self._exp = None
        self._t0 = 0.0
        self._payload_d = 0
        self._k_sum = 0.0
        self._bits_prev = 0
        self._spend_cum = 0.0
        self._rows = 0

    # -- RoundHook surface -------------------------------------------------
    def on_start(self, exp) -> None:
        """Open the stream and write the header record."""
        self._exp = exp
        self._t0 = time.perf_counter()
        self._payload_d = exp.model_cfg.param_count()
        self._f = open(self.path, "w")
        header = {
            "schema": self.SCHEMA,
            "arch": exp.model_cfg.name,
            "transport": exp.transport.name,
            "engine": exp.engine,
            "n_clients": exp.pz.n_clients,
            "d": self._payload_d,
            "payload_bits_per_client": exp.transport.payload_bits(
                exp.pz, self._payload_d),
            "epsilon": exp.pz.dp.epsilon,
            "delta": exp.pz.dp.delta,
        }
        self._f.write(json.dumps(header) + "\n")

    def on_round(self, t: int, metrics: Dict[str, Any]) -> None:
        """Append one trilemma record for executed round t."""
        exp = self._exp
        # round cost from the accountant's history, offset by whatever the
        # ledger held when the run started (restored checkpoints replay
        # spent-but-unlisted budget); incremental float adds reproduce the
        # accountant's sequential cumsum fold bit for bit
        idx = exp.hist_at_start + self._rows
        hist = exp.accountant.history
        cost = float(hist[idx]) if idx < len(hist) else 0.0
        if self._rows == 0:
            self._spend_cum = exp.spent_at_start
        self._spend_cum += cost
        k_eff = float(exp.round_k_eff[t - exp.start_round])
        # synchronized survivors this round (== k_eff when desync is off;
        # duck-typed getattr keeps the sink usable against older drivers)
        k_sync_all = getattr(exp, "round_k_sync", None)
        k_sync = float(k_sync_all[t - exp.start_round]) \
            if k_sync_all else k_eff
        self._k_sum += k_eff
        self._rows += 1
        bits_cum = tp.uplink_bits_total(
            exp.transport, exp.defense, exp.pz, self._payload_d,
            self._k_sum, self._rows)
        mem = exp.telemetry.memory
        row = {
            "round": int(t),
            "loss": float(metrics["loss"]),
            "k_eff": k_eff,
            "k_sync": k_sync,
            "stale_frac": (k_eff - k_sync) / k_eff if k_eff > 0 else 0.0,
            "bits_round": bits_cum - self._bits_prev,
            "bits_cum": bits_cum,
            "dp_cost": cost,
            "dp_spent_cum": self._spend_cum,
            "eps_cum": dp.epsilon_for_budget(self._spend_cum,
                                             exp.pz.dp.delta),
            "peak_bytes": int(mem.peak_bytes) if mem is not None else 0,
            "wall_s": time.perf_counter() - self._t0,
        }
        self._bits_prev = bits_cum
        self._f.write(json.dumps(row) + "\n")

    def on_boundary(self, t_done: int, exp) -> None:
        """Flush buffered rows at every chunk boundary."""
        if self._f is not None:
            self._f.flush()

    def close(self, exp) -> None:
        """Finalize the stream: flush + fsync, then close.

        The fsync is the torn-ledger fix: a run that completes `close`
        must leave a ledger whose every line parses even if the process
        is SIGKILLed right after — only a kill *mid-run* may leave a torn
        trailing record, which readers tolerate (`read_ledger(strict=
        False)`, check_trace's truncation report)."""
        if self._f is not None:
            self._f.flush()
            os.fsync(self._f.fileno())
            self._f.close()
            self._f = None

    # -- conveniences ------------------------------------------------------
    def rows_written(self) -> int:
        """Number of per-round records streamed so far."""
        return self._rows


def read_ledger(path: str, strict: bool = True) -> Dict[str, Any]:
    """Parse a ledger file back into {header, rows, truncated}.

    A SIGKILL mid-row leaves one torn trailing line; with
    ``strict=False`` that line is dropped and reported via
    ``"truncated": True`` instead of raising (crash-consistent readers:
    check_trace, chaos_run). A torn line anywhere *else* is corruption
    and always raises.
    """
    raw = []
    with open(path) as f:
        for ln in f:
            if ln.strip():
                raw.append(ln)
    lines, truncated = [], False
    for i, ln in enumerate(raw):
        try:
            lines.append(json.loads(ln))
        except json.JSONDecodeError:
            if i == len(raw) - 1 and not strict:
                truncated = True
                break
            raise
    if not lines or lines[0].get("schema") != MetricsSink.SCHEMA:
        raise ValueError(f"{path}: not a {MetricsSink.SCHEMA} ledger")
    return {"header": lines[0], "rows": lines[1:], "truncated": truncated}


def final_row(path: str) -> Optional[Dict[str, Any]]:
    """Last per-round record of a ledger file (None for an empty run)."""
    rows = read_ledger(path)["rows"]
    return rows[-1] if rows else None
