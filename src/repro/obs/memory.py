"""Device-memory watermark sampling (the trilemma's memory axis, per run).

The paper's memory claim is inference-level footprint; the benchmarks
measure it offline (`benchmarks/kernel_memory.py`). This module measures
it *per run*: periodic samples of live device bytes at chunk boundaries,
folded into a `peak_bytes` watermark surfaced on `RunResult` and in every
trilemma-ledger row.

Two sources, best first:

  * `device.memory_stats()["peak_bytes_in_use"]` — the allocator's own
    high-water mark, when the backend reports one (TPU/GPU; CPU returns
    None);
  * sum of `a.nbytes` over `jax.live_arrays()` — live-buffer bytes at the
    sample instant (always available; an instantaneous view, so the
    boundary cadence is what makes it a useful watermark).

Sampling is host-side and read-only — it never touches the traced program
(structural-neutrality pin: telemetry-off runs are bit-identical).
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import jax

from repro.obs import spans


def live_buffer_bytes(arrays=None) -> int:
    """Total bytes of live jax arrays on this process's devices.

    Donated carry buffers can still appear in `jax.live_arrays()` at a
    chunk-boundary sample even though their storage is gone (the Python
    handle outlives the donation), so anything whose `.is_deleted()` is
    true is skipped — counting it would double-book the carry against its
    replacement. `arrays` defaults to the live-array walk; tests pass an
    explicit list to pin the skip.
    """
    total = 0
    if arrays is None:
        arrays = jax.live_arrays()
    for a in arrays:
        try:
            if a.is_deleted():
                continue
            total += int(a.nbytes)
        except Exception:  # deleted/donated buffers race the walk
            continue
    return total


def device_peak_bytes() -> Optional[int]:
    """Allocator high-water mark summed over devices, or None when the
    backend (e.g. CPU) reports no memory stats."""
    total, seen = 0, False
    for dev in jax.devices():
        stats = dev.memory_stats()
        if stats and "peak_bytes_in_use" in stats:
            total += int(stats["peak_bytes_in_use"])
            seen = True
    return total if seen else None


class MemoryWatermark:
    """Periodic device-memory sampler with a running peak.

    `sample_every` is a round period gating `due(t)`; the driver samples
    at chunk boundaries that cross it (cadence 0: sampling never realigns
    chunk boundaries, so it can never change compiled chunk shapes).
    """

    def __init__(self, sample_every: int = 32):
        self.sample_every = max(1, int(sample_every))
        self.peak_bytes = 0
        self.samples: List[Tuple[int, int]] = []   # (round, bytes)
        self._last_t: Optional[int] = None

    def due(self, t: int) -> bool:
        """Whether round t crosses the sampling period since last sample."""
        return self._last_t is None or t - self._last_t >= self.sample_every

    def sample(self, t: int,
               tracer: spans.Tracer = spans.NULL_TRACER) -> int:
        """Take one sample at round t; returns the bytes observed and
        advances the `peak_bytes` watermark (also emitted as a trace
        counter event for the timeline view)."""
        peak = device_peak_bytes()
        b = peak if peak is not None else live_buffer_bytes()
        self.peak_bytes = max(self.peak_bytes, b)
        self.samples.append((int(t), int(b)))
        self._last_t = int(t)
        tracer.counter("device_bytes", b, round=int(t))
        return b
