"""Device-op capture merged onto the host span timeline (one Perfetto view).

`jax.profiler` records what the runtime actually executed — XLA executable
launches, buffer awaits, per-op device activity — but on its *own* clock
and in its own TensorBoard-oriented dump format. The PR-8 span tracer
records host-side truth (chunk_prep / dispatch / prep_stall) on a
`perf_counter` epoch. This module joins the two:

  1. `ProfilerSession.start()` begins a `jax.profiler` trace and
     immediately emits a named `TraceAnnotation` **anchor** at a recorded
     `perf_counter` instant. The anchor shows up verbatim as an event in
     the profiler dump, giving an exact affine map between the profiler
     clock and the tracer epoch (no clock guessing).
  2. `device_events(epoch)` loads the newest Chrome-format dump the
     profiler wrote (``plugins/profile/<ts>/*.trace.json.gz``), shifts
     every timestamp by the anchor offset onto the tracer epoch, and
     rebadges pids so device lanes render as their own Perfetto process
     next to the host spans (which always live on pid 0).
  3. `Tracer.export_chrome(..., extra_events=...)` appends them — host
     spans and XLA ops on ONE timeline (`train.py --profile-out`).

Opt-in and strictly additive: without `--profile-out` nothing here is
imported into the hot path, and the run's numerics are untouched either
way (the profiler observes; it never reschedules).
"""
from __future__ import annotations

import glob
import gzip
import json
import os
import tempfile
import time
from typing import Any, Dict, List, Optional, Tuple


class ProfilerSession:
    """One opt-in `jax.profiler` capture, alignable to a Tracer epoch.

    Lifecycle: `start()` before the run, `stop()` after, then
    `device_events(tracer.epoch)` for the merged-timeline events. All
    failure modes (profiler unavailable, no dump written) degrade to an
    empty event list with the error recorded in the meta dict — a broken
    profiler must never fail the run it was watching.
    """

    ANCHOR = "obs_profile_anchor"

    def __init__(self, logdir: Optional[str] = None):
        self.logdir = logdir or tempfile.mkdtemp(prefix="obs_profile_")
        self._anchor_host: Optional[float] = None
        self._start_host: Optional[float] = None
        self._active = False
        self._sess = None            # runtime-level session, when available
        self._error: Optional[str] = None

    def start(self) -> None:
        """Begin capture and stamp the clock anchor.

        Prefers a runtime-level session with the python call tracer OFF:
        at python_tracer_level>0 the profiler records every interpreter
        call, flooding its bounded buffer so badly that the actual XLA
        runtime events get dropped mid-run (observed on CPU: device
        events end seconds before the run does). Falls back to the public
        `jax.profiler.start_trace` when the options API is unavailable —
        `device_events` filters the python spam either way.
        """
        import jax
        try:
            try:
                from jax._src import profiler as _jprof
                opts = _jprof.xla_client.profiler.ProfileOptions()
                opts.python_tracer_level = 0
                self._sess = _jprof.xla_client.profiler.ProfilerSession(opts)
            except Exception:
                self._sess = None
                jax.profiler.start_trace(self.logdir)
            self._start_host = time.perf_counter()
            self._anchor_host = time.perf_counter()
            with jax.profiler.TraceAnnotation(self.ANCHOR):
                pass
            self._active = True
        except Exception as exc:  # profiler unavailable on this backend
            self._error = f"{type(exc).__name__}: {exc}"

    def stop(self) -> None:
        """End capture (writes the dump under `logdir`)."""
        if not self._active:
            return
        import jax
        try:
            if self._sess is not None:
                self._sess.export(self._sess.stop(), self.logdir)
                self._sess = None
            else:
                jax.profiler.stop_trace()
        except Exception as exc:
            self._error = f"{type(exc).__name__}: {exc}"
        self._active = False

    def _newest_dump(self) -> Optional[str]:
        pat = os.path.join(self.logdir, "plugins", "profile", "*",
                           "*.trace.json.gz")
        paths = sorted(glob.glob(pat))
        return paths[-1] if paths else None

    def device_events(self, epoch: float
                      ) -> Tuple[List[Dict[str, Any]], Dict[str, Any]]:
        """Profiler events shifted onto a tracer epoch (µs Chrome events).

        Returns ``(events, meta)``: events ready for
        `Tracer.export_chrome(extra_events=...)`; meta records the event
        count, whether the exact anchor was found (vs the min-timestamp
        fallback), the applied offset, and any capture error —
        `check_trace.py --require-device-lane` validates against it.
        """
        meta: Dict[str, Any] = {"events": 0, "anchor": False,
                                "offset_us": 0.0}
        if self._error:
            meta["error"] = self._error
        path = self._newest_dump()
        if path is None or self._start_host is None:
            return [], meta
        try:
            with gzip.open(path, "rt") as f:
                doc = json.load(f)
        except Exception as exc:
            meta["error"] = f"{type(exc).__name__}: {exc}"
            return [], meta
        raw = doc.get("traceEvents", [])
        # keep well-formed metadata + timestamped events; rebadge pid 0
        # (the host tracer's pid) so device lanes stay a separate process
        kept: List[Dict[str, Any]] = []
        anchor_ts: Optional[float] = None
        min_ts: Optional[float] = None
        for e in raw:
            ph = e.get("ph")
            if ph == "M":
                if "pid" in e and "name" in e:
                    kept.append(dict(e))
                continue
            if ph not in ("X", "i", "C"):
                continue
            name = e.get("name")
            if isinstance(name, str) and name.startswith("$"):
                continue             # python call-tracer spam
            ts = e.get("ts")
            if not isinstance(ts, (int, float)):
                continue
            if ph == "X" and not isinstance(e.get("dur"), (int, float)):
                e = dict(e)
                e["dur"] = 0.0
            kept.append(dict(e))
            min_ts = ts if min_ts is None else min(min_ts, ts)
            if e.get("name") == self.ANCHOR and anchor_ts is None:
                anchor_ts = ts
        if anchor_ts is not None:
            offset = (self._anchor_host - epoch) * 1e6 - anchor_ts
            meta["anchor"] = True
        elif min_ts is not None:
            # fallback: align the first captured event to session start
            offset = (self._start_host - epoch) * 1e6 - min_ts
        else:
            offset = 0.0
        meta["offset_us"] = offset
        out: List[Dict[str, Any]] = []
        for e in kept:
            pid = e.get("pid", 1)
            if pid == 0:
                e["pid"] = 1_000_000
            if "ts" in e and isinstance(e["ts"], (int, float)):
                e["ts"] = e["ts"] + offset
            out.append(e)
        meta["events"] = sum(1 for e in out if e.get("ph") != "M")
        meta["source"] = path
        return out, meta
