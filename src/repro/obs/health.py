"""Run-health monitoring: catch a diverging ZO run before it burns budget.

Long-horizon ZO fine-tuning fails quietly: a bad lr or a poisoned round
sends the loss to NaN or 10x its best, and the driver keeps charging the
DP accountant for rounds that can never help. `HealthMonitor` watches the
same per-round metrics stream the trilemma ledger reads and applies three
detectors:

  * **nonfinite** — loss is NaN/Inf this round;
  * **divergence** — loss exceeds `divergence_factor` x the running best;
  * **plateau**    — no improvement over the best for `plateau_rounds`
    consecutive rounds (off by default).

Policy `"warn"` records rising-edge events and lets the run proceed;
`"abort"` raises `HealthAbort` from `on_round`, which the driver catches
at chunk granularity — executed rounds stay equal to charged rounds, so
`RunResult.privacy_spent` is the *realized* (shorter) spend and
`train.py --audit` audits exactly what was bought (the abort itself is
recorded on `RunResult` and `train.py` exits with status 3).

Like `MetricsSink`, this is a duck-typed RoundHook — cadence 0 (it can
never realign chunk boundaries), no fedsim import, purely host-side reads
of already-materialized metrics. Off (no hook attached) the driver traces
the bit-exact historical program; on, it is numerically passive — both
pinned in tests on loop/scan/mesh.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List

POLICIES = ("warn", "abort")


class HealthAbort(RuntimeError):
    """Raised by HealthMonitor(policy="abort") on the first detection.

    Carries the round and reason; `Experiment.run` converts it into
    `RunResult.health_abort_round` / `health_abort_reason` after a
    best-effort checkpoint of the last completed boundary.
    """

    def __init__(self, round_: int, reason: str):
        super().__init__(f"health abort at round {round_}: {reason}")
        self.round = int(round_)
        self.reason = reason


class HealthMonitor:
    """NaN/divergence/plateau watcher over the per-round metrics stream.

    `events` collects rising-edge detections as
    ``{"round", "kind", "loss"}`` dicts (a kind re-fires only after it
    recovers, so an 8000-round plateau is one event, not 8000). With
    ``policy="abort"`` the first detection raises `HealthAbort` instead.
    """

    cadence = 0          # never realigns chunk boundaries

    def __init__(self, policy: str = "warn", *,
                 divergence_factor: float = 10.0,
                 plateau_rounds: int = 0,
                 plateau_tol: float = 0.0):
        if policy not in POLICIES:
            raise ValueError(
                f"unknown health policy: {policy!r} (want {POLICIES})")
        self.policy = policy
        self.divergence_factor = float(divergence_factor)
        self.plateau_rounds = int(plateau_rounds)
        self.plateau_tol = float(plateau_tol)
        self.events: List[Dict[str, Any]] = []
        self._best = math.inf
        self._since_best = 0
        self._firing: set = set()

    # -- RoundHook surface (duck-typed; cadence-0 contract) ---------------
    def on_start(self, exp) -> None:
        """Reset detector state for a fresh run."""
        self._best = math.inf
        self._since_best = 0
        self._firing.clear()

    def _fire(self, t: int, kind: str, loss: float) -> None:
        if kind not in self._firing:
            self._firing.add(kind)
            self.events.append(
                {"round": int(t), "kind": kind, "loss": float(loss)})
        if self.policy == "abort":
            raise HealthAbort(t, kind)

    def on_round(self, t: int, metrics: Dict[str, Any]) -> None:
        """Check this round's loss against the three detectors."""
        if "loss" not in metrics:
            return
        loss = float(metrics["loss"])
        if not math.isfinite(loss):
            self._fire(t, "nonfinite", loss)
            return
        if loss < self._best - self.plateau_tol:
            self._best = min(self._best, loss)
            self._since_best = 0
            self._firing.clear()      # recovered: kinds may re-fire later
        else:
            self._best = min(self._best, loss)
            self._since_best += 1
        if (self.divergence_factor > 0 and math.isfinite(self._best)
                and loss > self.divergence_factor * max(self._best, 1e-12)):
            self._fire(t, "divergence", loss)
            return
        if self.plateau_rounds > 0 and self._since_best >= self.plateau_rounds:
            self._fire(t, "plateau", loss)

    def on_boundary(self, t_done: int, exp) -> None:
        """No boundary-side effects (detectors are per-round)."""

    def close(self, exp) -> None:
        """Nothing to flush — events live on the monitor object."""
