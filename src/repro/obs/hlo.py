"""Compiled-executor introspection: FLOPs, bytes, peak memory, collectives.

The host-side pillars (spans/retrace/ledger) say what the *driver* did;
this module says what the *compiled program* is — straight from XLA's own
analyses of the memoized executors, never from running anything:

  * ``cost_analysis``   — compiler-estimated FLOPs and bytes accessed;
  * ``memory_analysis`` — argument/output/temp/alias buffer sizes, folded
    into the same analytic peak the dryrun harness reports
    (arg + out + temp − alias);
  * a structured **collective census** over the HLO text — per collective
    kind (all-reduce / all-gather / reduce-scatter / all-to-all /
    collective-permute): occurrence count, operand bytes, and replica
    group sizes. The census is what turns PR 4's "``all-reduce`` appears
    in the HLO" string assert into "exactly one client-axis all-reduce,
    spanning all client shards" — and gives the mesh-regression
    investigation per-collective numbers.

Everything here is AOT: `analyze_executor` lowers the executor's own
program for the shapes the driver actually dispatched (specs captured
before donation) under `retrace.suspended()`, so the compile-watermark
pins stay exact and the run's numerics are untouched. Results surface as
`RunResult.cost_stats`, the `bench_engine/v3` per-engine breakdown, and
`dryrun --cost`.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax

from repro.obs import retrace  # noqa: F401  (re-exported context for callers)

# dtype byte widths for HLO shape strings (mirrors the roofline parser —
# benchmarks cross-check the two against each other)
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# literal groups {{0,1},{2,3}} / {} or iota form [groups,size]<=[n]
_GROUPS_RE = re.compile(
    r"replica_groups=(\{(?:\{[^}]*\},?)*\}|\[[^\]]*\](?:<=\[[^\]]*\])?)")


def _shape_bytes(shape_str: str) -> float:
    nbytes = 0.0
    for sm in _SHAPE_RE.finditer(shape_str):
        dt, dims = sm.group(1), sm.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        nbytes += n * _DTYPE_BYTES[dt]
    return nbytes


def _group_sizes(raw: str) -> List[int]:
    """Participant count per replica group from the HLO attribute text."""
    if raw.startswith("["):             # iota form: [groups,size]<=[n]
        dims = [int(x) for x in raw[1:raw.index("]")].split(",") if x]
        if len(dims) == 2:
            return [dims[1]] * dims[0]
        if len(dims) == 1:
            return dims
        return []
    inner = raw.strip("{}")
    if not inner:
        return []
    return [len([t for t in grp.split(",") if t.strip()])
            for grp in inner.split("},{")]


def collective_census(hlo_text: str) -> Dict[str, Dict[str, Any]]:
    """Structured census of collectives in a per-device HLO module.

    Returns ``{op: {"count", "bytes", "group_sizes"}}`` where `bytes` sums
    output-shape operand bytes over occurrences (the roofline link-bytes
    convention) and `group_sizes` lists each occurrence's replica-group
    width (empty when the op carries no replica_groups attribute, e.g.
    collective-permute's source-target pairs). `-start` variants count as
    the base op; their `-done` halves carry no '=shape op(' pattern, so
    nothing is double-counted.
    """
    census: Dict[str, Dict[str, Any]] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        ent = census.setdefault(
            op, {"count": 0, "bytes": 0.0, "group_sizes": []})
        ent["count"] += 1
        ent["bytes"] += _shape_bytes(shape_str)
        gm = _GROUPS_RE.search(line)
        if gm:
            ent["group_sizes"].extend(_group_sizes(gm.group(1)))
    return census


@dataclass
class CostStats:
    """XLA's own account of one compiled program (per-device numbers)."""

    flops: float = 0.0              # cost_analysis "flops"
    bytes_accessed: float = 0.0     # cost_analysis "bytes accessed"
    argument_bytes: int = 0         # memory_analysis buffer classes
    output_bytes: int = 0
    temp_bytes: int = 0
    alias_bytes: int = 0
    peak_bytes: int = 0             # arg + out + temp − alias
    generated_code_bytes: int = 0
    collectives: Dict[str, Dict[str, Any]] = field(default_factory=dict)

    @property
    def collective_bytes(self) -> float:
        """Total operand bytes over every collective occurrence."""
        return float(sum(e["bytes"] for e in self.collectives.values()))

    def to_dict(self) -> Dict[str, Any]:
        """JSON-ready view (what RunResult/bench artifacts record)."""
        return {
            "flops": self.flops, "bytes_accessed": self.bytes_accessed,
            "argument_bytes": self.argument_bytes,
            "output_bytes": self.output_bytes,
            "temp_bytes": self.temp_bytes, "alias_bytes": self.alias_bytes,
            "peak_bytes": self.peak_bytes,
            "generated_code_bytes": self.generated_code_bytes,
            "collective_bytes": self.collective_bytes,
            "collectives": self.collectives,
        }


def describe(stats, indent: str = "  ") -> str:
    """Human-readable block for a CostStats (or its dict) — dryrun
    --cost, logs."""
    if hasattr(stats, "to_dict"):
        stats = stats.to_dict()
    lines = [
        f"{indent}flops            {stats['flops']:.3e}",
        f"{indent}bytes accessed   {stats['bytes_accessed']:.3e}",
        f"{indent}peak bytes       {stats['peak_bytes']:,}"
        f"  (arg {stats['argument_bytes']:,} + out {stats['output_bytes']:,}"
        f" + temp {stats['temp_bytes']:,} - alias {stats['alias_bytes']:,})",
    ]
    colls = stats.get("collectives") or {}
    if not colls:
        lines.append(f"{indent}collectives      none")
    for op, ent in sorted(colls.items()):
        gs = ent.get("group_sizes") or []
        lines.append(
            f"{indent}{op:<16} x{ent['count']}  {ent['bytes']:.3e} B"
            + (f"  groups={gs}" if gs else ""))
    return "\n".join(lines)


def analyze_compiled(compiled) -> CostStats:
    """Read cost/memory/collective analyses off an already-compiled
    executable (`jit(f).lower(...).compile()`); never executes it."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):    # jax <= 0.4.x: one dict per device
        ca = ca[0] if ca else {}
    stats = CostStats(
        flops=float(ca.get("flops", 0.0)),
        bytes_accessed=float(ca.get("bytes accessed", 0.0)))
    try:
        mem = compiled.memory_analysis()
    except Exception:                    # backend without memory stats
        mem = None
    if mem is not None:
        stats.argument_bytes = int(
            getattr(mem, "argument_size_in_bytes", 0) or 0)
        stats.output_bytes = int(
            getattr(mem, "output_size_in_bytes", 0) or 0)
        stats.temp_bytes = int(getattr(mem, "temp_size_in_bytes", 0) or 0)
        stats.alias_bytes = int(getattr(mem, "alias_size_in_bytes", 0) or 0)
        stats.generated_code_bytes = int(
            getattr(mem, "generated_code_size_in_bytes", 0) or 0)
        stats.peak_bytes = (stats.argument_bytes + stats.output_bytes
                            + stats.temp_bytes - stats.alias_bytes)
    try:
        hlo = compiled.as_text()
    except Exception:                    # text unavailable on some backends
        hlo = ""
    stats.collectives = collective_census(hlo)
    return stats


def specs_of(tree) -> Any:
    """ShapeDtypeStruct tree mirroring `tree`'s shapes/dtypes/shardings —
    capture this BEFORE dispatch so donation can't invalidate the args."""
    def spec(a):
        sh = getattr(a, "sharding", None)
        try:
            return jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=sh)
        except TypeError:                # leaves without device sharding
            return jax.ShapeDtypeStruct(a.shape, a.dtype)
    return jax.tree_util.tree_map(spec, tree)


def analyze_executor(executor, carry_spec, ctl_spec, batch_spec) -> CostStats:
    """Cost/memory/collective stats for the program `executor` would run
    on stacks of these shapes. Duck-typed over `aot_compiled` (both
    LoopExecutor and ScanExecutor expose it), so the caller — fedsim's
    driver, benchmarks — stays engine-agnostic. Compile-only; memoized on
    the executor per shape signature."""
    compiled = executor.aot_compiled(carry_spec, ctl_spec, batch_spec)
    return analyze_compiled(compiled)
