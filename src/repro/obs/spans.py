"""Host-side span timeline: nested wall-clock spans + instant events.

The tracer is the single clock for every host-side latency the driver
cares about — chunk prep, prefetch stalls, checkpoint snapshots, schedule
solves, metric flushes — recorded as (name, start, end, args) spans on a
shared `time.perf_counter` epoch. It is deliberately boring: pure Python,
thread-safe via one lock, no jax imports, so instrumented code paths stay
structurally identical whether telemetry is on (a `Tracer`) or off (the
shared `NULL_TRACER`, whose every method is a no-op).

Export is Chrome trace-event JSON (`export_chrome`), loadable directly in
Perfetto / chrome://tracing: spans become "X" complete events, instants
"i" events, counters "C" events, with one lane per host thread (the
driver, the chunk-prefetch worker, checkpoint writers).

Exactness contract: callers that already measure a latency (e.g.
`ChunkPrefetcher.stall_s`) record the span with `add_span` using the SAME
perf_counter endpoints they accumulate, so the sum of span durations
equals the legacy scalar exactly — the scalars are kept as derived sums,
never as a second clock.
"""
from __future__ import annotations

import json
import threading
import time
from contextlib import contextmanager
from typing import Any, Dict, List, Optional


class Tracer:
    """Thread-safe collector of wall-clock spans on one perf_counter epoch.

    Spans nest naturally through the `span(...)` context manager; code
    that measures its own interval reports it verbatim via `add_span`.
    `events()` returns host-side dicts (seconds, float) for tests and
    derived sums; `export_chrome` writes the Perfetto-loadable JSON.
    """

    def __init__(self):
        self._epoch = time.perf_counter()
        self._lock = threading.Lock()
        self._events: List[Dict[str, Any]] = []
        self._threads: Dict[int, str] = {}

    # -- recording --------------------------------------------------------
    def _tid(self) -> int:
        ident = threading.get_ident()
        if ident not in self._threads:
            self._threads[ident] = threading.current_thread().name
        return ident

    def add_span(self, name: str, start: float, end: float, **args) -> None:
        """Record a completed span from raw perf_counter endpoints (the
        exactness path: the caller's own measurement IS the span)."""
        with self._lock:
            self._events.append({
                "name": name, "ph": "X", "tid": self._tid(),
                "ts": start - self._epoch, "dur": end - start, "args": args})

    @contextmanager
    def span(self, name: str, **args):
        """Context manager recording the enclosed wall-clock interval."""
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.add_span(name, t0, time.perf_counter(), **args)

    def instant(self, name: str, **args) -> None:
        """Record a zero-duration marker (e.g. a prefetch kick)."""
        with self._lock:
            self._events.append({
                "name": name, "ph": "i", "tid": self._tid(),
                "ts": time.perf_counter() - self._epoch, "args": args})

    def counter(self, name: str, value: float, **args) -> None:
        """Record a sampled counter value (e.g. live device bytes)."""
        with self._lock:
            self._events.append({
                "name": name, "ph": "C", "tid": self._tid(),
                "ts": time.perf_counter() - self._epoch,
                "args": {"value": float(value), **args}})

    # -- reading ----------------------------------------------------------
    @property
    def enabled(self) -> bool:
        """Whether this tracer records anything (False on NULL_TRACER)."""
        return True

    @property
    def epoch(self) -> float:
        """The perf_counter instant that is t=0 for every span. External
        clocks (e.g. the jax profiler in repro.obs.profile) align their
        events onto the timeline by shifting relative to this epoch."""
        return self._epoch

    def events(self) -> List[Dict[str, Any]]:
        """Snapshot of every recorded event (ts/dur in seconds)."""
        with self._lock:
            return [dict(e) for e in self._events]

    def spans(self, name: Optional[str] = None) -> List[Dict[str, Any]]:
        """Completed spans, optionally filtered by name, in record order."""
        return [e for e in self.events()
                if e["ph"] == "X" and (name is None or e["name"] == name)]

    def total_s(self, name: str) -> float:
        """Sum of durations of every span called `name` (seconds). With
        `add_span` callers reporting their own endpoints, this equals the
        legacy scalar accumulator to float addition order."""
        return sum(e["dur"] for e in self.spans(name))

    # -- export -----------------------------------------------------------
    def export_chrome(self, path: str,
                      metadata: Optional[Dict[str, Any]] = None,
                      extra_events: Optional[List[Dict[str, Any]]] = None,
                      ) -> None:
        """Write Chrome trace-event JSON (Perfetto / chrome://tracing).

        `metadata` lands under `otherData` — the validation harness
        (tools/check_trace.py) cross-checks span-derived sums against the
        run's legacy counters recorded there.

        `extra_events` are pre-formed Chrome events appended verbatim —
        the profiler-merge path (repro.obs.profile) hands over device-op
        events already shifted onto this tracer's epoch, on their own pid
        so they render as a separate Perfetto process lane next to the
        host spans (which always live on pid 0).
        """
        with self._lock:
            events = [dict(e) for e in self._events]
            threads = dict(self._threads)
        out = []
        for ident, tname in sorted(threads.items()):
            out.append({"name": "thread_name", "ph": "M", "pid": 0,
                        "tid": ident, "args": {"name": tname}})
        for e in events:
            rec = {"name": e["name"], "ph": e["ph"], "pid": 0,
                   "tid": e["tid"], "ts": e["ts"] * 1e6,
                   "cat": "obs", "args": e["args"]}
            if e["ph"] == "X":
                rec["dur"] = e["dur"] * 1e6
            if e["ph"] == "i":
                rec["s"] = "t"
            out.append(rec)
        if extra_events:
            out.extend(extra_events)
        doc = {"traceEvents": out, "displayTimeUnit": "ms",
               "otherData": metadata or {}}
        with open(path, "w") as f:
            json.dump(doc, f)


class _NullContext:
    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NULL_CTX = _NullContext()


class NullTracer(Tracer):
    """No-op tracer: every instrumented call site stays a plain method
    call whether telemetry is on or off, so the telemetry-off program is
    structurally identical to the historical one (neutrality pin)."""

    def __init__(self):  # no lock, no buffers
        pass

    @property
    def enabled(self) -> bool:
        """Always False: nothing is ever recorded."""
        return False

    def add_span(self, name, start, end, **args):
        """No-op."""

    def span(self, name, **args):
        """Shared no-op context manager (no allocation per call)."""
        return _NULL_CTX

    def instant(self, name, **args):
        """No-op."""

    def counter(self, name, value, **args):
        """No-op."""

    def events(self):
        """Always empty."""
        return []

    @property
    def epoch(self) -> float:
        """Epoch of the null timeline (0.0; nothing aligns to it)."""
        return 0.0

    def export_chrome(self, path, metadata=None, extra_events=None):
        """Refuse silently: there is nothing to export."""


NULL_TRACER = NullTracer()
