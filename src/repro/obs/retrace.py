"""Process-wide compilation watermarks: build and retrace counters.

The engines lean on two layers of memoization — `pairzero.make_zo_step`/
`make_fo_step`/`jit_zo_step` (lru_cache over frozen configs) and
`engine.get_executor`/`get_loop_executor` (lru_cache over step objects) —
so a repeated config should compile exactly once per process. An
accidental cache-key break (an unhashable field, a fresh wrapper per run)
is silent: everything still works, 10x slower. These counters make it a
test failure instead.

Two kinds of event are counted, both as plain Python side effects:

  * ``*_build``  — bumped inside the lru-cached factory bodies, so they
    fire only on a cache MISS (a new step/executor object was built);
  * ``*_trace``  — bumped inside the traced function bodies, so they fire
    only while jax is TRACING (one per XLA compilation of that program;
    cached executions never re-enter Python).

`Experiment.run` snapshots the counters around each run and surfaces the
delta as `RunResult.compile_stats`; a warm second run of an identical
config must show an all-zero delta (tests/test_obs.py pins this, and
tools/check_trace.py asserts the expected cold-run counts in CI).

Counters are process-global and monotone (like jax's own compilation
cache); consumers diff snapshots rather than resetting.

One escape hatch: `suspended()`. The HLO introspection path
(repro.obs.hlo) re-lowers the memoized executors' programs to read their
compiled cost/memory analysis — that re-enters the traced bodies, which
would fire the `*_trace` counters and corrupt the exact cold-run counts
CI asserts. Analysis lowering wraps itself in `suspended()` so the
counters keep meaning "the *driver* (re)compiled something".
"""
from __future__ import annotations

import threading
from collections import Counter
from contextlib import contextmanager
from typing import Dict, Iterator

# canonical event names (the tests and check_trace key on these)
ZO_STEP_BUILD = "zo_step_build"        # make_zo_step cache miss
FO_STEP_BUILD = "fo_step_build"        # make_fo_step cache miss
LOOP_EXEC_BUILD = "loop_executor_build"  # get_loop_executor cache miss
SCAN_EXEC_BUILD = "scan_executor_build"  # get_executor cache miss
STEP_TRACE = "loop_step_trace"         # jitted per-round step retraced
CHUNK_TRACE = "scan_chunk_trace"       # scanned chunk program retraced

CANONICAL = (ZO_STEP_BUILD, FO_STEP_BUILD, LOOP_EXEC_BUILD,
             SCAN_EXEC_BUILD, STEP_TRACE, CHUNK_TRACE)

_LOCK = threading.Lock()
_COUNTS: Counter = Counter()
_SUSPEND = threading.local()


@contextmanager
def suspended() -> Iterator[None]:
    """Make `bump()` a no-op on this thread for the duration.

    Used by `repro.obs.hlo` around analysis-only `.lower()` calls: those
    re-enter the traced executor bodies (firing `scan_chunk_trace` /
    `loop_step_trace`) without representing a driver recompilation, which
    would break the exact cold/warm count pins. Thread-local because jax
    traces on the calling thread; re-entrant (nesting restores the prior
    state).
    """
    prev = getattr(_SUSPEND, "on", False)
    _SUSPEND.on = True
    try:
        yield
    finally:
        _SUSPEND.on = prev


def bump(name: str, n: int = 1) -> None:
    """Increment a counter (called from factory bodies / trace time)."""
    if getattr(_SUSPEND, "on", False):
        return
    with _LOCK:
        _COUNTS[name] += n


def snapshot() -> Dict[str, int]:
    """Current value of every counter (copy)."""
    with _LOCK:
        return dict(_COUNTS)


def since(before: Dict[str, int]) -> Dict[str, int]:
    """Per-counter delta vs an earlier `snapshot()`. Every CANONICAL
    counter is always present (plus any ad-hoc names seen in either
    snapshot), so 'no retrace happened' is an explicit, assertable
    {…: 0} rather than a missing key."""
    now = snapshot()
    keys = set(now) | set(before) | set(CANONICAL)
    return {k: now.get(k, 0) - before.get(k, 0) for k in sorted(keys)}
