"""Observability: span timeline, compile/memory watermarks, trilemma ledger.

Three pillars, all host-side and structurally neutral (telemetry off runs
the bit-exact historical program — pinned in tests/test_obs.py):

  1. **Span timeline** (`repro.obs.spans`) — a `Tracer` of nested
     wall-clock spans instrumented into the driver (`fedsim.Experiment`),
     `ChunkPrefetcher` kick/stall, chunk prep, `BatchStager`,
     `AsyncCheckpointer` snapshot/write, schedule solves, dispatch and
     metric flushes; exported as Chrome trace-event JSON
     (`train.py --trace-out trace.json`, loadable in Perfetto). Per-chunk
     stall spans use the SAME perf_counter endpoints as the legacy
     `prep_stall_s`/`ckpt_stall_s` scalars, which are kept as derived
     sums.
  2. **Compilation & memory watermarks** (`repro.obs.retrace`,
     `repro.obs.memory`) — build/retrace counters inside the memoized
     step/executor factories (surfaced as `RunResult.compile_stats`; a
     warm rerun must show zero) and periodic device-memory sampling at
     chunk boundaries (`RunResult.peak_bytes`).
  3. **Trilemma ledger** (`repro.obs.ledger`) — a `MetricsSink` round
     hook streaming one JSONL record per round: loss, uplink bits
     (the driver's own `transport.uplink_bits_total` accounting),
     cumulative (ε, δ) spend, peak memory, wall time
     (`train.py --metrics-out metrics.jsonl`).

`Telemetry` bundles the per-run pieces; `Telemetry.off()` (the default
everywhere) carries the shared no-op tracer and no sampler, so the
instrumented call sites cost one no-op method call when disabled.
tools/check_trace.py validates both artifact schemas in CI.
"""
from __future__ import annotations

from typing import Optional

from repro.obs import ledger, memory, retrace, spans
from repro.obs.ledger import MetricsSink, final_row, read_ledger
from repro.obs.memory import MemoryWatermark
from repro.obs.spans import NULL_TRACER, NullTracer, Tracer

__all__ = [
    "Telemetry", "Tracer", "NullTracer", "NULL_TRACER", "MemoryWatermark",
    "MetricsSink", "read_ledger", "final_row",
    "ledger", "memory", "retrace", "spans",
]


class Telemetry:
    """Per-run observability bundle: a tracer + an optional memory sampler.

    Pass one to `fedsim.Experiment(telemetry=...)` / `fedsim.run(...)`.
    The default (`Telemetry.off()`) is inert: the shared `NULL_TRACER`
    and no memory sampling — the historical program, bit for bit.
    """

    def __init__(self, tracer: Optional[Tracer] = None,
                 memory: Optional[MemoryWatermark] = None):
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.memory = memory

    @property
    def enabled(self) -> bool:
        """Whether any pillar is live (tracer recording or sampler set)."""
        return self.tracer.enabled or self.memory is not None

    @classmethod
    def on(cls, memory_sample_every: int = 32) -> "Telemetry":
        """Full telemetry: recording tracer + memory watermark sampler."""
        return cls(tracer=Tracer(),
                   memory=MemoryWatermark(memory_sample_every))

    @classmethod
    def off(cls) -> "Telemetry":
        """Inert telemetry (the default): no recording, no sampling."""
        return cls()
