"""Observability: spans, watermarks, ledger, device profile, HLO, health.

Host-side pillars, all structurally neutral (telemetry off runs the
bit-exact historical program — pinned in tests/test_obs.py):

  1. **Span timeline** (`repro.obs.spans`) — a `Tracer` of nested
     wall-clock spans instrumented into the driver (`fedsim.Experiment`),
     `ChunkPrefetcher` kick/stall, chunk prep, `BatchStager`,
     `AsyncCheckpointer` snapshot/write, schedule solves, dispatch and
     metric flushes; exported as Chrome trace-event JSON
     (`train.py --trace-out trace.json`, loadable in Perfetto). Per-chunk
     stall spans use the SAME perf_counter endpoints as the legacy
     `prep_stall_s`/`ckpt_stall_s` scalars, which are kept as derived
     sums.
  2. **Compilation & memory watermarks** (`repro.obs.retrace`,
     `repro.obs.memory`) — build/retrace counters inside the memoized
     step/executor factories (surfaced as `RunResult.compile_stats`; a
     warm rerun must show zero) and periodic device-memory sampling at
     chunk boundaries (`RunResult.peak_bytes`).
  3. **Trilemma ledger** (`repro.obs.ledger`) — a `MetricsSink` round
     hook streaming one JSONL record per round: loss, uplink bits
     (the driver's own `transport.uplink_bits_total` accounting),
     cumulative (ε, δ) spend, peak memory, wall time
     (`train.py --metrics-out metrics.jsonl`).

And the device-visible half:

  4. **Profiler merge** (`repro.obs.profile`) — opt-in `jax.profiler`
     capture whose device-op events are aligned onto the tracer's
     perf_counter epoch via a TraceAnnotation anchor and merged into the
     same Chrome trace (`train.py --profile-out`).
  5. **HLO introspection** (`repro.obs.hlo`) — compiler-reported FLOPs,
     bytes, peak memory and a structured collective census read off the
     memoized executors' compiled programs (AOT, never executed);
     surfaced as `RunResult.cost_stats` (the Telemetry `cost` flag),
     the `bench_engine/v3` per-engine breakdown, and `dryrun --cost`.
  6. **Run health** (`repro.obs.health`) — a duck-typed `HealthMonitor`
     round hook (NaN/divergence/plateau detectors) with a
     warn/checkpoint-then-abort policy; aborts land on `RunResult` so
     `--audit` consumes the realized (shorter) privacy spend.

`Telemetry` bundles the per-run pieces; `Telemetry.off()` (the default
everywhere) carries the shared no-op tracer and no sampler, so the
instrumented call sites cost one no-op method call when disabled.
tools/check_trace.py validates the artifact schemas in CI.
"""
from __future__ import annotations

from typing import Optional

from repro.obs import health, hlo, ledger, memory, profile, retrace, spans
from repro.obs.health import HealthAbort, HealthMonitor
from repro.obs.hlo import CostStats
from repro.obs.ledger import MetricsSink, final_row, read_ledger
from repro.obs.memory import MemoryWatermark
from repro.obs.profile import ProfilerSession
from repro.obs.spans import NULL_TRACER, NullTracer, Tracer

__all__ = [
    "Telemetry", "Tracer", "NullTracer", "NULL_TRACER", "MemoryWatermark",
    "MetricsSink", "read_ledger", "final_row",
    "HealthMonitor", "HealthAbort", "ProfilerSession", "CostStats",
    "health", "hlo", "ledger", "memory", "profile", "retrace", "spans",
]


class Telemetry:
    """Per-run observability bundle: tracer + memory sampler + cost flag.

    Pass one to `fedsim.Experiment(telemetry=...)` / `fedsim.run(...)`.
    The default (`Telemetry.off()`) is inert: the shared `NULL_TRACER`,
    no memory sampling, no cost analysis — the historical program, bit
    for bit. `cost=True` asks the driver to read the compiled executor's
    cost/memory/collective analysis into `RunResult.cost_stats` after
    the run (AOT introspection under `retrace.suspended()`: compile-only,
    numerically passive, invisible to the compile-watermark pins).
    """

    def __init__(self, tracer: Optional[Tracer] = None,
                 memory: Optional[MemoryWatermark] = None,
                 cost: bool = False):
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.memory = memory
        self.cost = bool(cost)

    @property
    def enabled(self) -> bool:
        """Whether any pillar is live (tracer, sampler, or cost stats)."""
        return self.tracer.enabled or self.memory is not None or self.cost

    @classmethod
    def on(cls, memory_sample_every: int = 32,
           cost: bool = False) -> "Telemetry":
        """Full telemetry: recording tracer + memory watermark sampler
        (+ optionally the post-run compiled-cost analysis)."""
        return cls(tracer=Tracer(),
                   memory=MemoryWatermark(memory_sample_every), cost=cost)

    @classmethod
    def off(cls) -> "Telemetry":
        """Inert telemetry (the default): no recording, no sampling."""
        return cls()
