"""Production mesh construction.

Single pod: (data=16, model=16) — 256 chips (one v5e pod).
Multi-pod:  (pod=2, data=16, model=16) — 512 chips across two pods; the
"pod" axis extends the client/data-parallel dimension (pAirZero clients ≡
(pod, data) groups; only scalar psums ever cross the pod boundary, which is
exactly what makes the paper's scheme attractive at multi-pod scale: DCI/ICI
bandwidth between pods is never on the critical path of a ZO round).

`make_production_mesh` is a FUNCTION (never a module-level constant) so
importing this module touches no jax device state. The dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import;
ordinary training/serving builds the mesh from the real device set.
"""
from __future__ import annotations

import jax


def make_mesh_compat(shape, axes):
    """jax.make_mesh across jax versions: axis_types (explicit-sharding API)
    only exists on jax >= 0.5; 0.4.x defaults every axis to Auto."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU tests/examples (same axis names)."""
    return make_mesh_compat((1, 1), ("data", "model"))


def make_client_mesh(spec: str = "auto", n_clients: int = 0):
    """Client mesh for the shard_map'd train step, from a CLI spec string.

    Spellings:
      "auto"  — all local devices on a ('data',) axis; when `n_clients` is
                given, uses the largest divisor of n_clients that fits the
                device count (pAirZero clients split evenly or not at all).
      "8"     — ('data',) axis of exactly 8 devices.
      "2x8"   — ('pod', 'data') = (2, 8): 16 devices, pod-major client ids
                (matching how PartitionSpec(('pod','data')) tiles the
                client dim).

    The mesh carries only client axes — `runtime.sharding` treats a missing
    'model' axis as TP of 1, so the same param/batch rules apply unchanged.
    """
    import numpy as np

    devices = jax.devices()
    if spec == "auto":
        n = len(devices)
        if n_clients:
            while n > 1 and n_clients % n != 0:
                n -= 1
        shape, axes = (n,), ("data",)
    elif "x" in spec:
        pod, data = (int(v) for v in spec.split("x"))
        shape, axes = (pod, data), ("pod", "data")
    else:
        shape, axes = (int(spec),), ("data",)
    want = int(np.prod(shape))
    if want > len(devices):
        raise ValueError(f"mesh spec {spec!r} wants {want} devices but only "
                         f"{len(devices)} are visible (set XLA_FLAGS="
                         f"--xla_force_host_platform_device_count={want} "
                         "for a CPU mesh)")
    from jax.sharding import Mesh
    return Mesh(np.asarray(devices[:want]).reshape(shape), axes)


def client_submesh(mesh):
    """The (pod, data) client-axes view of a production mesh: one device
    column along 'model'.

    Used by `dryrun --shard-clients`: jax 0.4.x's partial-auto shard_map
    (manual clients + auto TP) trips an XLA manual-subgroup check on
    large TP-sharded models, and the failure is a process abort rather
    than a catchable error. Compiling the shard_map'd step on the client
    submesh proves the cross-device psum + client fan-out lower at
    production client counts; TP stays a GSPMD-auto concern of the
    standard cells until the upstream partitioner handles the mix.
    """
    import numpy as np

    from jax.sharding import Mesh
    if "model" not in mesh.axis_names:
        return mesh
    idx = tuple(0 if a == "model" else slice(None)
                for a in mesh.axis_names)
    names = tuple(a for a in mesh.axis_names if a != "model")
    return Mesh(np.asarray(mesh.devices)[idx], names)


def n_clients(mesh) -> int:
    """pAirZero clients ≡ product of the (pod, data) axes."""
    k = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            k *= mesh.shape[a]
    return k
