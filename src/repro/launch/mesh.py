"""Production mesh construction.

Single pod: (data=16, model=16) — 256 chips (one v5e pod).
Multi-pod:  (pod=2, data=16, model=16) — 512 chips across two pods; the
"pod" axis extends the client/data-parallel dimension (pAirZero clients ≡
(pod, data) groups; only scalar psums ever cross the pod boundary, which is
exactly what makes the paper's scheme attractive at multi-pod scale: DCI/ICI
bandwidth between pods is never on the critical path of a ZO round).

`make_production_mesh` is a FUNCTION (never a module-level constant) so
importing this module touches no jax device state. The dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import;
ordinary training/serving builds the mesh from the real device set.
"""
from __future__ import annotations

import jax


def make_mesh_compat(shape, axes):
    """jax.make_mesh across jax versions: axis_types (explicit-sharding API)
    only exists on jax >= 0.5; 0.4.x defaults every axis to Auto."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU tests/examples (same axis names)."""
    return make_mesh_compat((1, 1), ("data", "model"))


def n_clients(mesh) -> int:
    """pAirZero clients ≡ product of the (pod, data) axes."""
    k = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            k *= mesh.shape[a]
    return k
