"""Training launcher: federated pAirZero fine-tuning from the CLI.

    PYTHONPATH=src python -m repro.launch.train \
        --arch opt-125m --task sst2 --transport analog --scheme solution \
        --rounds 800 --clients 5 --lr 5e-7 --checkpoint-dir ckpt/

The uplink mechanism is any registered Transport (analog | sign | perfect |
digital | fo — see repro.core.transport); `--variant` remains as a
deprecated alias for one release. The wireless channel is any registered
ChannelModel (see repro.channel), optionally wrapped:

    --channel rician --rician-k 4 --csi-phase-err 0.1 --outage-db -10 \
        --cell-radius 150

Mobility is specified physically for --channel ar1 via --doppler-hz (and
--round-s): the lag-1 correlation is derived by Jakes' J0(2*pi*f_D*tau).
`--audit` switches on the privacy subsystem (repro.privacy): eavesdropper
observation capture, the seed-replay reconstruction attack, and — on DP
transports — the empirical Clopper-Pearson eps_hat audit checked against
the analytic accountant (non-zero exit on violation: a CI-able gate).

`--mesh auto|8|2x8` shards the clients over a device mesh: each shard runs
its clients' forwards and the OTA scalar aggregate becomes a real
cross-device psum (bit-identical to the single-device run). On CPU, set
XLA_FLAGS=--xla_force_host_platform_device_count=8 before launch to get a
multi-device mesh.

On a real multi-host TPU fleet this process runs once per host after
jax.distributed.initialize() (see launch/scripts/); on CPU it runs the same
code on a 1-device mesh. Architecture choice is --arch <id> over the full
assigned-architecture registry.
"""
from __future__ import annotations

import argparse
import json

import jax.numpy as jnp
import numpy as np

from repro import byzantine as byz
from repro.configs.base import (ByzantineConfig, ChannelConfig, DesyncConfig,
                                DPConfig, PairZeroConfig,
                                PowerControlConfig, TransportConfig,
                                ZOConfig)
from repro.core import fedsim, transport
from repro.data.pipeline import FederatedPipeline
from repro.data.tasks import TaskSpec
from repro.models import registry
from repro.runtime.fault import ElasticSchedule, FaultModel
from repro.runtime.inject import FaultInjector


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="opt-125m",
                    help=f"one of {registry.list_archs()}")
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced same-family config (CPU-scale)")
    ap.add_argument("--task", default="sst2",
                    choices=["sst2", "squad", "lm"])
    ap.add_argument("--transport", default=None,
                    help="uplink mechanism from the transport registry "
                         f"{transport.available()}; default: --variant")
    ap.add_argument("--variant", default="analog",
                    choices=["analog", "sign", "fo"],
                    help="DEPRECATED alias for --transport")
    ap.add_argument("--scheme", default="solution",
                    choices=["solution", "static", "reversed", "perfect"],
                    help="power-control schedule for the OTA transports")
    ap.add_argument("--quant-bits", type=int, default=8,
                    help="bits/coordinate for --transport digital")
    ap.add_argument("--channel", default=None,
                    help="base fading model from the channel registry "
                         "(rayleigh | rician | static | ar1 | user-"
                         "registered); default rayleigh. The geometry/"
                         "imperfect-CSI/outage wrappers compose on top via "
                         "--cell-radius/--csi-phase-err/--outage-db")
    ap.add_argument("--rician-k", type=float, default=3.0,
                    help="K-factor for --channel rician")
    ap.add_argument("--ar1-rho", type=float, default=0.9,
                    help="lag-1 temporal correlation for --channel ar1")
    ap.add_argument("--doppler-hz", type=float, default=None,
                    help="maximum Doppler shift f_D (Hz) for --channel "
                         "ar1: rho is derived physically via Jakes' "
                         "J0(2*pi*f_D*tau) instead of --ar1-rho")
    ap.add_argument("--round-s", type=float, default=1e-3,
                    help="round duration tau (s) entering the Jakes "
                         "mapping of --doppler-hz")
    ap.add_argument("--csi-phase-err", type=float, default=0.0,
                    help="residual CSI phase-error std (radians); >0 wraps "
                         "the channel in ImperfectCSI")
    ap.add_argument("--outage-db", type=float, default=None,
                    help="deep-fade outage threshold (dB); set to wrap the "
                         "channel in OutageModel (straggling clients)")
    ap.add_argument("--cell-radius", type=float, default=0.0,
                    help="cell radius (m); >0 wraps the channel in "
                         "PathLossGeometry (per-client mean powers)")
    ap.add_argument("--shadow-std-db", type=float, default=0.0,
                    help="correlated log-normal shadowing std (dB) on the "
                         "PathLossGeometry gains; requires --cell-radius")
    ap.add_argument("--shadow-corr", type=float, default=0.5,
                    help="inter-client shadowing correlation rho in [0,1] "
                         "for --shadow-std-db")
    ap.add_argument("--rounds", type=int, default=800)
    ap.add_argument("--engine", default="loop", choices=["loop", "scan"],
                    help="round executor: per-round dispatch (loop) or the "
                         "device-resident chunked scan engine (scan)")
    ap.add_argument("--chunk-rounds", type=int, default=32,
                    help="rounds per device dispatch for --engine scan")
    ap.add_argument("--mesh", default=None,
                    help="shard clients over a device mesh: 'auto' (all "
                         "local devices on a data axis), '8' (data=8), or "
                         "'2x8' (pod=2, data=8). Clients must divide "
                         "evenly over the client shards; the OTA scalar "
                         "aggregate becomes a real cross-device psum")
    ap.add_argument("--no-overlap", action="store_true",
                    help="disable the chunk-prefetch thread (host prep of "
                         "chunk i+1 normally overlaps device compute of "
                         "chunk i) — the stall-measurement control")
    ap.add_argument("--clients", type=int, default=5)
    ap.add_argument("--batch", type=int, default=8,
                    help="per-client batch size")
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=5e-3)
    ap.add_argument("--mu", type=float, default=1e-3)
    ap.add_argument("--gamma", type=float, default=5.0)
    ap.add_argument("--n-perturb", type=int, default=4)
    ap.add_argument("--epsilon", type=float, default=5.0)
    ap.add_argument("--delta", type=float, default=0.01)
    ap.add_argument("--power", type=float, default=100.0)
    ap.add_argument("--n0", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--eval-every", type=int, default=100)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=100)
    ap.add_argument("--dropout-p", type=float, default=0.0,
                    help="per-round client dropout probability")
    ap.add_argument("--straggler-p", type=float, default=0.0)
    ap.add_argument("--elastic", default=None,
                    help="membership events: 'round:K,round:K' e.g. "
                         "'200:3,400:5'")
    ap.add_argument("--byzantine", default="none",
                    help="active-adversary client behavior from the "
                         f"byzantine registry {byz.available_behaviors()}; "
                         "'none' (default) runs the honest cohort — "
                         "bit-identical to a build without the subsystem")
    ap.add_argument("--byzantine-frac", type=float, default=0.25,
                    help="fraction of clients running --byzantine "
                         "(cohort size = round(frac * clients); 0 disables "
                         "the attack)")
    ap.add_argument("--byzantine-scale", type=float, default=3.0,
                    help="behavior parameter: lambda for scaled_poison, "
                         "the noise std for gaussian_noise")
    ap.add_argument("--defense", default="none",
                    help="server/PHY-side countermeasure from the byzantine "
                         f"registry {byz.available_defenses()}; 'none' "
                         "(default) keeps the mechanism's plain decode")
    ap.add_argument("--defense-groups", type=int, default=4,
                    help="orthogonal decode sub-slots for --defense "
                         "robust_decode/reweight (robustness grows with "
                         "groups at a linear resource-block cost)")
    ap.add_argument("--defense-clip-factor", type=float, default=0.5,
                    help="transmit-clip bound for --defense clip: "
                         "gamma_d = factor * gamma, folded into the "
                         "power-control solve")
    ap.add_argument("--desync-frac", type=float, default=0.0,
                    help="per-round probability a client is a stale "
                         "straggler whose scalar rode a lagged round seed "
                         "(repro.runtime.desync); 0 disables desync "
                         "modeling — bit-identical to a build without it")
    ap.add_argument("--desync-max-lag", type=int, default=4,
                    help="max staleness (rounds) for --desync-frac "
                         "stragglers; the realized lag is drawn per round")
    ap.add_argument("--desync-phase-std", type=float, default=0.0,
                    help="fractional-timing phase-error std (radians): "
                         "every client's OTA contribution is attenuated "
                         "by cos(theta) of its realized misalignment")
    ap.add_argument("--desync-frame-symbols", type=int, default=1,
                    help="symbols per OTA frame for the conventional "
                         "d-dimensional baseline's Dirichlet frame gain "
                         "(only affects --transport fo under desync)")
    ap.add_argument("--inject", action="append", default=[],
                    metavar="SITE:MODE[:SEL]",
                    help="arm a deterministic host fault (repeatable): "
                         "site in {chunk_prep, dispatch, ckpt_snapshot, "
                         "ckpt_write}, mode in {exception, delay, "
                         "torn_write}, selector '@2,5' (exact invocation "
                         "indices) or a probability like '0.1' (default: "
                         "every invocation). The run recovers via bounded "
                         "retries / graceful degradation and reports the "
                         "counters under summary.retry_attempts")
    ap.add_argument("--inject-seed", type=int, default=0,
                    help="seed for probabilistic --inject selectors "
                         "(fires are a pure function of seed, site, "
                         "invocation index)")
    ap.add_argument("--audit", action="store_true",
                    help="eavesdropper capture + empirical privacy audit "
                         "(repro.privacy): records what an over-the-air "
                         "listener sees every round, runs the seed-replay "
                         "reconstruction attack on it, and — for DP "
                         "transports — checks the Clopper-Pearson eps_hat "
                         "lower bound against the analytic accountant "
                         "(exit 1 if the audit ever exceeds it)")
    ap.add_argument("--audit-trials", type=int, default=1500,
                    help="paired canary traces for the eps_hat audit")
    ap.add_argument("--trace-out", default=None,
                    help="write the host-side span timeline here as Chrome "
                         "trace-event JSON (open in Perfetto / "
                         "chrome://tracing): chunk prep/prefetch/stall, "
                         "dispatch, metric flush, checkpoint snapshot "
                         "spans, plus the run's compile/stall counters "
                         "under otherData (see docs/observability.md)")
    ap.add_argument("--metrics-out", default=None,
                    help="stream the per-round trilemma ledger here as "
                         "JSONL (schema trilemma_ledger/v2): one record "
                         "per round with loss, uplink bits, cumulative "
                         "(eps, delta) spend, and the peak device-memory "
                         "watermark — machine-readable evidence for all "
                         "three trilemma axes")
    ap.add_argument("--obs-sample-every", type=int, default=32,
                    help="device-memory sampling period (rounds) for the "
                         "--trace-out/--metrics-out watermark; samples "
                         "are taken at chunk boundaries, so cadence never "
                         "changes chunk shapes")
    ap.add_argument("--profile-out", default=None,
                    help="capture the run under jax.profiler and write a "
                         "MERGED Chrome trace here: XLA device-op events "
                         "aligned onto the host span timeline via a "
                         "perf_counter anchor, so dispatch/prep_stall "
                         "spans and executable launches render on one "
                         "Perfetto timeline (see docs/observability.md)")
    ap.add_argument("--health-policy", default="off",
                    choices=["off", "warn", "abort"],
                    help="run-health monitor (repro.obs.health): NaN/Inf, "
                         "loss-divergence and plateau detectors over the "
                         "per-round metrics. 'warn' records events in the "
                         "summary; 'abort' checkpoints the last boundary, "
                         "stops the run and exits with status 3 — the "
                         "accountant keeps only the realized spend, which "
                         "--audit then consumes")
    ap.add_argument("--health-divergence", type=float, default=10.0,
                    help="divergence factor: abort/warn when loss exceeds "
                         "this multiple of the running best (<=0 disables "
                         "the detector)")
    ap.add_argument("--health-plateau", type=int, default=0,
                    help="plateau window (rounds with no new best loss) "
                         "before the plateau detector fires; 0 disables")
    ap.add_argument("--out", default=None, help="write result JSON here")
    return ap


def main() -> None:
    args = build_parser().parse_args()
    cfg = registry.get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    mechanism = args.transport or args.variant
    byzcfg = None
    if args.byzantine != "none" or args.defense != "none":
        byzcfg = ByzantineConfig(
            behavior=args.byzantine, fraction=args.byzantine_frac,
            scale=args.byzantine_scale, defense=args.defense,
            groups=args.defense_groups,
            clip_factor=args.defense_clip_factor, seed=args.seed)
    desynccfg = None
    if args.desync_frac or args.desync_phase_std:
        desynccfg = DesyncConfig(
            fraction=args.desync_frac, max_lag=args.desync_max_lag,
            phase_std=args.desync_phase_std,
            frame_symbols=args.desync_frame_symbols, seed=args.seed)
    pz = PairZeroConfig(
        variant=args.variant, n_clients=args.clients, rounds=args.rounds,
        zo=ZOConfig(mu=args.mu, lr=args.lr, clip_gamma=args.gamma,
                    n_perturb=args.n_perturb),
        channel=ChannelConfig(n0=args.n0, power=args.power,
                              d=cfg.param_count(),
                              model=args.channel, rician_k=args.rician_k,
                              ar1_rho=args.ar1_rho,
                              doppler_hz=args.doppler_hz,
                              round_duration_s=args.round_s,
                              phase_err_std=args.csi_phase_err,
                              outage_db=args.outage_db,
                              cell_radius=args.cell_radius,
                              shadow_std_db=args.shadow_std_db,
                              shadow_corr=args.shadow_corr),
        dp=DPConfig(epsilon=args.epsilon, delta=args.delta),
        power=PowerControlConfig(scheme=args.scheme),
        transport=TransportConfig(mechanism=mechanism, scheme=args.scheme,
                                  quant_bits=args.quant_bits),
        byzantine=byzcfg,
        desync=desynccfg,
        seed=args.seed)

    pipe = FederatedPipeline(
        task=args.task,
        spec=TaskSpec(args.task, cfg.vocab_size, args.seq_len),
        n_clients=args.clients, per_client_batch=args.batch, seed=args.seed,
        frontend_tokens=cfg.frontend.n_frontend_tokens,
        d_model=cfg.d_model)

    fault = None
    if args.dropout_p or args.straggler_p:
        fault = FaultModel(args.clients, dropout_p=args.dropout_p,
                           straggler_p=args.straggler_p, seed=args.seed)
    elastic = None
    if args.elastic:
        events = tuple(tuple(int(v) for v in e.split(":"))
                       for e in args.elastic.split(","))
        elastic = ElasticSchedule(args.clients, events=events)

    def log(t, metrics):
        if t % 50 == 0:
            print(f"round {t:5d} loss {metrics['loss']:.4f}", flush=True)

    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_client_mesh
        mesh = make_client_mesh(args.mesh, n_clients=args.clients)
        print(f"client mesh: {dict(mesh.shape)} over "
              f"{mesh.devices.size} devices", flush=True)

    adversary, attack_hook, extra_hooks = None, None, []
    if args.audit:
        from repro import privacy as pv
        adversary = pv.Adversary()
        # the OTA/digital observations are scalars per round; FO's is a
        # full [d] gradient — cap the host-side stream (the attacks
        # consume the early rounds; the eps_hat audit needs no capture)
        cap = 8 if mechanism == "fo" else None
        attack_hook = pv.AttackHook(max_rounds=cap)
        extra_hooks = [attack_hook]

    # observability (repro.obs): span timeline + memory watermark +
    # trilemma ledger + device profile — host-side only (the profiler
    # observes, never reschedules), trajectory bitwise unchanged
    telemetry, profiler, health = None, None, None
    if args.trace_out or args.metrics_out or args.profile_out:
        from repro import obs
        telemetry = obs.Telemetry.on(
            memory_sample_every=args.obs_sample_every,
            cost=bool(args.trace_out or args.profile_out))
        if args.metrics_out:
            extra_hooks = extra_hooks + [obs.MetricsSink(args.metrics_out)]
    if args.health_policy != "off":
        from repro import obs
        health = obs.HealthMonitor(
            args.health_policy,
            divergence_factor=args.health_divergence,
            plateau_rounds=args.health_plateau)
        extra_hooks = extra_hooks + [health]
    if args.profile_out:
        from repro import obs
        profiler = obs.ProfilerSession()
        profiler.start()

    injector = None
    if args.inject:
        from repro.obs.spans import NULL_TRACER
        injector = FaultInjector.from_specs(
            args.inject, seed=args.inject_seed,
            tracer=telemetry.tracer if telemetry is not None
            else NULL_TRACER)

    res = fedsim.run(cfg, pz, pipe, rounds=args.rounds,
                     engine=args.engine, chunk_rounds=args.chunk_rounds,
                     eval_every=args.eval_every,
                     checkpoint_dir=args.checkpoint_dir,
                     checkpoint_every=args.checkpoint_every,
                     fault=fault, elastic=elastic, dtype=jnp.float32,
                     mesh=mesh, overlap=not args.no_overlap,
                     adversary=adversary, hooks=extra_hooks,
                     telemetry=telemetry, injector=injector, on_round=log)

    if profiler is not None:
        profiler.stop()

    if args.trace_out or args.profile_out:
        metadata = {
            "engine": args.engine,
            "overlap": not args.no_overlap,
            "prep_stall_s": res.prep_stall_s,
            "ckpt_stall_s": res.ckpt_stall_s,
            "peak_bytes": res.peak_bytes,
            "compile_stats": res.compile_stats,
        }
        if res.cost_stats is not None:
            metadata["cost_stats"] = res.cost_stats
        if args.trace_out:
            telemetry.tracer.export_chrome(args.trace_out,
                                           metadata=metadata)
            print(f"trace timeline -> {args.trace_out}", flush=True)
        if args.profile_out:
            device_events, profile_meta = profiler.device_events(
                telemetry.tracer.epoch)
            telemetry.tracer.export_chrome(
                args.profile_out,
                metadata={**metadata, "profile": profile_meta},
                extra_events=device_events)
            print(f"merged device+host timeline -> {args.profile_out} "
                  f"({profile_meta['events']} device events)", flush=True)

    audit_summary = None
    if args.audit:
        audit_summary = run_audit(pz, res, attack_hook, args)

    summary = {
        "arch": cfg.name, "transport": mechanism, "scheme": args.scheme,
        "channel": args.channel or "rayleigh",
        "engine": args.engine,
        "byzantine": ({"behavior": args.byzantine,
                       "fraction": args.byzantine_frac,
                       "defense": args.defense}
                      if byzcfg is not None else None),
        "desync": ({"fraction": args.desync_frac,
                    "max_lag": args.desync_max_lag,
                    "phase_std": args.desync_phase_std}
                   if desynccfg is not None else None),
        "retry_attempts": res.retry_attempts,
        "injected": injector.fired if injector is not None else {},
        "mesh": dict(mesh.shape) if mesh is not None else None,
        "rounds": res.steps,
        "uplink_bits": res.uplink_bits,
        "final_loss": res.losses[-1] if res.losses else None,
        "accuracies": res.accuracies,
        "privacy_spent": res.privacy_spent,
        "privacy_budget": res.privacy_budget,
        "wall_time_s": round(res.wall_time_s, 1),
        "prep_stall_s": round(res.prep_stall_s, 3),
        "ckpt_stall_s": round(res.ckpt_stall_s, 3),
        "peak_bytes": res.peak_bytes,
        "compile_stats": res.compile_stats,
        "resumed_from": res.resumed_from,
    }
    if res.cost_stats is not None:
        summary["cost_stats"] = res.cost_stats
    if health is not None:
        summary["health"] = {
            "policy": args.health_policy,
            "events": health.events,
            "abort_round": res.health_abort_round,
            "abort_reason": res.health_abort_reason,
        }
    if audit_summary is not None:
        summary["audit"] = audit_summary
    print(json.dumps(summary, indent=2))
    if args.out:
        with open(args.out, "w") as f:
            json.dump({**summary, "losses": res.losses}, f)
    if audit_summary is not None and not audit_summary.get("dominated", True):
        raise SystemExit("AUDIT FAILURE: empirical eps_hat "
                         f"{audit_summary['eps_hat']:.4f} exceeds the "
                         "analytic accountant's "
                         f"{audit_summary['eps_analytic']:.4f}")
    if res.health_abort_round >= 0:
        # distinct exit status so CI can tell "health abort, audit clean"
        # (3) from an audit violation (1)
        print(f"HEALTH ABORT: {res.health_abort_reason} at round "
              f"{res.health_abort_round} — accountant charged only the "
              f"{res.steps} executed rounds", flush=True)
        raise SystemExit(3)


def run_audit(pz, res, attack_hook, args) -> dict:
    """Post-run privacy audit: seed-replay reconstruction on the captured
    observations + the paired-trace eps_hat bound vs the analytic ledger.
    Consumes the realized schedule/transport the run exposes on its
    RunResult — the adversary knows both (they are broadcast). An active
    defense adjusts the audited config (a transmit clip shrinks the
    canary's worst-case payload to gamma_d) so the audit measures the
    mechanism actually on the air."""
    from repro import privacy as pv
    defense = byz.resolve_defense(pz)
    if defense is not None:
        pz = defense.audited_pz(pz)
    out: dict = {}
    obs = attack_hook.observations()
    payloads = attack_hook.payloads()
    if payloads is not None and ("obs_y" in obs or "obs_q" in obs):
        # score against what was actually radiated (±1 ballots for sign)
        payloads = np.asarray(res.transport.transmitted(payloads))
        replay = pv.get("seed_replay")().run(
            obs, payloads, res.schedule.c, attack_hook.k_eff())
        out["seed_replay"] = {
            "victim_rmse": replay["victim_rmse"],
            "mean_rmse": replay["mean_rmse"],
            "per_client_exposed": replay["per_client_exposed"],
        }
    if res.transport.canary_payload(pz) is not None:
        # analytic side fed from the run's OWN accountant ledger (the
        # per-round spend curve on RunResult) instead of re-deriving the
        # spend from the schedule — one accounting, audit and ledger agree
        audit = pv.audit_transport(
            res.transport, res.schedule, pz,
            rounds=max(res.steps, 1), trials=args.audit_trials,
            spent=res.privacy_spent)
        out.update(audit.to_dict())
        verdict = "OK (eps_hat <= analytic)" if audit.dominated \
            else "VIOLATED"
        print(f"privacy audit: eps_hat={audit.eps_hat:.4f} <= "
              f"analytic eps={audit.eps_analytic:.4f}? {verdict}",
              flush=True)
    else:
        out["auditable"] = False
        print(f"privacy audit: transport {res.transport.name!r} provides "
              "no DP guarantee (payloads individually exposed; see "
              "seed_replay metrics)", flush=True)
    return out


if __name__ == "__main__":
    main()
