"""Training launcher: federated pAirZero fine-tuning from the CLI.

    PYTHONPATH=src python -m repro.launch.train \
        --arch opt-125m --task sst2 --transport analog --scheme solution \
        --rounds 800 --clients 5 --lr 5e-7 --checkpoint-dir ckpt/

The uplink mechanism is any registered Transport (analog | sign | perfect |
digital | fo — see repro.core.transport); `--variant` remains as a
deprecated alias for one release. The wireless channel is any registered
ChannelModel (see repro.channel), optionally wrapped:

    --channel rician --rician-k 4 --csi-phase-err 0.1 --outage-db -10 \
        --cell-radius 150

`--mesh auto|8|2x8` shards the clients over a device mesh: each shard runs
its clients' forwards and the OTA scalar aggregate becomes a real
cross-device psum (bit-identical to the single-device run). On CPU, set
XLA_FLAGS=--xla_force_host_platform_device_count=8 before launch to get a
multi-device mesh.

On a real multi-host TPU fleet this process runs once per host after
jax.distributed.initialize() (see launch/scripts/); on CPU it runs the same
code on a 1-device mesh. Architecture choice is --arch <id> over the full
assigned-architecture registry.
"""
from __future__ import annotations

import argparse
import json

import jax.numpy as jnp

from repro.configs.base import (ChannelConfig, DPConfig, PairZeroConfig,
                                PowerControlConfig, TransportConfig, ZOConfig)
from repro.core import fedsim, transport
from repro.data.pipeline import FederatedPipeline
from repro.data.tasks import TaskSpec
from repro.models import registry
from repro.runtime.fault import ElasticSchedule, FaultModel


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="opt-125m",
                    help=f"one of {registry.list_archs()}")
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced same-family config (CPU-scale)")
    ap.add_argument("--task", default="sst2",
                    choices=["sst2", "squad", "lm"])
    ap.add_argument("--transport", default=None,
                    help="uplink mechanism from the transport registry "
                         f"{transport.available()}; default: --variant")
    ap.add_argument("--variant", default="analog",
                    choices=["analog", "sign", "fo"],
                    help="DEPRECATED alias for --transport")
    ap.add_argument("--scheme", default="solution",
                    choices=["solution", "static", "reversed", "perfect"],
                    help="power-control schedule for the OTA transports")
    ap.add_argument("--quant-bits", type=int, default=8,
                    help="bits/coordinate for --transport digital")
    ap.add_argument("--channel", default=None,
                    help="base fading model from the channel registry "
                         "(rayleigh | rician | static | ar1 | user-"
                         "registered); default rayleigh. The geometry/"
                         "imperfect-CSI/outage wrappers compose on top via "
                         "--cell-radius/--csi-phase-err/--outage-db")
    ap.add_argument("--rician-k", type=float, default=3.0,
                    help="K-factor for --channel rician")
    ap.add_argument("--ar1-rho", type=float, default=0.9,
                    help="lag-1 temporal correlation for --channel ar1")
    ap.add_argument("--csi-phase-err", type=float, default=0.0,
                    help="residual CSI phase-error std (radians); >0 wraps "
                         "the channel in ImperfectCSI")
    ap.add_argument("--outage-db", type=float, default=None,
                    help="deep-fade outage threshold (dB); set to wrap the "
                         "channel in OutageModel (straggling clients)")
    ap.add_argument("--cell-radius", type=float, default=0.0,
                    help="cell radius (m); >0 wraps the channel in "
                         "PathLossGeometry (per-client mean powers)")
    ap.add_argument("--rounds", type=int, default=800)
    ap.add_argument("--engine", default="loop", choices=["loop", "scan"],
                    help="round executor: per-round dispatch (loop) or the "
                         "device-resident chunked scan engine (scan)")
    ap.add_argument("--chunk-rounds", type=int, default=32,
                    help="rounds per device dispatch for --engine scan")
    ap.add_argument("--mesh", default=None,
                    help="shard clients over a device mesh: 'auto' (all "
                         "local devices on a data axis), '8' (data=8), or "
                         "'2x8' (pod=2, data=8). Clients must divide "
                         "evenly over the client shards; the OTA scalar "
                         "aggregate becomes a real cross-device psum")
    ap.add_argument("--no-overlap", action="store_true",
                    help="disable the chunk-prefetch thread (host prep of "
                         "chunk i+1 normally overlaps device compute of "
                         "chunk i) — the stall-measurement control")
    ap.add_argument("--clients", type=int, default=5)
    ap.add_argument("--batch", type=int, default=8,
                    help="per-client batch size")
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=5e-3)
    ap.add_argument("--mu", type=float, default=1e-3)
    ap.add_argument("--gamma", type=float, default=5.0)
    ap.add_argument("--n-perturb", type=int, default=4)
    ap.add_argument("--epsilon", type=float, default=5.0)
    ap.add_argument("--delta", type=float, default=0.01)
    ap.add_argument("--power", type=float, default=100.0)
    ap.add_argument("--n0", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--eval-every", type=int, default=100)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=100)
    ap.add_argument("--dropout-p", type=float, default=0.0,
                    help="per-round client dropout probability")
    ap.add_argument("--straggler-p", type=float, default=0.0)
    ap.add_argument("--elastic", default=None,
                    help="membership events: 'round:K,round:K' e.g. "
                         "'200:3,400:5'")
    ap.add_argument("--out", default=None, help="write result JSON here")
    return ap


def main() -> None:
    args = build_parser().parse_args()
    cfg = registry.get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    mechanism = args.transport or args.variant
    pz = PairZeroConfig(
        variant=args.variant, n_clients=args.clients, rounds=args.rounds,
        zo=ZOConfig(mu=args.mu, lr=args.lr, clip_gamma=args.gamma,
                    n_perturb=args.n_perturb),
        channel=ChannelConfig(n0=args.n0, power=args.power,
                              d=cfg.param_count(),
                              model=args.channel, rician_k=args.rician_k,
                              ar1_rho=args.ar1_rho,
                              phase_err_std=args.csi_phase_err,
                              outage_db=args.outage_db,
                              cell_radius=args.cell_radius),
        dp=DPConfig(epsilon=args.epsilon, delta=args.delta),
        power=PowerControlConfig(scheme=args.scheme),
        transport=TransportConfig(mechanism=mechanism, scheme=args.scheme,
                                  quant_bits=args.quant_bits),
        seed=args.seed)

    pipe = FederatedPipeline(
        task=args.task,
        spec=TaskSpec(args.task, cfg.vocab_size, args.seq_len),
        n_clients=args.clients, per_client_batch=args.batch, seed=args.seed,
        frontend_tokens=cfg.frontend.n_frontend_tokens,
        d_model=cfg.d_model)

    fault = None
    if args.dropout_p or args.straggler_p:
        fault = FaultModel(args.clients, dropout_p=args.dropout_p,
                           straggler_p=args.straggler_p, seed=args.seed)
    elastic = None
    if args.elastic:
        events = tuple(tuple(int(v) for v in e.split(":"))
                       for e in args.elastic.split(","))
        elastic = ElasticSchedule(args.clients, events=events)

    def log(t, metrics):
        if t % 50 == 0:
            print(f"round {t:5d} loss {metrics['loss']:.4f}", flush=True)

    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_client_mesh
        mesh = make_client_mesh(args.mesh, n_clients=args.clients)
        print(f"client mesh: {dict(mesh.shape)} over "
              f"{mesh.devices.size} devices", flush=True)

    res = fedsim.run(cfg, pz, pipe, rounds=args.rounds,
                     engine=args.engine, chunk_rounds=args.chunk_rounds,
                     eval_every=args.eval_every,
                     checkpoint_dir=args.checkpoint_dir,
                     checkpoint_every=args.checkpoint_every,
                     fault=fault, elastic=elastic, dtype=jnp.float32,
                     mesh=mesh, overlap=not args.no_overlap,
                     on_round=log)

    summary = {
        "arch": cfg.name, "transport": mechanism, "scheme": args.scheme,
        "channel": args.channel or "rayleigh",
        "engine": args.engine,
        "mesh": dict(mesh.shape) if mesh is not None else None,
        "rounds": res.steps,
        "uplink_bits": res.uplink_bits,
        "final_loss": res.losses[-1] if res.losses else None,
        "accuracies": res.accuracies,
        "privacy_spent": res.privacy_spent,
        "privacy_budget": res.privacy_budget,
        "wall_time_s": round(res.wall_time_s, 1),
        "prep_stall_s": round(res.prep_stall_s, 3),
        "ckpt_stall_s": round(res.ckpt_stall_s, 3),
        "resumed_from": res.resumed_from,
    }
    print(json.dumps(summary, indent=2))
    if args.out:
        with open(args.out, "w") as f:
            json.dump({**summary, "losses": res.losses}, f)


if __name__ == "__main__":
    main()
