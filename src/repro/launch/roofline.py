"""Roofline derivation from compiled dry-run artifacts.

Why probes: XLA's cost_analysis counts a `while` (lax.scan) body ONCE, not
×trip-count — so the full scanned program under-reports FLOPs by ~n_layers.
Instead we compile small per-block PROBE programs with the *same shardings
and activation shapes* as one trip of each scan, read their compiled
cost_analysis + collective bytes, and scale by the statically-known trip
counts. The full program remains the compile/memory deliverable; probes are
the FLOPs/bytes/collectives ledger — and a fast feedback loop for §Perf.

Probes deliberately use the materialized-attention path (`impl="xla_full"`):
its FLOPs equal the chunked/fused path (same matmuls, different order), and
it contains no inner scan to undercount.

Roofline terms (per assignment; TPU v5e constants):
    compute    = FLOPs_total  / (chips × 197e12)
    memory     = bytes_total  / (chips × 819e9)
    collective = coll_bytes   / (chips × 50e9)
FLOPs_total/bytes_total are global (per-device probe numbers × chips);
collective bytes are summed over every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute operand in the per-device
program, × chips (a link-bytes proxy; per-op breakdown is recorded so the
dominant collective is attributable).
"""
from __future__ import annotations

import dataclasses
import re
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (ModelConfig, ShapeConfig, TPU_V5E,
                                HardwareSpec)
from repro.models import registry
from repro.runtime import sharding as shd

# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def collective_bytes(hlo_text: str) -> Tuple[float, Dict[str, float]]:
    """Sum output-shape bytes of every collective op in a per-device HLO."""
    total = 0.0
    by_op: Dict[str, float] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, op = m.group(1), m.group(2)
        nbytes = 0.0
        for sm in _SHAPE_RE.finditer(shape_str):
            dt, dims = sm.group(1), sm.group(2)
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        total += nbytes
        by_op[op] = by_op.get(op, 0.0) + nbytes
    return total, by_op


def compiled_cost(compiled) -> Tuple[float, float]:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):   # jax <= 0.4.x: one dict per device
        ca = ca[0] if ca else {}
    return float(ca.get("flops", 0.0)), float(ca.get("bytes accessed", 0.0))


# ---------------------------------------------------------------------------
# Probes
# ---------------------------------------------------------------------------

@dataclass
class Probe:
    name: str
    mult: float                      # occurrences per step
    fn: Callable                     # jittable
    args: tuple                      # ShapeDtypeStructs w/ shardings
    donate: tuple = ()


@dataclass
class ProbeCost:
    name: str
    mult: float
    flops: float                     # per device, one occurrence
    bytes_accessed: float
    coll_bytes: float
    coll_by_op: Dict[str, float]


def run_probe(probe: Probe, mesh=None,
              bf16_reduce: bool = False) -> ProbeCost:
    # input shardings ride on the ShapeDtypeStructs; the hints context lets
    # model-side `shd.hint(...)` constraints resolve during tracing
    from contextlib import nullcontext
    ctx = shd.hints(mesh, bf16_reduce) if mesh is not None else nullcontext()
    with ctx:
        lowered = jax.jit(probe.fn, donate_argnums=probe.donate).lower(
            *probe.args)
    compiled = lowered.compile()
    flops, bytes_a = compiled_cost(compiled)
    coll, by_op = collective_bytes(compiled.as_text())
    return ProbeCost(probe.name, probe.mult, flops, bytes_a, coll, by_op)


def _abstract(tree, mesh, sharding_tree):
    return jax.tree_util.tree_map(
        lambda leaf, s: jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                             sharding=s),
        tree, sharding_tree)


def _strip_layer_dim(tree):
    return jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape[1:], a.dtype), tree)


def _block_params_spec(mesh, blocks_like, serve: bool = False):
    """Shardings for one layer's params (leading L stripped)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(blocks_like)
    out = []
    for path, leaf in flat:
        stripped = jax.ShapeDtypeStruct(leaf.shape[1:], leaf.dtype)
        out.append(NamedSharding(mesh, shd.param_spec(mesh, path, stripped,
                                                      serve)))
    return jax.tree_util.tree_unflatten(treedef, out)


def _act_sds(mesh, shape, dtype=jnp.bfloat16):
    cl = shd.client_axes(mesh)
    lead = cl if shape[0] % shd.axis_size(mesh, cl) == 0 else None
    spec = P(lead, *([None] * (len(shape) - 1)))
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def _tok_sds(mesh, shape):
    cl = shd.client_axes(mesh)
    lead = cl if shape[0] % shd.axis_size(mesh, cl) == 0 else None
    spec = P(lead, *([None] * (len(shape) - 1)))
    return jax.ShapeDtypeStruct(shape, jnp.int32,
                                sharding=NamedSharding(mesh, spec))


def _layer_cache_abstract(mesh, cache_like):
    """Per-layer cache SDS (leading L stripped) with decode shardings."""
    def one(a):
        shape = a.shape[1:]
        ndim = len(shape)
        out = [None] * ndim
        cl = shd.client_axes(mesh)
        if shape[0] % shd.axis_size(mesh, cl) == 0:
            out[0] = cl
        if ndim >= 2:
            rest = list(range(1, ndim))
            big = max(rest, key=lambda i: shape[i])
            if shape[big] % shd.axis_size(mesh, "model") == 0:
                out[big] = "model"
        return jax.ShapeDtypeStruct(shape, a.dtype,
                                    sharding=NamedSharding(mesh, P(*out)))
    return jax.tree_util.tree_map(one, cache_like)


# -------------------------- family probe builders --------------------------

def build_probes(cfg: ModelConfig, shape: ShapeConfig, mesh,
                 dtype=jnp.bfloat16, n_perturb: int = 1,
                 fused_perturbation: bool = False) -> List[Probe]:
    """Per-block probe programs for one (arch, shape) cell.

    `fused_perturbation` mirrors PairZeroConfig.fused_perturbation: the
    fused dual forward regenerates z inside the layer kernels, so the
    per-round θ-sized axpy count drops from 3 (MeZO chain: +μz, −2μz,
    restore+update) to 1 (the update) — see `_axpy_probe`."""
    kind = shape.kind
    del kind
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return _transformer_probes(cfg, shape, mesh, dtype, n_perturb,
                                   fused_perturbation)
    if fam == "ssm":
        return _ssm_probes(cfg, shape, mesh, dtype, n_perturb,
                           fused_perturbation)
    if fam == "hybrid":
        return _hybrid_probes(cfg, shape, mesh, dtype, n_perturb,
                              fused_perturbation)
    if fam == "audio":
        return _encdec_probes(cfg, shape, mesh, dtype, n_perturb,
                              fused_perturbation)
    raise ValueError(fam)


def _fwd_mult(kind: str, n_perturb: int) -> float:
    """Forward-pass multiplicity: ZO train = 2 forwards × n_perturb."""
    return 2.0 * n_perturb if kind == "train" else 1.0


def _transformer_probes(cfg, shape, mesh, dtype, n_perturb, fused=False):
    from repro.models import transformer as T
    from repro.models import layers as L

    b_tot = shape.global_batch
    s = shape.seq_len
    if cfg.frontend.kind == "vision" and shape.kind != "decode":
        s = s + cfg.frontend.n_frontend_tokens
    abs_params = registry.abstract_params(cfg, dtype)
    blk_like = _strip_layer_dim(abs_params["blocks"])
    blk_sds = _abstract(blk_like, mesh, _block_params_spec(
        mesh, abs_params["blocks"], serve=shape.kind == "decode"))
    # probe config: no inner scans (moe single dispatch group)
    pcfg = cfg
    if cfg.moe.enabled:
        pcfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, chunk=0))

    probes = []
    fm = _fwd_mult(shape.kind, n_perturb)

    if shape.kind in ("train", "prefill"):
        x_sds = _act_sds(mesh, (b_tot, s, cfg.d_model), dtype)
        positions = np.arange(s)

        def block_fn(bp, x):
            y, _ = T._block_apply(bp, x, jnp.asarray(positions), pcfg,
                                  cache=None, cache_pos=None,
                                  impl="xla_full")
            return y

        probes.append(Probe("block", fm * cfg.n_layers, block_fn,
                            (blk_sds, x_sds)))

        head_parts = {k: abs_params[k] for k in
                      ("embed", "final_norm") if k in abs_params}
        if "lm_head" in abs_params:
            head_parts["lm_head"] = abs_params["lm_head"]
        head_sds = _abstract(head_parts, mesh,
                             shd.params_sharding(mesh, head_parts))
        tok_sds = _tok_sds(mesh, (b_tot, shape.seq_len))

        def head_fn(hp, tokens, targets):
            x = L.embed(hp["embed"], tokens)
            xn = L.rmsnorm(hp["final_norm"], x, cfg.norm_eps)
            logits = L.unembed(hp.get("lm_head", hp["embed"]), xn)
            return jnp.mean(L.cross_entropy(
                logits, targets, jnp.ones_like(targets, jnp.float32)))

        probes.append(Probe("embed_head", fm, head_fn,
                            (head_sds, tok_sds, tok_sds)))
    else:  # decode
        x_sds = _act_sds(mesh, (b_tot, 1, cfg.d_model), dtype)
        cache_like = registry.serve_cache_shapes(cfg, b_tot, shape.seq_len,
                                                 dtype)
        layer_cache = _layer_cache_abstract(mesh, cache_like)
        pos_sds = jax.ShapeDtypeStruct((), jnp.int32)

        def block_fn(bp, lc, x, pos):
            y, nc = T._block_apply(bp, x, pos + jnp.arange(1), pcfg,
                                   cache=lc, cache_pos=pos, impl="xla_full")
            return y, nc

        probes.append(Probe("block_decode", float(cfg.n_layers), block_fn,
                            (blk_sds, layer_cache, x_sds, pos_sds),
                            donate=(1,)))

        head_parts = {k: abs_params[k] for k in
                      ("embed", "final_norm") if k in abs_params}
        if "lm_head" in abs_params:
            head_parts["lm_head"] = abs_params["lm_head"]
        head_sds = _abstract(head_parts, mesh,
                             shd.params_sharding(mesh, head_parts))
        tok_sds = _tok_sds(mesh, (b_tot, 1))

        def head_fn(hp, tokens):
            x = L.embed(hp["embed"], tokens)
            xn = L.rmsnorm(hp["final_norm"], x, cfg.norm_eps)
            return L.unembed(hp.get("lm_head", hp["embed"]), xn)

        probes.append(Probe("embed_head", 1.0, head_fn,
                            (head_sds, tok_sds)))
    if shape.kind == "train":
        probes.append(_axpy_probe(cfg, mesh, dtype, n_perturb, fused))
    return probes


def _axpy_probe(cfg, mesh, dtype, n_perturb, fused=False):
    """ZO perturb/update axpys: 3 per perturbation (MeZO chain), or 1 when
    the fused dual forward is on (z regenerated inside the layer kernels;
    only the model update touches θ).

    Probed on a representative stacked weight (bytes dominate; flops are
    the Box–Muller transcendentals)."""
    from repro.kernels import ops as kops
    n_params = registry.count_params(cfg)
    cl = shd.client_axes(mesh)
    row_q = shd.axis_size(mesh, cl)
    cols = 1024
    rows = -(-n_params // cols)
    rows = -(-rows // row_q) * row_q          # round up to divisibility
    rep = jax.ShapeDtypeStruct(
        (rows, cols), dtype,
        sharding=NamedSharding(mesh, P(cl, "model")))
    seed_sds = jax.ShapeDtypeStruct((), jnp.uint32)

    def fn(w, seed):
        return kops.seeded_axpy(w, seed, 1e-3, impl="xla")

    # one probe covers ~all params; 3 axpys per perturbation round in the
    # chained walk, 1 (the update) when perturbation is fused into kernels
    return Probe("zo_axpy", (1.0 if fused else 3.0) * n_perturb, fn,
                 (rep, seed_sds), donate=(0,))


def _ssm_probes(cfg, shape, mesh, dtype, n_perturb, fused=False):
    from repro.models import ssm as S
    from repro.models import layers as L

    b_tot = shape.global_batch
    s = shape.seq_len
    abs_params = registry.abstract_params(cfg, dtype)
    blk_like = _strip_layer_dim(abs_params["blocks"])
    blk_sds = _abstract(blk_like, mesh,
                        _block_params_spec(mesh, abs_params["blocks"]))
    probes = []
    fm = _fwd_mult(shape.kind, n_perturb)

    if shape.kind in ("train", "prefill"):
        chunk = min(cfg.ssm.chunk, s)
        n_chunks = s // chunk if s % chunk == 0 else 1
        if s % chunk != 0:
            chunk = s
        x_sds = _act_sds(mesh, (b_tot, chunk, cfg.d_model), dtype)

        def block_fn(bp, x):
            y, _ = S._block_apply(bp, x, cfg, state=None, impl="xla")
            return y

        probes.append(Probe("block", fm * cfg.n_layers * n_chunks,
                            block_fn, (blk_sds, x_sds)))
        probes.append(_lm_head_probe(cfg, shape, mesh, dtype, fm,
                                     abs_params))
    else:
        x_sds = _act_sds(mesh, (b_tot, 1, cfg.d_model), dtype)
        state_like = registry.serve_cache_shapes(cfg, b_tot, shape.seq_len,
                                                 dtype)
        layer_state = _layer_cache_abstract(mesh, state_like)

        def block_fn(bp, st, x):
            y, ns = S._block_apply(bp, x, cfg, state=st, impl="xla")
            return y, ns

        probes.append(Probe("block_decode", float(cfg.n_layers), block_fn,
                            (blk_sds, layer_state, x_sds), donate=(1,)))
        probes.append(_lm_head_probe(cfg, shape, mesh, dtype, 1.0,
                                     abs_params, decode=True))
    if shape.kind == "train":
        probes.append(_axpy_probe(cfg, mesh, dtype, n_perturb, fused))
    return probes


def _lm_head_probe(cfg, shape, mesh, dtype, mult, abs_params, decode=False,
                   embed_key="embed", norm_key="final_norm"):
    from repro.models import layers as L
    b_tot = shape.global_batch
    s = 1 if decode else shape.seq_len
    head_parts = {embed_key: abs_params[embed_key],
                  norm_key: abs_params[norm_key]}
    if "lm_head" in abs_params:
        head_parts["lm_head"] = abs_params["lm_head"]
    head_sds = _abstract(head_parts, mesh,
                         shd.params_sharding(mesh, head_parts))
    tok_sds = _tok_sds(mesh, (b_tot, s))

    def head_fn(hp, tokens, targets):
        x = L.embed(hp[embed_key], tokens)
        xn = L.rmsnorm(hp[norm_key], x, cfg.norm_eps)
        logits = L.unembed(hp.get("lm_head", hp[embed_key]), xn)
        return jnp.mean(L.cross_entropy(
            logits, targets, jnp.ones_like(targets, jnp.float32)))

    return Probe("embed_head", mult, head_fn, (head_sds, tok_sds, tok_sds))


def _hybrid_probes(cfg, shape, mesh, dtype, n_perturb, fused=False):
    from repro.models import hybrid as H

    b_tot = shape.global_batch
    s = shape.seq_len
    abs_params = registry.abstract_params(cfg, dtype)
    n_groups = abs_params["groups"]["a"]["norm"]["g"].shape[0]
    n_tail = len(abs_params["tail"])
    r_like = _strip_layer_dim(abs_params["groups"]["r1"])
    r_sds = _abstract(r_like, mesh,
                      _block_params_spec(mesh, abs_params["groups"]["r1"]))
    a_like = _strip_layer_dim(abs_params["groups"]["a"])
    a_sds = _abstract(a_like, mesh,
                      _block_params_spec(mesh, abs_params["groups"]["a"]))
    probes = []
    fm = _fwd_mult(shape.kind, n_perturb)

    if shape.kind in ("train", "prefill"):
        x_sds = _act_sds(mesh, (b_tot, s, cfg.d_model), dtype)
        positions = np.arange(s)

        def r_fn(bp, x):
            y, _ = H._rglru_block_apply(bp, x, cfg, impl="xla")
            return y

        def a_fn(bp, x):
            y, _ = H._attn_block_apply(bp, x, jnp.asarray(positions), cfg,
                                       impl="xla_full")
            return y

        probes.append(Probe("rglru_block", fm * (2 * n_groups + n_tail),
                            r_fn, (r_sds, x_sds)))
        probes.append(Probe("attn_block", fm * n_groups, a_fn,
                            (a_sds, x_sds)))
        probes.append(_lm_head_probe(cfg, shape, mesh, dtype, fm,
                                     abs_params))
    else:
        x_sds = _act_sds(mesh, (b_tot, 1, cfg.d_model), dtype)
        state_like = registry.serve_cache_shapes(cfg, b_tot, shape.seq_len,
                                                 dtype)
        r_state = _layer_cache_abstract(mesh, {
            "lru": state_like["r1"]["lru"], "conv": state_like["r1"]["conv"]})
        kv_state = _layer_cache_abstract(mesh, state_like["attn"])
        pos_sds = jax.ShapeDtypeStruct((), jnp.int32)

        def r_fn(bp, st, x):
            y, ns = H._rglru_block_apply(bp, x, cfg, state=st, impl="xla")
            return y, ns

        def a_fn(bp, kv, x, pos):
            return H._attn_rolling(bp, x, pos + jnp.arange(1), cfg, kv, pos)

        probes.append(Probe("rglru_decode", float(2 * n_groups + n_tail),
                            r_fn, (r_sds, r_state, x_sds), donate=(1,)))
        probes.append(Probe("attn_decode", float(n_groups), a_fn,
                            (a_sds, kv_state, x_sds, pos_sds), donate=(1,)))
        probes.append(_lm_head_probe(cfg, shape, mesh, dtype, 1.0,
                                     abs_params, decode=True))
    if shape.kind == "train":
        probes.append(_axpy_probe(cfg, mesh, dtype, n_perturb, fused))
    return probes


def _encdec_probes(cfg, shape, mesh, dtype, n_perturb, fused=False):
    from repro.models import encdec as E
    from repro.models import layers as L

    b_tot = shape.global_batch
    s = shape.seq_len
    n_frames = cfg.frontend.n_frontend_tokens
    abs_params = registry.abstract_params(cfg, dtype)
    enc_like = _strip_layer_dim(abs_params["enc_blocks"])
    enc_sds = _abstract(enc_like, mesh,
                        _block_params_spec(mesh, abs_params["enc_blocks"]))
    dec_like = _strip_layer_dim(abs_params["dec_blocks"])
    dec_sds = _abstract(dec_like, mesh,
                        _block_params_spec(mesh, abs_params["dec_blocks"]))
    n_enc = cfg.n_encoder_layers or cfg.n_layers
    probes = []
    fm = _fwd_mult(shape.kind, n_perturb)

    frames_sds = _act_sds(mesh, (b_tot, n_frames, cfg.d_model), dtype)

    if shape.kind in ("train", "prefill"):
        x_sds = _act_sds(mesh, (b_tot, s, cfg.d_model), dtype)
        positions_e = np.arange(n_frames)
        positions_d = np.arange(s)

        def enc_fn(bp, x):
            hn = L.rmsnorm(bp["ln1"], x, cfg.norm_eps)
            a, _ = L.gqa_attend(bp["attn"], hn, jnp.asarray(positions_e),
                                cfg, causal=False, impl="xla_full")
            h = x + a
            return h + L.mlp(bp["mlp"],
                             L.rmsnorm(bp["ln2"], h, cfg.norm_eps))

        def dec_fn(bp, x, enc_out):
            y, _ = E._dec_block_apply(bp, x, enc_out,
                                      jnp.asarray(positions_d), cfg,
                                      impl="xla_full")
            return y

        probes.append(Probe("enc_block", fm * n_enc, enc_fn,
                            (enc_sds, frames_sds)))
        probes.append(Probe("dec_block", fm * cfg.n_layers, dec_fn,
                            (dec_sds, x_sds, frames_sds)))
        probes.append(_lm_head_probe(cfg, shape, mesh, dtype, fm,
                                     abs_params, embed_key="dec_embed",
                                     norm_key="dec_norm"))
    else:
        x_sds = _act_sds(mesh, (b_tot, 1, cfg.d_model), dtype)
        cache_like = registry.serve_cache_shapes(cfg, b_tot, shape.seq_len,
                                                 dtype)
        layer_cache = _layer_cache_abstract(mesh, cache_like)
        pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
        hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim()

        def dec_fn(bp, lc, x, pos):
            b = x.shape[0]
            s_ = 1
            hn = L.rmsnorm(bp["ln1"], x, cfg.norm_eps)
            q = L.dense({"w": bp["self_attn"]["wq"]}, hn).reshape(
                b, s_, hq, hd)
            k = L.dense({"w": bp["self_attn"]["wk"]}, hn).reshape(
                b, s_, hkv, hd)
            v = L.dense({"w": bp["self_attn"]["wv"]}, hn).reshape(
                b, s_, hkv, hd)
            sk = jax.lax.dynamic_update_slice(
                lc["self_k"], k.astype(lc["self_k"].dtype),
                (0, pos, 0, 0))
            sv = jax.lax.dynamic_update_slice(
                lc["self_v"], v.astype(lc["self_v"].dtype),
                (0, pos, 0, 0))
            a = L.decode_attend(q, sk, sv, pos + jnp.arange(s_))
            h = x + L.dense({"w": bp["self_attn"]["wo"]},
                            a.reshape(b, s_, hq * hd))
            hx = L.rmsnorm(bp["ln_x"], h, cfg.norm_eps)
            qx = L.dense({"w": bp["cross_attn"]["wq"]}, hx).reshape(
                b, s_, hq, hd)
            ax = L.decode_attend(qx, lc["cross_k"], lc["cross_v"],
                                 jnp.full((s_,), n_frames - 1))
            h = h + L.dense({"w": bp["cross_attn"]["wo"]},
                            ax.reshape(b, s_, hq * hd))
            h = h + L.mlp(bp["mlp"], L.rmsnorm(bp["ln2"], h, cfg.norm_eps))
            return h, {"self_k": sk, "self_v": sv}

        probes.append(Probe("dec_block_decode", float(cfg.n_layers), dec_fn,
                            (dec_sds, layer_cache, x_sds, pos_sds),
                            donate=(1,)))
        probes.append(_lm_head_probe(cfg, shape, mesh, dtype, 1.0,
                                     abs_params, decode=True,
                                     embed_key="dec_embed",
                                     norm_key="dec_norm"))
    if shape.kind == "train":
        probes.append(_axpy_probe(cfg, mesh, dtype, n_perturb, fused))
    return probes


# ---------------------------------------------------------------------------
# Aggregation
# ---------------------------------------------------------------------------

@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_total: float               # global
    bytes_total: float
    coll_bytes_total: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float               # 6·N·D convention
    useful_ratio: float              # MODEL_FLOPS / HLO_FLOPs
    probe_costs: List[Dict]
    coll_by_op: Dict[str, float]

    def to_dict(self):
        return dataclasses.asdict(self)


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6·N_active·D (train) / 2·N·D (prefill) / 2·N·B (decode, per step)."""
    n_act = registry.count_params(cfg, active_only=True)
    if shape.kind == "train":
        return 6.0 * n_act * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_act * shape.global_batch * shape.seq_len
    return 2.0 * n_act * shape.global_batch


def aggregate(arch: str, shape: ShapeConfig, mesh_name: str, chips: int,
              costs: List[ProbeCost], cfg: ModelConfig,
              hw: HardwareSpec = TPU_V5E,
              extra_coll_bytes: float = 0.0) -> RooflineReport:
    flops_dev = sum(c.flops * c.mult for c in costs)
    bytes_dev = sum(c.bytes_accessed * c.mult for c in costs)
    coll_dev = sum(c.coll_bytes * c.mult for c in costs) + extra_coll_bytes
    by_op: Dict[str, float] = {}
    for c in costs:
        for op, v in c.coll_by_op.items():
            by_op[op] = by_op.get(op, 0.0) + v * c.mult * chips

    flops_total = flops_dev * chips
    bytes_total = bytes_dev * chips
    coll_total = coll_dev * chips
    compute_s = flops_total / (chips * hw.peak_flops)
    memory_s = bytes_total / (chips * hw.hbm_bw)
    collective_s = coll_total / (chips * hw.ici_bw)
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    return RooflineReport(
        arch=arch, shape=shape.name, mesh=mesh_name, chips=chips,
        flops_total=flops_total, bytes_total=bytes_total,
        coll_bytes_total=coll_total, compute_s=compute_s,
        memory_s=memory_s, collective_s=collective_s, dominant=dominant,
        model_flops=mf,
        useful_ratio=mf / flops_total if flops_total else 0.0,
        probe_costs=[dataclasses.asdict(c) for c in costs],
        coll_by_op=by_op)
