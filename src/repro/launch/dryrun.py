"""Multi-pod dry-run: lower + compile every (architecture × shape × mesh).

THE proof that the distribution config is coherent without real hardware:
for each of the 40 assigned cells this compiles the *actual* step the system
would run (the ZO pAirZero train step for train shapes; serve prefill/decode
for inference shapes) against the production mesh — (16,16) single-pod and
(2,16,16) multi-pod — using ShapeDtypeStruct stand-ins (zero allocation).

Per cell it records: compile success, per-device memory analysis, raw
cost_analysis, the collective schedule (parsed from compiled HLO), and —
single-pod only — the probe-derived roofline terms (launch/roofline.py).

Usage:
    python -m repro.launch.dryrun                         # everything
    python -m repro.launch.dryrun --arch yi-6b --shape train_4k
    python -m repro.launch.dryrun --mesh multi            # multi-pod only
    python -m repro.launch.dryrun --variant fo            # FO baseline cells
    python -m repro.launch.dryrun --shard-clients         # shard_map'd step
    python -m repro.launch.dryrun --audit                 # capture variant
Results append incrementally to --out (default results/dryrun.json).
"""
# The VERY FIRST lines, before ANY other import (jax locks the device count
# on first init):
import os  # noqa: E402

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

import argparse        # noqa: E402
import dataclasses     # noqa: E402
import json            # noqa: E402
import time            # noqa: E402
import traceback       # noqa: E402
from typing import Dict, Optional, Tuple  # noqa: E402

import jax             # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ASSIGNED_ARCHS, SHAPES_BY_NAME  # noqa: E402
from repro.configs.base import (ModelConfig, PairZeroConfig, ShapeConfig,
                                ZOConfig)  # noqa: E402
from repro.core import pairzero  # noqa: E402
from repro.launch import roofline as rl  # noqa: E402
from repro.launch.mesh import make_production_mesh, n_clients  # noqa: E402
from repro.models import registry  # noqa: E402
from repro.optim import fo as fo_opt  # noqa: E402
from repro.runtime import sharding as shd  # noqa: E402

DTYPE = jnp.bfloat16


def audit_applies(shape_name: str, variant: str, audit: bool) -> bool:
    """The eavesdropper-capture variant exists only for the ZO train step."""
    return (audit and SHAPES_BY_NAME[shape_name].kind == "train"
            and variant == "zo")


def make_cell_id(arch: str, shape_name: str, mesh_name: str, variant: str,
                 *, bf16_reduce: bool = False, shard_clients: bool = False,
                 audit: bool = False) -> str:
    """The one cell-id spelling, shared by run_cell and the done-skip
    resume in main — a suffix added in only one place would make resume
    recompute finished cells or skip cells whose variant never lowered.
    `|audit` marks only cells that actually compile the capture variant."""
    return (f"{arch}|{shape_name}|{mesh_name}|{variant}"
            + ("|bf16r" if bf16_reduce else "")
            + ("|smap" if shard_clients else "")
            + ("|audit" if audit_applies(shape_name, variant, audit)
               else ""))


def skip_reason(cfg: ModelConfig, shape: ShapeConfig) -> Optional[str]:
    if shape.name == "long_500k" and not cfg.subquadratic:
        return ("long_500k requires sub-quadratic decode state; "
                f"{cfg.name} is full-attention (see DESIGN.md skip list)")
    return None


# ---------------------------------------------------------------------------
# input_specs — ShapeDtypeStruct stand-ins for every model input
# ---------------------------------------------------------------------------

def input_specs(arch: str, shape_name: str, mesh, *,
                variant: str = "zo") -> Tuple[Dict, Dict]:
    """(kwargs-for-step, meta). Every leaf is an abstract, sharded,
    weak-type-correct ShapeDtypeStruct — no device allocation anywhere."""
    cfg = registry.get_arch(arch)
    shape = SHAPES_BY_NAME[shape_name]
    k = n_clients(mesh)
    abs_params = registry.abstract_params(cfg, DTYPE)
    # decode cells use the serve-time EP-resident expert layout (§Perf)
    params = jax.tree_util.tree_map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        abs_params, shd.params_sharding(mesh, abs_params,
                                        serve=shape.kind == "decode"))
    meta = {"cfg": cfg, "shape": shape, "k": k}

    if shape.kind == "train":
        batch_like = registry.train_batch_shapes(cfg, shape, k)
        batch = jax.tree_util.tree_map(
            lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
            batch_like, shd.batch_sharding(mesh, batch_like))
        ctl_like = pairzero.control_spec(k)
        ctl = jax.tree_util.tree_map(
            lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
            ctl_like, shd.control_sharding(mesh, ctl_like))
        return {"params": params, "batch": batch, "ctl": ctl}, meta

    b = shape.global_batch
    if shape.kind == "prefill":
        toks = jax.ShapeDtypeStruct((b, shape.seq_len), jnp.int32)
        tokens = jax.ShapeDtypeStruct(
            toks.shape, toks.dtype,
            sharding=shd.serve_batch_sharding(mesh, toks))
        spec = {"params": params, "tokens": tokens}
        if cfg.frontend.kind != "none":
            fr = jax.ShapeDtypeStruct(
                (b, cfg.frontend.n_frontend_tokens, cfg.d_model), DTYPE)
            spec["frontend"] = jax.ShapeDtypeStruct(
                fr.shape, fr.dtype,
                sharding=shd.serve_batch_sharding(mesh, fr))
        return spec, meta

    # decode: one new token against a seq_len-deep cache/state
    toks = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    tokens = jax.ShapeDtypeStruct(
        toks.shape, toks.dtype,
        sharding=shd.serve_batch_sharding(mesh, toks))
    cache_like = registry.serve_cache_shapes(cfg, b, shape.seq_len, DTYPE)
    cache = jax.tree_util.tree_map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        cache_like, shd.cache_sharding(mesh, cache_like))
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return {"params": params, "cache": cache, "tokens": tokens,
            "pos": pos}, meta


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def build_step(cfg: ModelConfig, shape: ShapeConfig, k: int,
               variant: str = "zo", shard_clients_mesh=None,
               audit: bool = False):
    """Returns (fn, donate_argnums) for this cell.

    `shard_clients_mesh` compiles the shard_map'd ZO step instead: clients
    manual over (pod, data), 'model' under GSPMD auto — the dry-run proof
    that the cross-device psum aggregate lowers on the production mesh.
    `audit` compiles the eavesdropper-capture variant (repro.privacy): the
    step additionally emits the adversary's obs_* metrics — the dry-run
    proof that observation capture lowers at production scale too."""
    mod = registry.get_module(cfg)
    if shape.kind == "train":
        if variant == "zo":
            adversary = None
            if audit:
                from repro.privacy import Adversary
                adversary = Adversary()
            pz = PairZeroConfig(variant="analog", n_clients=k,
                                zo=ZOConfig(mu=1e-3, lr=5e-7,
                                            clip_gamma=100.0))
            step = pairzero.make_zo_step(cfg, pz, impl="xla",
                                         scheme="solution",
                                         mesh=shard_clients_mesh,
                                         adversary=adversary)
            return (lambda params, batch, ctl: step(params, batch, ctl)), (0,)
        if variant in ("fo", "fo_sgd"):
            opt = fo_opt.SGD(lr=1e-3) if variant == "fo_sgd" \
                else fo_opt.Adam(lr=1e-4)
            fostep = pairzero.make_fo_step(cfg, opt, impl="xla")

            def fo_with_init(params, batch, ctl):
                opt_state = opt.init(params)
                return fostep(params, opt_state, batch, ctl)

            return fo_with_init, (0,)
        raise ValueError(variant)

    if shape.kind == "prefill":
        if cfg.family == "audio":
            return (lambda params, tokens, frontend:
                    mod.prefill(params, cfg, tokens, frontend,
                                impl="xla")), ()
        if cfg.family == "vlm":
            return (lambda params, tokens, frontend:
                    mod.prefill(params, cfg, tokens,
                                prefix_embeds=frontend, impl="xla")), ()
        return (lambda params, tokens:
                mod.prefill(params, cfg, tokens, impl="xla")), ()

    # decode
    return (lambda params, cache, tokens, pos:
            mod.decode_step(params, cfg, cache, tokens, pos,
                            impl="xla")), (1,)


# ---------------------------------------------------------------------------
# cell runner
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, multi_pod: bool,
             variant: str = "zo", with_roofline: bool = True,
             bf16_reduce: bool = False, shard_clients: bool = False,
             audit: bool = False, cost: bool = False) -> Dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    audit = audit_applies(shape_name, variant, audit)
    cell_id = make_cell_id(arch, shape_name, mesh_name, variant,
                           bf16_reduce=bf16_reduce,
                           shard_clients=shard_clients, audit=audit)
    cfg = registry.get_arch(arch)
    shape = SHAPES_BY_NAME[shape_name]
    out: Dict = {"cell": cell_id, "arch": arch, "shape": shape_name,
                 "mesh": mesh_name, "variant": variant,
                 "params_b": registry.count_params(cfg),
                 "active_params_b": registry.count_params(cfg, True)}

    reason = skip_reason(cfg, shape)
    if reason:
        out["status"] = "skipped"
        out["reason"] = reason
        return out

    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        if shard_clients and shape.kind == "train" and variant == "zo":
            # jax-0.4.x workaround: partial-auto (manual clients + auto TP)
            # aborts XLA on large TP-sharded models, so the shard_map cell
            # compiles on the client-axes submesh (see mesh.client_submesh)
            from repro.launch.mesh import client_submesh
            mesh = client_submesh(mesh)
            out["client_submesh"] = True
        chips = mesh.devices.size
        specs, meta = input_specs(arch, shape_name, mesh, variant=variant)
        fn, donate = build_step(
            cfg, shape, meta["k"], variant,
            shard_clients_mesh=mesh if shard_clients
            and shape.kind == "train" and variant == "zo" else None,
            audit=audit)
        with shd.hints(mesh, bf16_reduce):
            lowered = jax.jit(fn, donate_argnums=donate).lower(
                **{k2: v for k2, v in specs.items()})
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        ma = compiled.memory_analysis()
        flops, bytes_a = rl.compiled_cost(compiled)
        coll, coll_by_op = rl.collective_bytes(compiled.as_text())
        out.update({
            "status": "ok",
            "chips": int(chips),
            "n_clients": meta["k"],
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "memory": {
                "argument_bytes_per_device": int(ma.argument_size_in_bytes),
                "output_bytes_per_device": int(ma.output_size_in_bytes),
                "temp_bytes_per_device": int(ma.temp_size_in_bytes),
                "alias_bytes_per_device": int(ma.alias_size_in_bytes),
                "peak_bytes_per_device": int(ma.argument_size_in_bytes
                                             + ma.output_size_in_bytes
                                             + ma.temp_size_in_bytes
                                             - ma.alias_size_in_bytes),
            },
            "raw_cost_analysis": {"flops_per_device_scan_once": flops,
                                  "bytes_per_device_scan_once": bytes_a},
            "full_program_collectives": {"bytes_per_device_scan_once": coll,
                                         "by_op": coll_by_op},
        })

        if cost:
            # the run-time introspection view (repro.obs.hlo) of the same
            # compiled cell — census with per-op counts/group sizes on
            # top of the raw numbers above, printed compile-only
            from repro.obs import hlo as _hlo
            stats = _hlo.analyze_compiled(compiled)
            out["cost_stats"] = stats.to_dict()
            print(_hlo.describe(stats, indent="    "), flush=True)

        if with_roofline and not multi_pod:
            probes = rl.build_probes(cfg, shape, mesh, DTYPE)
            costs = [rl.run_probe(p, mesh, bf16_reduce) for p in probes]
            report = rl.aggregate(arch, shape, mesh_name, int(chips), costs,
                                  cfg)
            out["roofline"] = report.to_dict()
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        out["status"] = "failed"
        out["error"] = f"{type(e).__name__}: {e}"
        out["traceback"] = traceback.format_exc()[-4000:]
    out["wall_s"] = round(time.time() - t0, 2)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default all)")
    ap.add_argument("--shape", default=None,
                    help="one shape name (default all four)")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--variant", default="zo",
                    choices=["zo", "fo", "fo_sgd"])
    ap.add_argument("--no-roofline", action="store_true")
    ap.add_argument("--bf16-reduce", action="store_true",
                    help="bf16 TP psums (§Perf beyond-paper optimization)")
    ap.add_argument("--shard-clients", action="store_true",
                    help="compile the shard_map'd ZO step (clients manual "
                         "over pod/data, model under GSPMD auto) — proves "
                         "the cross-device psum aggregate lowers on the "
                         "production mesh (train cells only)")
    ap.add_argument("--audit", action="store_true",
                    help="compile the eavesdropper-capture step variant "
                         "(repro.privacy observation capture as obs_* "
                         "metrics) — proves the privacy subsystem's "
                         "capture path lowers at production scale "
                         "(train cells only)")
    ap.add_argument("--cost", action="store_true",
                    help="print the repro.obs.hlo introspection of each "
                         "compiled cell (flops / memory / collective "
                         "census) and record it as cost_stats — "
                         "compile-only, nothing executes")
    ap.add_argument("--out", default="results/dryrun.json")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ASSIGNED_ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES_BY_NAME)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {r["cell"] for r in results if r.get("status") == "ok"
            or r.get("status") == "skipped"}

    for arch in archs:
        for shape_name in shapes:
            for multi in meshes:
                mesh_name = "pod2x16x16" if multi else "pod16x16"
                cell_id = make_cell_id(arch, shape_name, mesh_name,
                                       args.variant,
                                       bf16_reduce=args.bf16_reduce,
                                       shard_clients=args.shard_clients,
                                       audit=args.audit)
                if cell_id in done:
                    print(f"[skip-done] {cell_id}", flush=True)
                    continue
                print(f"[cell] {cell_id} ...", flush=True)
                r = run_cell(arch, shape_name, multi, args.variant,
                             with_roofline=not args.no_roofline,
                             bf16_reduce=args.bf16_reduce,
                             shard_clients=args.shard_clients,
                             audit=args.audit, cost=args.cost)
                print(f"  -> {r['status']} ({r.get('wall_s', 0)}s)"
                      + (f" err={r.get('error', '')[:200]}"
                         if r["status"] == "failed" else ""), flush=True)
                results = [x for x in results if x["cell"] != cell_id]
                results.append(r)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)

    ok = sum(1 for r in results if r["status"] == "ok")
    sk = sum(1 for r in results if r["status"] == "skipped")
    fail = sum(1 for r in results if r["status"] == "failed")
    print(f"\ndone: {ok} ok, {sk} skipped, {fail} failed -> {args.out}")


if __name__ == "__main__":
    main()
