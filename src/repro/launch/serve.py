"""Serving launcher: batched autoregressive decode for any --arch.

    PYTHONPATH=src python -m repro.launch.serve \
        --arch mamba2-370m --reduced --batch 4 --prompt-len 32 --gen 16

Runs prefill once, then a jitted decode loop with the architecture's native
state (KV cache / compressed MLA latents / SSD state / rolling window). The
same decode_step is what the decode_* dry-run cells lower at production
shapes.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import registry


def serve_loop(cfg, params, tokens, gen_steps: int, *, extra_cap: int = 0,
               impl=None):
    """Prefill + greedy decode. tokens: [B, S_prompt] → [B, S_prompt+gen]."""
    mod = registry.get_module(cfg)
    b, s = tokens.shape
    dtype = jax.tree_util.tree_leaves(params)[0].dtype

    if cfg.family in ("ssm", "hybrid"):
        logits, state = mod.prefill(params, cfg, jnp.asarray(tokens),
                                    impl=impl)
        cache = state
    elif cfg.family == "audio":
        frames = jnp.zeros((b, cfg.frontend.n_frontend_tokens, cfg.d_model),
                           dtype)
        logits, small = mod.prefill(params, cfg, jnp.asarray(tokens), frames,
                                    impl=impl)
        cache = mod.init_cache(cfg, b, s + gen_steps,
                               cfg.frontend.n_frontend_tokens, dtype=dtype)
        cache = jax.tree_util.tree_map(
            lambda big, sm: jax.lax.dynamic_update_slice(
                big, sm.astype(big.dtype), (0,) * big.ndim)
            if big.shape != sm.shape else sm, cache, small)
    else:
        prefix = None
        if cfg.family == "vlm":
            prefix = jnp.zeros((b, cfg.frontend.n_frontend_tokens,
                                cfg.d_model), dtype)
        logits, small = mod.prefill(params, cfg, jnp.asarray(tokens),
                                    prefix_embeds=prefix, impl=impl)
        s_tot = s + (prefix.shape[1] if prefix is not None else 0)
        cache = mod.init_cache(cfg, b, s_tot + gen_steps, dtype=dtype)
        cache = jax.tree_util.tree_map(
            lambda big, sm: jax.lax.dynamic_update_slice(
                big, sm.astype(big.dtype), (0,) * big.ndim), cache, small)
        s = s_tot

    step = jax.jit(
        lambda p, c, t, pos: mod.decode_step(p, cfg, c, t, pos, impl=impl),
        donate_argnums=(1,))
    out = [np.asarray(jnp.argmax(logits[:, -1:], axis=-1))]
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    for i in range(gen_steps - 1):
        logits, cache = step(params, cache, tok, jnp.int32(s + i))
        tok = jnp.argmax(logits[:, -1:][..., 0, :], axis=-1)[:, None] \
            .astype(jnp.int32)
        out.append(np.asarray(tok))
    return np.concatenate([tokens] + out, axis=1)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="mamba2-370m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = registry.get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = registry.init_params(jax.random.key(args.seed), cfg,
                                  jnp.float32)
    rng = np.random.default_rng(args.seed)
    tokens = rng.integers(8, cfg.vocab_size,
                          size=(args.batch, args.prompt_len)).astype(np.int32)
    t0 = time.time()
    out = serve_loop(cfg, params, tokens, args.gen)
    dt = time.time() - t0
    print(f"arch={cfg.name} generated {args.gen} tokens x batch "
          f"{args.batch} in {dt:.2f}s "
          f"({args.gen * args.batch / dt:.1f} tok/s)")
    print("sample row:", out[0, -args.gen:])


if __name__ == "__main__":
    main()
