"""Synthetic task generators (offline stand-ins for SST-2 / SQuAD / LM).

The container has no datasets, so the paper's two evaluation tasks are
replaced by synthetic analogues with the same *shape of difficulty*:

  * sst2  — sentence-level binary classification: sequences carry a latent
    sentiment (an excess of "positive" vs "negative" lexicon tokens); the
    model must emit the correct verdict token at the answer position.
    Metric: accuracy (as in the paper's SST-2 plots).
  * squad — extraction: a context contains a KEY marker followed by an
    answer token; after the QUESTION marker the model must reproduce the
    answer token. Metric: exact match.
  * lm    — generic next-token modeling over a seeded order-1 Markov chain
    (used for throughput/LM benchmarks).

All generation is purely seeded numpy → runs are reproducible and the
federated split can be made non-IID (Dirichlet over lexicon topics), matching
the heterogeneity that FL papers care about.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

# reserved token ids (low range)
PAD, CLS, QUESTION, KEY, POS_VERDICT, NEG_VERDICT = 0, 1, 2, 3, 4, 5
N_RESERVED = 8


@dataclass
class TaskSpec:
    name: str
    vocab_size: int
    seq_len: int
    # non-IID knob: per-client Dirichlet concentration over lexicon halves
    dirichlet_alpha: float = 1e9   # → IID by default


def _lexicons(vocab: int):
    usable = np.arange(N_RESERVED, vocab)
    half = len(usable) // 2
    return usable[:half], usable[half:]


def sample_sst2(spec: TaskSpec, rng: np.random.Generator, n: int,
                client_bias: Optional[np.ndarray] = None) -> Dict:
    """Binary sentiment: label = which lexicon dominates the sequence."""
    pos_lex, neg_lex = _lexicons(spec.vocab_size)
    s = spec.seq_len
    tokens = np.zeros((n, s), dtype=np.int32)
    targets = np.zeros((n, s), dtype=np.int32)
    mask = np.zeros((n, s), dtype=np.float32)
    labels = rng.integers(0, 2, size=n)
    for i in range(n):
        dom, sub = (pos_lex, neg_lex) if labels[i] else (neg_lex, pos_lex)
        # 70/30 lexicon mixture → learnable but non-trivial
        mix = rng.random(s - 2) < 0.7
        body = np.where(mix, rng.choice(dom, s - 2), rng.choice(sub, s - 2))
        tokens[i, 0] = CLS
        tokens[i, 1:-1] = body
        tokens[i, -1] = QUESTION
        targets[i, -1] = POS_VERDICT if labels[i] else NEG_VERDICT
        mask[i, -1] = 1.0
    return {"tokens": tokens, "targets": targets, "mask": mask,
            "labels": labels.astype(np.int32)}


def sample_squad(spec: TaskSpec, rng: np.random.Generator, n: int,
                 client_bias: Optional[np.ndarray] = None) -> Dict:
    """Extraction: reproduce the token that followed the KEY marker."""
    s = spec.seq_len
    usable = np.arange(N_RESERVED, spec.vocab_size)
    tokens = rng.choice(usable, size=(n, s)).astype(np.int32)
    targets = np.zeros((n, s), dtype=np.int32)
    mask = np.zeros((n, s), dtype=np.float32)
    answers = rng.choice(usable, size=n)
    key_pos = rng.integers(1, s - 3, size=n)
    for i in range(n):
        tokens[i, key_pos[i]] = KEY
        tokens[i, key_pos[i] + 1] = answers[i]
        tokens[i, -1] = QUESTION
        targets[i, -1] = answers[i]
        mask[i, -1] = 1.0
    return {"tokens": tokens, "targets": targets, "mask": mask,
            "labels": answers.astype(np.int32)}


def sample_lm(spec: TaskSpec, rng: np.random.Generator, n: int,
              client_bias: Optional[np.ndarray] = None) -> Dict:
    """Order-1 Markov stream with a per-task random transition structure."""
    v = spec.vocab_size
    s = spec.seq_len
    # sparse deterministic-ish successor table keyed by the task seed
    succ = (np.arange(v) * 31 + 7) % (v - N_RESERVED) + N_RESERVED
    tokens = np.zeros((n, s + 1), dtype=np.int32)
    tokens[:, 0] = rng.integers(N_RESERVED, v, size=n)
    noise = rng.random((n, s)) < 0.15
    rand_tok = rng.integers(N_RESERVED, v, size=(n, s))
    for t in range(s):
        nxt = succ[tokens[:, t]]
        tokens[:, t + 1] = np.where(noise[:, t], rand_tok[:, t], nxt)
    return {"tokens": tokens[:, :-1], "targets": tokens[:, 1:],
            "mask": np.ones((n, s), dtype=np.float32),
            "labels": np.zeros(n, dtype=np.int32)}


_SAMPLERS = {"sst2": sample_sst2, "squad": sample_squad, "lm": sample_lm}


def sample(task: str, spec: TaskSpec, rng: np.random.Generator, n: int,
           client_bias=None) -> Dict:
    return _SAMPLERS[task](spec, rng, n, client_bias)


def accuracy(logits: np.ndarray, batch: Dict) -> float:
    """Answer-position accuracy (SST-2 accuracy / SQuAD exact match)."""
    pred = np.argmax(logits[:, -1], axis=-1)
    return float(np.mean(pred == batch["targets"][:, -1]))
