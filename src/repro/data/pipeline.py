"""Federated data pipeline: seeded, shardable, non-IID capable.

Every client owns a private shard generated from fold(seed, client_id) — the
same construction a real FL deployment has (data never leaves the client; the
pipeline here only ever *materializes* a client's batch on the devices that
simulate that client). Batches come out as [K, b, S] so the client axis maps
1:1 onto the (pod, data) mesh axes.

Determinism contract: batch(t) is a pure function of (seed, t, K, shape) —
checkpoint-resumed runs see the identical data stream (no iterator state to
persist), and an elastically re-joining client replays its own stream.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

from repro.data import tasks as T


@dataclass
class FederatedPipeline:
    task: str                 # sst2 | squad | lm
    spec: T.TaskSpec
    n_clients: int
    per_client_batch: int
    seed: int = 0
    frontend_tokens: int = 0  # >0 → attach stub modality embeddings
    d_model: int = 0

    def client_rng(self, client: int, t: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.seed * 1_000_003 + client) * 2_654_435_761 % (2 ** 63)
            + t)

    def batch(self, t: int) -> Dict[str, np.ndarray]:
        """Round-t global batch [K, b, S] (pure function of (seed, t))."""
        per = []
        for k in range(self.n_clients):
            rng = self.client_rng(k, t)
            per.append(T.sample(self.task, self.spec, rng,
                                self.per_client_batch))
        out = {key: np.stack([p[key] for p in per])
               for key in per[0] if key != "labels"}
        out["labels"] = np.stack([p["labels"] for p in per])
        if self.frontend_tokens > 0:
            rng = np.random.default_rng(self.seed ^ 0xF0F0 + t)
            out["prefix_embeds"] = rng.standard_normal(
                (self.n_clients, self.per_client_batch,
                 self.frontend_tokens, self.d_model)).astype(np.float32) * 0.1
        return out

    def eval_batch(self, n: int, t: int = 10 ** 9) -> Dict[str, np.ndarray]:
        """Held-out batch [n, S] (disjoint stream index range)."""
        rng = np.random.default_rng(self.seed ^ 0xE7A1 + t)
        return T.sample(self.task, self.spec, rng, n)
