"""Architecture registry: one uniform interface over all model families.

Every assigned architecture is selectable by id (--arch <id>); the registry
dispatches to the family module and provides:
  * init / loss_per_client / prefill / decode_step / serve-state init
  * exact parameter counts via jax.eval_shape (no allocation — works for the
    236B config on a laptop)
  * abstract batch/state specs used by the dry-run's input_specs()
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, hybrid, ssm, transformer, vlm

_FAMILIES = {
    "dense": transformer,
    "moe": transformer,
    "vlm": vlm,
    "audio": encdec,
    "hybrid": hybrid,
    "ssm": ssm,
}


def get_module(cfg: ModelConfig):
    try:
        return _FAMILIES[cfg.family]
    except KeyError:
        raise ValueError(
            f"unknown family {cfg.family!r} for {cfg.name}") from None


def init_params(key, cfg: ModelConfig, dtype=jnp.float32) -> Any:
    return get_module(cfg).init(key, cfg, dtype)


def abstract_params(cfg: ModelConfig, dtype=jnp.bfloat16) -> Any:
    """ShapeDtypeStruct pytree of the full parameter set (no allocation)."""
    return jax.eval_shape(
        functools.partial(get_module(cfg).init, cfg=cfg, dtype=dtype),
        jax.random.key(0))


@functools.lru_cache(maxsize=None)
def _count_params_cached(cfg: ModelConfig) -> int:
    tree = abstract_params(cfg)
    return int(sum(int(np.prod(l.shape))
                   for l in jax.tree_util.tree_leaves(tree)))


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    total = _count_params_cached(cfg)
    if active_only and cfg.moe.enabled:
        m = cfg.moe
        per_expert = 3 * cfg.d_model * m.d_expert
        inactive = cfg.n_layers * (m.n_experts - m.n_experts_per_tok) \
            * per_expert
        return total - inactive
    return total


# ---------------------------------------------------------------------------
# Abstract input specs (the dry-run's input_specs() builds on these)
# ---------------------------------------------------------------------------

def train_batch_shapes(cfg: ModelConfig, shape: ShapeConfig, n_clients: int
                       ) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract train batch: tokens/targets/mask [K, b, S] (+ stub frontend)."""
    assert shape.global_batch % n_clients == 0, \
        f"global_batch {shape.global_batch} not divisible by K={n_clients}"
    b = shape.global_batch // n_clients
    s = shape.seq_len
    spec = {
        "tokens": jax.ShapeDtypeStruct((n_clients, b, s), jnp.int32),
        "targets": jax.ShapeDtypeStruct((n_clients, b, s), jnp.int32),
        "mask": jax.ShapeDtypeStruct((n_clients, b, s), jnp.float32),
    }
    if cfg.frontend.kind != "none":
        spec["prefix_embeds"] = jax.ShapeDtypeStruct(
            (n_clients, b, cfg.frontend.n_frontend_tokens, cfg.d_model),
            jnp.bfloat16)
    return spec


def serve_cache_shapes(cfg: ModelConfig, batch: int, max_len: int,
                       dtype=jnp.bfloat16) -> Any:
    mod = get_module(cfg)
    if cfg.family == "ssm":
        return jax.eval_shape(
            lambda: mod.init_state(cfg, batch, dtype=dtype))
    if cfg.family == "hybrid":
        return jax.eval_shape(
            lambda: mod.init_state(cfg, batch, dtype=dtype))
    if cfg.family == "audio":
        return jax.eval_shape(
            lambda: mod.init_cache(cfg, batch, max_len,
                                   cfg.frontend.n_frontend_tokens,
                                   dtype=dtype))
    return jax.eval_shape(
        lambda: mod.init_cache(cfg, batch, max_len, dtype=dtype))


# ---------------------------------------------------------------------------
# Registry of architecture ids → ModelConfig builders
# ---------------------------------------------------------------------------

def get_arch(arch_id: str) -> ModelConfig:
    _ensure_configs_loaded()
    from repro.models.arch_registry import arch_builder
    return arch_builder(arch_id)()


def list_archs():
    _ensure_configs_loaded()
    from repro.models.arch_registry import registered
    return registered()


def _ensure_configs_loaded():
    # importing repro.configs registers every architecture module
    import repro.configs  # noqa: F401
