"""RecurrentGemma-style hybrid: RG-LRU recurrent blocks + local attention.

Pattern "rra" (2 recurrent : 1 local-attention) cycled over n_layers
(arXiv:2402.19427). 26 layers = 8 × (r, r, a) + (r, r) tail; the 8 full
groups are scan-stacked (one HLO body for the whole trunk), the tail is
unrolled.

Decode state is O(1): RG-LRU hidden [B, lru_width] + conv tail per recurrent
block, and a rolling `local_window`-deep KV buffer per attention block —
which is why this arch runs the long_500k cell.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops as kops
from repro.models import layers as L

_LRU_C = 8.0   # RG-LRU decay sharpness constant (paper value)


def layer_kinds(cfg: ModelConfig):
    pat = cfg.hybrid.pattern
    return [pat[i % len(pat)] for i in range(cfg.n_layers)]


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _rglru_block_init(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    w = cfg.hybrid.lru_width or d
    ks = jax.random.split(key, 7)
    return {
        "norm": L.rmsnorm_init(d, dtype),
        "lin_x": L.dense_init(ks[0], d, w, dtype),
        "lin_gate": L.dense_init(ks[1], d, w, dtype),
        "conv_w": (jax.random.normal(ks[2], (cfg.hybrid.conv1d_width, w),
                                     dtype=jnp.float32)
                   / math.sqrt(cfg.hybrid.conv1d_width)).astype(dtype),
        "w_rec_gate": L.dense_init(ks[3], w, w, dtype),
        "w_in_gate": L.dense_init(ks[4], w, w, dtype),
        "lambda_p": jnp.full((w,), 2.0, dtype=jnp.float32),  # softplus param
        "out": L.dense_init(ks[5], w, d, dtype),
        "mlp_norm": L.rmsnorm_init(d, dtype),
        "mlp": L.mlp_init(ks[6], d, cfg.d_ff, dtype),
    }


def _attn_block_init(key, cfg: ModelConfig, dtype) -> dict:
    ks = jax.random.split(key, 2)
    return {
        "norm": L.rmsnorm_init(cfg.d_model, dtype),
        "attn": L.gqa_init(ks[0], cfg, dtype),
        "mlp_norm": L.rmsnorm_init(cfg.d_model, dtype),
        "mlp": L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, dtype),
    }


def _rglru_mix(bp: dict, xn: jnp.ndarray, *, state: Optional[dict],
               impl: Optional[str]) -> Tuple[jnp.ndarray, Optional[dict]]:
    """RG-LRU temporal mixing. xn: [B, S, D_model] (already normed)."""
    xw = L.dense(bp["lin_x"], xn)
    gate = jax.nn.gelu(L.dense(bp["lin_gate"], xn).astype(jnp.float32)
                       ).astype(xw.dtype)
    conv_tail = state["conv"] if state is not None else None
    xw, new_tail = _hybrid_conv(xw, bp["conv_w"], conv_tail)

    r = jax.nn.sigmoid(L.dense(bp["w_rec_gate"], xw).astype(jnp.float32))
    i = jax.nn.sigmoid(L.dense(bp["w_in_gate"], xw).astype(jnp.float32))
    log_a = -_LRU_C * jax.nn.softplus(bp["lambda_p"])[None, None] * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    drive = (beta * i * xw.astype(jnp.float32)).astype(xw.dtype)

    h0 = state["lru"] if state is not None else None
    hs, h_last = kops.linear_recurrence(a.astype(xw.dtype), drive, h0,
                                        impl=impl)
    y = gate * hs
    new_state = None
    if state is not None:
        new_state = {"lru": h_last, "conv": new_tail}
    return L.dense_rp(bp["out"], y), new_state


def _hybrid_conv(x, w, tail):
    b, s, c = x.shape
    wlen = w.shape[0]
    if tail is None:
        tail = jnp.zeros((b, wlen - 1, c), dtype=x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)
    y = sum(xp[:, i:i + s] * w[i][None, None].astype(x.dtype)
            for i in range(wlen))
    new_tail = xp[:, -(wlen - 1):] if wlen > 1 else tail
    return y, new_tail


def _rglru_block_apply(bp, x, cfg, *, state=None, impl=None):
    from repro.runtime.sharding import hint
    x = hint(x, "client", None, None)
    mix, new_state = _rglru_mix(bp, L.rmsnorm(bp["norm"], x, cfg.norm_eps),
                                state=state, impl=impl)
    x = x + mix
    x = x + L.mlp(bp["mlp"], L.rmsnorm(bp["mlp_norm"], x, cfg.norm_eps))
    return x, new_state


def _attn_block_apply(bp, x, positions, cfg, *, cache=None, cache_pos=None,
                      impl=None):
    from repro.runtime.sharding import hint
    x = hint(x, "client", None, None)
    h = L.rmsnorm(bp["norm"], x, cfg.norm_eps)
    a, new_cache = L.gqa_attend(bp["attn"], h, positions, cfg, causal=True,
                                window=cfg.hybrid.local_window,
                                kv_cache=cache, cache_pos=cache_pos,
                                impl=impl)
    x = x + a
    x = x + L.mlp(bp["mlp"], L.rmsnorm(bp["mlp_norm"], x, cfg.norm_eps))
    return x, new_cache


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

def _group_counts(cfg: ModelConfig) -> Tuple[int, int]:
    """(#full rra groups, #tail recurrent layers)."""
    plen = len(cfg.hybrid.pattern)
    return cfg.n_layers // plen, cfg.n_layers % plen


def init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    assert cfg.hybrid.pattern == "rra", "assignment uses the 1:2 rra pattern"
    n_groups, tail = _group_counts(cfg)
    ks = jax.random.split(key, 5)

    def group_init(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"r1": _rglru_block_init(k1, cfg, dtype),
                "r2": _rglru_block_init(k2, cfg, dtype),
                "a": _attn_block_init(k3, cfg, dtype)}

    p = {
        "embed": L.embed_init(ks[0], cfg.vocab_size, cfg.d_model, dtype),
        "groups": jax.vmap(group_init)(jax.random.split(ks[1], n_groups)),
        "final_norm": L.rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = L.embed_init(ks[2], cfg.vocab_size, cfg.d_model,
                                    dtype)
    tail_keys = jax.random.split(ks[3], max(tail, 1))
    p["tail"] = [_rglru_block_init(tail_keys[i], cfg, dtype)
                 for i in range(tail)]
    return p


def forward(params: dict, cfg: ModelConfig, tokens: jnp.ndarray, *,
            impl: Optional[str] = None) -> jnp.ndarray:
    x = L.embed(params["embed"], tokens)
    positions = jnp.arange(x.shape[1])

    def body(h, gp):
        h, _ = _rglru_block_apply(gp["r1"], h, cfg, impl=impl)
        h, _ = _rglru_block_apply(gp["r2"], h, cfg, impl=impl)
        h, _ = _attn_block_apply(gp["a"], h, positions, cfg, impl=impl)
        return h, None

    x, _ = jax.lax.scan(body, x, params["groups"])
    for bp in params["tail"]:
        x, _ = _rglru_block_apply(bp, x, cfg, impl=impl)
    return L.rmsnorm(params["final_norm"], x, cfg.norm_eps)


def token_nll(params, cfg, tokens, targets, mask, *, impl=None,
              prefix_embeds=None):
    x = forward(params, cfg, tokens, impl=impl)
    logits = L.unembed(params.get("lm_head", params["embed"]), x)
    return L.cross_entropy(logits, targets, mask)


def loss_per_client(params: dict, cfg: ModelConfig, batch: dict, *,
                    impl: Optional[str] = None) -> jnp.ndarray:
    k, b, s = batch["tokens"].shape
    flat = lambda a: a.reshape((k * b,) + a.shape[2:])
    nll = token_nll(params, cfg, flat(batch["tokens"]),
                    flat(batch["targets"]), flat(batch["mask"]), impl=impl)
    return jnp.mean(nll.reshape(k, b), axis=-1)


# ---------------------------------------------------------------------------
# Serving — O(1) state (rolling window for attention blocks)
# ---------------------------------------------------------------------------

def init_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> dict:
    w = cfg.hybrid.lru_width or cfg.d_model
    cw = cfg.hybrid.conv1d_width
    win = cfg.hybrid.local_window
    hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim()
    n_groups, tail = _group_counts(cfg)

    def rec_state(n):
        return {"lru": jnp.zeros((n, batch, w), dtype=dtype),
                "conv": jnp.zeros((n, batch, cw - 1, w), dtype=dtype)}

    return {
        "r1": rec_state(n_groups),
        "r2": rec_state(n_groups),
        "attn": {"k": jnp.zeros((n_groups, batch, win, hkv, hd), dtype=dtype),
                 "v": jnp.zeros((n_groups, batch, win, hkv, hd),
                                dtype=dtype)},
        "tail": rec_state(max(tail, 1)),
    }


def decode_step(params: dict, cfg: ModelConfig, state: dict,
                tokens: jnp.ndarray, cache_pos, *,
                impl: Optional[str] = None) -> Tuple[jnp.ndarray, dict]:
    """tokens: [B, 1]; rolling-window attention cache (slot = pos mod W)."""
    x = L.embed(params["embed"], tokens)
    win = cfg.hybrid.local_window
    positions = cache_pos + jnp.arange(tokens.shape[1])

    def body(h, xs):
        gp, s_r1, s_r2, s_attn = xs
        h, ns1 = _rglru_block_apply(gp["r1"], h, cfg, state=s_r1, impl=impl)
        h, ns2 = _rglru_block_apply(gp["r2"], h, cfg, state=s_r2, impl=impl)
        h, new_kv = _attn_rolling(gp["a"], h, positions, cfg, s_attn,
                                  cache_pos)
        return h, (ns1, ns2, new_kv)

    xs = (params["groups"],
          {"lru": state["r1"]["lru"], "conv": state["r1"]["conv"]},
          {"lru": state["r2"]["lru"], "conv": state["r2"]["conv"]},
          state["attn"])
    x, (ns1, ns2, nkv) = jax.lax.scan(body, x, xs)
    new_tail = {"lru": [], "conv": []}
    for i, bp in enumerate(params["tail"]):
        st = {"lru": state["tail"]["lru"][i], "conv": state["tail"]["conv"][i]}
        x, ns = _rglru_block_apply(bp, x, cfg, state=st, impl=impl)
        new_tail["lru"].append(ns["lru"])
        new_tail["conv"].append(ns["conv"])
    if params["tail"]:
        tail_state = {"lru": jnp.stack(new_tail["lru"]),
                      "conv": jnp.stack(new_tail["conv"])}
    else:
        tail_state = state["tail"]
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params.get("lm_head", params["embed"]), x)
    return logits, {"r1": ns1, "r2": ns2, "attn": nkv, "tail": tail_state}


def _attn_rolling(bp: dict, x: jnp.ndarray, positions, cfg: ModelConfig,
                  kv: dict, cache_pos) -> Tuple[jnp.ndarray, dict]:
    """Local attention against a rolling [B, W, hkv, hd] buffer."""
    b, s, d = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim()
    win = cfg.hybrid.local_window
    h = L.rmsnorm(bp["norm"], x, cfg.norm_eps)
    q = L.dense({"w": bp["attn"]["wq"]}, h).reshape(b, s, hq, hd)
    k = L.dense({"w": bp["attn"]["wk"]}, h).reshape(b, s, hkv, hd)
    v = L.dense({"w": bp["attn"]["wv"]}, h).reshape(b, s, hkv, hd)
    q = L.rope(q.swapaxes(1, 2), positions, cfg.rope_theta).swapaxes(1, 2)
    k = L.rope(k.swapaxes(1, 2), positions, cfg.rope_theta).swapaxes(1, 2)

    slot = jnp.mod(cache_pos, win)
    ck = jax.lax.dynamic_update_slice(kv["k"], k.astype(kv["k"].dtype),
                                      (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(kv["v"], v.astype(kv["v"].dtype),
                                      (0, slot, 0, 0))
    # absolute position held by each slot: cache_pos − ((slot − i) mod W)
    slot_idx = jnp.arange(win)
    slot_pos = cache_pos - jnp.mod(slot - slot_idx, win)
    valid = (slot_pos >= 0) & (slot_pos <= cache_pos) \
        & (slot_pos > cache_pos - win)

    group = hq // hkv
    qg = (q.reshape(b, s, hkv, group, hd).astype(jnp.float32)
          / (hd ** 0.5))
    scores = jnp.einsum("bshgd,bthd->bhgst", qg, ck.astype(jnp.float32))
    scores = jnp.where(valid[None, None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgst,bthd->bshgd", probs, cv.astype(jnp.float32))
    out = out.reshape(b, s, hq * hd).astype(x.dtype)
    x = x + L.dense_rp({"w": bp["attn"]["wo"]}, out)
    x = x + L.mlp(bp["mlp"], L.rmsnorm(bp["mlp_norm"], x, cfg.norm_eps))
    return x, {"k": ck, "v": cv}


def prefill(params: dict, cfg: ModelConfig, tokens: jnp.ndarray, *,
            impl: Optional[str] = None) -> Tuple[jnp.ndarray, dict]:
    """Prefill via full forward; serving state collection is supported for
    the window-bounded cache by re-running the last `window` tokens through
    decode in production; for dry-run purposes we return logits + fresh state
    primed with the final window of k/v."""
    logits_all = forward(params, cfg, tokens, impl=impl)
    logits = L.unembed(params.get("lm_head", params["embed"]), logits_all[:, -1:])
    state = init_state(cfg, tokens.shape[0],
                       dtype=params["embed"]["w"].dtype)
    return logits, state
