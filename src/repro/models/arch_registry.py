"""Dependency-free architecture-id registry (breaks config↔model cycles)."""
from __future__ import annotations

from typing import Callable, Dict

_ARCHS: Dict[str, Callable] = {}


def register_arch(arch_id: str, builder: Callable) -> None:
    _ARCHS[arch_id] = builder


def arch_builder(arch_id: str) -> Callable:
    try:
        return _ARCHS[arch_id]
    except KeyError:
        raise ValueError(
            f"unknown arch {arch_id!r}; available: "
            f"{sorted(_ARCHS)}") from None


def registered() -> list:
    return sorted(_ARCHS)
