"""Whisper-style encoder–decoder (audio family).

The conv frontend is a STUB per the assignment: input_specs() supplies
precomputed frame embeddings [B, n_frames, d_model] (30 s of audio → 1500
frames for whisper-medium). The transformer backbone — bidirectional encoder,
causal decoder with cross-attention — is fully implemented.

Decode shapes exercise the decoder: self-attention KV cache of seq_len plus a
fixed cross-attention cache over the 1500 encoder frames.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L


def _enc_block_init(key, cfg: ModelConfig, dtype) -> dict:
    ks = jax.random.split(key, 2)
    return {
        "ln1": L.rmsnorm_init(cfg.d_model, dtype),
        "attn": L.gqa_init(ks[0], cfg, dtype),
        "ln2": L.rmsnorm_init(cfg.d_model, dtype),
        "mlp": L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, dtype),
    }


def _dec_block_init(key, cfg: ModelConfig, dtype) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "ln1": L.rmsnorm_init(cfg.d_model, dtype),
        "self_attn": L.gqa_init(ks[0], cfg, dtype),
        "ln_x": L.rmsnorm_init(cfg.d_model, dtype),
        "cross_attn": L.gqa_init(ks[1], cfg, dtype),
        "ln2": L.rmsnorm_init(cfg.d_model, dtype),
        "mlp": L.mlp_init(ks[2], cfg.d_model, cfg.d_ff, dtype),
    }


def init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 5)
    n_enc = cfg.n_encoder_layers or cfg.n_layers
    enc_keys = jax.random.split(ks[0], n_enc)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    p = {
        "enc_blocks": jax.vmap(lambda k: _enc_block_init(k, cfg, dtype))(
            enc_keys),
        "enc_norm": L.rmsnorm_init(cfg.d_model, dtype),
        "dec_embed": L.embed_init(ks[2], cfg.vocab_size, cfg.d_model, dtype),
        "dec_blocks": jax.vmap(lambda k: _dec_block_init(k, cfg, dtype))(
            dec_keys),
        "dec_norm": L.rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = L.embed_init(ks[3], cfg.vocab_size, cfg.d_model,
                                    dtype)
    return p


def encode(params: dict, cfg: ModelConfig, frames: jnp.ndarray, *,
           impl: Optional[str] = None) -> jnp.ndarray:
    """frames: [B, T_enc, D] (stub frontend output) → encoder hidden."""
    positions = jnp.arange(frames.shape[1])
    x = frames

    def body(h, bp):
        hn = L.rmsnorm(bp["ln1"], h, cfg.norm_eps)
        a, _ = L.gqa_attend(bp["attn"], hn, positions, cfg, causal=False,
                            impl=impl)
        h = h + a
        h = h + L.mlp(bp["mlp"], L.rmsnorm(bp["ln2"], h, cfg.norm_eps))
        return h, None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return L.rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def _dec_block_apply(bp, h, enc_out, positions, cfg, *, cache=None,
                     cache_pos=None, impl=None):
    from repro.runtime.sharding import hint
    h = hint(h, "client", None, None)
    hn = L.rmsnorm(bp["ln1"], h, cfg.norm_eps)
    a, new_self = L.gqa_attend(bp["self_attn"], hn, positions, cfg,
                               causal=True, kv_cache=cache,
                               cache_pos=cache_pos, impl=impl)
    h = h + a
    hx = L.rmsnorm(bp["ln_x"], h, cfg.norm_eps)
    xa, _ = L.gqa_attend(bp["cross_attn"], hx, positions, cfg, causal=False,
                         kv_x=enc_out, impl=impl)
    h = h + xa
    h = h + L.mlp(bp["mlp"], L.rmsnorm(bp["ln2"], h, cfg.norm_eps))
    return h, new_self


def decode_hidden(params: dict, cfg: ModelConfig, tokens: jnp.ndarray,
                  enc_out: jnp.ndarray, *,
                  impl: Optional[str] = None) -> jnp.ndarray:
    x = L.embed(params["dec_embed"], tokens)
    positions = jnp.arange(tokens.shape[1])

    def body(h, bp):
        h, _ = _dec_block_apply(bp, h, enc_out, positions, cfg, impl=impl)
        return h, None

    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    return L.rmsnorm(params["dec_norm"], x, cfg.norm_eps)


def token_nll(params, cfg, tokens, targets, mask, *, frames=None, impl=None,
              prefix_embeds=None):
    frames = frames if frames is not None else prefix_embeds
    enc_out = encode(params, cfg, frames, impl=impl)
    x = decode_hidden(params, cfg, tokens, enc_out, impl=impl)
    logits = L.unembed(params.get("lm_head", params["dec_embed"]), x)
    return L.cross_entropy(logits, targets, mask)


def loss_per_client(params: dict, cfg: ModelConfig, batch: dict, *,
                    impl: Optional[str] = None) -> jnp.ndarray:
    k, b, s = batch["tokens"].shape
    flat = lambda a: a.reshape((k * b,) + a.shape[2:])
    nll = token_nll(params, cfg, flat(batch["tokens"]),
                    flat(batch["targets"]), flat(batch["mask"]),
                    frames=flat(batch["prefix_embeds"]), impl=impl)
    return jnp.mean(nll.reshape(k, b), axis=-1)


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int, n_frames: int,
               dtype=jnp.float32) -> dict:
    lc = cfg.n_layers
    hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim()
    return {
        "self_k": jnp.zeros((lc, batch, max_len, hkv, hd), dtype=dtype),
        "self_v": jnp.zeros((lc, batch, max_len, hkv, hd), dtype=dtype),
        "cross_k": jnp.zeros((lc, batch, n_frames, hkv, hd), dtype=dtype),
        "cross_v": jnp.zeros((lc, batch, n_frames, hkv, hd), dtype=dtype),
    }


def prefill(params: dict, cfg: ModelConfig, tokens: jnp.ndarray,
            frames: jnp.ndarray, *, impl: Optional[str] = None
            ) -> Tuple[jnp.ndarray, dict]:
    """Encode frames, run the decoder prefix, build self+cross caches."""
    b, s = tokens.shape
    enc_out = encode(params, cfg, frames, impl=impl)
    x = L.embed(params["dec_embed"], tokens)
    positions = jnp.arange(s)
    hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim()
    cache = init_cache(cfg, b, s, frames.shape[1], dtype=x.dtype)

    def body(h, xs):
        bp, lc = xs
        h_in = h
        h, _ = _dec_block_apply(bp, h, enc_out, positions, cfg, impl=impl)
        hn = L.rmsnorm(bp["ln1"], h_in, cfg.norm_eps)
        k = L.dense({"w": bp["self_attn"]["wk"]}, hn).reshape(b, s, hkv, hd)
        k = L.rope(k.swapaxes(1, 2), positions, cfg.rope_theta).swapaxes(1, 2)
        v = L.dense({"w": bp["self_attn"]["wv"]}, hn).reshape(b, s, hkv, hd)
        ck = L.dense({"w": bp["cross_attn"]["wk"]}, enc_out).reshape(
            b, -1, hkv, hd)
        cv = L.dense({"w": bp["cross_attn"]["wv"]}, enc_out).reshape(
            b, -1, hkv, hd)
        from repro.runtime.sharding import hint
        new_lc = {"self_k": hint(lc["self_k"].at[:, :s].set(
                      k.astype(x.dtype)), "client", "model", None, None),
                  "self_v": hint(lc["self_v"].at[:, :s].set(
                      v.astype(x.dtype)), "client", "model", None, None),
                  "cross_k": hint(ck.astype(x.dtype),
                                  "client", None, None, None),
                  "cross_v": hint(cv.astype(x.dtype),
                                  "client", None, None, None)}
        return h, new_lc

    x, new_cache = jax.lax.scan(body, x, (params["dec_blocks"], cache))
    x = L.rmsnorm(params["dec_norm"], x, cfg.norm_eps)
    return L.unembed(params.get("lm_head", params["dec_embed"]), x[:, -1:]), new_cache


def decode_step(params: dict, cfg: ModelConfig, cache: dict,
                tokens: jnp.ndarray, cache_pos, *,
                impl: Optional[str] = None) -> Tuple[jnp.ndarray, dict]:
    """tokens: [B, 1] against self cache [L,B,S_max] + fixed cross cache."""
    b, s = tokens.shape
    x = L.embed(params["dec_embed"], tokens)
    positions = cache_pos + jnp.arange(s)
    hkv, hq = cfg.n_kv_heads, cfg.n_heads
    hd = cfg.resolved_head_dim()

    def body(carry, xs):
        h, full_cache = carry
        li, bp = xs
        lc = jax.tree_util.tree_map(
            lambda c: jax.lax.dynamic_index_in_dim(c, li, 0, False),
            full_cache)
        hn = L.rmsnorm(bp["ln1"], h, cfg.norm_eps)
        q = L.dense({"w": bp["self_attn"]["wq"]}, hn).reshape(b, s, hq, hd)
        q = L.rope(q.swapaxes(1, 2), positions, cfg.rope_theta).swapaxes(1, 2)
        k = L.dense({"w": bp["self_attn"]["wk"]}, hn).reshape(b, s, hkv, hd)
        k = L.rope(k.swapaxes(1, 2), positions, cfg.rope_theta).swapaxes(1, 2)
        v = L.dense({"w": bp["self_attn"]["wv"]}, hn).reshape(b, s, hkv, hd)
        sk = jax.lax.dynamic_update_slice(
            lc["self_k"], k.astype(lc["self_k"].dtype), (0, cache_pos, 0, 0))
        sv = jax.lax.dynamic_update_slice(
            lc["self_v"], v.astype(lc["self_v"].dtype), (0, cache_pos, 0, 0))
        a = L.decode_attend(q, sk, sv, cache_pos + jnp.arange(s))
        h = h + L.dense_rp({"w": bp["self_attn"]["wo"]},
                        a.reshape(b, s, hq * hd))
        # cross attention against the fixed encoder cache (no mask)
        hx = L.rmsnorm(bp["ln_x"], h, cfg.norm_eps)
        qx = L.dense({"w": bp["cross_attn"]["wq"]}, hx).reshape(b, s, hq, hd)
        n_frames = lc["cross_k"].shape[1]
        ax = L.decode_attend(qx, lc["cross_k"], lc["cross_v"],
                             jnp.full((s,), n_frames - 1))
        h = h + L.dense_rp({"w": bp["cross_attn"]["wo"]},
                        ax.reshape(b, s, hq * hd))
        h = h + L.mlp(bp["mlp"], L.rmsnorm(bp["ln2"], h, cfg.norm_eps))
        new_lc = {"self_k": sk, "self_v": sv,
                  "cross_k": lc["cross_k"], "cross_v": lc["cross_v"]}
        full_cache = jax.tree_util.tree_map(
            lambda c, nc: jax.lax.dynamic_update_index_in_dim(
                c, nc.astype(c.dtype), li, 0), full_cache, new_lc)
        return (h, full_cache), None

    (x, new_cache), _ = jax.lax.scan(
        body, (x, cache),
        (jnp.arange(cfg.n_layers, dtype=jnp.int32), params["dec_blocks"]))
    x = L.rmsnorm(params["dec_norm"], x, cfg.norm_eps)
    return L.unembed(params.get("lm_head", params["dec_embed"]), x), new_cache
