"""Decoder-only transformer LM: dense / MoE / MLA variants.

Covers 7 of the 10 assigned architectures (moonshot, deepseek-v2,
deepseek-coder, granite, minicpm3, yi, internvl2-backbone) plus the paper's
OPT-125M. Layers are scan-stacked (leading L dim on every leaf): compile time
and HLO size stay O(1) in depth, and FSDP weight gathers stream layer-by-layer
under the scan.

Three entry points per the shape cells:
  * loss_per_client — train shapes (the ZO/FO objective)
  * prefill         — inference-prefill shapes (build cache, last logits)
  * decode_step     — decode shapes (one token against a full cache)
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L


def _block_init(key, cfg: ModelConfig, dtype) -> dict:
    ks = jax.random.split(key, 4)
    p = {"ln1": L.rmsnorm_init(cfg.d_model, dtype),
         "ln2": L.rmsnorm_init(cfg.d_model, dtype)}
    if cfg.mla.enabled:
        p["attn"] = L.mla_init(ks[0], cfg, dtype)
    else:
        p["attn"] = L.gqa_init(ks[0], cfg, dtype)
    if cfg.moe.enabled:
        p["moe"] = L.moe_init(ks[1], cfg, dtype)
    else:
        p["mlp"] = L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, dtype)
    return p


def init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 4)
    # scan-stacked blocks: one init vmapped over layer keys
    layer_keys = jax.random.split(ks[0], cfg.n_layers)
    blocks = jax.vmap(lambda k: _block_init(k, cfg, dtype))(layer_keys)
    p = {
        "embed": L.embed_init(ks[1], cfg.vocab_size, cfg.d_model, dtype),
        "blocks": blocks,
        "final_norm": L.rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = L.embed_init(ks[2], cfg.vocab_size, cfg.d_model,
                                    dtype)
    return p


def _block_apply(bp: dict, x: jnp.ndarray, positions: jnp.ndarray,
                 cfg: ModelConfig, *, cache: Optional[dict],
                 cache_pos, impl: Optional[str]
                 ) -> Tuple[jnp.ndarray, Optional[dict]]:
    from repro.runtime.sharding import hint
    x = hint(x, "client", None, None)
    h = L.rmsnorm(bp["ln1"], x, cfg.norm_eps)
    if cfg.mla.enabled:
        a, new_cache = L.mla_attend(bp["attn"], h, positions, cfg,
                                    kv_cache=cache, cache_pos=cache_pos,
                                    impl=impl)
    else:
        a, new_cache = L.gqa_attend(bp["attn"], h, positions, cfg,
                                    causal=True, kv_cache=cache,
                                    cache_pos=cache_pos, impl=impl)
    x = x + a
    h = L.rmsnorm(bp["ln2"], x, cfg.norm_eps)
    f = L.moe(bp["moe"], h, cfg) if cfg.moe.enabled else L.mlp(bp["mlp"], h)
    return x + f, new_cache


def forward(params: dict, cfg: ModelConfig, tokens: jnp.ndarray, *,
            prefix_embeds: Optional[jnp.ndarray] = None,
            inputs_embeds: Optional[jnp.ndarray] = None,
            impl: Optional[str] = None) -> jnp.ndarray:
    """tokens: [B, S] → hidden [B, S(+P), D]. prefix_embeds ([B, P, D])
    are prepended (VLM stub frontend). inputs_embeds ([B, S, D]) replaces
    the embedding lookup entirely (tokens may be None) — the continuous
    input surface gradient-inversion attacks (repro.privacy.attacks) and
    soft-token methods differentiate through."""
    x = L.embed(params["embed"], tokens) if inputs_embeds is None \
        else inputs_embeds
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    positions = jnp.arange(x.shape[1])

    def body(h, bp):
        h, _ = _block_apply(bp, h, positions, cfg, cache=None,
                            cache_pos=None, impl=impl)
        return h, None

    x, _ = jax.lax.scan(body, x, params["blocks"])
    return L.rmsnorm(params["final_norm"], x, cfg.norm_eps)


def logits_from_hidden(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    head = params.get("lm_head", params["embed"])
    return L.unembed(head, x)


def token_nll(params: dict, cfg: ModelConfig, tokens: jnp.ndarray,
              targets: jnp.ndarray, mask: jnp.ndarray, *,
              prefix_embeds: Optional[jnp.ndarray] = None,
              inputs_embeds: Optional[jnp.ndarray] = None,
              impl: Optional[str] = None) -> jnp.ndarray:
    """Per-sequence-row mean NLL: [B, S] → [B]. (f32 CE over sharded vocab.)"""
    x = forward(params, cfg, tokens, prefix_embeds=prefix_embeds,
                inputs_embeds=inputs_embeds, impl=impl)
    if prefix_embeds is not None:
        x = x[:, prefix_embeds.shape[1]:]
    logits = logits_from_hidden(params, x)                  # [B, S, V] f32
    return L.cross_entropy(logits, targets, mask)


def loss_per_client(params: dict, cfg: ModelConfig, batch: dict, *,
                    impl: Optional[str] = None) -> jnp.ndarray:
    """batch tokens/targets/mask: [K, b, S] → per-client losses [K]."""
    k, b, s = batch["tokens"].shape
    flat = lambda a: a.reshape((k * b,) + a.shape[2:])
    prefix = batch.get("prefix_embeds")
    nll = token_nll(params, cfg, flat(batch["tokens"]),
                    flat(batch["targets"]), flat(batch["mask"]),
                    prefix_embeds=flat(prefix) if prefix is not None else None,
                    impl=impl)
    return jnp.mean(nll.reshape(k, b), axis=-1)


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.float32) -> dict:
    lcfg = cfg.n_layers
    if cfg.mla.enabled:
        return {
            "ckv": jnp.zeros((lcfg, batch, max_len, cfg.mla.kv_lora_rank),
                             dtype=dtype),
            "krope": jnp.zeros((lcfg, batch, max_len,
                                cfg.mla.qk_rope_head_dim), dtype=dtype),
        }
    hd = cfg.resolved_head_dim()
    return {
        "k": jnp.zeros((lcfg, batch, max_len, cfg.n_kv_heads, hd),
                       dtype=dtype),
        "v": jnp.zeros((lcfg, batch, max_len, cfg.n_kv_heads, hd),
                       dtype=dtype),
    }


def cache_spec(cfg: ModelConfig, batch: int, max_len: int, dtype):
    """ShapeDtypeStruct skeleton of init_cache (dry-run input specs)."""
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len, dtype))


def decode_step(params: dict, cfg: ModelConfig, cache: dict,
                tokens: jnp.ndarray, cache_pos, *,
                impl: Optional[str] = None) -> Tuple[jnp.ndarray, dict]:
    """One decode step. tokens: [B, S_new(=1)]; cache leaves: [L, B, ...].

    The cache rides in the scan CARRY (not xs→ys): while-loop carries alias
    in place under buffer donation, so the multi-GB cache updates without a
    second copy — scan-stacked xs/ys outputs cannot alias and would double
    the decode working set.
    """
    x = L.embed(params["embed"], tokens)
    positions = cache_pos + jnp.arange(tokens.shape[1])

    def body(carry, xs):
        h, full_cache = carry
        li, bp = xs
        layer_cache = jax.tree_util.tree_map(
            lambda c: jax.lax.dynamic_index_in_dim(c, li, 0, False),
            full_cache)
        h, new_cache = _block_apply(bp, h, positions, cfg, cache=layer_cache,
                                    cache_pos=cache_pos, impl=impl)
        full_cache = jax.tree_util.tree_map(
            lambda c, nc: jax.lax.dynamic_update_index_in_dim(
                c, nc.astype(c.dtype), li, 0),
            full_cache, new_cache)
        return (h, full_cache), None

    (x, new_cache), _ = jax.lax.scan(
        body, (x, cache),
        (jnp.arange(cfg.n_layers, dtype=jnp.int32), params["blocks"]))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return logits_from_hidden(params, x), new_cache


def prefill(params: dict, cfg: ModelConfig, tokens: jnp.ndarray, *,
            prefix_embeds: Optional[jnp.ndarray] = None,
            impl: Optional[str] = None) -> Tuple[jnp.ndarray, dict]:
    """Full-sequence prefill: returns (last-position logits [B, V], cache).

    The cache is built by running each block in cache-write mode at pos 0
    with the full sequence (write-once, no dynamic slices on the hot path).
    """
    b, s = tokens.shape
    x = L.embed(params["embed"], tokens)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    s_tot = x.shape[1]
    positions = jnp.arange(s_tot)
    cache = init_cache(cfg, b, s_tot, dtype=x.dtype)

    # cache filling recomputes this layer's k/v projection from the block
    # input (one extra projection per layer; no attention recompute).
    def body2(h, xs):
        bp, layer_cache = xs
        h_in = h
        h, _ = _block_apply(bp, h, positions, cfg, cache=None,
                            cache_pos=None, impl=impl)
        filled = _fill_cache(bp, L.rmsnorm(bp["ln1"], h_in, cfg.norm_eps),
                             layer_cache, positions, cfg)
        return h, filled

    x, new_cache = jax.lax.scan(body2, x, (params["blocks"], cache))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return logits_from_hidden(params, x[:, -1:]), new_cache


def _fill_cache(bp: dict, h_norm: jnp.ndarray, layer_cache: dict,
                positions: jnp.ndarray, cfg: ModelConfig) -> dict:
    """Write this layer's k/v (or latent) projections into the cache."""
    b, s, _ = h_norm.shape
    if cfg.mla.enabled:
        m = cfg.mla
        kv = L.dense({"w": bp["attn"]["wkv_a"]}, h_norm)
        ckv = L.rmsnorm(bp["attn"]["kv_norm"], kv[..., :m.kv_lora_rank],
                        cfg.norm_eps)
        krope = L.rope(kv[..., m.kv_lora_rank:][:, None], positions,
                       cfg.rope_theta)[:, 0]
        from repro.runtime.sharding import hint
        return {
            "ckv": hint(layer_cache["ckv"].at[:, :s].set(
                ckv.astype(layer_cache["ckv"].dtype)),
                "client", "model", None),
            "krope": hint(layer_cache["krope"].at[:, :s].set(
                krope.astype(layer_cache["krope"].dtype)),
                "client", "model", None),
        }
    hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim()
    k = L.dense({"w": bp["attn"]["wk"]}, h_norm).reshape(b, s, hkv, hd)
    k = L.rope(k.swapaxes(1, 2), positions, cfg.rope_theta).swapaxes(1, 2)
    v = L.dense({"w": bp["attn"]["wv"]}, h_norm).reshape(b, s, hkv, hd)
    from repro.runtime.sharding import hint
    return {
        "k": hint(layer_cache["k"].at[:, :s].set(
            k.astype(layer_cache["k"].dtype)), "client", "model", None, None),
        "v": hint(layer_cache["v"].at[:, :s].set(
            v.astype(layer_cache["v"].dtype)), "client", "model", None, None),
    }
