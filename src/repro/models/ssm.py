"""Mamba-2 (SSD) language model — attention-free family.

Block layout follows arXiv:2405.21060: in_proj → (z gate | xBC) with a causal
depthwise conv over xBC → SSD mixing (chunked kernel) → gated RMSNorm →
out_proj. State for decode is O(1) in sequence length: a [B, H, P, N] SSD
state plus a (d_conv−1)-deep conv tail — which is why mamba2 runs the
long_500k cell that full-attention archs must skip.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops as kops
from repro.models import layers as L


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    return d_inner, n_heads, s.d_state, s.d_conv, s.head_dim


def _block_init(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    d_inner, h, n, d_conv, p_dim = _dims(cfg)
    conv_ch = d_inner + 2 * n            # x, B, C share the conv
    ks = jax.random.split(key, 5)
    return {
        "norm": L.rmsnorm_init(d, dtype),
        "in_proj": L.dense_init(ks[0], d, 2 * d_inner + 2 * n + h, dtype),
        "conv_w": (jax.random.normal(ks[1], (d_conv, conv_ch),
                                     dtype=jnp.float32)
                   / math.sqrt(d_conv)).astype(dtype),
        "a_log": jnp.zeros((h,), dtype=jnp.float32),
        "dt_bias": jnp.zeros((h,), dtype=jnp.float32),
        "gate_norm": L.rmsnorm_init(d_inner, dtype),
        "out_proj": L.dense_init(ks[2], d_inner, d, dtype),
    }


def init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 3)
    layer_keys = jax.random.split(ks[0], cfg.n_layers)
    blocks = jax.vmap(lambda k: _block_init(k, cfg, dtype))(layer_keys)
    p = {
        "embed": L.embed_init(ks[1], cfg.vocab_size, cfg.d_model, dtype),
        "blocks": blocks,
        "final_norm": L.rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = L.embed_init(ks[2], cfg.vocab_size, cfg.d_model,
                                    dtype)
    return p


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray,
                 tail: Optional[jnp.ndarray] = None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv. x: [B, S, C]; w: [W, C]; tail: [B, W−1, C]
    carried state. Returns (y [B,S,C], new_tail)."""
    b, s, c = x.shape
    wlen = w.shape[0]
    if tail is None:
        tail = jnp.zeros((b, wlen - 1, c), dtype=x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)          # [B, S+W-1, C]
    y = sum(xp[:, i:i + s] * w[i][None, None].astype(x.dtype)
            for i in range(wlen))
    new_tail = xp[:, -(wlen - 1):] if wlen > 1 else tail
    return jax.nn.silu(y.astype(jnp.float32)).astype(x.dtype), new_tail


def _block_apply(bp: dict, x: jnp.ndarray, cfg: ModelConfig, *,
                 state: Optional[dict] = None, impl: Optional[str] = None
                 ) -> Tuple[jnp.ndarray, Optional[dict]]:
    """x: [B, S, D]. state: {"ssd": [B,H,P,N], "conv": [B,W−1,C]} for decode."""
    from repro.runtime.sharding import hint
    x = hint(x, "client", None, None)
    b, s, d = x.shape
    d_inner, h, n, d_conv, p_dim = _dims(cfg)
    res = x
    xn = L.rmsnorm(bp["norm"], x, cfg.norm_eps)
    zxbcdt = L.dense(bp["in_proj"], xn)
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner:d_inner + d_inner + 2 * n]
    dt_raw = zxbcdt[..., -h:]

    conv_tail = state["conv"] if state is not None else None
    xbc, new_tail = _causal_conv(xbc, bp["conv_w"], conv_tail)
    xs = xbc[..., :d_inner].reshape(b, s, h, p_dim)
    b_mat = xbc[..., d_inner:d_inner + n]
    c_mat = xbc[..., d_inner + n:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + bp["dt_bias"][None, None])
    a = -jnp.exp(bp["a_log"])

    if state is None:
        chunk = min(cfg.ssm.chunk, s)
        if s % chunk != 0:
            chunk = s
        y, _ = kops.ssd(xs, dt, a, b_mat, c_mat, chunk=chunk, impl=impl)
        new_state = None
    else:
        y_t, ssd_state = kops.ssd_decode_step(
            state["ssd"], xs[:, 0], dt[:, 0], a, b_mat[:, 0], c_mat[:, 0])
        y = y_t[:, None]
        new_state = {"ssd": ssd_state, "conv": new_tail}

    y = y.reshape(b, s, d_inner)
    y = L.rmsnorm(bp["gate_norm"],
                  y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                  cfg.norm_eps)
    return res + L.dense_rp(bp["out_proj"], y), new_state


def forward(params: dict, cfg: ModelConfig, tokens: jnp.ndarray, *,
            impl: Optional[str] = None) -> jnp.ndarray:
    x = L.embed(params["embed"], tokens)

    def body(hk, bp):
        hk, _ = _block_apply(bp, hk, cfg, impl=impl)
        return hk, None

    x, _ = jax.lax.scan(body, x, params["blocks"])
    return L.rmsnorm(params["final_norm"], x, cfg.norm_eps)


def token_nll(params, cfg, tokens, targets, mask, *, impl=None,
              prefix_embeds=None):
    x = forward(params, cfg, tokens, impl=impl)
    logits = L.unembed(params.get("lm_head", params["embed"]), x)
    return L.cross_entropy(logits, targets, mask)


def loss_per_client(params: dict, cfg: ModelConfig, batch: dict, *,
                    impl: Optional[str] = None) -> jnp.ndarray:
    k, b, s = batch["tokens"].shape
    flat = lambda a: a.reshape((k * b,) + a.shape[2:])
    nll = token_nll(params, cfg, flat(batch["tokens"]),
                    flat(batch["targets"]), flat(batch["mask"]), impl=impl)
    return jnp.mean(nll.reshape(k, b), axis=-1)


# ---------------------------------------------------------------------------
# Serving — O(1) state
# ---------------------------------------------------------------------------

def init_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> dict:
    d_inner, h, n, d_conv, p_dim = _dims(cfg)
    lcount = cfg.n_layers
    return {
        "ssd": jnp.zeros((lcount, batch, h, p_dim, n), dtype=jnp.float32),
        "conv": jnp.zeros((lcount, batch, d_conv - 1, d_inner + 2 * n),
                          dtype=dtype),
    }


def decode_step(params: dict, cfg: ModelConfig, state: dict,
                tokens: jnp.ndarray, cache_pos=None, *,
                impl: Optional[str] = None) -> Tuple[jnp.ndarray, dict]:
    """tokens: [B, 1]. cache_pos unused (state is position-free)."""
    x = L.embed(params["embed"], tokens)

    def body(hk, xs):
        bp, layer_state = xs
        hk, new_state = _block_apply(bp, hk, cfg, state=layer_state,
                                     impl=impl)
        return hk, new_state

    x, new_state = jax.lax.scan(body, x, (params["blocks"], state))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return L.unembed(params.get("lm_head", params["embed"]), x), new_state


def prefill(params: dict, cfg: ModelConfig, tokens: jnp.ndarray, *,
            impl: Optional[str] = None) -> Tuple[jnp.ndarray, dict]:
    """Prefill = full forward while collecting final states per layer."""
    b, s = tokens.shape
    x = L.embed(params["embed"], tokens)
    d_inner, h, n, d_conv, p_dim = _dims(cfg)

    def body(hk, bp):
        # run block in train mode but also compute the final ssd/conv state
        res = hk
        xn = L.rmsnorm(bp["norm"], hk, cfg.norm_eps)
        zxbcdt = L.dense(bp["in_proj"], xn)
        z = zxbcdt[..., :d_inner]
        xbc = zxbcdt[..., d_inner:d_inner + d_inner + 2 * n]
        dt_raw = zxbcdt[..., -h:]
        xbc_c, tail = _causal_conv(xbc, bp["conv_w"])
        xs_ = xbc_c[..., :d_inner].reshape(b, s, h, p_dim)
        b_mat = xbc_c[..., d_inner:d_inner + n]
        c_mat = xbc_c[..., d_inner + n:]
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                             + bp["dt_bias"][None, None])
        a = -jnp.exp(bp["a_log"])
        chunk = min(cfg.ssm.chunk, s)
        if s % chunk != 0:
            chunk = s
        y, ssd_state = kops.ssd(xs_, dt, a, b_mat, c_mat, chunk=chunk,
                                impl=impl)
        y = y.reshape(b, s, d_inner)
        y = L.rmsnorm(bp["gate_norm"],
                      y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                      cfg.norm_eps)
        hk = res + L.dense_rp(bp["out_proj"], y)
        return hk, {"ssd": ssd_state, "conv": tail}

    x, state = jax.lax.scan(body, x, params["blocks"])
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return L.unembed(params.get("lm_head", params["embed"]), x[:, -1:]), state
