"""Raw-JAX building blocks shared by every architecture family.

Conventions:
  * params are nested dicts of jnp arrays; init_* builds them, the matching
    apply function consumes them. No module framework — pure functions keep
    pjit/scan/ZO-perturbation trivially composable.
  * scan-stacked layers carry a leading L dim on every leaf.
  * compute happens in the array dtype (bf16 on TPU) with f32 accumulation
    via preferred_element_type; norms/softmax in f32.
  * `impl` threads the kernel dispatch (pallas | xla | ...) from ModelConfig.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops as kops


def _init(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, dtype=jnp.float32) * scale
            ).astype(dtype)


def dense_init(key, d_in: int, d_out: int, dtype) -> dict:
    return {"w": _init(key, (d_in, d_out), 1.0 / math.sqrt(d_in), dtype)}


def dense(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    if isinstance(p["w"], kops.PerturbedParam):
        # fused ZO dual forward: x @ (w + εz), z regenerated in-kernel
        return kops.perturbed_matmul(x, p["w"])
    return jnp.einsum("...d,df->...f", x, p["w"],
                      preferred_element_type=jnp.float32).astype(x.dtype)


def dense_rp(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    """Row-parallel projection (contraction dim TP-sharded ⇒ followed by a
    psum). Under `hints(..., bf16_reduce=True)` partials are emitted bf16 so
    the all-reduce moves half the bytes (local MXU accumulation is f32
    internally regardless)."""
    from repro.runtime.sharding import bf16_reduce_active
    if not isinstance(p["w"], kops.PerturbedParam) \
            and bf16_reduce_active() and x.dtype == jnp.bfloat16:
        return jnp.einsum("...d,df->...f", x, p["w"],
                          preferred_element_type=jnp.bfloat16)
    return dense(p, x)


def rmsnorm_init(d: int, dtype) -> dict:
    return {"g": jnp.ones((d,), dtype=dtype)}


def rmsnorm(p: dict, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    g = kops.resolve(p["g"])   # [D]-sized transient when tagged (fused ZO)
    xf = x.astype(jnp.float32)
    scale = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * scale * g.astype(jnp.float32)).astype(x.dtype)


def embed_init(key, vocab: int, d: int, dtype) -> dict:
    return {"w": _init(key, (vocab, d), 0.02, dtype)}


def embed(p: dict, tokens: jnp.ndarray) -> jnp.ndarray:
    from repro.runtime.sharding import hint
    if isinstance(p["w"], kops.PerturbedParam):
        # fused ZO: z drawn only for the gathered rows, never for the table
        x = kops.perturbed_gather(p["w"], tokens)
    else:
        x = jnp.take(p["w"], tokens, axis=0)
    # batch over clients; keeps the gather output from replicating when the
    # table is vocab-sharded over `model`
    return hint(x, "client", *([None] * (x.ndim - 1)))


def unembed(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    """lm head: [.., D] @ [V, D]ᵀ → [.., V] (f32 logits for a stable CE).

    The output is hinted (batch→clients, vocab→model) so GSPMD never
    materializes a replicated [B, S, V] logits tensor.
    """
    from repro.runtime.sharding import hint
    if isinstance(p["w"], kops.PerturbedParam):
        logits = kops.perturbed_unembed(x, p["w"])
    else:
        logits = jnp.einsum("...d,vd->...v", x, p["w"],
                            preferred_element_type=jnp.float32)
    roles = [None] * logits.ndim
    roles[0] = "client"
    roles[-1] = "model"
    return hint(logits, *roles)


def cross_entropy(logits: jnp.ndarray, targets: jnp.ndarray,
                  mask: jnp.ndarray) -> jnp.ndarray:
    """Per-row mean NLL: logits [.., S, V], targets/mask [.., S] → [..].

    The target logit is extracted with a fused iota-compare-select-reduce
    instead of take_along_axis: with the vocab dim sharded over `model`,
    a gather would force GSPMD to replicate the full logits tensor; the
    masked reduction keeps it sharded (partial sums + a tiny psum).
    """
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    iota = jax.lax.broadcasted_iota(targets.dtype, logits.shape,
                                    logits.ndim - 1)
    tgt = jnp.sum(jnp.where(iota == targets[..., None], logits, 0.0),
                  axis=-1)
    nll = (lse - tgt) * mask
    return jnp.sum(nll, axis=-1) / jnp.maximum(jnp.sum(mask, axis=-1), 1.0)


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------

def rope(x: jnp.ndarray, positions: jnp.ndarray,
         theta: float = 10000.0) -> jnp.ndarray:
    """x: [..., S, D_head(even)]; positions: [S] or broadcastable."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32)
                    / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # [S, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------

def gqa_init(key, cfg: ModelConfig, dtype) -> dict:
    d, hq, hkv = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    hd = cfg.resolved_head_dim()
    ks = jax.random.split(key, 4)
    return {
        "wq": _init(ks[0], (d, hq * hd), 1.0 / math.sqrt(d), dtype),
        "wk": _init(ks[1], (d, hkv * hd), 1.0 / math.sqrt(d), dtype),
        "wv": _init(ks[2], (d, hkv * hd), 1.0 / math.sqrt(d), dtype),
        "wo": _init(ks[3], (hq * hd, d), 1.0 / math.sqrt(hq * hd), dtype),
    }


def gqa_attend(p: dict, x: jnp.ndarray, positions: jnp.ndarray,
               cfg: ModelConfig, *, causal: bool = True,
               window: Optional[int] = None,
               kv_cache: Optional[dict] = None,
               cache_pos: Optional[jnp.ndarray] = None,
               impl: Optional[str] = None,
               kv_x: Optional[jnp.ndarray] = None
               ) -> Tuple[jnp.ndarray, Optional[dict]]:
    """x: [B, S, D] → ([B, S, D], new_cache).

    kv_cache: {"k","v": [B, S_max, Hkv, hd]} decode/rolling cache.
    cache_pos: scalar write position for decode (tokens enter at cache_pos).
    kv_x: cross-attention source (enc-dec); defaults to x (self-attention).
    """
    b, s, d = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim()
    src = x if kv_x is None else kv_x
    q = dense({"w": p["wq"]}, x).reshape(b, s, hq, hd)
    k = dense({"w": p["wk"]}, src).reshape(b, src.shape[1], hkv, hd)
    v = dense({"w": p["wv"]}, src).reshape(b, src.shape[1], hkv, hd)
    if causal or kv_x is None:  # self-attention → rope
        q = rope(q.swapaxes(1, 2), positions, cfg.rope_theta).swapaxes(1, 2)
        kpos = positions if kv_cache is None else (
            cache_pos + jnp.arange(src.shape[1]))
        k = rope(k.swapaxes(1, 2), kpos, cfg.rope_theta).swapaxes(1, 2)

    if kv_cache is not None:
        ck = jax.lax.dynamic_update_slice(
            kv_cache["k"], k.astype(kv_cache["k"].dtype), (0, cache_pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            kv_cache["v"], v.astype(kv_cache["v"].dtype), (0, cache_pos, 0, 0))
        new_cache = {"k": ck, "v": cv}
        # decode attention over the full cache buffer with an explicit
        # absolute-position mask (stale slots beyond cache_pos+s excluded).
        q_abs = cache_pos + jnp.arange(s)
        out = decode_attend(q, ck, cv, q_abs, window=window)
        out = out.reshape(b, s, hq * hd)
        return dense_rp({"w": p["wo"]}, out), new_cache

    out = kops.attention(q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2),
                         causal=causal, window=window, impl=impl)
    out = out.swapaxes(1, 2).reshape(b, s, hq * hd)
    return dense_rp({"w": p["wo"]}, out), None


def decode_attend(q: jnp.ndarray, ck: jnp.ndarray, cv: jnp.ndarray,
                  q_abs: jnp.ndarray,
                  window: Optional[int] = None) -> jnp.ndarray:
    """Decode attention with a KV-cache buffer and absolute positions.

    q: [B, S, Hq, hd]; ck/cv: [B, S_max, Hkv, hd]; q_abs: [S] absolute
    positions of the query tokens. Linear in S_max (no S² transient).
    """
    b, s, hq, hd = q.shape
    hkv = ck.shape[2]
    group = hq // hkv
    qg = (q.reshape(b, s, hkv, group, hd).astype(jnp.float32)
          / math.sqrt(hd))
    scores = jnp.einsum("bshgd,bthd->bhgst", qg, ck.astype(jnp.float32))
    t_pos = jnp.arange(ck.shape[1])
    mask = t_pos[None, :] <= q_abs[:, None]
    if window is not None:
        mask &= t_pos[None, :] > q_abs[:, None] - window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgst,bthd->bshgd", probs, cv.astype(jnp.float32))
    return out.reshape(b, s, hq, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (DeepSeek-V2 / MiniCPM3)
# ---------------------------------------------------------------------------

def mla_init(key, cfg: ModelConfig, dtype) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    m = cfg.mla
    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    p = {
        "wkv_a": _init(ks[0], (d, m.kv_lora_rank + m.qk_rope_head_dim),
                       1.0 / math.sqrt(d), dtype),
        "kv_norm": rmsnorm_init(m.kv_lora_rank, dtype),
        "wkv_b": _init(ks[1], (m.kv_lora_rank,
                               h * (m.qk_nope_head_dim + m.v_head_dim)),
                       1.0 / math.sqrt(m.kv_lora_rank), dtype),
        "wo": _init(ks[2], (h * m.v_head_dim, d),
                    1.0 / math.sqrt(h * m.v_head_dim), dtype),
    }
    if m.q_lora_rank > 0:
        p["wq_a"] = _init(ks[3], (d, m.q_lora_rank), 1.0 / math.sqrt(d),
                          dtype)
        p["q_norm"] = rmsnorm_init(m.q_lora_rank, dtype)
        p["wq_b"] = _init(ks[4], (m.q_lora_rank, h * qd),
                          1.0 / math.sqrt(m.q_lora_rank), dtype)
    else:
        p["wq"] = _init(ks[5], (d, h * qd), 1.0 / math.sqrt(d), dtype)
    return p


def _mla_q(p: dict, x: jnp.ndarray, cfg: ModelConfig,
           positions: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    b, s, _ = x.shape
    m = cfg.mla
    h = cfg.n_heads
    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
    if "wq_a" in p:
        ql = rmsnorm(p["q_norm"], dense({"w": p["wq_a"]}, x), cfg.norm_eps)
        q = dense({"w": p["wq_b"]}, ql)
    else:
        q = dense({"w": p["wq"]}, x)
    q = q.reshape(b, s, h, qd)
    q_nope = q[..., :m.qk_nope_head_dim]
    q_rope = rope(q[..., m.qk_nope_head_dim:].swapaxes(1, 2), positions,
                  cfg.rope_theta).swapaxes(1, 2)
    return q_nope, q_rope


def mla_attend(p: dict, x: jnp.ndarray, positions: jnp.ndarray,
               cfg: ModelConfig, *, kv_cache: Optional[dict] = None,
               cache_pos: Optional[jnp.ndarray] = None,
               impl: Optional[str] = None
               ) -> Tuple[jnp.ndarray, Optional[dict]]:
    """MLA with compressed latent cache.

    Prefill/train: expand k/v per head and run fused attention.
    Decode (s small, cache present): ABSORBED path — attention runs in the
    kv_lora latent space; per-token cache cost is kv_lora + rope_dim floats.
    kv_cache: {"ckv": [B, S_max, R], "krope": [B, S_max, rd]}.
    """
    b, s, d = x.shape
    m = cfg.mla
    h = cfg.n_heads
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)

    kv = dense({"w": p["wkv_a"]}, x)
    ckv = rmsnorm(p["kv_norm"], kv[..., :m.kv_lora_rank], cfg.norm_eps)
    kpos = positions if kv_cache is None else (
        cache_pos + jnp.arange(s))
    krope = rope(kv[..., m.kv_lora_rank:][:, None], kpos,
                 cfg.rope_theta)[:, 0]                       # [B, S, rd]
    q_nope, q_rope = _mla_q(p, x, cfg, positions)

    wkv_b = kops.resolve(p["wkv_b"]).reshape(
        m.kv_lora_rank, h, m.qk_nope_head_dim + m.v_head_dim)
    wk = wkv_b[..., :m.qk_nope_head_dim]                     # [R, H, dn]
    wv = wkv_b[..., m.qk_nope_head_dim:]                     # [R, H, dv]

    if kv_cache is not None:
        cckv = jax.lax.dynamic_update_slice(
            kv_cache["ckv"], ckv.astype(kv_cache["ckv"].dtype),
            (0, cache_pos, 0))
        ckrope = jax.lax.dynamic_update_slice(
            kv_cache["krope"], krope.astype(kv_cache["krope"].dtype),
            (0, cache_pos, 0))
        new_cache = {"ckv": cckv, "krope": ckrope}
        # --- absorbed decode: q projected INTO the latent space ---
        q_lat = jnp.einsum("bshn,rhn->bshr", q_nope, wk,
                           preferred_element_type=jnp.float32)  # [B,S,H,R]
        s_lat = jnp.einsum("bshr,btr->bhst", q_lat,
                           cckv.astype(jnp.float32))
        s_rope = jnp.einsum("bshr,btr->bhst",
                            q_rope.astype(jnp.float32),
                            ckrope.astype(jnp.float32))
        scores = (s_lat + s_rope) * scale
        q_abs_pos = cache_pos + jnp.arange(s)
        t_pos = jnp.arange(cckv.shape[1])
        mask = t_pos[None, :] <= q_abs_pos[:, None]
        scores = jnp.where(mask[None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        o_lat = jnp.einsum("bhst,btr->bshr", probs,
                           cckv.astype(jnp.float32))          # [B,S,H,R]
        out = jnp.einsum("bshr,rhv->bshv", o_lat, wv.astype(jnp.float32))
        out = out.reshape(b, s, h * m.v_head_dim).astype(x.dtype)
        return dense_rp({"w": p["wo"]}, out), new_cache

    # --- prefill/train: expand and use the fused kernel ---
    k_nope = jnp.einsum("btr,rhn->bthn", ckv, wk,
                        preferred_element_type=jnp.float32).astype(x.dtype)
    v = jnp.einsum("btr,rhv->bthv", ckv, wv,
                   preferred_element_type=jnp.float32).astype(x.dtype)
    k_rope_b = jnp.broadcast_to(krope[:, :, None, :],
                                (b, s, h, m.qk_rope_head_dim))
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    # pad v head dim up to qk dim for the fused kernel, slice after
    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
    v_pad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, qd - m.v_head_dim)))
    out = kops.attention(q_full.swapaxes(1, 2), k_full.swapaxes(1, 2),
                         v_pad.swapaxes(1, 2), causal=True, scale=scale,
                         impl=impl)
    out = out.swapaxes(1, 2)[..., :m.v_head_dim].reshape(
        b, s, h * m.v_head_dim)
    return dense_rp({"w": p["wo"]}, out), None


# ---------------------------------------------------------------------------
# MLPs (gated SwiGLU — llama family) and MoE
# ---------------------------------------------------------------------------

def mlp_init(key, d: int, d_ff: int, dtype) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "wi": _init(ks[0], (d, d_ff), 1.0 / math.sqrt(d), dtype),
        "wg": _init(ks[1], (d, d_ff), 1.0 / math.sqrt(d), dtype),
        "wd": _init(ks[2], (d_ff, d), 1.0 / math.sqrt(d_ff), dtype),
    }


def mlp(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.silu(dense({"w": p["wg"]}, x).astype(jnp.float32)) \
        * dense({"w": p["wi"]}, x).astype(jnp.float32)
    return dense_rp({"w": p["wd"]}, h.astype(x.dtype))


def moe_init(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    m = cfg.moe
    ks = jax.random.split(key, 5)
    p = {
        "router": _init(ks[0], (d, m.n_experts), 1.0 / math.sqrt(d), dtype),
        "we_i": _init(ks[1], (m.n_experts, d, m.d_expert),
                      1.0 / math.sqrt(d), dtype),
        "we_g": _init(ks[2], (m.n_experts, d, m.d_expert),
                      1.0 / math.sqrt(d), dtype),
        "we_d": _init(ks[3], (m.n_experts, m.d_expert, d),
                      1.0 / math.sqrt(m.d_expert), dtype),
    }
    if m.n_shared_experts > 0:
        p["shared"] = mlp_init(ks[4], d, m.d_expert * m.n_shared_experts,
                               dtype)
    return p


def _axes_size(axes) -> int:
    import jax.core as _core  # axis sizes resolved at trace time via mesh
    from repro.runtime.sharding import _HINT_MESH
    mesh = _HINT_MESH.get()
    if mesh is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _row_pin(x: jnp.ndarray) -> jnp.ndarray:
    """Inside the vmapped MoE row fn: pin all unmapped dims replicated.

    Under vmap(spmd_axis_name=client_axes) the constraint becomes
    P(clients, None, ...) on the batched value — exactly what keeps the
    dispatch gather/scatter local to each client shard (GSPMD's propagation
    through batched gathers otherwise replicates the operand)."""
    from repro.runtime.sharding import _HINT_MESH
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = _HINT_MESH.get()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*([None] * x.ndim))))


def _moe_row(p: dict, xr: jnp.ndarray, e: int, k: int, cap: int,
             pin=None) -> jnp.ndarray:
    """Dispatch one token row [T, D] through capacity-grouped experts.

    Dispatch/combine are gathers/scatters (memory ops, not FLOPs — unlike the
    classic GShard one-hot einsums, which are quadratic in tokens); expert
    compute is a [E,C,D]×[E,D,F] batched einsum (MXU-friendly).

    `pin` overrides the per-tensor sharding pin (default: `_row_pin` for the
    vmapped train path; the EP decode path pins the expert dim to `model`).
    """
    if pin is None:
        pin = _row_pin
    t, d = xr.shape
    logits = dense({"w": p["router"]}, xr).astype(jnp.float32)   # [T, E]
    gates, top_idx = jax.lax.top_k(logits, k)                    # [T, k]
    gates = jax.nn.softmax(gates, axis=-1)

    # position of each (token, slot) in its expert queue
    flat_e = top_idx.reshape(-1)                                 # [T*k]
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
    pos = jnp.take_along_axis(jnp.cumsum(onehot, axis=0) - 1,
                              flat_e[:, None], axis=1)[:, 0]
    keep = pos < cap                                             # drop overflow

    tok_ids = jnp.repeat(jnp.arange(t), k)
    slot_e = jnp.where(keep, flat_e, 0)
    slot_c = jnp.where(keep, pos, cap - 1)
    dispatch_tok = jnp.zeros((e, cap), dtype=jnp.int32).at[
        slot_e, slot_c].set(jnp.where(keep, tok_ids, 0), mode="drop")
    dispatch_w = jnp.zeros((e, cap), dtype=jnp.float32).at[
        slot_e, slot_c].set(jnp.where(keep, gates.reshape(-1), 0.0),
                            mode="drop")

    xe = pin(jnp.take(xr, dispatch_tok.reshape(-1), axis=0
                      ).reshape(e, cap, d))                      # gather
    # expert banks resolve to per-layer transients when ZO-fusion-tagged
    hi = jnp.einsum("ecd,edf->ecf", xe, kops.resolve(p["we_i"]),
                    preferred_element_type=jnp.float32)
    hg = jnp.einsum("ecd,edf->ecf", xe, kops.resolve(p["we_g"]),
                    preferred_element_type=jnp.float32)
    h = (jax.nn.silu(hg) * hi).astype(xr.dtype)
    from repro.runtime.sharding import bf16_reduce_active
    down_dt = (jnp.bfloat16 if bf16_reduce_active()
               and xr.dtype == jnp.bfloat16 else jnp.float32)
    ye = jnp.einsum("ecf,efd->ecd", h, kops.resolve(p["we_d"]),
                    preferred_element_type=down_dt)              # [E, C, D]
    ye = ye * dispatch_w[..., None]
    out = jnp.zeros((t, d), dtype=jnp.float32).at[
        dispatch_tok.reshape(-1)].add(ye.reshape(-1, d), mode="drop")
    return _row_pin(out.astype(xr.dtype)) if pin is _row_pin \
        else out.astype(xr.dtype)


def _moe_tiny_tokens(p: dict, x: jnp.ndarray, cfg: ModelConfig
                     ) -> jnp.ndarray:
    """EP decode path (§Perf hillclimb cell 3): for tiny token counts
    (decode steps) the dispatch runs GLOBALLY (no per-row vmap) with the
    expert dim pinned to `model`. Combined with the serve-time expert
    layout (E→model, FSDP on the contraction dim; sharding.param_spec
    serve=True), GSPMD keeps weights resident and psums only token-sized
    activations — instead of streaming ~1 GB/layer of expert weights per
    generated token."""
    from repro.runtime.sharding import _HINT_MESH
    from jax.sharding import NamedSharding, PartitionSpec as P
    b, s, d = x.shape
    m = cfg.moe
    e, k = m.n_experts, m.n_experts_per_tok
    t = b * s
    cap = max(int(math.ceil(k * t * m.capacity_factor / e)), 1)
    mesh = _HINT_MESH.get()

    def pin_e(arr):  # expert-dim over model, rest replicated
        if mesh is None:
            return arr
        spec = ["model"] + [None] * (arr.ndim - 1)
        return jax.lax.with_sharding_constraint(
            arr, NamedSharding(mesh, P(*spec)))

    out = _moe_row(p, x.reshape(t, d), e, k, cap, pin=pin_e)
    if mesh is not None:
        out = jax.lax.with_sharding_constraint(
            out, NamedSharding(mesh, P(*([None] * out.ndim))))
    out = out.reshape(b, s, d)
    if "shared" in p:
        out = out + mlp(p["shared"], x)
    return out


def moe(p: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Chunked capacity-grouped top-k MoE.

    x: [B, S, D]. The sequence is processed in dispatch groups of
    `moe.chunk` tokens under lax.scan, bounding the duplicated-token
    transient to B·chunk·k·cf·D instead of B·S·k·cf·D. Dispatch is
    *per batch row* (vmap), so tokens never cross client/batch shards —
    no collective traffic is induced on the client axes.
    """
    from repro.runtime.sharding import hint
    b, s, d = x.shape
    m = cfg.moe
    e, k = m.n_experts, m.n_experts_per_tok
    if b * s <= 4096 and e % _axes_size("model") == 0 \
            and _axes_size("model") > 1:
        return _moe_tiny_tokens(p, x, cfg)
    x = hint(x, "client", None, None)
    chunk = min(m.chunk, s) if m.chunk > 0 else s
    if s % chunk != 0:
        chunk = s  # tiny/smoke shapes: single group
    n_c = s // chunk
    cap = max(int(math.ceil(k * chunk * m.capacity_factor / e)), 1)

    from repro.runtime.sharding import current_client_axes
    spmd = current_client_axes()
    if spmd is not None and b % _axes_size(spmd) == 0:
        # keep the vmapped row dim sharded over clients through the
        # dispatch gather/scatter (GSPMD propagation alone loses it)
        row_fn = jax.vmap(lambda xr: _moe_row(p, xr, e, k, cap),
                          spmd_axis_name=spmd)
    else:
        row_fn = jax.vmap(lambda xr: _moe_row(p, xr, e, k, cap))

    if n_c == 1:
        out = row_fn(x)
        out = hint(out, "client", None, None)
    else:
        xs = x.reshape(b, n_c, chunk, d).swapaxes(0, 1)   # [n_c, B, chunk, D]

        def step(_, xc):
            return None, row_fn(xc)

        _, ys = jax.lax.scan(step, None, xs)
        out = ys.swapaxes(0, 1).reshape(b, s, d)
    out = hint(out, "client", None, None)
    if "shared" in p:
        out = out + mlp(p["shared"], x)
    return out
