"""InternVL2-style VLM: vision-frontend STUB + LM backbone.

Per the assignment, the modality frontend is a stub: input_specs() supplies
precomputed patch embeddings [B, n_img_tokens, d_model] (InternViT output
after the mlp1 projector). They are prepended to the text embeddings and the
full sequence runs through the standard decoder-only backbone
(transformer.py). Loss is masked to text positions by the data pipeline.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T


def init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    return T.init(key, cfg, dtype)


def forward(params: dict, cfg: ModelConfig, tokens: jnp.ndarray, *,
            prefix_embeds: Optional[jnp.ndarray] = None,
            impl: Optional[str] = None) -> jnp.ndarray:
    return T.forward(params, cfg, tokens, prefix_embeds=prefix_embeds,
                     impl=impl)


def token_nll(params, cfg, tokens, targets, mask, *, prefix_embeds=None,
              impl=None):
    return T.token_nll(params, cfg, tokens, targets, mask,
                       prefix_embeds=prefix_embeds, impl=impl)


def loss_per_client(params: dict, cfg: ModelConfig, batch: dict, *,
                    impl: Optional[str] = None) -> jnp.ndarray:
    assert "prefix_embeds" in batch, "vlm batches carry patch embeddings"
    return T.loss_per_client(params, cfg, batch, impl=impl)


def prefill(params: dict, cfg: ModelConfig, tokens: jnp.ndarray, *,
            prefix_embeds: Optional[jnp.ndarray] = None,
            impl: Optional[str] = None) -> Tuple[jnp.ndarray, dict]:
    return T.prefill(params, cfg, tokens, prefix_embeds=prefix_embeds,
                     impl=impl)


def decode_step(params: dict, cfg: ModelConfig, cache: dict,
                tokens: jnp.ndarray, cache_pos, *,
                impl: Optional[str] = None):
    return T.decode_step(params, cfg, cache, tokens, cache_pos, impl=impl)


init_cache = T.init_cache
