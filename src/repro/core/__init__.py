"""pAirZero core — the paper's contribution.

zo:            seeded SPSA (MeZO-chained dual forward, scalar projections)
ota:           over-the-air channel model (analog + sign, channel inversion)
transport:     pluggable uplink mechanisms (Transport protocol + registry:
               analog | sign | perfect | digital | fo)
dp:            (ε, δ) accountant — R_dp, C(x), bisection inverse
power_control: Theorems 3 & 4 closed-form schedules (+ Static/Reversed)
pairzero:      composable jitted train-step factory over any Transport
fedsim:        Experiment orchestrator + round hooks (faults, checkpoints,
               eval) shared by the loop and scan engines
"""
from repro.core import dp, ota, power_control, transport, zo  # noqa: F401
