"""pAirZero core — the paper's contribution.

zo:            seeded SPSA (MeZO-chained dual forward, scalar projections)
ota:           over-the-air channel model (analog + sign, channel inversion)
dp:            (ε, δ) accountant — R_dp, C(x), bisection inverse
power_control: Theorems 3 & 4 closed-form schedules (+ Static/Reversed)
pairzero:      composable jitted train-step factory (analog | sign | fo)
fedsim:        host-side federated driver (faults, checkpoints, eval)
"""
from repro.core import dp, ota, power_control, zo  # noqa: F401
