"""Pluggable uplink mechanisms: the Transport protocol + registry.

The paper's central claim is comparative — analog/sign OTA superposition vs
conventional digital orthogonal transmission on communication, memory and
privacy (Table II, Figs. 2-4). A `Transport` is one such uplink mechanism,
owning everything that used to be string-dispatched across four modules:

  (a) jit-side `aggregate(p_k, ctl, key) -> p_hat` plus the control-block
      spec the step factory feeds it (`control_spec`),
  (b) the host-side schedule solve (`make_schedule` — power control for the
      OTA transports, trivial for digital/FO),
  (c) the per-round DP cost charged to the accountant (`round_dp_costs`,
      `charges_privacy`),
  (d) the per-round communication cost in bits (`payload_bits` per client,
      `bits_per_round` = payload x clients) — so Table II's comm column is
      computed, not hard-coded.

Mechanisms are frozen dataclasses (hashable, so the memoized step factories
and the jit/scan caches key on them) registered by name:

  analog   — analog pAirZero: clipped projection over superposing OTA
             (Eqs. 8-9), channel inversion, Theorem-3 power control.
  sign     — Sign-pAirZero: 1-bit sign over OTA (Eq. 11), Theorem-4 control.
  perfect  — noise-free superposition upper bound (Eq. 38).
  digital  — conventional baseline: per-client b-bit stochastic quantization
             over orthogonal TDMA slots, no superposition, no DP mechanism.
  smart_digital — FedZO-style seed-and-scalar digital: the shared-seed trick
             shrinks the slot payload to b bits per perturbation, but
             orthogonal decoding still leaks every client's scalar.
  fo       — first-order FedSGD/Adam baseline (d-dimensional uplink).

Each mechanism additionally exposes its *eavesdropper observation model*
(`observe`/`observation_spec`) — what an over-the-air listener records per
round — consumed by the privacy subsystem (repro.privacy: attacks + the
empirical DP audit).

New scenarios (imperfect CSI, straggler-aware schemes, RIS channels) plug in
here: subclass `Transport`, decorate with `@register("name")`, and every
engine, launcher and benchmark can run it. See README "Adding a transport".
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Dict, Optional, Type

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ota
from repro.core.dp import round_privacy_cost

# Power-control schemes understood by the OTA transports. "perfect" doubles
# as the noise-free channel (no schedule solve, no DP spend).
OTA_SCHEMES = ("solution", "static", "reversed", "perfect")


def client_all_gather(x: jnp.ndarray, axis_names: tuple, offset: jnp.ndarray,
                      k_total: int) -> jnp.ndarray:
    """Reassemble the full per-client [..., K] array from this shard's
    [..., K/n] slice, inside a shard_map over the client mesh axes.

    Every shard scatters its slice into a zeroed [..., K] buffer at its
    `offset` (the shard's first global client id — delivered as *data*, a
    client-id iota sharded exactly like the batch, because `lax.axis_index`
    does not lower under partial-auto meshes on jax 0.4.x) and ONE
    `jax.lax.psum` over the named axes sums the disjoint supports — the
    all-reduce IS the simulated over-the-air superposition, and it is what
    shows up in the compiled HLO. Adding zero is bitwise-exact, so the
    gathered vector is bit-identical to the single-device payload (the
    only caveat is the sign of a ±0.0 payload, which cannot affect the
    update).
    """
    full = jnp.zeros(x.shape[:-1] + (k_total,), x.dtype)
    full = jax.lax.dynamic_update_slice_in_dim(full, x, offset, axis=-1)
    return jax.lax.psum(full, axis_names)


def masked_ctl(ctl: Dict[str, jnp.ndarray], mask: jnp.ndarray
               ) -> Dict[str, jnp.ndarray]:
    """Control block with a substituted survival mask — the sub-slot decode
    convention: a robust defense (repro.byzantine.defenses) decodes each
    chunked re-transmission group by re-running the mechanism's own
    `aggregate` with the mask restricted to that group's clients; every
    other control field (inversion gain, noise floor, CSI factors) is the
    round's broadcast values, shared across sub-slots."""
    out = dict(ctl)
    out["mask"] = mask
    return out


# ---------------------------------------------------------------------------
# Protocol
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Transport:
    """One uplink mechanism. Subclass + `@register(name)` to add one.

    Subclasses are frozen dataclasses: every field that changes the traced
    computation (scheme, quantizer bits, clip range) is part of the hash, so
    the lru-cached step factories retrace exactly when they must.
    """

    #: registry name (set by @register)
    name = "?"
    #: "zo" transports carry a scalar projection; "fo" carries full gradients
    kind = "zo"

    @classmethod
    def from_config(cls, tc, pz) -> "Transport":
        """Build an instance from a TransportConfig + run config. The default
        suits parameter-free mechanisms; override to consume tc/pz fields
        (scheme, quant_bits, clip range, ...)."""
        return cls()

    # -- jit side ---------------------------------------------------------
    def aggregate(self, p: jnp.ndarray, ctl: Dict[str, jnp.ndarray],
                  key: jax.Array) -> jnp.ndarray:
        """Recover the server-side estimate p_hat from the [K] per-client
        payload vector under this round's control block."""
        raise NotImplementedError

    def aggregate_mesh(self, p_local: jnp.ndarray,
                       ctl: Dict[str, jnp.ndarray], key: jax.Array,
                       axis_names: tuple, offset: jnp.ndarray) -> jnp.ndarray:
        """Cross-device aggregate for the shard_map'd step: the [K] client
        axis lives on the mesh, so `p_local` is this shard's [K/n] slice
        and `offset` its first global client id (see `client_all_gather`).

        The default reassembles the full payload with one `jax.lax.psum`
        over the named client axes (`client_all_gather` — the all-reduce is
        the over-the-air superposition) and decodes identically to the
        single-device `aggregate`, which is what makes the mesh engine
        bit-identical to the single-device engine. A mechanism may override
        to psum locally-reduced partial sums instead (a scalar-only
        collective payload — the paper's O(1) uplink taken literally at the
        cost of fp-reduction-order bit-identity)."""
        k_total = ctl["mask"].shape[-1]
        p = client_all_gather(p_local, axis_names, offset, k_total)
        return self.aggregate(p, ctl, key)

    def control_spec(self, n_clients: int) -> Dict[str, jax.ShapeDtypeStruct]:
        """Abstract shapes of the per-round control block this mechanism's
        step consumes (dry-run input spec). The standard block serves every
        built-in transport; override to add mechanism-specific fields."""
        return {
            "seed": jax.ShapeDtypeStruct((), jnp.uint32),
            "c": jax.ShapeDtypeStruct((), jnp.float32),
            "sigma": jax.ShapeDtypeStruct((n_clients,), jnp.float32),
            "n0": jax.ShapeDtypeStruct((), jnp.float32),
            "mask": jax.ShapeDtypeStruct((n_clients,), jnp.float32),
            "g": jax.ShapeDtypeStruct((n_clients,), jnp.float32),
            "noise_bits": jax.ShapeDtypeStruct((2,), jnp.uint32),
        }

    # -- eavesdropper observation model (repro.privacy) -------------------
    def observe(self, p: jnp.ndarray, ctl: Dict[str, jnp.ndarray],
                key: jax.Array) -> Dict[str, jnp.ndarray]:
        """What an over-the-air listener at the receiver front-end sees
        when the [K] payload vector `p` is transmitted under this round's
        control block — BEFORE any server-side decode.

        Called with the same per-round key as `aggregate`, so noise draws
        are bit-identical to the signal the server actually decoded: for
        the OTA mechanisms the observation is the superposed noisy scalar
        of Eq. 4 (the quantity Lemma 1 privatizes); for digital orthogonal
        transmission every client's payload is individually decodable (the
        trilemma's third corner). Pure and passive — calling it never
        perturbs the training trajectory. Default: nothing observable
        (mechanisms without a modeled eavesdropper)."""
        return {}

    def observation_spec(self, n_clients: int
                         ) -> Dict[str, jax.ShapeDtypeStruct]:
        """Abstract shapes of the `observe()` dict (capture/dry-run spec)."""
        return {}

    def transmitted(self, p: jnp.ndarray) -> jnp.ndarray:
        """The [K] payload actually radiated given the clipped projections
        `p` — the ground truth observation-based attacks score against.
        Identity for the scalar-payload mechanisms; the sign transport
        radiates ±1 ballots."""
        return p

    # -- host side --------------------------------------------------------
    def make_schedule(self, trace, pz) -> "object":
        """Solve the transmit plan for the horizon (a PowerSchedule).

        `trace` is the realized ChannelTrace (repro.channel) — or, for
        backward compatibility, a bare [T, K] magnitude array. OTA
        transports run the Theorem-3/4 solvers on the trace magnitudes
        (per-client mean powers from a geometry wrapper enter the power-cap
        min over k); non-OTA transports return a trivial plan."""
        return _trivial_schedule(trace_magnitudes(trace), scheme="perfect")

    def charges_privacy(self, schedule, pz) -> bool:
        """Whether rounds under this transport spend (eps, delta) budget."""
        return False

    def canary_payload(self, pz) -> Optional[float]:
        """Worst-case payload magnitude one client can contribute — the
        canary the empirical DP audit (repro.privacy.audit) injects. None
        means the mechanism provides no DP guarantee to audit."""
        return None

    def round_dp_costs(self, schedule, t0: int, t1: int, pz) -> np.ndarray:
        """Per-round DP cost vector for rounds [t0, t1) (Eq. 16 terms);
        zeros when the mechanism provides no DP guarantee."""
        return np.zeros(t1 - t0)

    # -- communication accounting ----------------------------------------
    def payload_bits(self, pz, d: int) -> int:
        """Uplink bits ONE client sends per round (d = model dimension)."""
        raise NotImplementedError

    def bits_per_round(self, pz, d: int) -> int:
        """Total uplink bits per round: payload x clients. OTA superposition
        collapses K transmissions into one resource block, but every client
        still radiates its payload — the accounting is per transmitted bit."""
        return pz.n_clients * self.payload_bits(pz, d)


def uplink_bits_total(transport: "Transport", defense, pz, d: int,
                      client_rounds: float, rounds: int) -> int:
    """Total uplink spend for `rounds` executed rounds with Σ_t K_eff(t) =
    `client_rounds` transmitting client-rounds: payload per transmitting
    client times client-rounds, with a defense's payload factor and
    side-channel bits billed on top.

    This is THE uplink accounting expression — `fedsim.Experiment` and the
    trilemma ledger (`repro.obs.MetricsSink`) both call it, in the same
    operation order, so the ledger's cumulative bits land on the exact
    `RunResult.uplink_bits` integer (per-client payloads and K_eff counts
    are integer-valued, so the float64 products/sums are exact well past
    any realistic horizon).
    """
    bits = transport.payload_bits(pz, d) * client_rounds
    if defense is not None:
        bits = bits * defense.payload_bits_factor(pz) \
            + defense.extra_bits_per_round(pz, d) * rounds
    return int(round(bits))


def trace_magnitudes(trace) -> np.ndarray:
    """[T, K] channel magnitudes from a ChannelTrace or a bare array (the
    pre-channel-registry calling convention, kept working one release)."""
    return np.asarray(getattr(trace, "h", trace), dtype=np.float64)


def _trivial_schedule(h: np.ndarray, scheme: str = "perfect"):
    from repro.core.power_control import PowerSchedule
    t, k = trace_magnitudes(h).shape
    return PowerSchedule(c=np.ones(t), sigma=np.zeros((t, k)),
                         scheme=scheme, n0=0.0)


def ota_dp_costs(schedule, t0: int, t1: int, gamma: float) -> np.ndarray:
    """Vectorized Eq.-16 terms, bit-equal to the per-round accountant path
    (same float64 ops round for round)."""
    c = np.asarray(schedule.c[t0:t1], dtype=np.float64)
    sigma = np.asarray(schedule.sigma[t0:t1], dtype=np.float64)
    m = np.sqrt(c * c * np.sum(sigma ** 2, axis=1) + schedule.n0)
    return np.asarray([round_privacy_cost(float(c[r]), gamma, float(m[r]))
                       if c[r] != 0.0 else 0.0 for r in range(len(c))])


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Type[Transport]] = {}


def register(name: str):
    """Class decorator: `@register("analog")` adds a Transport to the
    registry under `name` (and sets `cls.name`)."""
    def deco(cls: Type[Transport]) -> Type[Transport]:
        cls.name = name
        _REGISTRY[name] = cls
        return cls
    return deco


def available() -> tuple:
    """Sorted names of every registered transport mechanism."""
    return tuple(sorted(_REGISTRY))


def get(name: str) -> Type[Transport]:
    """Look up a registered Transport class by mechanism name."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown transport {name!r} "
                         f"(registered: {available()})") from None


def resolve(pz, scheme: Optional[str] = None) -> Transport:
    """Build the Transport instance a PairZeroConfig asks for.

    New-style configs carry `pz.transport` (a TransportConfig); legacy
    configs are resolved from the free-floating `variant` + `power.scheme`
    strings — the one-release deprecation shim."""
    tc = getattr(pz, "transport", None)
    if tc is not None:
        return get(tc.mechanism).from_config(tc, pz)
    return from_strings(pz.variant, scheme or pz.power.scheme, pz)


def from_strings(variant: str, scheme: str, pz=None) -> Transport:
    """Legacy (variant, scheme) strings -> Transport instance."""
    if variant == "analog":
        return AnalogOTA(scheme=scheme)
    if variant == "sign":
        return SignOTA(scheme=scheme)
    if variant == "fo":
        return FirstOrder()
    if variant == "digital":
        if pz is None:
            raise ValueError("the digital transport needs run-config "
                             "context (quantizer clip range) — build it "
                             "via TransportConfig or DigitalTDMA directly")
        return DigitalTDMA(clip=float(pz.zo.clip_gamma))
    raise ValueError(f"unknown variant: {variant!r}")


def deprecated_strings(variant: str, scheme: str, where: str) -> None:
    """Emit the one-release DeprecationWarning for string dispatch."""
    warnings.warn(
        f"{where}: string-dispatched variant={variant!r}/scheme={scheme!r} "
        "is deprecated; pass a TransportConfig (configs.base) or a Transport "
        "from repro.core.transport instead. The shim routes through the "
        "transport registry and will be removed next release.",
        DeprecationWarning, stacklevel=3)


# ---------------------------------------------------------------------------
# OTA transports (analog / sign / perfect)
# ---------------------------------------------------------------------------

@register("analog")
@dataclass(frozen=True)
class AnalogOTA(Transport):
    """Analog pAirZero: clipped fp projection over superposing OTA.

    Payload: one fp16 scalar per perturbation direction (Table II's
    "16 bits"); privacy: channel + artificial noise per Lemma 1."""
    scheme: str = "solution"

    @classmethod
    def from_config(cls, tc, pz) -> "AnalogOTA":
        """Build from a TransportConfig (only the scheme carries over)."""
        return cls(scheme=tc.scheme)

    def aggregate(self, p, ctl, key):
        """Recover p_hat from the superposed noisy uplink (Eq. 4 decode)."""
        if self.scheme == "perfect":
            return ota.perfect_analog(p, ctl["mask"])
        return ota.analog_ota(p, ctl["c"], ctl["sigma"], ctl["n0"], key,
                              ctl["mask"], ctl.get("g"),
                              ctl.get("dsync_a"))[0]

    def observe(self, p, ctl, key):
        """What an eavesdropper hears: the same electromagnetic
        superposition the server front-end receives — one noisy scalar per
        round (Eq. 4), bit-identical to the decode path's input (same key,
        same draws). Noise-free "perfect" rounds superpose without
        channel/artificial noise — the observation is the bare masked sum."""
        if self.scheme == "perfect":
            w = ctl["mask"].astype(p.dtype)
            return {"y": jnp.sum(w * p)}
        y, _ = ota.superpose(p, ctl["c"], ctl["sigma"], ctl["n0"], key,
                             ctl["mask"], ctl.get("g"), ctl.get("dsync_a"))
        return {"y": y}

    def observation_spec(self, n_clients):
        """Abstract shape of one round's observation: a single scalar."""
        return {"y": jax.ShapeDtypeStruct((), jnp.float32)}

    def make_schedule(self, trace, pz):
        """Solve the horizon's power control (Theorem 3) on the trace
        magnitudes for this mechanism's scheme."""
        from repro.core import power_control as pc
        h = trace_magnitudes(trace)
        if self.scheme == "perfect":
            return _trivial_schedule(h)
        kw = dict(power=pz.channel.power, n0=pz.channel.n0,
                  gamma=pz.zo.clip_gamma, epsilon=pz.dp.epsilon,
                  delta=pz.dp.delta)
        if self.scheme == "solution":
            return pc.solve_analog(h, contraction_a=pz.power.contraction_a,
                                   **kw)
        if self.scheme == "static":
            return pc.static_analog(h, **kw)
        if self.scheme == "reversed":
            return pc.reversed_analog(
                h, contraction_a=pz.power.contraction_a, **kw)
        raise ValueError(f"unknown power-control scheme: {self.scheme!r} "
                         f"(want one of {OTA_SCHEMES})")

    def charges_privacy(self, schedule, pz) -> bool:
        """Noisy OTA rounds spend (eps, delta); "perfect" rounds do not."""
        return bool(pz.dp.enabled and schedule.scheme != "perfect")

    def round_dp_costs(self, schedule, t0, t1, pz):
        """Per-round DP spend over [t0, t1) with sensitivity gamma."""
        return ota_dp_costs(schedule, t0, t1, pz.zo.clip_gamma)

    def canary_payload(self, pz):
        """Worst-case payload for the empirical audit: projections are
        clipped to +/-gamma (Assumption 3), so the canary transmits the
        clip boundary."""
        return None if self.scheme == "perfect" else float(pz.zo.clip_gamma)

    def payload_bits(self, pz, d):
        """Uplink bits/round/client: one fp16 scalar per perturbation."""
        return 16 * pz.zo.n_perturb


@register("sign")
@dataclass(frozen=True)
class SignOTA(AnalogOTA):
    """Sign-pAirZero: 1-bit majority consensus via superposition (Eq. 11).

    The sensitivity entering the DP cost is 1 (signs), not gamma."""
    scheme: str = "solution"

    def aggregate(self, p, ctl, key):
        """Recover the majority vote from the superposed sign ballots."""
        if self.scheme == "perfect":
            return ota.perfect_sign(p, ctl["mask"])
        return ota.sign_ota(p, ctl["c"], ctl["sigma"], ctl["n0"], key,
                            ctl["mask"], ctl.get("g"),
                            ctl.get("dsync_a"))[0]

    def observe(self, p, ctl, key):
        """The radiated payload is the +/-1 ballot, so the listener hears
        the superposed noisy vote count — individual sign bits only
        superpose, they are never separable over the air (unlike digital
        slots)."""
        return super().observe(jnp.sign(p), ctl, key)

    def transmitted(self, p):
        """The on-air payload: the sign of the clipped projection."""
        return jnp.sign(p)

    def make_schedule(self, trace, pz):
        """Solve the sign-variant power control (Theorem 4) on the trace
        magnitudes for this mechanism's scheme."""
        from repro.core import power_control as pc
        h = trace_magnitudes(trace)
        if self.scheme == "perfect":
            return _trivial_schedule(h)
        kw = dict(power=pz.channel.power, n0=pz.channel.n0,
                  epsilon=pz.dp.epsilon, delta=pz.dp.delta)
        if self.scheme == "solution":
            return pc.solve_sign(
                h, n_clients=pz.n_clients, e0=pz.power.e0,
                contraction_a_tilde=pz.power.contraction_a_tilde, **kw)
        if self.scheme == "static":
            return pc.static_sign(h, **kw)
        if self.scheme == "reversed":
            return pc.reversed_sign(
                h, n_clients=pz.n_clients, e0=pz.power.e0,
                contraction_a_tilde=pz.power.contraction_a_tilde, **kw)
        raise ValueError(f"unknown power-control scheme: {self.scheme!r} "
                         f"(want one of {OTA_SCHEMES})")

    def round_dp_costs(self, schedule, t0, t1, pz):
        """Per-round DP spend over [t0, t1); sign sensitivity is 1."""
        return ota_dp_costs(schedule, t0, t1, 1.0)

    def canary_payload(self, pz):
        """Worst-case payload for the empirical audit: a +/-1 ballot."""
        return None if self.scheme == "perfect" else 1.0

    def payload_bits(self, pz, d):
        """Uplink bits/round/client: one sign bit per perturbation."""
        return 1 * pz.zo.n_perturb


@register("perfect")
@dataclass(frozen=True)
class PerfectUplink(AnalogOTA):
    """Noise-free superposition upper bound (Eq. 38) as a first-class
    mechanism (legacy spelling: variant="analog", scheme="perfect")."""
    scheme: str = "perfect"

    @classmethod
    def from_config(cls, tc, pz) -> "PerfectUplink":
        """Build from a TransportConfig (no tunables; scheme is fixed)."""
        return cls()


# ---------------------------------------------------------------------------
# Digital baseline (conventional orthogonal transmission)
# ---------------------------------------------------------------------------

def stochastic_quantize(p: jnp.ndarray, key: jax.Array, *, bits: int,
                        clip: float) -> jnp.ndarray:
    """Unbiased b-bit stochastic quantizer on [-clip, +clip].

    The range is split into 2^b - 1 cells; a value is rounded to the upper
    cell edge with probability equal to its fractional position, so
    E[Q(p)] = clamp(p) exactly (QSGD-style dithering).
    """
    levels = jnp.float32(2 ** bits - 1)
    half = jnp.float32(clip)
    u = (jnp.clip(p, -half, half) + half) * (levels / (2.0 * half))
    lo = jnp.floor(u)
    up = (jax.random.uniform(key, p.shape, p.dtype) < (u - lo)
          ).astype(p.dtype)
    return (lo + up) * (2.0 * half / levels) - half


@register("digital")
@dataclass(frozen=True)
class DigitalTDMA(Transport):
    """Conventional digital uplink: b-bit stochastic quantization, one
    orthogonal TDMA slot per client, no superposition, no DP mechanism.

    This is the baseline pAirZero is compared against. Without the shared-
    seed reconstruction trick, a conventional client must upload its whole
    d-dimensional model update — quantized to `quant_bits` per coordinate —
    so the payload scales with model size (Table II's FO-style comm column)
    while OTA uploads a constant handful of bits. The trajectory-level
    simulation applies the statistically equivalent scalar form: each
    client's clipped projection is stochastically quantized and the base
    station decodes every slot error-free and averages (TDMA at scheduled
    SNR; quantization, not channel noise, is the distortion).

    Privacy: none — digital orthogonal decoding exposes each client's
    payload exactly (the trilemma's third corner). The accountant is never
    charged and `charges_privacy` is False; pair with DPConfig(enabled=False)
    or treat runs as non-private.
    """
    quant_bits: int = 8
    clip: float = 1.0

    @classmethod
    def from_config(cls, tc, pz) -> "DigitalTDMA":
        """Build from a TransportConfig; the quantizer clips at gamma."""
        return cls(quant_bits=tc.quant_bits, clip=float(pz.zo.clip_gamma))

    def aggregate(self, p, ctl, key):
        """Straggler-aware TDMA decode: clients masked out (faults OR
        deep-fade outage from the channel trace) yield their slots — the
        decode averages only scheduled slots, and the mask-aware bit
        accounting never bills an unscheduled payload. Per-slot decode is
        coherent, so the OTA CSI phase factor `g` does not distort the
        scalar."""
        mask = ctl["mask"].astype(p.dtype)
        q = stochastic_quantize(p, key, bits=self.quant_bits, clip=self.clip)
        return jnp.sum(mask * q) / jnp.maximum(jnp.sum(mask), 1.0)

    def observe(self, p, ctl, key):
        """Orthogonal slots are the privacy failure mode: an eavesdropper
        decodes every scheduled client's payload INDIVIDUALLY, exactly as
        the base station does (same key => same dither draw). Unscheduled
        slots radiate nothing (masked to 0 in the observation)."""
        mask = ctl["mask"].astype(p.dtype)
        q = stochastic_quantize(p, key, bits=self.quant_bits, clip=self.clip)
        return {"q": mask * q}

    def observation_spec(self, n_clients):
        """Abstract observation shape: one decoded scalar per client."""
        return {"q": jax.ShapeDtypeStruct((n_clients,), jnp.float32)}

    def make_schedule(self, trace, pz):
        """No power control to solve — TDMA slots run at scheduled SNR."""
        return _trivial_schedule(trace_magnitudes(trace), scheme="digital")

    def payload_bits(self, pz, d):
        """Uplink bits/round/client: one combined d-dimensional update,
        b bits per coordinate (perturbation directions sum into a single
        uploaded vector)."""
        return self.quant_bits * d


@register("smart_digital")
@dataclass(frozen=True)
class SmartDigital(DigitalTDMA):
    """FedZO-style seed-and-scalar digital uplink: the strongest digital
    competitor on communication.

    Clients exploit the same shared-seed reconstruction trick as pAirZero —
    the perturbation z is regenerated from the broadcast round seed, so the
    payload per perturbation direction is ONE b-bit quantized scalar sent
    over an orthogonal TDMA slot (not the d-dimensional update the naive
    `digital` baseline uploads). Communication therefore matches the OTA
    mechanisms within a constant factor (`quant_bits` vs 16/1 bits), and
    memory matches (same ZO step) — but the third bird stays uncaged:
    orthogonal decoding still exposes each client's scalar exactly, and
    with the public seed an eavesdropper replays z and reconstructs the
    client's full gradient estimate p_k·z (see repro.privacy's seed-replay
    attack). No DP is charged and none is provided.

    Decode/schedule are inherited from DigitalTDMA (per-slot decode +
    straggler-aware average); only the comm accounting differs.
    """

    def payload_bits(self, pz, d):
        """Uplink bits/round/client: one quantized scalar per perturbation
        direction — d drops out (the shared-seed trick)."""
        return self.quant_bits * pz.zo.n_perturb


# ---------------------------------------------------------------------------
# First-order baseline
# ---------------------------------------------------------------------------

@register("fo")
@dataclass(frozen=True)
class FirstOrder(Transport):
    """FO FedSGD/Adam baseline: full backprop + d-dimensional gradient
    upload (fp16 per Table II) — the cost pAirZero eliminates."""
    kind = "fo"

    @classmethod
    def from_config(cls, tc, pz) -> "FirstOrder":
        """Build from a TransportConfig (no tunables)."""
        return cls()

    def aggregate(self, p, ctl, key):  # pragma: no cover - fo has no p_k
        """FO has no scalar uplink — gradients average inside the step."""
        raise NotImplementedError("the FO baseline averages gradients in the "
                                  "step itself; it has no scalar uplink")

    def payload_bits(self, pz, d):
        """Uplink bits/round/client: the full fp16 gradient."""
        return 16 * d
