"""pAirZero step factory: the paper's algorithm as composable jitted steps.

One round of Algorithm 1, as a single jitted function over the client mesh:

  1. every client evaluates its clipped gradient projection p_k from the
     shared round seed (two forwards, MeZO-chained — inference-level memory);
  2. the round's Transport (repro.core.transport) recovers p̂ from the [K]
     payload vector — for the OTA mechanisms that is superposition +
     channel inversion, ONE scalar psum over the client axes (the paper's
     O(1) communication claim, visible in HLO); for the digital baseline it
     is per-slot decode + average;
  3. every replica applies w ← w − η p̂ z from the same seed (replicas stay
     bit-identical by construction — no parameter broadcast ever happens).

Round-varying control (c(t), σ(t), round seed, survival mask, noise key) is
passed as *data*, so the step compiles exactly once per shape. The uplink
mechanism is part of the (hashable) config, so the memoized factories and
jit caches key on it.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.byzantine import behaviors as byz_behaviors
from repro.configs.base import ModelConfig, PairZeroConfig
from repro.core import transport as tp
from repro.core import zo
from repro.models import registry
from repro.obs import retrace
from repro.runtime import desync as ds

PyTree = Any


def make_loss_fn(model_cfg: ModelConfig, impl: Optional[str] = None
                 ) -> Callable[[PyTree, Dict], jnp.ndarray]:
    """Per-client loss vector [K] for this architecture."""
    mod = registry.get_module(model_cfg)

    def loss_fn(params: PyTree, batch: Dict) -> jnp.ndarray:
        return mod.loss_per_client(params, model_cfg, batch, impl=impl)

    return loss_fn


def control_spec(n_clients: int,
                 transport: Optional[tp.Transport] = None,
                 behavior: Optional[Any] = None,
                 desync: Optional[Any] = None
                 ) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract shapes of the per-round control block (dry-run input spec).

    The spec is owned by the Transport; the default is the standard block
    shared by every built-in mechanism. An active `behavior`
    (repro.byzantine) extends it with the [K] cohort indicator row; an
    active `desync` (repro.runtime.desync) with the lagged round seed and
    the [K] stale/alignment/frame rows."""
    t = transport if transport is not None else tp.Transport()
    spec = t.control_spec(n_clients)
    if behavior is not None:
        spec = dict(spec)
        spec["byz"] = jax.ShapeDtypeStruct((n_clients,), jnp.float32)
    if desync is not None:
        spec = dict(spec)
        spec["dsync_seed"] = jax.ShapeDtypeStruct((), jnp.uint32)
        for row in ("dsync_stale", "dsync_a", "dsync_frame"):
            spec[row] = jax.ShapeDtypeStruct((n_clients,), jnp.float32)
    return spec


def make_control(t: int, schedule, base_seed: int, n_clients: int,
                 mask=None, g=None, byz=None, dsync=None) -> Dict:
    """Host-side: build round-t control block from a PowerSchedule.

    `g` is the round's [K] per-client effective-gain (cos θ) vector from
    the channel trace; None means perfect CSI (all ones — bitwise neutral
    in the step). `byz` is the [K] malicious-cohort indicator
    (repro.byzantine); None keeps the historical block — the key is only
    present when a behavior is active, mirroring `engine.build_trace`.
    `dsync` is the round's desync row dict (dsync_seed / dsync_stale /
    dsync_a / dsync_frame, from `repro.runtime.desync.control_rows`);
    None likewise keeps the rows absent."""
    key = jax.random.fold_in(jax.random.key(base_seed ^ 0x5EED), t)
    ctl = {
        "seed": zo.round_seed(base_seed, t),
        "c": jnp.float32(schedule.c[t]),
        "sigma": jnp.asarray(schedule.sigma[t], jnp.float32),
        "n0": jnp.float32(schedule.n0),
        "mask": jnp.ones((n_clients,), jnp.float32) if mask is None
        else jnp.asarray(mask, jnp.float32),
        "g": jnp.ones((n_clients,), jnp.float32) if g is None
        else jnp.asarray(g, jnp.float32),
        "noise_bits": jax.random.key_data(key),
    }
    if byz is not None:
        ctl["byz"] = jnp.asarray(byz, jnp.float32)
    if dsync is not None:
        ctl["dsync_seed"] = jnp.asarray(dsync["dsync_seed"], jnp.uint32)
        for row in ("dsync_stale", "dsync_a", "dsync_frame"):
            ctl[row] = jnp.asarray(dsync[row], jnp.float32)
    return ctl


@functools.lru_cache(maxsize=128)
def make_zo_step(model_cfg: ModelConfig, pz: PairZeroConfig,
                 impl: Optional[str] = None,
                 scheme: Optional[str] = None,
                 transport: Optional[tp.Transport] = None,
                 mesh: Optional[Mesh] = None,
                 adversary: Optional[Any] = None,
                 behavior: Optional[Any] = None,
                 defense: Optional[Any] = None,
                 desync: Optional[Any] = None) -> Callable:
    """Build the jitted ZO train step for any scalar-payload Transport
    (analog / sign / perfect / digital / user-registered).

    Returns step(params, batch, ctl) → (new_params, metrics).

    Memoized on the (frozen, hashable) configs and the (frozen, hashable)
    Transport: repeated runs with identical configs get the *same* function
    object back, so jit/scan caches hit instead of retracing — fedsim and
    the scan engine stay compile-once across invocations (benchmarks,
    tests, resumed runs). `scheme` is the deprecated string override kept
    for one release; prefer `transport` or `pz.transport`.

    `mesh` (hashable, part of the memo key) selects the shard_map'd
    variant: the per-client dual forward runs on the mesh's (pod, data)
    client axes — each shard holds its clients' batch slice and evaluates
    only their losses — and the Transport's scalar decode consumes ONE
    `jax.lax.psum` over those axes (`Transport.aggregate_mesh`), the
    cross-device all-reduce the paper's O(1) uplink maps onto. Params and
    control enter replicated w.r.t. the client axes (a 'model' axis, if
    present, stays under GSPMD auto for TP/FSDP); the trajectory is
    bit-identical to the single-device step (tests/test_mesh_engine.py).

    `adversary` (a frozen `repro.privacy.Adversary`, hashable — part of the
    memo key) switches on eavesdropper observation capture: the round's
    Transport recomputes what an over-the-air listener sees (same per-round
    key as the decode ⇒ bit-identical noise draws) and the observation
    rides the metrics stream as `obs_*` entries — device-resident through a
    scanned chunk, stacked identically by both executors. Capture is
    passive: the training trajectory is bitwise unchanged, and
    `adversary=None` traces the exact historical program.

    `behavior` (a frozen `repro.byzantine.ClientBehavior`) rewrites the
    [K] payload vector AFTER projection and BEFORE the Transport aggregate
    — the malicious payload superposes through the real decode path on
    every engine, gated per client by the device-resident ctl["byz"]
    cohort row. `defense` (a frozen `repro.byzantine.Defense`) applies the
    PHY transmit constraint to every client and, when it overrides the
    decode, replaces the aggregate call (sub-slot group decodes). Both are
    part of the memo key; None traces the historical program unchanged —
    Byzantine neutrality is structural, like the adversary's.

    `desync` (a frozen `repro.runtime.DesyncModel`) models clients that
    missed the round-t seed broadcast: each stale client's scalar is the
    projection of an EXTRA fresh-mode dual forward evaluated against the
    lagged broadcast seed ctl["dsync_seed"] (z_{t−d}), selected per client
    by the device-resident ctl["dsync_stale"] row before the behavior /
    defense / Transport chain; the per-client timing attenuation
    ctl["dsync_a"] enters `ota.superpose` inside the Transports. Part of
    the memo key; None traces the bit-exact synchronized program.
    """
    retrace.bump(retrace.ZO_STEP_BUILD)     # lru MISS: a fresh step build
    loss_fn = make_loss_fn(model_cfg, impl=impl)
    transport = transport if transport is not None \
        else tp.resolve(pz, scheme=scheme)
    mu = pz.zo.mu
    lr = pz.zo.lr
    gamma = pz.zo.clip_gamma
    n_perturb = pz.zo.n_perturb
    if pz.fused_perturbation:
        # fused dual forward: z regenerated inside the layer kernels
        # (zo.tag_perturbed) — wired for the transformer families only
        if model_cfg.family not in ("dense", "moe"):
            raise ValueError(
                f"fused_perturbation supports the dense/moe families; "
                f"{model_cfg.name!r} is family {model_cfg.family!r} "
                "(its layer stack has consumers without a fused path)")
        mode = "fused"
    else:
        mode = "chained" if pz.zo.dual_mode in ("chained", "sequential") \
            else "fresh"

    def round_body(params: PyTree, batch: Dict, ctl: Dict,
                   client_ids: Optional[jnp.ndarray] = None,
                   client_axes: Tuple[str, ...] = ()
                   ) -> Tuple[PyTree, Dict[str, jnp.ndarray]]:
        """One pAirZero round. With `client_axes` set this runs as a
        shard_map body: the dual forward sees only the local client shard
        (`client_ids` is its slice of the global client-id iota — data, not
        `axis_index`, so the same body lowers on partial-auto meshes);
        (L+, L−) are reassembled across shards for the loss/projection
        metrics while the Transport performs its own client-axis psum."""
        metrics = {}
        p_hat_sum = jnp.float32(0.0)
        loss_acc = jnp.float32(0.0)
        k_total = ctl["mask"].shape[-1]
        for j in range(n_perturb):
            seed = zo.perturb_seed(ctl["seed"], j)
            if desync is not None:
                # stale clients evaluated against the LAGGED broadcast
                # seed: a non-destructive fresh-mode dual forward BEFORE
                # the main (possibly chained, in-place) walk below
                s_seed = zo.perturb_seed(ctl["dsync_seed"], j)
                lp_s, lm_s, _ = zo.dual_forward(
                    lambda p: loss_fn(p, batch), params, s_seed, mu,
                    mode="fresh")
            lp, lm, params_at = zo.dual_forward(
                lambda p: loss_fn(p, batch), params, seed, mu, mode=mode)
            noise_key = jax.random.wrap_key_data(ctl["noise_bits"])
            round_key = jax.random.fold_in(noise_key, j)
            if client_axes:
                offset = client_ids[0]        # shard's first global client
                p_local = zo.projection(lp, lm, mu, gamma)    # [K/n]
                if desync is not None:
                    p_local = ds.stale_payload(
                        p_local, zo.projection(lp_s, lm_s, mu, gamma),
                        ctl, offset)
                if behavior is not None:
                    p_local = byz_behaviors.apply_behavior(
                        behavior, p_local, ctl, round_key, offset)
                if defense is not None:
                    p_local = defense.transmit(p_local, ctl)
                    p_hat = defense.aggregate_mesh(
                        transport, p_local, ctl, round_key, client_axes,
                        offset)
                else:
                    p_hat = transport.aggregate_mesh(
                        p_local, ctl, round_key, client_axes, offset)
                if desync is not None:
                    lp, lm, lp_s, lm_s = tp.client_all_gather(
                        jnp.stack([lp, lm, lp_s, lm_s]), client_axes,
                        offset, k_total)
                else:
                    lp, lm = tp.client_all_gather(
                        jnp.stack([lp, lm]), client_axes, offset, k_total)
                p_k = zo.projection(lp, lm, mu, gamma)        # [K], full
                # the full radiated payload for metrics/observations:
                # re-applying attack + PHY clip on the gathered vector is
                # bit-identical to the concatenation of the shard-local
                # payloads (elementwise ops; shared draws sliced per shard)
                if desync is not None:
                    p_k = ds.stale_payload(
                        p_k, zo.projection(lp_s, lm_s, mu, gamma), ctl)
                if behavior is not None:
                    p_k = byz_behaviors.apply_behavior(
                        behavior, p_k, ctl, round_key)
                if defense is not None:
                    p_k = defense.transmit(p_k, ctl)
            else:
                p_k = zo.projection(lp, lm, mu, gamma)        # [K]
                if desync is not None:
                    p_k = ds.stale_payload(
                        p_k, zo.projection(lp_s, lm_s, mu, gamma), ctl)
                if behavior is not None:
                    p_k = byz_behaviors.apply_behavior(
                        behavior, p_k, ctl, round_key)
                if defense is not None:
                    p_k = defense.transmit(p_k, ctl)
                    p_hat = defense.aggregate(transport, p_k, ctl,
                                              round_key)
                else:
                    p_hat = transport.aggregate(p_k, ctl, round_key)
            # restore + update fused into one axpy (chained mode)
            params = zo.apply_update(params_at, seed, p_hat,
                                     lr / n_perturb, mu, mode=mode)
            p_hat_sum += p_hat.astype(jnp.float32)
            loss_acc += jnp.mean(0.5 * (lp + lm)).astype(jnp.float32)
            if j == 0:
                metrics["p_clients"] = p_k
                if adversary is not None:
                    # what the eavesdropper records this round (first
                    # perturbation direction): same payload vector and same
                    # round key as the decode, so the captured observation
                    # is bit-identical to the signal the server inverted
                    metrics.update(
                        adversary.observe(transport, p_k, ctl, round_key))
        metrics["loss"] = loss_acc / n_perturb
        metrics["p_hat"] = p_hat_sum / n_perturb
        metrics["k_eff"] = jnp.sum(ctl["mask"])
        return params, metrics

    if mesh is None:
        return round_body

    from repro.runtime import sharding as shd
    axes = shd.client_axes(mesh)
    if not axes:
        raise ValueError(f"mesh {mesh.axis_names} has no client axes "
                         "(want 'pod' and/or 'data')")
    auto = frozenset(a for a in mesh.axis_names if a not in axes)
    body = functools.partial(round_body, client_axes=axes)

    def sharded_step(params: PyTree, batch: Dict, ctl: Dict
                     ) -> Tuple[PyTree, Dict[str, jnp.ndarray]]:
        repl = lambda tree: jax.tree_util.tree_map(lambda _: P(), tree)
        bspecs = jax.tree_util.tree_map(
            lambda l: P(axes, *([None] * (l.ndim - 1))), batch)
        metric_specs = {"p_clients": P(), "loss": P(), "p_hat": P(),
                        "k_eff": P()}
        if adversary is not None:
            # observations are computed from the gathered [K] payload and
            # the replicated control block — replicated w.r.t. the client
            # axes like every other scalar metric
            metric_specs.update({k: P() for k in adversary.observation_spec(
                transport, pz.n_clients)})
        out_specs = (repl(params), metric_specs)
        k_total = ctl["mask"].shape[-1]
        ids = jnp.arange(k_total, dtype=jnp.int32)

        def manual_body(pr, ba, ct, ci):
            # model-side sharding hints must not mention the now-manual
            # client axes (with_sharding_constraint would reject them)
            with shd.manual_axes(axes):
                return body(pr, ba, ct, client_ids=ci)

        new_params, metrics = shard_map(
            manual_body, mesh=mesh,
            in_specs=(repl(params), bspecs, repl(ctl), P(axes)),
            out_specs=out_specs, check_rep=False, auto=auto)(
                params, batch, ctl, ids)
        # pin the carry back to the FSDP layout so a surrounding lax.scan
        # keeps one stable placement instead of round-tripping per round
        new_params = jax.lax.with_sharding_constraint(
            new_params, shd.params_sharding(mesh, new_params))
        return new_params, metrics

    return sharded_step


@functools.lru_cache(maxsize=128)
def make_fo_step(model_cfg: ModelConfig, optimizer,
                 impl: Optional[str] = None,
                 adversary: Optional[Any] = None,
                 desync: Optional[Any] = None) -> Callable:
    """First-order FedSGD baseline: full backprop + cross-client grad
    averaging (the d-dimensional all-reduce the paper eliminates).

    Memoized like `make_zo_step` — optimizers are frozen dataclasses, so
    equal configs return the same function object and jit caches hit.

    `adversary` captures what the FO uplink leaks: the victim client's raw
    d-dimensional gradient (flattened, f32) as the `obs_grad0` metric — the
    classic gradient-inversion surface repro.privacy's DLG attack consumes.
    Capture is honest about FO's cost: one EXTRA per-client backward per
    round, and a [d] f32 observation riding every round's metrics (a scan
    chunk carries chunk_rounds of them) — at production model sizes run
    audited FO on short horizons/small chunks and cap the host-side stream
    with `AttackHook(max_rounds=...)`.

    `desync` (a frozen `repro.runtime.DesyncModel`) models what frame
    desynchronization does to this CONVENTIONAL d-dimensional analog OTA
    uplink: a client's phase error θ accumulates across the
    frame_symbols-slot frame, so the coordinate riding symbol k combines
    with gain cos(kθ) — averaged over clients the late-frame coordinates
    random-phase out while the server still inverts by the full
    surviving count (`desync.conventional_frame`; stale clients
    contribute nothing — their frame carries an old round), and the
    energy the misaligned clients lose re-enters as inter-symbol
    interference noise on the decoded gradient
    (`desync.conventional_ici`, keyed off the round's noise_bits). The
    degraded decode drives the GRADIENT only; the reported `loss` metric
    stays the true masked mean, so desynced and clean runs are directly
    comparable. None traces the bit-exact synchronized program.
    """
    retrace.bump(retrace.FO_STEP_BUILD)     # lru MISS: a fresh step build
    loss_fn = make_loss_fn(model_cfg, impl=impl)

    def step(params: PyTree, opt_state: PyTree, batch: Dict, ctl: Dict
             ) -> Tuple[PyTree, PyTree, Dict[str, jnp.ndarray]]:
        def mean_loss(p):
            per_client = loss_fn(p, batch)                    # [K]
            mask = ctl["mask"]
            return jnp.sum(per_client * mask) / jnp.maximum(
                jnp.sum(mask), 1.0)

        loss, grads = jax.value_and_grad(mean_loss)(params)
        if desync is not None:
            # the server decodes a per-coordinate attenuated frame (phase
            # error accumulating over the frame's symbol slots) plus the
            # lost energy as interference; the reported `loss` metric
            # stays the true masked mean so desynced and clean runs are
            # directly comparable
            noise_key = jax.random.wrap_key_data(ctl["noise_bits"])
            framed = ds.conventional_frame(grads, ctl,
                                           desync.frame_symbols)
            grads = ds.conventional_ici(framed, ctl, noise_key,
                                        ref=grads)
        metrics = {"loss": loss}
        if adversary is not None:
            from jax.flatten_util import ravel_pytree
            from repro.privacy.adversary import OBS_PREFIX
            g0 = jax.grad(lambda p: loss_fn(p, batch)[0])(params)
            metrics[OBS_PREFIX + "grad0"] = ravel_pytree(
                jax.tree_util.tree_map(
                    lambda x: x.astype(jnp.float32), g0))[0]
        params, opt_state = optimizer.update(params, grads, opt_state)
        return params, opt_state, metrics

    return step


@functools.lru_cache(maxsize=128)
def jit_zo_step(step: Callable, donate: bool = True):
    """jit with parameter-buffer donation (the MeZO in-place chain).

    Memoized so the same step object maps to the same jitted wrapper (and
    therefore the same XLA executable cache) across fedsim.run calls.

    The wrapper's only addition over a bare `jax.jit(step)` is a Python
    side effect at TRACE time (`retrace.STEP_TRACE`): it calls `step`
    unchanged, so the jaxpr — and therefore the loop engine's trajectory —
    is bit-identical to the historical direct jit.
    """
    @functools.wraps(step)
    def traced(*args):
        retrace.bump(retrace.STEP_TRACE)
        return step(*args)

    return jax.jit(traced, donate_argnums=(0,) if donate else ())
