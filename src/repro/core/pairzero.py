"""pAirZero step factory: the paper's algorithm as composable jitted steps.

One round of Algorithm 1, as a single jitted function over the client mesh:

  1. every client evaluates its clipped gradient projection p_k from the
     shared round seed (two forwards, MeZO-chained — inference-level memory);
  2. the OTA channel superposes c·(payload_k + n_k) + z and the server
     inverts by (K_eff · c)  — on the mesh this is ONE scalar psum over the
     client axes (the paper's O(1) communication claim, visible in HLO);
  3. every replica applies w ← w − η p̂ z from the same seed (replicas stay
     bit-identical by construction — no parameter broadcast ever happens).

Round-varying control (c(t), σ(t), round seed, survival mask, noise key) is
passed as *data*, so the step compiles exactly once per shape.

`variant`: "analog" | "sign" | "fo" (first-order FedSGD/Adam baseline, for
the paper's Table II comparisons).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, PairZeroConfig
from repro.core import ota, zo
from repro.kernels.seeded_axpy import fmix32
from repro.models import registry

PyTree = Any


def make_loss_fn(model_cfg: ModelConfig, impl: Optional[str] = None
                 ) -> Callable[[PyTree, Dict], jnp.ndarray]:
    """Per-client loss vector [K] for this architecture."""
    mod = registry.get_module(model_cfg)

    def loss_fn(params: PyTree, batch: Dict) -> jnp.ndarray:
        return mod.loss_per_client(params, model_cfg, batch, impl=impl)

    return loss_fn


def control_spec(n_clients: int) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract shapes of the per-round control block (dry-run input spec)."""
    return {
        "seed": jax.ShapeDtypeStruct((), jnp.uint32),
        "c": jax.ShapeDtypeStruct((), jnp.float32),
        "sigma": jax.ShapeDtypeStruct((n_clients,), jnp.float32),
        "n0": jax.ShapeDtypeStruct((), jnp.float32),
        "mask": jax.ShapeDtypeStruct((n_clients,), jnp.float32),
        "noise_bits": jax.ShapeDtypeStruct((2,), jnp.uint32),
    }


def make_control(t: int, schedule, base_seed: int, n_clients: int,
                 mask=None) -> Dict:
    """Host-side: build round-t control block from a PowerSchedule."""
    key = jax.random.fold_in(jax.random.key(base_seed ^ 0x5EED), t)
    return {
        "seed": zo.round_seed(base_seed, t),
        "c": jnp.float32(schedule.c[t]),
        "sigma": jnp.asarray(schedule.sigma[t], jnp.float32),
        "n0": jnp.float32(schedule.n0),
        "mask": jnp.ones((n_clients,), jnp.float32) if mask is None
        else jnp.asarray(mask, jnp.float32),
        "noise_bits": jax.random.key_data(key),
    }


@functools.lru_cache(maxsize=128)
def make_zo_step(model_cfg: ModelConfig, pz: PairZeroConfig,
                 impl: Optional[str] = None,
                 scheme: Optional[str] = None) -> Callable:
    """Build the jitted ZO train step for `variant` ∈ {analog, sign}.

    Returns step(params, batch, ctl) → (new_params, metrics).

    Memoized on the (frozen, hashable) configs: repeated runs with identical
    configs get the *same* function object back, so jit/scan caches hit
    instead of retracing — fedsim.run and the scan engine stay compile-once
    across invocations (benchmarks, tests, resumed runs).
    """
    loss_fn = make_loss_fn(model_cfg, impl=impl)
    variant = pz.variant
    scheme = scheme or pz.power.scheme
    mu = pz.zo.mu
    lr = pz.zo.lr
    gamma = pz.zo.clip_gamma
    n_perturb = pz.zo.n_perturb
    mode = "chained" if pz.zo.dual_mode in ("chained", "sequential") \
        else "fresh"

    def step(params: PyTree, batch: Dict, ctl: Dict
             ) -> Tuple[PyTree, Dict[str, jnp.ndarray]]:
        metrics = {}
        p_hat_sum = jnp.float32(0.0)
        loss_acc = jnp.float32(0.0)
        for j in range(n_perturb):
            seed = fmix32(ctl["seed"]
                          + jnp.uint32((0x9E3779B9 * (j + 1)) & 0xFFFFFFFF))
            lp, lm, params_at = zo.dual_forward(
                lambda p: loss_fn(p, batch), params, seed, mu, mode=mode)
            p_k = zo.projection(lp, lm, mu, gamma)            # [K]
            noise_key = jax.random.wrap_key_data(ctl["noise_bits"])
            p_hat = ota.aggregate(variant, scheme, p_k, ctl["c"],
                                  ctl["sigma"], ctl["n0"],
                                  jax.random.fold_in(noise_key, j),
                                  ctl["mask"])
            # restore + update fused into one axpy (chained mode)
            params = zo.apply_update(params_at, seed, p_hat,
                                     lr / n_perturb, mu, mode=mode)
            p_hat_sum += p_hat.astype(jnp.float32)
            loss_acc += jnp.mean(0.5 * (lp + lm)).astype(jnp.float32)
            if j == 0:
                metrics["p_clients"] = p_k
        metrics["loss"] = loss_acc / n_perturb
        metrics["p_hat"] = p_hat_sum / n_perturb
        metrics["k_eff"] = jnp.sum(ctl["mask"])
        return params, metrics

    return step


@functools.lru_cache(maxsize=128)
def make_fo_step(model_cfg: ModelConfig, optimizer,
                 impl: Optional[str] = None) -> Callable:
    """First-order FedSGD baseline: full backprop + cross-client grad
    averaging (the d-dimensional all-reduce the paper eliminates).

    Memoized like `make_zo_step` — optimizers are frozen dataclasses, so
    equal configs return the same function object and jit caches hit."""
    loss_fn = make_loss_fn(model_cfg, impl=impl)

    def step(params: PyTree, opt_state: PyTree, batch: Dict, ctl: Dict
             ) -> Tuple[PyTree, PyTree, Dict[str, jnp.ndarray]]:
        def mean_loss(p):
            per_client = loss_fn(p, batch)                    # [K]
            mask = ctl["mask"]
            return jnp.sum(per_client * mask) / jnp.maximum(
                jnp.sum(mask), 1.0)

        loss, grads = jax.value_and_grad(mean_loss)(params)
        params, opt_state = optimizer.update(params, grads, opt_state)
        return params, opt_state, {"loss": loss}

    return step


@functools.lru_cache(maxsize=128)
def jit_zo_step(step: Callable, donate: bool = True):
    """jit with parameter-buffer donation (the MeZO in-place chain).

    Memoized so the same step object maps to the same jitted wrapper (and
    therefore the same XLA executable cache) across fedsim.run calls.
    """
    return jax.jit(step, donate_argnums=(0,) if donate else ())
