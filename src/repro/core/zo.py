"""Zeroth-order (SPSA / MeZO-style) seeded gradient estimation (paper Sec. IV-A).

The central trick: the perturbation z ~ N(0, I_d) is *never stored and never
transmitted* — it is regenerated on demand from a shared round seed. A client
needs only

    p_k = ( F_k(w + μz) − F_k(w − μz) ) / (2μ)                    (Eq. 7)

and the server/global update is w ← w − η p̂ z (Algorithm 1, line 14).

Seeds are plain int32 scalars (what a base station actually broadcasts); each
parameter leaf gets an independent stream via a hash of (round_seed, leaf_idx).
The z-stream itself is the counter-hash generator shared bitwise by the
Pallas kernel, its interpret mode, and the XLA fallback (kernels/seeded_axpy).

Memory discipline (the paper's "inference-level memory" claim, made real):
`chained` mode walks the MeZO sequence  w → w+μz → w−μz → w−μz+(μ−ηp̂)z  with
every step an in-place-style axpy (buffer-donated under jit), so the peak
footprint is ONE copy of the parameters plus one layer's activations. The
final restore and the update share a single fused axpy.

`fresh` mode recomputes each perturbed copy directly from w (no chained
floating-point drift, 2× memory) — tests use it as the oracle for `chained`.
"""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.kernels.seeded_axpy import fmix32

PyTree = Any


def leaf_seed(seed, leaf_idx: int) -> jnp.ndarray:
    """Independent per-leaf stream seed: fmix32(seed · φ + leaf_idx)."""
    s = jnp.asarray(seed).astype(jnp.uint32)
    return fmix32(s * jnp.uint32(0x9E3779B9) + jnp.uint32(leaf_idx))


def round_seed(base_seed: int, t) -> jnp.ndarray:
    """The seed the server broadcasts for round t (pure function — clients
    and a restarted server re-derive the identical stream)."""
    return fmix32(jnp.asarray(base_seed).astype(jnp.uint32)
                  ^ (jnp.asarray(t).astype(jnp.uint32)
                     * jnp.uint32(0x85EBCA6B)))


def perturb_seed(round_seed_t, j: int) -> jnp.ndarray:
    """Seed of perturbation direction j within a round (the stream the
    round body perturbs with). Derived from the broadcast round seed, so it
    is just as public — an eavesdropper replays z(perturb_seed) exactly,
    which is the premise of the seed-replay attack (repro.privacy)."""
    return fmix32(jnp.asarray(round_seed_t).astype(jnp.uint32)
                  + jnp.uint32((0x9E3779B9 * (j + 1)) & 0xFFFFFFFF))


# ---------------------------------------------------------------------------
# Seeded perturbation
# ---------------------------------------------------------------------------

def perturb(params: PyTree, seed, scale, impl=None) -> PyTree:
    """params + scale · z(seed), with z regenerated leaf-by-leaf.

    `scale` may be a traced scalar (e.g. −η·p̂) — the same code path serves
    perturbation, restoration and the model update.
    """
    leaves, treedef = jax.tree_util.tree_flatten(params)
    out = [kops.seeded_axpy(leaf, leaf_seed(seed, i), scale, impl=impl)
           for i, leaf in enumerate(leaves)]
    return jax.tree_util.tree_unflatten(treedef, out)


def tag_perturbed(params: PyTree, seed, scale) -> PyTree:
    """Tag every leaf as lazily perturbed: leaf → PerturbedParam(leaf, …).

    The fused counterpart of `perturb`: instead of materializing
    params + scale · z, each leaf carries (seed, offset, scale) metadata and
    the consumers in models/layers.py regenerate z inside their own
    matmul/gather (kernels.ops.perturbed_matmul / perturbed_gather) or
    resolve a layer-sized transient. Leaf enumeration and per-leaf streams
    are identical to `perturb`, so the loss seen through a tagged tree
    equals the loss at `perturb(params, seed, scale)` up to matmul
    reassociation (bitwise for the z values themselves).

    Children are broadcast to each leaf's leading dim so scan-stacked
    leaves slice into valid per-layer tags under `lax.scan` (the slice's
    `off` continues the whole-leaf counter stream).
    """
    leaves, treedef = jax.tree_util.tree_flatten(params)
    out = []
    for i, leaf in enumerate(leaves):
        ls = leaf_seed(seed, i)
        if leaf.ndim == 0:
            # 0-d leaf: nothing to fuse into — materialize directly
            out.append(kops.seeded_axpy(leaf.reshape(1), ls, scale,
                                        impl="xla").reshape(()))
            continue
        lead = leaf.shape[0]
        stride = 1
        for d in leaf.shape[1:]:
            stride *= d
        out.append(kops.PerturbedParam(
            leaf,
            jnp.broadcast_to(ls, (lead,)),
            jnp.arange(lead, dtype=jnp.uint32)
            * jnp.uint32(stride & 0xFFFFFFFF),
            jnp.broadcast_to(jnp.asarray(scale, jnp.float32), (lead,))))
    return jax.tree_util.tree_unflatten(treedef, out)


def draw_z(params: PyTree, seed) -> PyTree:
    """Materialize z(seed) with the same per-leaf streams as `perturb`.

    Only used by tests and analysis tooling (e.g. the Fig. 4–6 sign-reversing
    study) — the training path never materializes z.
    """
    from repro.kernels.ref import draw_z_ref
    leaves, treedef = jax.tree_util.tree_flatten(params)
    zs = [draw_z_ref(leaf.shape, leaf_seed(seed, i)).astype(leaf.dtype)
          for i, leaf in enumerate(leaves)]
    return jax.tree_util.tree_unflatten(treedef, zs)


# ---------------------------------------------------------------------------
# Dual forward: loss at w ± μz
# ---------------------------------------------------------------------------

def dual_forward(loss_fn: Callable[[PyTree], jnp.ndarray], params: PyTree,
                 seed, mu: float, mode: str = "chained"
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, PyTree]:
    """Evaluate (loss(w+μz), loss(w−μz)) and return params positioned for update.

    Returns (loss_plus, loss_minus, params_at) where `params_at` is w−μz in
    chained mode (caller fuses restore+update via one axpy of (μ − η·p̂)·z)
    or w itself in fresh mode (caller applies −η·p̂·z).
    """
    if mode == "chained":
        p_plus = perturb(params, seed, mu)           # w + μz   (donates w)
        loss_plus = loss_fn(p_plus)
        # data-depend the second axpy on loss_plus so XLA cannot reorder the
        # buffer chain (the scalar add is free).
        anchor = (jnp.sum(loss_plus) * 0.0).astype(jnp.float32)
        p_minus = perturb(p_plus, seed, -2.0 * mu + anchor)  # w − μz
        loss_minus = loss_fn(p_minus)
        return loss_plus, loss_minus, p_minus
    if mode == "fresh":
        loss_plus = loss_fn(perturb(params, seed, mu))
        loss_minus = loss_fn(perturb(params, seed, -mu))
        return loss_plus, loss_minus, params
    if mode == "fused":
        # Perturbed weights never materialize tree-wide: consumers
        # regenerate z from the tags (see tag_perturbed) inside their own
        # matmul/gather, resolving at most one layer-sized transient.
        # Both rollouts run under ONE vmap over eps = (+μ, −μ): z depends
        # only on (seed, off) — never eps — so each leaf's z is generated
        # once per round and shared by the two rollouts.
        def one_rollout(eps):
            return loss_fn(tag_perturbed(params, seed, eps))

        lpm = jax.vmap(one_rollout)(jnp.asarray([mu, -mu], jnp.float32))
        return lpm[0], lpm[1], params
    raise ValueError(f"unknown dual mode: {mode}")


def projection(loss_plus: jnp.ndarray, loss_minus: jnp.ndarray, mu: float,
               clip_gamma: float) -> jnp.ndarray:
    """Gradient projection p = (L+ − L−)/(2μ), clipped to ±γ (Assumption 3)."""
    p = (loss_plus - loss_minus) / (2.0 * mu)
    return jnp.clip(p, -clip_gamma, clip_gamma)


def apply_update(params_at: PyTree, seed, p_hat: jnp.ndarray,
                 lr, mu: float, mode: str = "chained") -> PyTree:
    """Global model update w ← w − η p̂ z (Algorithm 1 line 14).

    chained: params_at = w−μz ⇒ one fused axpy of (μ − η p̂)·z restores and
    updates simultaneously. fresh: params_at = w ⇒ axpy of (−η p̂)·z.
    """
    if mode == "chained":
        return perturb(params_at, seed, mu - lr * p_hat)
    if mode in ("fresh", "fused"):
        return perturb(params_at, seed, -lr * p_hat)
    raise ValueError(f"unknown dual mode: {mode}")


# ---------------------------------------------------------------------------
# Reference SPSA estimator (tests / analysis)
# ---------------------------------------------------------------------------

def spsa_gradient(loss_fn: Callable[[PyTree], jnp.ndarray], params: PyTree,
                  seed, mu: float) -> PyTree:
    """g = p · z — the full estimated gradient (Eq. 6). Materializes z; for
    tests and the e₀ study only."""
    lp, lm, _ = dual_forward(loss_fn, params, seed, mu, mode="fresh")
    p = (lp - lm) / (2.0 * mu)
    z = draw_z(params, seed)
    return jax.tree_util.tree_map(lambda zl: p.astype(zl.dtype) * zl, z)


def directional_derivative(loss_fn: Callable[[PyTree], jnp.ndarray],
                           params: PyTree, seed) -> jnp.ndarray:
    """Exact zᵀ∇F(w) via jvp — oracle for SPSA projection tests and the
    Fig. 4–6 sign-reversing study."""
    z = draw_z(params, seed)
    f32 = lambda t: jax.tree_util.tree_map(
        lambda x: x.astype(jnp.float32), t)
    _, jvp_val = jax.jvp(lambda p: loss_fn(p), (f32(params),), (f32(z),))
    return jvp_val
