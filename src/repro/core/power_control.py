"""Optimality-gap-minimizing power control (paper Sec. VI, Theorems 3 & 4).

Solves, per training horizon, for the common effective channel gain c⁽ᵗ⁾ =
h_k⁽ᵗ⁾ α_k⁽ᵗ⁾ and artificial-noise stds σ_k⁽ᵗ⁾ minimizing the convergence-bound
neighborhood subject to the DP budget (C1)/(C3) and per-client power (C2)/(C4).

Both theorems prove σ_k* = 0 — channel noise alone, modulated through the
transmit gain, is the optimal privacy mechanism — so the solver returns the
c⁽ᵗ⁾ schedule plus σ ≡ 0; non-zero σ is still supported by the OTA simulator
for the ablation baselines.

Paper-typo notes (also in DESIGN.md §1): we implement the versions that are
dimensionally consistent with constraints (C1)–(C4); the property tests verify
(a) the DP constraint holds with equality when active, (b) the power
constraint holds for every (k, t), and (c) the solution beats Static/Reversed
on the bound objective.

Everything here is host-side numpy — power control is a base-station decision
made between rounds, not a jitted device computation.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass

import numpy as np

from repro.core.dp import r_dp


def defended_config(pz, clip: float):
    """Fold a transmit-clip defense into the Theorem-3/4 inputs.

    A PHY clip at ±γ_d tightens Assumption 3's payload bound, which enters
    the solve in two places at once: the power-cap min over clients
    (`_analog_full_power_c` scales with 1/γ) and the Lemma-1 DP sensitivity
    (Eq. 16 cost ∝ (c γ)²) — so the same (ε, δ) budget affords a HIGHER
    channel-inversion gain c. Returns the run config with
    `zo.clip_gamma = min(γ, γ_d)`; schedules, DP costs, and the audit
    canary solved from it are then consistent with what clients actually
    radiate. Host-side, like the rest of this module: the re-solve is
    invisible to the attacker."""
    g = min(float(pz.zo.clip_gamma), float(clip))
    if g == float(pz.zo.clip_gamma):
        return pz
    return dataclasses.replace(
        pz, zo=dataclasses.replace(pz.zo, clip_gamma=g))


@dataclass
class PowerSchedule:
    """Per-round transmit plan for T rounds and K clients."""
    c: np.ndarray             # [T] effective channel gain c(t)
    sigma: np.ndarray         # [T, K] artificial-noise std
    scheme: str
    zeta: float = 0.0         # Lagrange multiplier (0 ⇒ full power feasible)
    n0: float = 1.0

    def effective_noise_std(self, t: int) -> float:
        """m(t) = sqrt(c² Σ_k σ_k² + N0)  (Eq. 12)."""
        c = self.c[t]
        return math.sqrt(c * c * float(np.sum(self.sigma[t] ** 2)) + self.n0)

    def privacy_cost(self, gamma: np.ndarray) -> float:
        """Σ_t 2 (c γ / m)² — LHS of the accountant (Eq. 16)."""
        gam = np.broadcast_to(np.asarray(gamma, dtype=np.float64),
                              self.c.shape)
        total = 0.0
        for t in range(len(self.c)):
            m = self.effective_noise_std(t)
            if self.c[t] == 0.0:
                continue
            total += 2.0 * (self.c[t] * gam[t] / m) ** 2
        return total


# ---------------------------------------------------------------------------
# Analog pAirZero — Theorem 3
# ---------------------------------------------------------------------------

def _analog_full_power_c(h: np.ndarray, power: float,
                         gamma: np.ndarray) -> np.ndarray:
    """Power-cap gain per round: c_cap(t) = min_k √P h_k(t) / γ_k(t)."""
    return np.min(math.sqrt(power) * h / gamma[:, None], axis=1)


def solve_analog(h: np.ndarray, *, power: float, n0: float, gamma: float,
                 contraction_a: float, epsilon: float, delta: float,
                 bisect_tol: float = 1e-12,
                 bisect_iters: int = 200) -> PowerSchedule:
    """Theorem 3: closed-form c(t) schedule for analog pAirZero.

    Args:
      h: [T, K] per-round per-client channel magnitudes.
      gamma: projection clip bound γ (identical across clients, per paper
        Sec. VII-D3; per-client bounds enter only via the min in the cap).
    """
    h = np.asarray(h, dtype=np.float64)
    T, K = h.shape
    gam = np.full(T, float(gamma))
    budget = r_dp(epsilon, delta)
    c_cap = _analog_full_power_c(h, power, gam)
    a = float(contraction_a)

    # privacy cost at full power (σ = 0 ⇒ m² = N0): Σ_t 2 γ² c_cap² / N0
    cap_cost_t = 2.0 * gam ** 2 * c_cap ** 2 / n0
    if float(np.sum(cap_cost_t)) <= budget:
        # Condition (28): full power forever stays inside the budget.
        return PowerSchedule(c=c_cap, sigma=np.zeros((T, K)),
                             scheme="solution", zeta=0.0, n0=n0)

    t_idx = np.arange(1, T + 1, dtype=np.float64)

    def c_of_zeta(zeta: float) -> np.ndarray:
        # adaptive term of Eq. (30): A^{-t/4} N0^{1/2} (2ζ)^{-1/4} γ^{-1/2}
        adaptive = (a ** (-t_idx / 4.0)) * math.sqrt(n0) \
            / ((2.0 * zeta) ** 0.25 * np.sqrt(gam))
        return np.minimum(adaptive, c_cap)

    def spent(zeta: float) -> float:
        c = c_of_zeta(zeta)
        return float(np.sum(2.0 * gam ** 2 * c ** 2 / n0))

    # bracket ζ: spent() is strictly decreasing in ζ
    lo, hi = 0.0, 1.0
    while spent(hi) > budget:
        hi *= 4.0
        if hi > 1e30:  # pragma: no cover
            raise RuntimeError("power-control bisection failed to bracket")
    for _ in range(bisect_iters):
        mid = 0.5 * (lo + hi)
        if spent(mid) > budget:
            lo = mid
        else:
            hi = mid
        if hi - lo <= bisect_tol * max(hi, 1.0):
            break
    zeta = hi  # feasible side
    return PowerSchedule(c=c_of_zeta(zeta), sigma=np.zeros((T, K)),
                         scheme="solution", zeta=zeta, n0=n0)


def static_analog(h: np.ndarray, *, power: float, n0: float, gamma: float,
                  epsilon: float, delta: float) -> PowerSchedule:
    """Static baseline (Eq. 40): even privacy spend, c(t) constant."""
    h = np.asarray(h, dtype=np.float64)
    T, K = h.shape
    gam = np.full(T, float(gamma))
    budget = r_dp(epsilon, delta)
    c_static = math.sqrt(n0 * budget / (2.0 * T * gamma * gamma))
    c_cap = _analog_full_power_c(h, power, gam)
    return PowerSchedule(c=np.minimum(c_static, c_cap),
                         sigma=np.zeros((T, K)), scheme="static", n0=n0)


def reversed_analog(h: np.ndarray, *, power: float, n0: float, gamma: float,
                    contraction_a: float, epsilon: float, delta: float,
                    bisect_tol: float = 1e-12,
                    bisect_iters: int = 200) -> PowerSchedule:
    """Reversed baseline: A^{-t/4} → A^{+t/4} (decreasing gain trend)."""
    h = np.asarray(h, dtype=np.float64)
    T, K = h.shape
    gam = np.full(T, float(gamma))
    budget = r_dp(epsilon, delta)
    c_cap = _analog_full_power_c(h, power, gam)
    a = float(contraction_a)
    t_idx = np.arange(1, T + 1, dtype=np.float64)

    def c_of_zeta(zeta: float) -> np.ndarray:
        adaptive = (a ** (+t_idx / 4.0)) * math.sqrt(n0) \
            / ((2.0 * zeta) ** 0.25 * np.sqrt(gam))
        return np.minimum(adaptive, c_cap)

    def spent(zeta: float) -> float:
        c = c_of_zeta(zeta)
        return float(np.sum(2.0 * gam ** 2 * c ** 2 / n0))

    if float(np.sum(2.0 * gam ** 2 * c_cap ** 2 / n0)) <= budget:
        return PowerSchedule(c=c_cap, sigma=np.zeros((T, K)),
                             scheme="reversed", n0=n0)
    lo, hi = 0.0, 1.0
    while spent(hi) > budget:
        hi *= 4.0
    for _ in range(bisect_iters):
        mid = 0.5 * (lo + hi)
        if spent(mid) > budget:
            lo = mid
        else:
            hi = mid
        if hi - lo <= bisect_tol * max(hi, 1.0):
            break
    return PowerSchedule(c=c_of_zeta(hi), sigma=np.zeros((T, K)),
                         scheme="reversed", zeta=hi, n0=n0)


# ---------------------------------------------------------------------------
# Sign-pAirZero — Theorem 4 (γ ≡ 1)
# ---------------------------------------------------------------------------

def _sign_b_constants(n_clients: int, e0: float) -> tuple:
    """B1, B2 of Lemma 2 / Eq. (67) (Lemma-2-consistent squared form)."""
    b1 = n_clients ** 2 * (1.0 - 2.0 * e0) ** 2
    b2 = 4.0 * n_clients * e0 * (1.0 - e0)
    return b1, b2


def solve_sign(h: np.ndarray, *, power: float, n0: float, n_clients: int,
               e0: float, contraction_a_tilde: float, epsilon: float,
               delta: float, bisect_tol: float = 1e-12,
               bisect_iters: int = 200) -> PowerSchedule:
    """Theorem 4: closed-form c(t) schedule for Sign-pAirZero.

    Internally solves in the substituted variable m(t) = Σσ² + N0/c² (the
    post-inversion noise-to-gain measure of Appendix E); with σ* = 0 the
    transmit gain is c(t) = √(N0 / m(t)).
    """
    h = np.asarray(h, dtype=np.float64)
    T, K = h.shape
    budget = r_dp(epsilon, delta)
    b1, b2 = _sign_b_constants(n_clients, e0)
    at = float(contraction_a_tilde)
    t_idx = np.arange(1, T + 1, dtype=np.float64)
    # full-power floor on m (Eq. 84 taken over all clients)
    m_floor = n0 / (power * np.min(h, axis=1) ** 2)

    # full-power privacy cost: Σ_t 2 / m_floor
    if float(np.sum(2.0 / m_floor)) <= budget:
        c = np.sqrt(n0 / m_floor)
        return PowerSchedule(c=c, sigma=np.zeros((T, K)), scheme="solution",
                             zeta=0.0, n0=n0)

    def m_of_zeta(zeta: float) -> np.ndarray:
        # positive root of the KKT quadratic (Eq. 86); ∞ once Ã^{-t}B2² ≤ 2ζ
        disc = at ** (-t_idx) * b2 * b2 - 2.0 * zeta
        with np.errstate(divide="ignore", invalid="ignore"):
            m_formula = np.where(
                disc > 0.0,
                (b1 + b2) * (4.0 * zeta
                             + np.sqrt(8.0 * at ** (-t_idx) * b2 * b2 * zeta))
                / (2.0 * disc),
                np.inf)
        return np.maximum(m_floor, m_formula)

    def spent(zeta: float) -> float:
        return float(np.sum(2.0 / m_of_zeta(zeta)))

    lo, hi = 0.0, 1.0
    while spent(hi) > budget:
        hi *= 4.0
        if hi > 1e30:  # pragma: no cover
            raise RuntimeError("sign power-control bisection failed")
    for _ in range(bisect_iters):
        mid = 0.5 * (lo + hi)
        if spent(mid) > budget:
            lo = mid
        else:
            hi = mid
        if hi - lo <= bisect_tol * max(hi, 1.0):
            break
    zeta = hi
    m = m_of_zeta(zeta)
    c = np.where(np.isfinite(m), np.sqrt(n0 / m), 0.0)
    return PowerSchedule(c=c, sigma=np.zeros((T, K)), scheme="solution",
                         zeta=zeta, n0=n0)


def static_sign(h: np.ndarray, *, power: float, n0: float,
                epsilon: float, delta: float) -> PowerSchedule:
    h = np.asarray(h, dtype=np.float64)
    T, K = h.shape
    budget = r_dp(epsilon, delta)
    c_static = math.sqrt(n0 * budget / (2.0 * T))
    c_cap = np.min(math.sqrt(power) * h, axis=1)
    return PowerSchedule(c=np.minimum(c_static, c_cap),
                         sigma=np.zeros((T, K)), scheme="static", n0=n0)


def reversed_sign(h: np.ndarray, *, power: float, n0: float, n_clients: int,
                  e0: float, contraction_a_tilde: float, epsilon: float,
                  delta: float, bisect_tol: float = 1e-12,
                  bisect_iters: int = 200) -> PowerSchedule:
    """Reversed baseline for sign: Ã^{-t} → Ã^{+t} in the adaptive term."""
    h = np.asarray(h, dtype=np.float64)
    T, K = h.shape
    budget = r_dp(epsilon, delta)
    b1, b2 = _sign_b_constants(n_clients, e0)
    at = float(contraction_a_tilde)
    t_idx = np.arange(1, T + 1, dtype=np.float64)
    m_floor = n0 / (power * np.min(h, axis=1) ** 2)
    if float(np.sum(2.0 / m_floor)) <= budget:
        c = np.sqrt(n0 / m_floor)
        return PowerSchedule(c=c, sigma=np.zeros((T, K)), scheme="reversed",
                             n0=n0)

    def m_of_zeta(zeta: float) -> np.ndarray:
        disc = at ** (+t_idx) * b2 * b2 - 2.0 * zeta
        with np.errstate(divide="ignore", invalid="ignore"):
            m_formula = np.where(
                disc > 0.0,
                (b1 + b2) * (4.0 * zeta
                             + np.sqrt(8.0 * at ** (+t_idx) * b2 * b2 * zeta))
                / (2.0 * disc),
                np.inf)
        return np.maximum(m_floor, m_formula)

    def spent(zeta: float) -> float:
        return float(np.sum(2.0 / m_of_zeta(zeta)))

    lo, hi = 0.0, 1.0
    while spent(hi) > budget:
        hi *= 4.0
    for _ in range(bisect_iters):
        mid = 0.5 * (lo + hi)
        if spent(mid) > budget:
            lo = mid
        else:
            hi = mid
        if hi - lo <= bisect_tol * max(hi, 1.0):
            break
    m = m_of_zeta(hi)
    c = np.where(np.isfinite(m), np.sqrt(n0 / m), 0.0)
    return PowerSchedule(c=c, sigma=np.zeros((T, K)), scheme="reversed",
                         zeta=hi, n0=n0)


# ---------------------------------------------------------------------------
# Dispatch
# ---------------------------------------------------------------------------

def make_schedule(variant: str, scheme: str, h: np.ndarray, *, power: float,
                  n0: float, gamma: float, n_clients: int, e0: float,
                  contraction_a: float, contraction_a_tilde: float,
                  epsilon: float, delta: float) -> PowerSchedule:
    """Build a T-round schedule for (variant ∈ {analog, sign}) × scheme.

    Compatibility dispatcher: the schedule solve is owned by the Transport
    (`Transport.make_schedule(h, pz)` in repro.core.transport), which calls
    the solve_*/static_*/reversed_* functions above directly. This flat-
    kwarg spelling remains for host-side tooling and tests."""
    if scheme == "perfect":
        T, K = np.asarray(h).shape
        return PowerSchedule(c=np.ones(T), sigma=np.zeros((T, K)),
                             scheme="perfect", n0=0.0)
    if variant == "analog":
        if scheme == "solution":
            return solve_analog(h, power=power, n0=n0, gamma=gamma,
                                contraction_a=contraction_a,
                                epsilon=epsilon, delta=delta)
        if scheme == "static":
            return static_analog(h, power=power, n0=n0, gamma=gamma,
                                 epsilon=epsilon, delta=delta)
        if scheme == "reversed":
            return reversed_analog(h, power=power, n0=n0, gamma=gamma,
                                   contraction_a=contraction_a,
                                   epsilon=epsilon, delta=delta)
    elif variant == "sign":
        if scheme == "solution":
            return solve_sign(h, power=power, n0=n0, n_clients=n_clients,
                              e0=e0, contraction_a_tilde=contraction_a_tilde,
                              epsilon=epsilon, delta=delta)
        if scheme == "static":
            return static_sign(h, power=power, n0=n0, epsilon=epsilon,
                               delta=delta)
        if scheme == "reversed":
            return reversed_sign(h, power=power, n0=n0, n_clients=n_clients,
                                 e0=e0, contraction_a_tilde=contraction_a_tilde,
                                 epsilon=epsilon, delta=delta)
    raise ValueError(f"unknown variant/scheme: {variant}/{scheme}")


def transmit_power(schedule: PowerSchedule, h: np.ndarray, gamma: float,
                   d: int) -> np.ndarray:
    """Per-(t,k) transmit power (c/h_k)²(γ² + d σ_k²) — LHS of (C2)/(C4)."""
    h = np.asarray(h, dtype=np.float64)
    c = schedule.c[:, None]
    return (c / h) ** 2 * (gamma ** 2 + d * schedule.sigma ** 2)
