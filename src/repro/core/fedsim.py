"""Federated simulation driver: the paper's Algorithm 1 end to end.

Host-side orchestration (what the edge server + base station do):
  1. draw the block-fading channel trace h_k(t) for the horizon,
  2. solve power control (Theorem 3/4 — or Static/Reversed/Perfect ablation),
  3. run the rounds through one of two engines:
       engine="scan": the device-resident scan-over-rounds engine
         (core/engine.py) — the whole control trace is precomputed, and
         `chunk_rounds` rounds execute per dispatch under one lax.scan with
         parameter-buffer donation; the host touches down only at chunk
         boundaries (DP accounting, eval, checkpoint, fault-trace draw);
       engine="loop" (default): the per-round dispatch path — no chunk
         compile cost, and the bit-identical equivalence oracle for scan,
  4. charge the DP accountant (hard stop on overspend — privacy over
     utility), handle faults (survival masks), checkpoint/resume, eval.

The driver is deliberately boring: every interesting decision lives in
core/{zo,ota,dp,power_control,pairzero,engine}. It is the substrate for the
three examples, the Fig. 2/3 benchmarks, and the integration tests.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt
from repro.configs.base import ModelConfig, PairZeroConfig
from repro.core import engine as eng
from repro.core import ota, pairzero, power_control as pc
from repro.core.dp import PrivacyAccountant
from repro.data.pipeline import FederatedPipeline
from repro.models import registry
from repro.optim import fo as fo_opt
from repro.runtime.fault import FaultModel, ElasticSchedule, combined_mask


@functools.lru_cache(maxsize=32)
def _fo_scan_step(raw_step: Callable) -> Callable:
    """Adapter: FO step's (params, opt_state) pair as a single scan carry.
    Memoized on the (memoized) raw step so the executor cache hits too."""
    def scan_step(carry, batch, ctl):
        p, o, metrics = raw_step(carry[0], carry[1], batch, ctl)
        return (p, o), metrics
    return scan_step


@dataclass
class RunResult:
    losses: List[float] = field(default_factory=list)
    accuracies: List[float] = field(default_factory=list)
    p_hats: List[float] = field(default_factory=list)
    privacy_spent: float = 0.0
    privacy_budget: float = 0.0
    steps: int = 0
    wall_time_s: float = 0.0
    resumed_from: int = 0
    privacy_exhausted_at: int = -1   # round at which the guard tripped


def run(model_cfg: ModelConfig, pz: PairZeroConfig,
        pipeline: FederatedPipeline, rounds: int, *,
        engine: str = "loop", chunk_rounds: int = 32,
        eval_every: int = 0, eval_n: int = 64,
        checkpoint_dir: Optional[str] = None, checkpoint_every: int = 0,
        fault: Optional[FaultModel] = None,
        elastic: Optional[ElasticSchedule] = None,
        impl: Optional[str] = None, dtype=jnp.float32,
        params: Optional[Any] = None,
        on_round: Optional[Callable[[int, Dict], None]] = None) -> RunResult:
    """Run T rounds of pAirZero (or the FO baseline) on one host.

    engine: "scan" (device-resident chunked lax.scan over rounds) or "loop"
      (legacy per-round dispatch). For the ZO variants (analog/sign) the
      two produce bit-identical trajectories at fixed seed; the FO baseline
      agrees only to fp tolerance (~1e-7 — XLA fuses value_and_grad
      differently under scan). Scan amortizes dispatch overhead over
      `chunk_rounds` rounds per dispatch and is the high-throughput choice
      once the chunk program is compiled (long horizons, repeated runs,
      accelerators). "loop" remains the default so short/ad-hoc CPU runs
      don't pay the chunk compile.
    """
    if engine not in ("scan", "loop"):
        raise ValueError(f"unknown engine: {engine!r} (want 'scan'|'loop')")
    t0 = time.time()
    k_clients = pz.n_clients
    result = RunResult()

    # --- channel + power schedule (the base station's offline solve) ---
    # The schedule is solved over the PLANNED horizon (pz.rounds), not this
    # invocation's `rounds`: Theorem 3/4 budgets privacy across all T, and a
    # checkpoint-resumed run must replay the identical schedule.
    horizon = max(pz.rounds, rounds)
    h = ota.draw_channels(pz.seed ^ 0xC4A7, horizon, k_clients,
                          pz.channel.fading)
    if pz.variant in ("analog", "sign"):
        schedule = pc.make_schedule(
            pz.variant, pz.power.scheme, h,
            power=pz.channel.power, n0=pz.channel.n0,
            gamma=pz.zo.clip_gamma, n_clients=k_clients, e0=pz.power.e0,
            contraction_a=pz.power.contraction_a,
            contraction_a_tilde=pz.power.contraction_a_tilde,
            epsilon=pz.dp.epsilon, delta=pz.dp.delta)
    else:
        schedule = pc.PowerSchedule(c=np.ones(horizon),
                                    sigma=np.zeros((horizon, k_clients)),
                                    scheme="perfect", n0=0.0)

    accountant = PrivacyAccountant(pz.dp.epsilon, pz.dp.delta)
    result.privacy_budget = accountant.budget

    # --- model / step ---
    if params is None:
        params = registry.init_params(jax.random.key(pz.seed), model_cfg,
                                      dtype)
    mod = registry.get_module(model_cfg)

    start_round = 0
    if checkpoint_dir:
        latest = ckpt.latest(checkpoint_dir)
        if latest:
            params, start_round, extra = ckpt.restore(latest, params)
            accountant = PrivacyAccountant.from_state_dict(
                extra["accountant"])
            result.resumed_from = start_round

    if pz.variant == "fo":
        optimizer = fo_opt.make("adam", pz.zo.lr)
        opt_state = optimizer.init(params)
        raw_step = pairzero.make_fo_step(model_cfg, optimizer, impl=impl)
        step = jax.jit(raw_step, donate_argnums=(0, 1))
    else:
        raw_step = pairzero.make_zo_step(model_cfg, pz, impl=impl)
        step = pairzero.jit_zo_step(raw_step)
        opt_state = None

    checkpointer = None
    if checkpoint_dir and checkpoint_every:
        checkpointer = ckpt.AsyncCheckpointer(checkpoint_dir)

    eval_fn = None
    if eval_every:
        def eval_fn(p, ebatch):
            toks = jnp.asarray(ebatch["tokens"])
            x = mod.forward(p, model_cfg, toks, impl=impl) \
                if model_cfg.family != "audio" else None
            if model_cfg.family == "audio":
                frames = jnp.zeros((toks.shape[0],
                                    model_cfg.frontend.n_frontend_tokens,
                                    model_cfg.d_model), dtype)
                enc = mod.encode(p, model_cfg, frames, impl=impl)
                x = mod.decode_hidden(p, model_cfg, toks, enc, impl=impl)
            from repro.models import layers as L
            head = p.get("lm_head", p.get("embed", p.get("dec_embed")))
            return L.unembed(head, x)
        eval_fn = jax.jit(eval_fn)

    def run_eval(t_done: int) -> None:
        ebatch = pipeline.eval_batch(eval_n)
        logits = np.asarray(eval_fn(params, ebatch))
        from repro.data import tasks as T
        acc = T.accuracy(logits, ebatch)
        result.accuracies.append(acc)

    # --- round execution: scan engine (default) or legacy loop ---
    if engine == "scan":
        if pz.variant == "fo":
            carry = (params, opt_state)
            executor = eng.get_executor(_fo_scan_step(raw_step))
        else:
            carry = params
            executor = eng.get_executor(raw_step)
        align = (eval_every if eval_every else 0,
                 checkpoint_every if checkpointer is not None else 0)

        # Software-pipelined chunk loop: the metric sync for chunk i is
        # deferred until chunk i+1 has been *dispatched*, so the host-side
        # prep of the next chunk (control trace, DP lookahead, batch
        # stacking) overlaps the device executing the current one. The
        # per-round loop cannot do this — it blocks on every round's loss.
        pending = None            # (first_round, n_rounds, device metrics)

        def flush() -> None:
            nonlocal pending
            if pending is None:
                return
            a0, n0_rounds, metrics = pending
            pending = None
            host = {k: np.asarray(v) for k, v in metrics.items()}
            result.losses.extend(float(x) for x in host["loss"])
            if "p_hat" in host:
                result.p_hats.extend(float(x) for x in host["p_hat"])
            if on_round is not None:
                for r in range(n0_rounds):
                    on_round(a0 + r, {k: v[r] for k, v in host.items()})

        for a, b in eng.chunk_boundaries(start_round, rounds, chunk_rounds,
                                         align):
            trace = eng.build_trace(schedule, pz, a, b,
                                    fault=fault, elastic=elastic)
            n_ok = eng.affordable_rounds(accountant, trace)
            if n_ok == 0:
                result.privacy_exhausted_at = a
                break
            eng.charge_rounds(accountant, trace, n_ok)
            batches = eng.stack_batches(pipeline, a, a + n_ok)
            carry, metrics = executor.run(carry, trace.rows(n_ok), batches)
            flush()               # sync chunk i-1 while chunk i runs
            pending = (a, n_ok, metrics)
            if pz.variant == "fo":
                params, opt_state = carry
            else:
                params = carry
            t_done = a + n_ok
            if n_ok < b - a:      # guard tripped mid-chunk: hard stop
                flush()
                result.privacy_exhausted_at = t_done
                break
            if eval_every and t_done % eval_every == 0:
                run_eval(t_done)
            if checkpointer is not None and t_done % checkpoint_every == 0:
                checkpointer.save(
                    t_done, params,
                    extra={"accountant": accountant.state_dict(),
                           "round": t_done})
        flush()
    else:
        for t in range(start_round, rounds):
            batch_np = pipeline.batch(t)
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()
                     if k != "labels"}
            mask = combined_mask(t, fault, elastic, n_clients=k_clients)
            ctl = pairzero.make_control(t, schedule, pz.seed, k_clients,
                                        mask=mask)

            if pz.variant == "fo":
                params, opt_state, metrics = step(params, opt_state, batch,
                                                  ctl)
            else:
                if pz.dp.enabled and schedule.scheme != "perfect":
                    # hard enforcement: a correct schedule sums exactly to the
                    # budget over the horizon; this guard trips only on
                    # misconfiguration (e.g. resuming with a different scheme)
                    # and stops all further transmission — privacy over
                    # utility.
                    gamma_t = pz.zo.clip_gamma if pz.variant == "analog" \
                        else 1.0
                    if accountant.would_violate(
                            float(schedule.c[t]), gamma_t,
                            schedule.effective_noise_std(t), slack=1e-6):
                        result.privacy_exhausted_at = t
                        break
                    accountant.charge(float(schedule.c[t]), gamma_t,
                                      schedule.effective_noise_std(t))
                params, metrics = step(params, batch, ctl)

            loss = float(metrics["loss"])
            result.losses.append(loss)
            if "p_hat" in metrics:
                result.p_hats.append(float(metrics["p_hat"]))

            if eval_every and (t + 1) % eval_every == 0:
                run_eval(t + 1)

            if on_round is not None:
                on_round(t, {"loss": loss, **{k: np.asarray(v)
                                              for k, v in metrics.items()}})

            if checkpointer is not None and (t + 1) % checkpoint_every == 0:
                checkpointer.save(t + 1, params,
                                  extra={"accountant":
                                         accountant.state_dict(),
                                         "round": t + 1})

    if checkpointer is not None:
        checkpointer.wait()
    result.steps = (result.privacy_exhausted_at - start_round
                    if result.privacy_exhausted_at >= 0
                    else rounds - start_round)
    result.privacy_spent = accountant.spent
    result.wall_time_s = time.time() - t0
    result.params = params  # type: ignore[attr-defined]
    return result
