"""Federated simulation driver: the paper's Algorithm 1 end to end.

`Experiment` is the single host-side orchestrator (what the edge server +
base station do):

  1. realize the wireless channel for the horizon via the channel registry
     (repro.channel: fading magnitudes, residual CSI phases, deep-fade
     participation — whatever stack pz.channel configures),
  2. ask the run's Transport (repro.core.transport) for its schedule —
     Theorem-3/4 power control for the OTA mechanisms, a trivial plan for
     the digital/FO baselines,
  3. run the rounds through one of two executors sharing ONE driver loop:
       engine="scan": the device-resident scan-over-rounds engine
         (core/engine.py) — `chunk_rounds` rounds per dispatch under one
         lax.scan with parameter-buffer donation;
       engine="loop" (default): per-round dispatch — no chunk compile
         cost, and the bit-identical equivalence oracle for scan,
  4. charge the DP accountant with the Transport's per-round costs (hard
     stop on overspend — privacy over utility), handle faults (survival
     masks), and fire the round hooks.

Eval, checkpointing and logging are uniform `RoundHook`s shared by both
engines: the driver aligns chunk boundaries to every hook cadence, so a
hook fires at exactly the same rounds regardless of dispatch granularity.

`run(...)` keeps the historical flat-kwarg surface (it builds the hooks and
delegates); its `variant=`/`scheme=` kwargs are a one-release deprecation
shim routed through the transport registry.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro import byzantine as byz
from repro import channel
from repro import obs
from repro.checkpoint import checkpoint as ckpt
from repro.configs.base import ModelConfig, PairZeroConfig
from repro.core import engine as eng
from repro.core import pairzero
from repro.core import transport as tp
from repro.core.dp import PrivacyAccountant, cumulative_spend
from repro.data.pipeline import FederatedPipeline
from repro.models import registry
from repro.optim import fo as fo_opt
from repro.runtime import desync as dsync
from repro.runtime import inject as inj
from repro.runtime import sharding as shd
from repro.runtime.fault import ElasticSchedule, FaultModel


@functools.lru_cache(maxsize=32)
def _fo_scan_step(raw_step: Callable) -> Callable:
    """Adapter: FO step's (params, opt_state) pair as a single carry.
    Memoized on the (memoized) raw step so the executor cache hits too."""
    def scan_step(carry, batch, ctl):
        p, o, metrics = raw_step(carry[0], carry[1], batch, ctl)
        return (p, o), metrics
    return scan_step


@dataclass
class RunResult:
    losses: List[float] = field(default_factory=list)
    accuracies: List[float] = field(default_factory=list)
    p_hats: List[float] = field(default_factory=list)
    privacy_spent: float = 0.0
    privacy_budget: float = 0.0
    steps: int = 0
    wall_time_s: float = 0.0
    resumed_from: int = 0
    privacy_exhausted_at: int = -1   # round at which the guard tripped
    uplink_bits: int = 0             # total uplink spend (Transport-accounted)
    params: Optional[Any] = None     # final model parameters
    # the base station's offline solve, exposed for post-hoc analysis
    # (privacy audits/attacks consume the realized schedule + transport)
    schedule: Optional[Any] = None
    transport: Optional[Any] = None
    # chunk-boundary stall accounting (seconds over the whole run):
    prep_stall_s: float = 0.0        # driver blocked on host-side chunk prep
    ckpt_stall_s: float = 0.0        # driver blocked on checkpoint snapshots
    # observability (repro.obs):
    peak_bytes: int = 0              # device-memory watermark (0: no sampler)
    # build/retrace counter deltas for this run (always recorded — a warm
    # rerun of an identical config must show all zeros)
    compile_stats: Dict[str, int] = field(default_factory=dict)
    # [steps] cumulative Eq.-16 ledger after each executed round (the
    # accountant's own float64 fold — dp.cumulative_spend); the audit CLI
    # and the MetricsSink trilemma ledger read these same numbers
    privacy_spent_per_round: Optional[np.ndarray] = None
    # robustness accounting (repro.runtime.inject): nonzero retry /
    # degradation counters by site ("dispatch", "ckpt_write",
    # "prefetch_degraded", "ckpt_write_failed", "ckpt_snapshot_failed").
    # Empty on a clean run — the ledger's final row asserts against it.
    retry_attempts: Dict[str, int] = field(default_factory=dict)
    # compiled-program introspection (repro.obs.hlo), populated when the
    # run's Telemetry has cost=True: XLA-reported flops / bytes / peak
    # memory / collective census for the executor program actually
    # dispatched ({"error": ...} if the analysis itself failed)
    cost_stats: Optional[Dict[str, Any]] = None
    # run-health outcome (repro.obs.health): the round and detector kind
    # of a policy="abort" stop; -1/"" on a run that finished naturally.
    # The accountant only ever charged executed rounds, so privacy_spent
    # remains the realized spend `--audit` should consume.
    health_abort_round: int = -1
    health_abort_reason: str = ""


# ---------------------------------------------------------------------------
# Round hooks — eval / checkpoint / logging, uniform across engines
# ---------------------------------------------------------------------------

class RoundHook:
    """Host-side side effect wired into the driver loop.

    `cadence` (rounds) aligns chunk boundaries so `on_boundary` fires at
    exactly the multiples it would under per-round dispatch. `on_round`
    receives every round's host metrics (one chunk late under the scan
    engine's software pipelining — never reordered).
    """
    cadence: int = 0

    def on_start(self, exp: "Experiment") -> None:
        """Before round execution; may restore state (params, accountant)."""

    def on_round(self, t: int, metrics: Dict[str, np.ndarray]) -> None:
        """Per executed round, with that round's host-side metrics."""

    def on_boundary(self, t_done: int, exp: "Experiment") -> None:
        """At every aligned chunk boundary (t_done rounds executed)."""

    def close(self, exp: "Experiment") -> None:
        """After the run (flush async work)."""


class EvalHook(RoundHook):
    """Greedy eval on the held-out batch every `cadence` rounds."""

    def __init__(self, every: int, eval_n: int = 64):
        self.cadence = every
        self.eval_n = eval_n
        self._fn = None

    def on_start(self, exp: "Experiment") -> None:
        model_cfg, impl, dtype = exp.model_cfg, exp.impl, exp.dtype
        mod = registry.get_module(model_cfg)

        def eval_fn(p, ebatch):
            toks = jnp.asarray(ebatch["tokens"])
            if model_cfg.family == "audio":
                frames = jnp.zeros((toks.shape[0],
                                    model_cfg.frontend.n_frontend_tokens,
                                    model_cfg.d_model), dtype)
                enc = mod.encode(p, model_cfg, frames, impl=impl)
                x = mod.decode_hidden(p, model_cfg, toks, enc, impl=impl)
            else:
                x = mod.forward(p, model_cfg, toks, impl=impl)
            from repro.models import layers as L
            head = p.get("lm_head", p.get("embed", p.get("dec_embed")))
            return L.unembed(head, x)

        self._fn = jax.jit(eval_fn)

    def on_boundary(self, t_done: int, exp: "Experiment") -> None:
        if self.cadence and t_done % self.cadence == 0:
            ebatch = exp.pipeline.eval_batch(self.eval_n)
            logits = np.asarray(self._fn(exp.params, ebatch))
            from repro.data import tasks as T
            exp.result.accuracies.append(T.accuracy(logits, ebatch))


class CheckpointHook(RoundHook):
    """Crash-safe restore-on-start + async save every `cadence` rounds.

    `double_buffer` selects the non-blocking snapshot path (on-device copy
    + `copy_to_host_async`, materialized on the writer thread) — the next
    chunk dispatches without waiting for the device→host transfer. False
    keeps the historical synchronous `device_get` (the stall baseline).
    """

    def __init__(self, directory: str, every: int = 0,
                 double_buffer: bool = True):
        self.directory = directory
        self.cadence = every
        self.double_buffer = double_buffer
        self._saver = None

    def on_start(self, exp: "Experiment") -> None:
        # newest *CRC-valid* checkpoint: a SIGKILL mid-write (or simulated
        # bitrot) leaves a torn step_N that plain `latest` would return —
        # crash-consistent resume falls back to the last intact save
        latest = ckpt.latest_valid(self.directory)
        if latest:
            exp.params, exp.start_round, extra = ckpt.restore(latest,
                                                              exp.params)
            exp.accountant = PrivacyAccountant.from_state_dict(
                extra["accountant"])
            exp.result.resumed_from = exp.start_round
        if self.cadence:
            self._saver = ckpt.AsyncCheckpointer(
                self.directory, double_buffer=self.double_buffer,
                tracer=exp.telemetry.tracer, injector=exp.injector)

    def on_boundary(self, t_done: int, exp: "Experiment") -> None:
        if self._saver is not None and t_done % self.cadence == 0:
            self._saver.save(
                t_done, exp.params,
                extra={"accountant": exp.accountant.state_dict(),
                       "round": t_done})

    def close(self, exp: "Experiment") -> None:
        if self._saver is not None:
            self._saver.wait()


class CallbackHook(RoundHook):
    """Per-round logging callback (the historical `on_round=` kwarg)."""

    def __init__(self, fn: Callable[[int, Dict], None]):
        self._fn = fn

    def on_round(self, t: int, metrics: Dict[str, np.ndarray]) -> None:
        self._fn(t, metrics)


# ---------------------------------------------------------------------------
# The orchestrator
# ---------------------------------------------------------------------------

class Experiment:
    """One federated run: model + pAirZero config + data + a Transport.

    The driver is deliberately boring: every interesting decision lives in
    core/{zo,transport,dp,power_control,pairzero,engine}. Both engines run
    the SAME loop here — chunk boundaries, control traces, DP lookahead and
    hooks are shared; only the executor (per-round jit vs chunked lax.scan)
    differs, which is what makes loop/scan bit-identity testable.
    """

    def __init__(self, model_cfg: ModelConfig, pz: PairZeroConfig,
                 pipeline: FederatedPipeline, rounds: int, *,
                 engine: str = "loop", chunk_rounds: int = 32,
                 transport: Optional[tp.Transport] = None,
                 channel_model: Optional[channel.ChannelModel] = None,
                 hooks: Sequence[RoundHook] = (),
                 fault: Optional[FaultModel] = None,
                 elastic: Optional[ElasticSchedule] = None,
                 impl: Optional[str] = None, dtype=jnp.float32,
                 params: Optional[Any] = None,
                 mesh: Optional[Mesh] = None, overlap: bool = True,
                 adversary: Optional[Any] = None,
                 behavior: Optional[Any] = None,
                 defense: Optional[Any] = None,
                 telemetry: Optional[obs.Telemetry] = None,
                 desync: Optional[dsync.DesyncModel] = None,
                 injector: Optional[inj.FaultInjector] = None):
        if engine not in ("scan", "loop"):
            raise ValueError(
                f"unknown engine: {engine!r} (want 'scan'|'loop')")
        self.model_cfg = model_cfg
        self.pz = pz
        self.pipeline = pipeline
        self.rounds = rounds
        self.engine = engine
        self.chunk_rounds = chunk_rounds
        self.transport = transport if transport is not None \
            else tp.resolve(pz)
        # explicit ChannelModel overrides the pz.channel config stack
        # (mirrors `transport=`) — how user-built/wrapped models run
        self.channel_model = channel_model if channel_model is not None \
            else channel.from_config(pz.channel)
        self.hooks = list(hooks)
        self.fault = fault
        self.elastic = elastic
        self.impl = impl
        self.dtype = dtype
        self.params = params
        self.mesh = mesh
        self.overlap = overlap
        # eavesdropper observation capture (repro.privacy.Adversary): the
        # step emits obs_* metrics; pair with an AttackHook to collect them
        self.adversary = adversary
        # active-adversary scenario (repro.byzantine): explicit instances
        # override the pz.byzantine config resolution (mirrors transport=)
        self.behavior = behavior if behavior is not None \
            else byz.resolve_behavior(pz)
        self.defense = defense if defense is not None \
            else byz.resolve_defense(pz)
        # imperfect synchronization (repro.runtime.desync): explicit model
        # overrides the pz.desync config resolution (mirrors transport=).
        # Unlike byzantine, desync IS meaningful for the FO baseline — the
        # Dirichlet frame-gain collapse is the fig_desync comparison.
        self.desync = desync if desync is not None else dsync.resolve(pz)
        if self.desync is not None and not self.desync.active:
            self.desync = None     # inert config == historical program
        # chaos testing (repro.runtime.inject): deterministic fault
        # injection at the named host sites; None arms nothing
        self.injector = injector
        if self.transport.kind == "fo" and (self.behavior is not None
                                            or self.defense is not None):
            raise ValueError(
                "Byzantine behaviors/defenses act on the scalar ZO payload "
                "vector; the FO baseline has no scalar uplink to attack or "
                "defend — run it without a ByzantineConfig")
        # realized channel + schedule, exposed after run() for post-hoc
        # attacks/audits (the adversary knows both — they are broadcast)
        self.channel_trace = None
        self.schedule = None
        if mesh is not None:
            cl = shd.client_axes(mesh)
            n_shards = shd.axis_size(mesh, cl)
            if not cl or n_shards <= 0:
                raise ValueError(f"mesh {mesh.axis_names} has no client "
                                 "axes (want 'pod' and/or 'data')")
            if pz.n_clients % n_shards != 0:
                raise ValueError(
                    f"n_clients={pz.n_clients} must divide evenly over the "
                    f"{n_shards} client shards of mesh {dict(mesh.shape)} — "
                    "pAirZero clients split evenly or not at all")
            if self.transport.kind == "fo":
                raise ValueError(
                    "the FO baseline has no shard_map variant (it uploads "
                    "d-dimensional gradients, not a scalar) — run it "
                    "without mesh=")
        # host-side observability (repro.obs): span timeline + memory
        # watermark. The default is the inert bundle (NULL_TRACER, no
        # sampler) — instrumentation sites are then no-op method calls and
        # the traced program is the bit-exact historical one.
        self.telemetry = telemetry if telemetry is not None \
            else obs.Telemetry.off()
        # populated by run()/hooks
        self.result = RunResult()
        self.accountant = PrivacyAccountant(pz.dp.epsilon, pz.dp.delta)
        self.start_round = 0
        # per-round observability state populated by run(): realized
        # K_eff(t) per executed round (the ledger's bit accounting), and
        # the accountant ledger position when the run started (restored
        # checkpoints begin with spent > 0 and an empty history)
        self.round_k_eff: List[float] = []
        # per executed round: surviving clients whose scalar rode the
        # CURRENT round seed (K_eff minus the stale stragglers) — the
        # ledger's k_sync column; == round_k_eff when desync is off
        self.round_k_sync: List[float] = []
        self.spent_at_start = 0.0
        self.hist_at_start = 0
        # bounded-retry counters by site, merged into result.retry_attempts
        self._retries: Dict[str, int] = {}

    # -- engine plumbing --------------------------------------------------
    def _build_step(self):
        """(step_fn, carry): the scan-body step and its initial carry."""
        if self.transport.kind == "fo":
            optimizer = fo_opt.make("adam", self.pz.zo.lr)
            raw = pairzero.make_fo_step(self.model_cfg, optimizer,
                                        impl=self.impl,
                                        adversary=self.adversary,
                                        desync=self.desync)
            return _fo_scan_step(raw), (self.params,
                                        optimizer.init(self.params))
        raw = pairzero.make_zo_step(self.model_cfg, self.pz, impl=self.impl,
                                    transport=self.transport, mesh=self.mesh,
                                    adversary=self.adversary,
                                    behavior=self.behavior,
                                    defense=self.defense,
                                    desync=self.desync)
        return raw, self.params

    def _executor(self, step_fn):
        if self.engine == "scan":
            return eng.get_executor(step_fn)
        return eng.get_loop_executor(pairzero.jit_zo_step(step_fn))

    # -- the run ----------------------------------------------------------
    def run(self) -> RunResult:
        t0 = time.time()
        pz, result = self.pz, self.result
        tr = self.telemetry.tracer
        mem = self.telemetry.memory
        compile_before = obs.retrace.snapshot()
        result.privacy_budget = self.accountant.budget

        # channel + transmit schedule (the base station's offline solve).
        # Realized/solved over the PLANNED horizon (pz.rounds), not this
        # invocation's `rounds`: Theorem 3/4 budget privacy across all T,
        # and a resumed run must replay the identical channel + schedule.
        horizon = max(pz.rounds, self.rounds)
        with tr.span("channel_realize", horizon=horizon):
            ctrace = self.channel_model.realize(pz.seed ^ 0xC4A7, horizon,
                                                pz.n_clients)
        # an active defense may fold its PHY constraint into the solve
        # (transmit clip => tightened Theorem-3/4 sensitivity)
        with tr.span("schedule_solve", transport=self.transport.name):
            schedule = self.transport.make_schedule(ctrace, pz) \
                if self.defense is None \
                else self.defense.make_schedule(self.transport, ctrace, pz)
        self.channel_trace, self.schedule = ctrace, schedule
        result.schedule, result.transport = schedule, self.transport

        if self.params is None:
            with tr.span("params_init"):
                self.params = registry.init_params(jax.random.key(pz.seed),
                                                   self.model_cfg,
                                                   self.dtype)
        for hook in self.hooks:
            hook.on_start(self)
        # the accountant may have been replaced by a restoring hook; the
        # ledger position NOW is what per-round spend curves fold from
        self.spent_at_start = self.accountant.spent
        self.hist_at_start = len(self.accountant.history)
        if mem is not None:
            mem.sample(self.start_round, tracer=tr)
        if self.mesh is not None:
            # FSDP placement over the client axes ('model' TP when present);
            # restored checkpoints land default-placed, so this reshards
            # fresh-init and resumed runs alike
            self.params = jax.device_put(
                self.params, shd.params_sharding(self.mesh, self.params))

        step_fn, carry = self._build_step()
        executor = self._executor(step_fn)
        align = tuple(hk.cadence for hk in self.hooks if hk.cadence)
        # The loop engine dispatches (and syncs) one round at a time — run
        # it on 1-round spans so metrics/on_round stay live and batches
        # transfer per round, exactly as per-round dispatch always did.
        # Span length never changes numerics (trace values are split-
        # invariant); only the scan engine benefits from longer spans.
        span = 1 if self.engine == "loop" else self.chunk_rounds
        bounds = eng.chunk_boundaries(self.start_round, self.rounds,
                                      span, align)

        # Host-side chunk prep — control trace (+ its single device_put,
        # replicated over the mesh) and batch staging into preallocated
        # buffers — runs one chunk ahead on the prefetch thread while the
        # device executes the current chunk. Prep order == round order, so
        # the stateful FaultModel RNG replays exactly the per-round draw.
        ctl_shard = NamedSharding(self.mesh, PartitionSpec()) \
            if self.mesh is not None else None
        stager = eng.BatchStager(
            self.pipeline,
            sharding_fn=(lambda like:
                         shd.chunk_batch_sharding(self.mesh, like))
            if self.mesh is not None else None,
            tracer=tr)

        def prepare(a: int, b: int):
            with tr.span("ctl_build", t0=a, t1=b):
                trace = eng.build_trace(schedule, pz, a, b,
                                        transport=self.transport,
                                        fault=self.fault,
                                        elastic=self.elastic,
                                        channel=ctrace,
                                        ctl_sharding=ctl_shard,
                                        behavior=self.behavior,
                                        defense=self.defense,
                                        desync=self.desync)
            return trace, stager.stage(a, b)

        prefetch = eng.ChunkPrefetcher(prepare, bounds,
                                       overlap=self.overlap, tracer=tr,
                                       injector=self.injector)
        # dispatch retry is sound only for entry injection: the executor
        # donates the carry buffers, so a REAL mid-flight failure is not
        # replayable — without an armed injector, fail fast (attempts=1)
        dispatch_attempts = 3 if (self.injector is not None
                                  and self.injector.armed("dispatch")) else 1

        # Software-pipelined chunk loop: the metric sync for chunk i is
        # deferred until chunk i+1 has been *dispatched*, so both the
        # prefetch thread and the flush overlap the device executing the
        # current chunk.
        pending = None            # (first_round, n_rounds, metrics)
        client_rounds = 0.0       # Σ_t K_eff(t) over executed rounds
        # dispatch-arg specs for post-run cost analysis, captured on the
        # first chunk BEFORE the executor donates the carry buffers
        cost_specs = None
        # HealthMonitor(policy="abort") raises from on_round inside a
        # flush; caught at chunk granularity so executed == charged rounds
        health_abort: Optional[obs.HealthAbort] = None
        last_boundary = self.start_round   # newest completed hook boundary

        def flush() -> None:
            nonlocal pending
            if pending is None:
                return
            a0, n_rounds, metrics = pending
            pending = None
            with tr.span("metrics_flush", t0=a0, rounds=n_rounds):
                host = {k: np.asarray(v) for k, v in metrics.items()}
                result.losses.extend(float(x) for x in host["loss"])
                if "p_hat" in host:
                    result.p_hats.extend(float(x) for x in host["p_hat"])
                for hook in self.hooks:
                    for r in range(n_rounds):
                        hook.on_round(a0 + r,
                                      {k: v[r] for k, v in host.items()})

        try:
            for i, (a, b) in enumerate(bounds):
                with tr.span("chunk", chunk=i, t0=a, t1=b):
                    trace, batches = prefetch.get(i)
                    n_ok = eng.affordable_rounds(self.accountant, trace)
                    if n_ok == 0:
                        result.privacy_exhausted_at = a
                        break
                    eng.charge_rounds(self.accountant, trace, n_ok)
                    # uplink accounting: only clients that actually
                    # transmit (survival mask 1) are billed their payload
                    # this round; the per-round K_eff view feeds the
                    # trilemma ledger (obs.MetricsSink)
                    k_rows = trace.host_masks[:n_ok].sum(axis=1)
                    client_rounds += float(k_rows.sum())
                    self.round_k_eff.extend(float(x) for x in k_rows)
                    # synchronized survivors: exclude the stale stragglers
                    # whose scalar rode a lagged round seed this round
                    if trace.host_stale is not None:
                        sync_rows = (trace.host_masks[:n_ok]
                                     * (1.0 - trace.host_stale[:n_ok])
                                     ).sum(axis=1)
                    else:
                        sync_rows = k_rows
                    self.round_k_sync.extend(float(x) for x in sync_rows)
                    if n_ok < b - a:  # guard trips mid-chunk: truncate
                        batches = {k: v[:n_ok] for k, v in batches.items()}
                    if self.telemetry.cost and cost_specs is None:
                        cost_specs = (obs.hlo.specs_of(carry),
                                      obs.hlo.specs_of(trace.rows(n_ok)),
                                      obs.hlo.specs_of(batches))
                    with tr.span("dispatch", chunk=i, rounds=n_ok):
                        carry, metrics = inj.with_retries(
                            lambda: executor.run(carry, trace.rows(n_ok),
                                                 batches),
                            site="dispatch", attempts=dispatch_attempts,
                            injector=self.injector, tracer=tr,
                            retries=self._retries)
                    flush()       # sync chunk i-1 while chunk i runs
                    pending = (a, n_ok, metrics)
                    if self.engine == "loop":
                        # per-round dispatch already synced each round —
                        # deliver metrics/on_round immediately (live
                        # logging), nothing to pipeline against.
                        flush()
                    # chunk i-1 is now synced ⇒ its stager slot (shared
                    # with chunk i+1) is reusable: start the next prep
                    prefetch.kick(i + 1)
                    self.params = carry[0] if self.transport.kind == "fo" \
                        else carry
                    t_done = a + n_ok
                    if n_ok < b - a:  # guard tripped mid-chunk: hard stop
                        flush()
                        result.privacy_exhausted_at = t_done
                        break
                    if mem is not None and mem.due(t_done):
                        mem.sample(t_done, tracer=tr)
                    with tr.span("hooks_boundary", t=t_done):
                        for hook in self.hooks:
                            hook.on_boundary(t_done, self)
                    last_boundary = t_done
        except obs.HealthAbort as e:
            health_abort = e
            pending = None       # rounds past the abort stay unreported
        finally:
            prefetch.close()
        # final watermark BEFORE the last flush: MetricsSink rows and
        # result.peak_bytes then report the same peak
        if mem is not None:
            mem.sample(self.start_round + len(self.round_k_eff), tracer=tr)
        if health_abort is None:
            try:
                flush()
            except obs.HealthAbort as e:
                health_abort = e
                pending = None

        if health_abort is not None:
            result.health_abort_round = int(health_abort.round)
            result.health_abort_reason = str(health_abort.reason)
            # checkpoint-then-abort: persist the newest consistent state
            # (params + accountant at the last completed boundary) so the
            # run can be resumed/inspected; best effort — the abort report
            # must survive a failing writer
            for hk in self.hooks:
                if isinstance(hk, CheckpointHook) and hk._saver is not None:
                    try:
                        hk._saver.save(
                            last_boundary, self.params,
                            extra={"accountant":
                                   self.accountant.state_dict(),
                                   "round": last_boundary})
                    except Exception:
                        pass

        for hook in self.hooks:
            hook.close(self)
        if health_abort is not None:
            # every charged round executed; rounds after the abort within
            # the final chunk were bought and ran, so they count as steps
            result.steps = len(self.round_k_eff)
        else:
            result.steps = max(0,
                               result.privacy_exhausted_at - self.start_round
                               if result.privacy_exhausted_at >= 0
                               else self.rounds - self.start_round)
        result.privacy_spent = self.accountant.spent
        # the per-round ε ledger: the accountant's own charges for this
        # run's executed rounds, folded with the identical float64 cumsum
        # (uncharged transports: a flat curve at the starting ledger)
        costs = np.asarray(
            self.accountant.history[self.hist_at_start:], dtype=np.float64)
        if costs.size != result.steps:
            costs = np.zeros(result.steps, dtype=np.float64)
        result.privacy_spent_per_round = cumulative_spend(
            costs, initial=self.spent_at_start)
        # payload per transmitting client x Σ_t K_eff(t): dropped/silenced
        # clients send nothing, so they cost nothing; an active defense
        # scales the payload (re-transmission factors) and bills its own
        # side-channel bits per executed round. uplink_bits_total is the
        # ONE accounting expression — the MetricsSink ledger calls it too.
        result.uplink_bits = tp.uplink_bits_total(
            self.transport, self.defense, pz, self.model_cfg.param_count(),
            client_rounds, result.steps)
        result.prep_stall_s = prefetch.stall_s
        result.ckpt_stall_s = sum(
            hk._saver.stall_s for hk in self.hooks
            if isinstance(hk, CheckpointHook) and hk._saver is not None)
        # robustness ledger: only nonzero counters, so a clean run reports
        # an empty dict (asserted bit-for-bit by the trace checker)
        attempts = dict(self._retries)
        if prefetch.degraded:
            attempts["prefetch_degraded"] = prefetch.degraded
        for hk in self.hooks:
            if isinstance(hk, CheckpointHook) and hk._saver is not None:
                for site, n in hk._saver.retries.items():
                    attempts[site] = attempts.get(site, 0) + n
                if hk._saver.write_failures:
                    attempts["ckpt_write_failed"] = (
                        attempts.get("ckpt_write_failed", 0)
                        + hk._saver.write_failures)
                if hk._saver.snapshot_failures:
                    attempts["ckpt_snapshot_failed"] = (
                        attempts.get("ckpt_snapshot_failed", 0)
                        + hk._saver.snapshot_failures)
        result.retry_attempts = {k: v for k, v in attempts.items() if v}
        result.peak_bytes = mem.peak_bytes if mem is not None else 0
        result.compile_stats = obs.retrace.since(compile_before)
        result.wall_time_s = time.time() - t0
        result.params = self.params
        if cost_specs is not None:
            # AOT introspection of the dispatched program (repro.obs.hlo):
            # compile-only, after the run clock stopped, counters
            # suspended — timing, numerics and compile-watermark pins are
            # untouched. Analysis failure must not fail a finished run.
            try:
                result.cost_stats = obs.hlo.analyze_executor(
                    executor, *cost_specs).to_dict()
            except Exception as exc:  # noqa: BLE001 - record, don't raise
                result.cost_stats = {"error": f"{type(exc).__name__}: {exc}"}
        return result


# ---------------------------------------------------------------------------
# Flat-kwarg compatibility surface
# ---------------------------------------------------------------------------

def run(model_cfg: ModelConfig, pz: PairZeroConfig,
        pipeline: FederatedPipeline, rounds: int, *,
        engine: str = "loop", chunk_rounds: int = 32,
        eval_every: int = 0, eval_n: int = 64,
        checkpoint_dir: Optional[str] = None, checkpoint_every: int = 0,
        fault: Optional[FaultModel] = None,
        elastic: Optional[ElasticSchedule] = None,
        impl: Optional[str] = None, dtype=jnp.float32,
        params: Optional[Any] = None,
        on_round: Optional[Callable[[int, Dict], None]] = None,
        transport: Optional[tp.Transport] = None,
        channel_model: Optional[channel.ChannelModel] = None,
        mesh: Optional[Mesh] = None, overlap: bool = True,
        adversary: Optional[Any] = None,
        behavior: Optional[Any] = None,
        defense: Optional[Any] = None,
        hooks: Sequence[RoundHook] = (),
        telemetry: Optional[obs.Telemetry] = None,
        desync: Optional[dsync.DesyncModel] = None,
        injector: Optional[inj.FaultInjector] = None,
        variant: Optional[str] = None,
        scheme: Optional[str] = None) -> RunResult:
    """Run T rounds of pAirZero (or a baseline transport) on one host.

    Thin wrapper over `Experiment`: builds the eval/checkpoint/logging
    hooks from the historical kwargs and delegates. `mesh=` runs the
    shard_map'd step with clients mapped over the mesh's (pod, data) axes
    (see `pairzero.make_zo_step`); `overlap=False` disables the prefetch
    thread (the no-overlap stall control). `adversary=` (a
    `repro.privacy.Adversary`) switches on eavesdropper observation
    capture — pair it with a `repro.privacy.AttackHook` in `hooks=` to
    collect the observations. `behavior=`/`defense=` (repro.byzantine)
    override the pz.byzantine config resolution with explicit instances —
    the active-adversary scenario axis. `telemetry=` (a
    `repro.obs.Telemetry`) switches on the host-side span timeline and
    device-memory watermark; pair it with a `repro.obs.MetricsSink` in
    `hooks=` for the per-round trilemma ledger — all host-side, so the
    trajectory is bitwise unchanged. `desync=` (a
    `repro.runtime.DesyncModel`) switches on imperfect-synchronization
    modeling — stale stragglers riding lagged round seeds plus fractional
    timing misalignment entering the OTA superposition; `injector=` (a
    `repro.runtime.FaultInjector`) arms deterministic fault injection at
    the named host sites for chaos testing. Both default to None, tracing
    the bit-exact historical program. `variant=`/`scheme=` are the
    DEPRECATED string spellings, routed through the transport registry for
    one more release — pass `transport=` or put a TransportConfig in
    `pz.transport` instead.
    """
    if variant is not None or scheme is not None:
        tp.deprecated_strings(variant or pz.variant,
                              scheme or pz.power.scheme, "fedsim.run")
        pz = dataclasses.replace(
            pz, variant=variant or pz.variant,
            power=dataclasses.replace(pz.power,
                                      scheme=scheme or pz.power.scheme),
            transport=None)
    all_hooks: List[RoundHook] = list(hooks)
    if eval_every:
        all_hooks.append(EvalHook(eval_every, eval_n))
    if checkpoint_dir:
        all_hooks.append(CheckpointHook(checkpoint_dir, checkpoint_every))
    if on_round is not None:
        all_hooks.append(CallbackHook(on_round))
    return Experiment(model_cfg, pz, pipeline, rounds, engine=engine,
                      chunk_rounds=chunk_rounds, transport=transport,
                      channel_model=channel_model, hooks=all_hooks,
                      fault=fault, elastic=elastic, impl=impl, dtype=dtype,
                      params=params, mesh=mesh, overlap=overlap,
                      adversary=adversary, behavior=behavior,
                      defense=defense, telemetry=telemetry,
                      desync=desync, injector=injector).run()
