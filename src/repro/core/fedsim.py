"""Federated simulation driver: the paper's Algorithm 1 end to end.

Host-side loop (what the edge server + base station do):
  1. draw the block-fading channel trace h_k(t) for the horizon,
  2. solve power control (Theorem 3/4 — or Static/Reversed/Perfect ablation),
  3. per round: broadcast the seed, run the jitted ZO step (clients' dual
     forwards + OTA aggregation + update), charge the DP accountant,
  4. handle faults (survival masks), checkpoint/resume, periodic eval.

The driver is deliberately boring: every interesting decision lives in
core/{zo,ota,dp,power_control,pairzero}. It is the substrate for the three
examples, the Fig. 2/3 benchmarks, and the integration tests.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt
from repro.configs.base import ModelConfig, PairZeroConfig
from repro.core import ota, pairzero, power_control as pc
from repro.core.dp import PrivacyAccountant
from repro.data.pipeline import FederatedPipeline
from repro.models import registry
from repro.optim import fo as fo_opt
from repro.runtime.fault import FaultModel, ElasticSchedule, combined_mask


@dataclass
class RunResult:
    losses: List[float] = field(default_factory=list)
    accuracies: List[float] = field(default_factory=list)
    p_hats: List[float] = field(default_factory=list)
    privacy_spent: float = 0.0
    privacy_budget: float = 0.0
    steps: int = 0
    wall_time_s: float = 0.0
    resumed_from: int = 0
    privacy_exhausted_at: int = -1   # round at which the guard tripped


def run(model_cfg: ModelConfig, pz: PairZeroConfig,
        pipeline: FederatedPipeline, rounds: int, *,
        eval_every: int = 0, eval_n: int = 64,
        checkpoint_dir: Optional[str] = None, checkpoint_every: int = 0,
        fault: Optional[FaultModel] = None,
        elastic: Optional[ElasticSchedule] = None,
        impl: Optional[str] = None, dtype=jnp.float32,
        params: Optional[Any] = None,
        on_round: Optional[Callable[[int, Dict], None]] = None) -> RunResult:
    """Run T rounds of pAirZero (or the FO baseline) on one host."""
    t0 = time.time()
    k_clients = pz.n_clients
    result = RunResult()

    # --- channel + power schedule (the base station's offline solve) ---
    # The schedule is solved over the PLANNED horizon (pz.rounds), not this
    # invocation's `rounds`: Theorem 3/4 budgets privacy across all T, and a
    # checkpoint-resumed run must replay the identical schedule.
    horizon = max(pz.rounds, rounds)
    h = ota.draw_channels(pz.seed ^ 0xC4A7, horizon, k_clients,
                          pz.channel.fading)
    if pz.variant in ("analog", "sign"):
        schedule = pc.make_schedule(
            pz.variant, pz.power.scheme, h,
            power=pz.channel.power, n0=pz.channel.n0,
            gamma=pz.zo.clip_gamma, n_clients=k_clients, e0=pz.power.e0,
            contraction_a=pz.power.contraction_a,
            contraction_a_tilde=pz.power.contraction_a_tilde,
            epsilon=pz.dp.epsilon, delta=pz.dp.delta)
    else:
        schedule = pc.PowerSchedule(c=np.ones(horizon),
                                    sigma=np.zeros((horizon, k_clients)),
                                    scheme="perfect", n0=0.0)

    accountant = PrivacyAccountant(pz.dp.epsilon, pz.dp.delta)
    result.privacy_budget = accountant.budget

    # --- model / step ---
    if params is None:
        params = registry.init_params(jax.random.key(pz.seed), model_cfg,
                                      dtype)
    mod = registry.get_module(model_cfg)

    start_round = 0
    if checkpoint_dir:
        latest = ckpt.latest(checkpoint_dir)
        if latest:
            params, start_round, extra = ckpt.restore(latest, params)
            accountant = PrivacyAccountant.from_state_dict(
                extra["accountant"])
            result.resumed_from = start_round

    if pz.variant == "fo":
        optimizer = fo_opt.make("adam", pz.zo.lr)
        opt_state = optimizer.init(params)
        raw_step = pairzero.make_fo_step(model_cfg, optimizer, impl=impl)
        step = jax.jit(raw_step, donate_argnums=(0, 1))
    else:
        raw_step = pairzero.make_zo_step(model_cfg, pz, impl=impl)
        step = pairzero.jit_zo_step(raw_step)
        opt_state = None

    checkpointer = None
    if checkpoint_dir and checkpoint_every:
        checkpointer = ckpt.AsyncCheckpointer(checkpoint_dir)

    eval_fn = None
    if eval_every:
        def eval_fn(p, ebatch):
            toks = jnp.asarray(ebatch["tokens"])
            x = mod.forward(p, model_cfg, toks, impl=impl) \
                if model_cfg.family != "audio" else None
            if model_cfg.family == "audio":
                frames = jnp.zeros((toks.shape[0],
                                    model_cfg.frontend.n_frontend_tokens,
                                    model_cfg.d_model), dtype)
                enc = mod.encode(p, model_cfg, frames, impl=impl)
                x = mod.decode_hidden(p, model_cfg, toks, enc, impl=impl)
            from repro.models import layers as L
            head = p.get("lm_head", p.get("embed", p.get("dec_embed")))
            return L.unembed(head, x)
        eval_fn = jax.jit(eval_fn)

    # --- round loop ---
    for t in range(start_round, rounds):
        batch_np = pipeline.batch(t)
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()
                 if k != "labels"}
        mask = combined_mask(t, fault, elastic, n_clients=k_clients)
        ctl = pairzero.make_control(t, schedule, pz.seed, k_clients,
                                    mask=mask)

        if pz.variant == "fo":
            params, opt_state, metrics = step(params, opt_state, batch, ctl)
        else:
            if pz.dp.enabled and schedule.scheme != "perfect":
                # hard enforcement: a correct schedule sums exactly to the
                # budget over the horizon; this guard trips only on
                # misconfiguration (e.g. resuming with a different scheme)
                # and stops all further transmission — privacy over utility.
                gamma_t = pz.zo.clip_gamma if pz.variant == "analog" else 1.0
                if accountant.would_violate(
                        float(schedule.c[t]), gamma_t,
                        schedule.effective_noise_std(t), slack=1e-6):
                    result.privacy_exhausted_at = t
                    break
                accountant.charge(float(schedule.c[t]), gamma_t,
                                  schedule.effective_noise_std(t))
            params, metrics = step(params, batch, ctl)

        loss = float(metrics["loss"])
        result.losses.append(loss)
        if "p_hat" in metrics:
            result.p_hats.append(float(metrics["p_hat"]))

        if eval_every and (t + 1) % eval_every == 0:
            ebatch = pipeline.eval_batch(eval_n)
            logits = np.asarray(eval_fn(params, ebatch))
            from repro.data import tasks as T
            acc = T.accuracy(logits, ebatch)
            result.accuracies.append(acc)

        if on_round is not None:
            on_round(t, {"loss": loss, **{k: np.asarray(v)
                                          for k, v in metrics.items()}})

        if checkpointer is not None and (t + 1) % checkpoint_every == 0:
            checkpointer.save(t + 1, params,
                              extra={"accountant": accountant.state_dict(),
                                     "round": t + 1})

    if checkpointer is not None:
        checkpointer.wait()
    result.steps = rounds - start_round
    result.privacy_spent = accountant.spent
    result.wall_time_s = time.time() - t0
    result.params = params  # type: ignore[attr-defined]
    return result
