"""Over-the-air computation channel model (paper Sec. III-B, IV-B).

The physics: K clients transmit simultaneously on one resource block; the
receiver observes the electromagnetic superposition

    y(t) = Σ_k h_k(t) x_k(t) + z(t)                                (Eq. 4)

with x_k = α_k (payload_k + n_k), α_k chosen so h_k α_k = c(t) (phase
pre-compensation + gain alignment — the standard OTA assumption, so the
effective channel is the real positive scalar c). The server recovers the mean
payload by channel inversion p̂ = y / (K c) (Eq. 5).

In the framework this module is the *simulation* of that channel, layered on
top of the only real collective the step performs: a scalar psum over the
client mesh axes. All functions are jit-compatible and operate on a [K]-vector
of per-client payloads (sharded over the client axes on a real mesh).

Fault tolerance: every aggregation takes a survival `mask` — a dropped or
straggling client simply does not superpose its signal, and the server inverts
by the *surviving* count K_t (detected via pilot symbols in a real system).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Channel realization (host side)
# ---------------------------------------------------------------------------

def draw_channels(seed: int, rounds: int, n_clients: int,
                  fading: str = "rayleigh") -> np.ndarray:
    """DEPRECATED shim — kept for one release.

    Block-fading channel magnitudes h_k(t) ∈ [T, K], routed through the
    channel registry (repro.channel): bit-identical to the historical
    inline draw for "rayleigh"/"static" at equal seed. New code should
    build a ChannelModel (`repro.channel.get(name)(...)`) and consume the
    full `realize(...)` ChannelTrace (magnitudes + CSI phases +
    participation), not just magnitudes.
    """
    import warnings

    from repro import channel as ch
    warnings.warn(
        "ota.draw_channels is deprecated; use "
        "repro.channel.get(name)().realize(seed, rounds, n_clients) and "
        "consume the ChannelTrace. The shim routes through the channel "
        "registry and will be removed next release.",
        DeprecationWarning, stacklevel=2)
    if fading not in ("rayleigh", "static"):
        raise ValueError(f"unknown fading model: {fading}")
    return ch.get(fading)().realize(seed, rounds, n_clients).h


# ---------------------------------------------------------------------------
# OTA aggregation (jit-side)
# ---------------------------------------------------------------------------

def superpose(p: jnp.ndarray, c: jnp.ndarray, sigma: jnp.ndarray,
              n0: jnp.ndarray, key: jax.Array,
              mask: Optional[jnp.ndarray] = None,
              g: Optional[jnp.ndarray] = None,
              a: Optional[jnp.ndarray] = None
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """The raw RF observation at the receiver front-end (Eq. 4):

        y = c Σ_k w_k (p_k + n_k) + z

    This is the superposed noisy scalar BEFORE channel inversion — exactly
    what an over-the-air eavesdropper (or the honest-but-curious server)
    sees, and the signal Lemma 1's DP analysis privatizes. The decode path
    (`analog_ota`) and the privacy subsystem's observation capture
    (repro.privacy) both call this function with the same key, so the
    captured observation is bit-identical to the signal the server decoded.

    `g` is the per-client cos θ of residual CSI phase error after
    pre-compensation; `a` is the per-client timing/phase *misalignment*
    attenuation from the desync trace (repro.runtime.desync) — both
    default to None, which traces the historical aligned program.

    Returns (y, k_eff): the observation and the surviving client count.
    """
    k_clients = p.shape[0]
    if mask is None:
        mask = jnp.ones((k_clients,), dtype=p.dtype)
    mask = mask.astype(p.dtype)
    nk_key, z_key = jax.random.split(key)
    n_k = sigma.astype(p.dtype) * jax.random.normal(nk_key, (k_clients,),
                                                    dtype=p.dtype)
    z = jnp.sqrt(n0).astype(p.dtype) * jax.random.normal(z_key, (),
                                                         dtype=p.dtype)
    # superposition: only surviving clients contribute signal AND noise,
    # each rotated to cos θ of its residual pre-compensation error and
    # attenuated by its symbol-timing alignment
    w = mask if g is None else mask * g.astype(p.dtype)
    if a is not None:
        w = w * a.astype(p.dtype)
    y = c * jnp.sum(w * (p + n_k)) + z
    k_eff = jnp.maximum(jnp.sum(mask), 1.0)
    return y, k_eff


def analog_ota(p: jnp.ndarray, c: jnp.ndarray, sigma: jnp.ndarray,
               n0: jnp.ndarray, key: jax.Array,
               mask: Optional[jnp.ndarray] = None,
               g: Optional[jnp.ndarray] = None,
               a: Optional[jnp.ndarray] = None
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Analog pAirZero uplink (Eqs. 8–9) + channel inversion (Eq. 5).

    Args:
      p:     [K] per-client gradient projections (already clipped to γ).
      c:     scalar effective gain c(t) (h_k α_k = c for all k).
      sigma: [K] artificial-noise stds.
      n0:    scalar server noise power N0.
      key:   PRNG key for this round's noise (shared across devices so every
             replica sees the *same* channel draw — replicas stay in sync).
      mask:  [K] 0/1 survival mask (1 = client transmitted this round).
      g:     [K] per-client effective-gain factor cos θ_k from the channel
             trace (residual CSI phase error after pre-compensation). None
             or all-ones is the perfect-CSI h_k α_k = c alignment; the
             all-ones multiply is bitwise neutral, so perfect-CSI runs are
             unchanged by the trace plumbing.
      a:     [K] per-client timing/phase misalignment attenuation from the
             desync trace (None = perfectly synchronized, historical
             program).

    Returns:
      (p_hat, k_eff): the recovered noisy mean and the surviving client count.
    """
    y, k_eff = superpose(p, c, sigma, n0, key, mask, g, a)
    # c == 0 means a SILENT round (the sign-variant schedule zeroes early
    # rounds when Ã^{-t} weighting concentrates the privacy budget late):
    # nobody transmits, the server applies no update.
    safe_c = jnp.where(c > 0, c, 1.0)
    p_hat = jnp.where(c > 0, y / (k_eff * safe_c), 0.0)
    return p_hat, k_eff


def sign_ota(p: jnp.ndarray, c: jnp.ndarray, sigma: jnp.ndarray,
             n0: jnp.ndarray, key: jax.Array,
             mask: Optional[jnp.ndarray] = None,
             g: Optional[jnp.ndarray] = None,
             a: Optional[jnp.ndarray] = None
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sign-pAirZero uplink (Eq. 11): clients transmit sign{p_k} + n_k.

    Majority consensus emerges from the superposition itself; the server
    inverts by (K c) exactly as in the analog case and updates with the
    recovered p̂ (Algorithm 1, line 14). Imperfect CSI weighs each vote by
    cos θ_k — a deeply misaligned client can even flip its ballot.
    """
    return analog_ota(jnp.sign(p), c, sigma, n0, key, mask, g, a)


def perfect_analog(p: jnp.ndarray,
                   mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Noise-free upper-bound baseline (Eq. 38)."""
    if mask is None:
        return jnp.mean(p)
    mask = mask.astype(p.dtype)
    return jnp.sum(mask * p) / jnp.maximum(jnp.sum(mask), 1.0)


def perfect_sign(p: jnp.ndarray,
                 mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Noise-free majority vote (Eq. 39): sign{Σ_k sign{p_k}}."""
    if mask is None:
        mask = jnp.ones_like(p)
    return jnp.sign(jnp.sum(mask.astype(p.dtype) * jnp.sign(p)))


def effective_noise_std(c: jnp.ndarray, sigma: jnp.ndarray,
                        n0: jnp.ndarray) -> jnp.ndarray:
    """m(t) = sqrt(c² Σ_k σ_k² + N0)  (Eq. 12)."""
    return jnp.sqrt(c * c * jnp.sum(sigma * sigma) + n0)


#: fold_in tag deriving per-sub-slot noise keys from the round key
_SUBSLOT_TAG = 0x51B5


def subslot_keys(key: jax.Array, slots: int) -> list:
    """Per-sub-slot noise keys for chunked re-transmission decodes.

    A robust decode (repro.byzantine.defenses) splits one logical round
    into `slots` orthogonal resource blocks — each block is an independent
    channel use, so each gets its own receiver-noise key derived from the
    shared round key (identical across engines and mesh shards, like every
    other draw in the step)."""
    return [jax.random.fold_in(key, _SUBSLOT_TAG + s) for s in range(slots)]


def aggregate(variant: str, scheme: str, p: jnp.ndarray, c: jnp.ndarray,
              sigma: jnp.ndarray, n0: jnp.ndarray, key: jax.Array,
              mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """DEPRECATED string-dispatch shim — kept for one release.

    Routes through the transport registry (repro.core.transport); new code
    should build a Transport and call `transport.aggregate(p, ctl, key)`.
    """
    from repro.core import transport as tp
    tp.deprecated_strings(variant, scheme, "ota.aggregate")
    if variant not in ("analog", "sign"):
        # the historical surface only ever spoke analog/sign; newer
        # mechanisms (digital, ...) need run-config context the string API
        # cannot carry — use the Transport registry directly.
        raise ValueError(f"unknown variant: {variant}")
    if mask is None:
        mask = jnp.ones((p.shape[0],), dtype=p.dtype)
    ctl = {"c": c, "sigma": sigma, "n0": n0, "mask": mask}
    return tp.from_strings(variant, scheme).aggregate(p, ctl, key)
