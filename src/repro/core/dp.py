"""Differential-privacy accountant for pAirZero (paper Sec. IV-C, Lemma 1).

The mechanism: channel noise + artificial noise privatize the *scalar* gradient
projection during OTA transmission. The (ε, δ)-DP condition over T rounds is

    Σ_t ( √2 · c⁽ᵗ⁾ γ⁽ᵗ⁾ / m⁽ᵗ⁾ )²  ≤  R_dp(ε, δ)              (Eq. 16)

with

    R_dp(ε, δ) = ( √(ε + [C⁻¹(1/δ)]²) − C⁻¹(1/δ) )²            (Eq. 17)
    C(x)       = √π · x · e^{x²}

C is strictly increasing on (0, ∞), so C⁻¹ is computed by bisection (in log
space for robustness — C spans many orders of magnitude).

This module is pure numpy/python: the accountant runs on the host inside the
training loop, never inside jit (it controls *transmit scaling*, a host-side
decision, exactly as a real base station would do it).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List

import numpy as np


SQRT_PI = math.sqrt(math.pi)


def c_func(x: float) -> float:
    """C(x) = √π · x · e^{x²}, defined for x ≥ 0."""
    if x < 0:
        raise ValueError("C(x) defined for x >= 0")
    return SQRT_PI * x * math.exp(x * x)


def log_c_func(x: float) -> float:
    """log C(x) — overflow-safe companion of `c_func`."""
    if x <= 0:
        return -math.inf
    return 0.5 * math.log(math.pi) + math.log(x) + x * x


def c_inverse(y: float, tol: float = 1e-14, max_iter: int = 400) -> float:
    """C⁻¹(y) for y > 0 by bisection on log C(x) (monotone increasing)."""
    if y <= 0:
        raise ValueError("C^{-1} defined for y > 0")
    log_y = math.log(y)
    # bracket: C(x) ~ √π x for small x; C(x) ≥ e^{x²} √π x for large x
    lo, hi = 0.0, 1.0
    while log_c_func(hi) < log_y:
        hi *= 2.0
        if hi > 1e8:  # pragma: no cover - unreachable for sane δ
            break
    for _ in range(max_iter):
        mid = 0.5 * (lo + hi)
        if log_c_func(mid) < log_y:
            lo = mid
        else:
            hi = mid
        if hi - lo < tol * max(1.0, hi):
            break
    return 0.5 * (lo + hi)


def r_dp(epsilon: float, delta: float) -> float:
    """Privacy budget radius R_dp(ε, δ) of Eq. (17).

    Larger ε or δ ⇒ larger budget (weaker privacy, more rounds affordable).
    """
    if epsilon <= 0:
        raise ValueError("epsilon must be > 0")
    if not (0 < delta < 1):
        raise ValueError("delta must be in (0, 1)")
    cinv = c_inverse(1.0 / delta)
    return (math.sqrt(epsilon + cinv * cinv) - cinv) ** 2


def epsilon_for_budget(spent: float, delta: float) -> float:
    """Inverse accountant: the analytic ε implied by a spent Eq.-16 sum.

    R_dp(ε, δ) = (√(ε + c²) − c)² with c = C⁻¹(1/δ) inverts in closed form
    to ε = R + 2c√R, so a partially-executed run (spent = Σ_t round costs)
    carries the tight analytic guarantee (ε_spent, δ) with
    ε_spent ≤ the configured ε whenever the accountant admitted the rounds.
    This is the ceiling the empirical audit's ε̂ lower bound is checked
    against (repro.privacy.audit).
    """
    if spent < 0:
        raise ValueError("spent budget must be >= 0")
    if not (0 < delta < 1):
        raise ValueError("delta must be in (0, 1)")
    if spent == 0.0:
        return 0.0
    cinv = c_inverse(1.0 / delta)
    return spent + 2.0 * cinv * math.sqrt(spent)


def round_privacy_cost(c_t: float, gamma_t: float, m_t: float) -> float:
    """Per-round term (√2 c γ / m)² of the accountant sum (Eq. 16).

    `m_t` is the effective-noise std m⁽ᵗ⁾ = √(c² Σσ_k² + N0) of Eq. (12).
    """
    if m_t <= 0:
        raise ValueError("effective noise m must be > 0")
    return 2.0 * (c_t * gamma_t / m_t) ** 2


def cumulative_spend(costs, initial: float = 0.0) -> np.ndarray:
    """[R] ledger value after charging each of `costs` in order.

    The same strictly-sequential float64 left fold `spend`/`spend_batch`
    perform (`np.cumsum` accumulates element by element), seeded with
    `initial` (the ledger before the first of these rounds): entry r is
    bit-identical to `PrivacyAccountant.spent` after charging rounds ≤ r.
    This is the per-round ε ledger `RunResult.privacy_spent_per_round`
    exposes and the audit/MetricsSink consume — one accounting, not three.
    """
    costs = np.asarray(costs, dtype=np.float64)
    if costs.size == 0:
        return np.zeros(0, dtype=np.float64)
    return np.cumsum(np.concatenate(([float(initial)], costs)))[1:]


@dataclass
class PrivacyAccountant:
    """Tracks spent DP budget across rounds; part of the checkpointed state.

    The accountant is *conservative and crash-safe*: budget spent is persisted
    with the model checkpoint so a restart can never double-spend privacy.
    """
    epsilon: float
    delta: float
    spent: float = 0.0
    history: List[float] = field(default_factory=list)

    @property
    def budget(self) -> float:
        return r_dp(self.epsilon, self.delta)

    @property
    def remaining(self) -> float:
        return max(0.0, self.budget - self.spent)

    def spend(self, cost: float) -> float:
        """Charge a precomputed per-round cost (what a Transport reports via
        `round_dp_costs`); returns the cost for chaining."""
        self.spent += cost
        self.history.append(cost)
        return cost

    def spend_batch(self, costs) -> float:
        """Charge a whole chunk of per-round costs in one call.

        The ledger advances by the same float64 left fold the per-round
        `spend` loop performs (`np.cumsum` accumulates strictly
        sequentially), so the final spent value — and therefore any
        downstream budget comparison — is bit-identical to charging round
        by round. Returns the total charged.
        """
        costs = np.asarray(costs, dtype=np.float64)
        if costs.size == 0:
            return 0.0
        before = self.spent
        self.spent = float(np.cumsum(np.concatenate(([before], costs)))[-1])
        self.history.extend(float(c) for c in costs)
        return self.spent - before

    def would_exceed(self, cost: float, slack: float = 1e-9) -> bool:
        return self.spent + cost > self.budget * (1.0 + slack)

    def charge(self, c_t: float, gamma_t: float, m_t: float) -> float:
        """Gaussian-mechanism convenience: charge the Eq.-16 term for one
        round of OTA transmission at gain c, sensitivity gamma, noise m."""
        return self.spend(round_privacy_cost(c_t, gamma_t, m_t))

    def would_violate(self, c_t: float, gamma_t: float, m_t: float,
                      slack: float = 1e-9) -> bool:
        return self.would_exceed(round_privacy_cost(c_t, gamma_t, m_t), slack)

    # -- checkpoint (de)serialization ------------------------------------
    def state_dict(self) -> dict:
        return {"epsilon": self.epsilon, "delta": self.delta,
                "spent": self.spent}

    @classmethod
    def from_state_dict(cls, d: dict) -> "PrivacyAccountant":
        return cls(epsilon=float(d["epsilon"]), delta=float(d["delta"]),
                   spent=float(d["spent"]))
