"""Round executors: per-round dispatch (loop) and scan-over-rounds (scan).

The per-round loop dispatches one jitted step per round from Python: every
round pays a kernel-launch round trip and a blocking metric sync. But a
pAirZero trajectory is a *pure function* of (params, seeds, schedule): the
per-round control — c(t), σ(t), the broadcast seed, the channel-noise key,
the survival mask — is all known the moment the base station solves the
power schedule. So we precompute the whole control trace as stacked device
arrays once per chunk; `ScanExecutor` compiles `lax.scan` over the existing
ZO step (one dispatch per `chunk_rounds` rounds, parameters donated through
the whole chunk, metrics returned stacked) while `LoopExecutor` walks the
same trace one jitted call at a time. Both consume identical inputs, so the
driver in fedsim is engine-agnostic.

The host stays in charge of everything a real server does *between* chunks:
DP accounting (the run's Transport prices each round; the hard privacy stop
truncates the chunk at the first round that would overspend), eval,
checkpointing, and fault-trace generation (the FaultModel RNG is stateful,
so masks are drawn host-side in round order — identical for both engines).

Invariant: for the ZO variants (analog/sign), `engine="scan"` and
`engine="loop"` produce bit-identical loss trajectories at fixed seed
(tests/test_engine.py enforces this). The scan body is the *same* step
function the loop jits; only the dispatch granularity changes. The FO
baseline agrees to fp tolerance only (XLA fuses value_and_grad differently
under scan).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import transport as tp
from repro.core import zo
from repro.core.dp import PrivacyAccountant
from repro.runtime.fault import combined_mask

PyTree = Any


# ---------------------------------------------------------------------------
# Control-trace precomputation (host → device, once per chunk)
# ---------------------------------------------------------------------------

@dataclass
class ControlTrace:
    """Stacked per-round control for rounds [t0, t0+R) plus the host-side
    accounting view of the same schedule slice.

    `ctl` mirrors `pairzero.make_control` exactly, with a leading round axis:
      seed [R] u32, c [R] f32, sigma [R,K] f32, n0 [R] f32, mask [R,K] f32,
      g [R,K] f32 (per-client cos θ CSI factors from the channel trace),
      noise_bits [R,2] u32.
    """
    t0: int
    ctl: Dict[str, jnp.ndarray]
    acct_cost: np.ndarray     # [R] per-round DP cost (Transport.round_dp_costs)
    charged: bool             # whether these rounds cost privacy at all

    def __len__(self) -> int:
        return int(self.ctl["seed"].shape[0])

    def rows(self, n: int) -> Dict[str, jnp.ndarray]:
        """First n rounds of the stacked control block (for a truncated
        chunk after a privacy stop)."""
        if n == len(self):
            return self.ctl
        return {k: v[:n] for k, v in self.ctl.items()}


@jax.jit
def _noise_bits_trace(key_base: jax.Array, ts: jnp.ndarray) -> jnp.ndarray:
    """[R, 2] key_data(fold_in(key_base, t)) for each round t."""
    return jax.vmap(
        lambda t: jax.random.key_data(jax.random.fold_in(key_base, t)))(ts)


def build_trace(schedule, pz, t0: int, t1: int, *,
                transport=None, fault=None, elastic=None,
                channel=None) -> ControlTrace:
    """Precompute the control trace for rounds [t0, t1).

    Mask generation consumes the (stateful) FaultModel RNG in round order, so
    calling build_trace over consecutive chunks replays the identical fault
    trace the per-round loop would draw. DP accounting (per-round cost,
    whether the rounds are charged at all) is delegated to the Transport.

    `channel` is the horizon's realized ChannelTrace (repro.channel); its
    per-round views ride device-resident inside the scanned chunk: cos θ
    CSI factors as ctl["g"], deep-fade participation folded into
    ctl["mask"] alongside the fault/elastic survival masks. None (or a
    perfect-CSI, no-outage trace) reproduces the historical control block
    bit for bit.
    """
    if transport is None:
        transport = tp.resolve(pz)
    k = pz.n_clients
    rounds = int(t1 - t0)
    ts = np.arange(t0, t1, dtype=np.int64)

    # vectorized zo.round_seed: fmix32 is elementwise over the round index
    seeds = zo.round_seed(pz.seed, jnp.asarray(ts, jnp.uint32))

    key_base = jax.random.key(pz.seed ^ 0x5EED)
    noise_bits = _noise_bits_trace(key_base, jnp.asarray(ts, jnp.int32))

    if fault is None and elastic is None:
        masks = np.ones((rounds, k), dtype=np.float32)
    else:
        masks = np.stack([combined_mask(int(t), fault, elastic, n_clients=k)
                          for t in ts])

    if channel is None:
        g = np.ones((rounds, k), dtype=np.float32)
    else:
        g = np.asarray(np.cos(channel.phase[t0:t1]), dtype=np.float32)
        participation = np.asarray(channel.participation[t0:t1], np.float32)
        survival = masks                 # fault/elastic view, pre-outage
        masks = masks * participation
        # outage x faults can zero a whole round even though each mask
        # alone never does; re-admit the strongest FAULT-SURVIVING client
        # that round (combined_mask's never-empty convention, pilot-
        # informed — a crashed client must never be resurrected)
        empty = np.flatnonzero(masks.sum(axis=1) == 0)
        if empty.size:
            h_rows = np.asarray(channel.h[t0:t1])[empty] * survival[empty]
            masks[empty, np.argmax(h_rows, axis=1)] = 1.0

    c_slice = np.asarray(schedule.c[t0:t1], dtype=np.float64)
    sigma_slice = np.asarray(schedule.sigma[t0:t1], dtype=np.float64)
    ctl = {
        "seed": seeds.astype(jnp.uint32),
        "c": jnp.asarray(c_slice, jnp.float32),
        "sigma": jnp.asarray(sigma_slice, jnp.float32),
        "n0": jnp.full((rounds,), schedule.n0, jnp.float32),
        "mask": jnp.asarray(masks, jnp.float32),
        "g": jnp.asarray(g, jnp.float32),
        "noise_bits": noise_bits.astype(jnp.uint32),
    }

    charged = bool(transport.charges_privacy(schedule, pz))
    acct_cost = transport.round_dp_costs(schedule, t0, t1, pz) if charged \
        else np.zeros(rounds)
    return ControlTrace(t0=t0, ctl=ctl, acct_cost=acct_cost, charged=charged)


def affordable_rounds(accountant: PrivacyAccountant, trace: ControlTrace,
                      slack: float = 1e-6) -> int:
    """How many leading rounds of `trace` the DP budget affords.

    Pure lookahead — charges nothing. Uses the same slack as the historical
    per-round `would_violate` guard, so a mid-chunk trip lands on the
    identical round under either engine.
    """
    if not trace.charged:
        return len(trace)
    spent = accountant.spent
    for r in range(len(trace)):
        cost = float(trace.acct_cost[r])
        if spent + cost > accountant.budget * (1.0 + slack):
            return r
        spent += cost
    return len(trace)


def charge_rounds(accountant: PrivacyAccountant, trace: ControlTrace,
                  n: int) -> None:
    """Charge the accountant for the first n rounds of the trace (what the
    loop does before each step, batched between chunks)."""
    if not trace.charged:
        return
    for r in range(n):
        accountant.spend(float(trace.acct_cost[r]))


# ---------------------------------------------------------------------------
# Batch stacking (host → device, one transfer per chunk)
# ---------------------------------------------------------------------------

def stack_batches(pipeline, t0: int, t1: int) -> Dict[str, jnp.ndarray]:
    """Stacked round batches [R, ...] for rounds [t0, t1) (labels dropped,
    exactly as the loop path feeds the step)."""
    per_round = [pipeline.batch(int(t)) for t in range(t0, t1)]
    return {k: jnp.asarray(np.stack([b[k] for b in per_round]))
            for k in per_round[0] if k != "labels"}


# ---------------------------------------------------------------------------
# Executors: per-round dispatch (loop) and chunked lax.scan (scan)
# ---------------------------------------------------------------------------

class LoopExecutor:
    """Per-round dispatch over an already-jitted step — no chunk compile
    cost, and the bit-identity oracle for ScanExecutor.

    Consumes the same (trace rows, stacked batches) interface as the scan
    executor, so the driver in fedsim is engine-agnostic: loop and scan
    differ only in dispatch granularity, never in orchestration.
    """

    def __init__(self, step: Callable):
        self._step = step                   # jitted, carry donated

    def run(self, carry: PyTree, ctl_stack: Dict[str, jnp.ndarray],
            batch_stack: Dict[str, jnp.ndarray]
            ) -> Tuple[PyTree, Dict[str, np.ndarray]]:
        rounds = int(ctl_stack["seed"].shape[0])
        collected: Optional[Dict[str, list]] = None
        for r in range(rounds):
            ctl = {k: v[r] for k, v in ctl_stack.items()}
            batch = {k: v[r] for k, v in batch_stack.items()}
            carry, metrics = self._step(carry, batch, ctl)
            if collected is None:
                collected = {k: [] for k in metrics}
            for k, v in metrics.items():
                collected[k].append(v)
        metrics = {} if collected is None else \
            {k: np.stack([np.asarray(x) for x in v])
             for k, v in collected.items()}
        return carry, metrics


@functools.lru_cache(maxsize=64)
def get_loop_executor(step: Callable) -> "LoopExecutor":
    """Executor cache keyed on the jitted step object (mirrors
    `get_executor`) so identical configs share one executor."""
    return LoopExecutor(step)


class ScanExecutor:
    """Compiles lax.scan over a per-round step; one program per chunk length.

    `step(carry, batch, ctl) -> (carry, metrics)` is the *same* function the
    per-round loop jits (ZO: carry = params; FO: carry = (params, opt_state)
    via an adapter in fedsim). The carry buffer is donated, so parameters
    live on device across the whole chunk — the MeZO in-place chain extended
    over rounds.

    unroll=None (default) fully unrolls each chunk: XLA then compiles the
    round body exactly as it compiles the standalone per-round jit, which is
    what makes engine="scan" *bitwise* identical to engine="loop" (a rolled
    while-loop body fuses with slightly different fp rounding on CPU).
    Compile time grows with chunk length; pass an int (e.g. unroll=1) for an
    O(1)-size rolled program that is numerically equivalent only up to fp
    rounding — the right trade once chunks are long and models are large.
    """

    def __init__(self, step: Callable, unroll: Optional[int] = None):
        @functools.partial(jax.jit, donate_argnums=(0,),
                           static_argnums=(3,))
        def chunk(carry, ctl_stack, batch_stack, _unroll):
            def body(c, xs):
                ctl, batch = xs
                return step(c, batch, ctl)
            return jax.lax.scan(body, carry, (ctl_stack, batch_stack),
                                unroll=_unroll)

        self._chunk = chunk
        self._unroll = unroll

    def run(self, carry: PyTree, ctl_stack: Dict[str, jnp.ndarray],
            batch_stack: Dict[str, jnp.ndarray]
            ) -> Tuple[PyTree, Dict[str, jnp.ndarray]]:
        """Execute one chunk; returns (carry, metrics stacked over rounds)."""
        rounds = int(ctl_stack["seed"].shape[0])
        unroll = rounds if self._unroll is None else min(self._unroll, rounds)
        return self._chunk(carry, ctl_stack, batch_stack, unroll)


@functools.lru_cache(maxsize=64)
def get_executor(step: Callable, unroll: Optional[int] = None
                 ) -> "ScanExecutor":
    """Executor cache keyed on the step function object. Paired with the
    memoized `pairzero.make_zo_step`, identical configs share one compiled
    chunk program across fedsim.run invocations."""
    return ScanExecutor(step, unroll=unroll)


def chunk_boundaries(start: int, stop: int, chunk_rounds: int,
                     align: Tuple[int, ...] = ()) -> list:
    """Split [start, stop) into chunks of ≤ chunk_rounds, additionally
    cutting at every multiple of each period in `align` (eval/checkpoint
    cadences), so host-side side effects fire at exactly the rounds the
    per-round loop fires them."""
    periods = [p for p in align if p and p > 0]
    bounds = []
    t = start
    while t < stop:
        nxt = min(t + max(1, chunk_rounds), stop)
        for p in periods:
            # next multiple of p strictly after t
            m = ((t // p) + 1) * p
            if t < m < nxt:
                nxt = m
        bounds.append((t, nxt))
        t = nxt
    return bounds
