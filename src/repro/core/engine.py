"""Round executors: per-round dispatch (loop) and scan-over-rounds (scan).

The per-round loop dispatches one jitted step per round from Python: every
round pays a kernel-launch round trip and a blocking metric sync. But a
pAirZero trajectory is a *pure function* of (params, seeds, schedule): the
per-round control — c(t), σ(t), the broadcast seed, the channel-noise key,
the survival mask — is all known the moment the base station solves the
power schedule. So we precompute the whole control trace as stacked device
arrays once per chunk; `ScanExecutor` compiles `lax.scan` over the existing
ZO step (one dispatch per `chunk_rounds` rounds, parameters donated through
the whole chunk, metrics returned stacked) while `LoopExecutor` walks the
same trace one jitted call at a time. Both consume identical inputs, so the
driver in fedsim is engine-agnostic.

The host stays in charge of everything a real server does *between* chunks:
DP accounting (the run's Transport prices each round; the hard privacy stop
truncates the chunk at the first round that would overspend), eval,
checkpointing, and fault-trace generation (the FaultModel RNG is stateful,
so masks are drawn host-side in round order — identical for both engines).

Invariant: for the ZO variants (analog/sign), `engine="scan"` and
`engine="loop"` produce bit-identical loss trajectories at fixed seed
(tests/test_engine.py enforces this). The scan body is the *same* step
function the loop jits; only the dispatch granularity changes. The FO
baseline agrees to fp tolerance only (XLA fuses value_and_grad differently
under scan).
"""
from __future__ import annotations

import functools
import time
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import transport as tp
from repro.core import zo
from repro.core.dp import PrivacyAccountant
from repro.obs import retrace
from repro.obs import spans as ob
from repro.runtime.fault import combined_mask

PyTree = Any


# ---------------------------------------------------------------------------
# Control-trace precomputation (host → device, once per chunk)
# ---------------------------------------------------------------------------

@dataclass
class ControlTrace:
    """Stacked per-round control for rounds [t0, t0+R) plus the host-side
    accounting view of the same schedule slice.

    `ctl` mirrors `pairzero.make_control` exactly, with a leading round axis:
      seed [R] u32, c [R] f32, sigma [R,K] f32, n0 [R] f32, mask [R,K] f32,
      g [R,K] f32 (per-client cos θ CSI factors from the channel trace),
      noise_bits [R,2] u32.

    `host_masks` is the host-side numpy view of ctl["mask"] — the driver's
    uplink-bit accounting reads it instead of syncing the device copy back.
    `host_stale` is the matching [R, K] view of ctl["dsync_stale"] when a
    desync model is active (None otherwise) — the ledger's k_sync column
    derives from it the same way.
    """
    t0: int
    ctl: Dict[str, jnp.ndarray]
    acct_cost: np.ndarray     # [R] per-round DP cost (Transport.round_dp_costs)
    charged: bool             # whether these rounds cost privacy at all
    host_masks: Optional[np.ndarray] = None   # [R, K] survival view
    host_stale: Optional[np.ndarray] = None   # [R, K] desync stale view

    def __len__(self) -> int:
        return int(self.ctl["seed"].shape[0])

    def rows(self, n: int) -> Dict[str, jnp.ndarray]:
        """First n rounds of the stacked control block (for a truncated
        chunk after a privacy stop)."""
        if n == len(self):
            return self.ctl
        return {k: v[:n] for k, v in self.ctl.items()}


@jax.jit
def _noise_bits_trace(key_base: jax.Array, ts: jnp.ndarray) -> jnp.ndarray:
    """[R, 2] key_data(fold_in(key_base, t)) for each round t."""
    return jax.vmap(
        lambda t: jax.random.key_data(jax.random.fold_in(key_base, t)))(ts)


def build_trace(schedule, pz, t0: int, t1: int, *,
                transport=None, fault=None, elastic=None,
                channel=None, ctl_sharding=None,
                behavior=None, defense=None,
                desync=None) -> ControlTrace:
    """Precompute the control trace for rounds [t0, t1).

    Mask generation consumes the (stateful) FaultModel RNG in round order, so
    calling build_trace over consecutive chunks replays the identical fault
    trace the per-round loop would draw. DP accounting (per-round cost,
    whether the rounds are charged at all) is delegated to the Transport.

    `channel` is the horizon's realized ChannelTrace (repro.channel); its
    per-round views ride device-resident inside the scanned chunk: cos θ
    CSI factors as ctl["g"], deep-fade participation folded into
    ctl["mask"] alongside the fault/elastic survival masks. None (or a
    perfect-CSI, no-outage trace) reproduces the historical control block
    bit for bit.

    The whole control block is staged host-side and shipped in ONE
    `jax.device_put` of the dict — with `ctl_sharding` (a pytree of
    NamedShardings from `runtime.sharding.control_sharding`) the block
    lands replicated across the client mesh at transfer time.

    `behavior` (repro.byzantine.ClientBehavior) adds its [K] malicious-
    cohort indicator as a per-round ctl["byz"] row — the mask rides the
    same device-resident path as survival/outage, so the attacked step is
    one traced program across engines. `defense` (repro.byzantine.Defense)
    takes over the DP pricing (a transmit clip tightens the Lemma-1
    sensitivity; delegation keeps the accounting Transport-owned). None
    for either reproduces the historical trace bit for bit.

    `desync` (repro.runtime.DesyncModel) adds the synchronization-failure
    rows: the lagged broadcast seed ctl["dsync_seed"] plus the per-client
    ctl["dsync_stale"] / ctl["dsync_a"] / ctl["dsync_frame"] rows. The
    per-round draws are seeded by (desync seed, round), so the trace is
    invariant to chunk boundaries and resume points. None keeps the rows
    absent — the historical block, bit for bit.
    """
    if transport is None:
        transport = tp.resolve(pz)
    k = pz.n_clients
    rounds = int(t1 - t0)
    ts = np.arange(t0, t1, dtype=np.int64)

    # vectorized zo.round_seed: fmix32 is elementwise over the round index
    seeds = zo.round_seed(pz.seed, jnp.asarray(ts, jnp.uint32))

    key_base = jax.random.key(pz.seed ^ 0x5EED)
    noise_bits = _noise_bits_trace(key_base, jnp.asarray(ts, jnp.int32))

    if fault is None and elastic is None:
        masks = np.ones((rounds, k), dtype=np.float32)
    else:
        masks = np.stack([combined_mask(int(t), fault, elastic, n_clients=k)
                          for t in ts])

    if channel is None:
        g = np.ones((rounds, k), dtype=np.float32)
    else:
        g = np.asarray(np.cos(channel.phase[t0:t1]), dtype=np.float32)
        participation = np.asarray(channel.participation[t0:t1], np.float32)
        survival = masks                 # fault/elastic view, pre-outage
        masks = masks * participation
        # outage x faults can zero a whole round even though each mask
        # alone never does; re-admit the strongest FAULT-SURVIVING client
        # that round (combined_mask's never-empty convention, pilot-
        # informed — a crashed client must never be resurrected)
        empty = np.flatnonzero(masks.sum(axis=1) == 0)
        if empty.size:
            h_rows = np.asarray(channel.h[t0:t1])[empty] * survival[empty]
            masks[empty, np.argmax(h_rows, axis=1)] = 1.0

    c_slice = np.asarray(schedule.c[t0:t1], dtype=np.float64)
    sigma_slice = np.asarray(schedule.sigma[t0:t1], dtype=np.float64)
    masks = np.asarray(masks, dtype=np.float32)
    host_ctl = {
        "seed": np.asarray(seeds, dtype=np.uint32),
        "c": np.asarray(c_slice, dtype=np.float32),
        "sigma": np.asarray(sigma_slice, dtype=np.float32),
        "n0": np.full((rounds,), schedule.n0, dtype=np.float32),
        "mask": masks,
        "g": np.asarray(g, dtype=np.float32),
        "noise_bits": np.asarray(noise_bits, dtype=np.uint32),
    }
    if behavior is not None:
        host_ctl["byz"] = np.broadcast_to(
            behavior.client_mask(k)[None, :], (rounds, k)).copy()
    host_stale = None
    if desync is not None:
        from repro.runtime import desync as ds
        dsync_rows, host_stale = ds.control_rows(desync, pz.seed, t0, t1, k)
        host_ctl.update(dsync_rows)
    # one transfer for the whole block (sharded placement, when requested,
    # happens here rather than as a post-hoc reshard)
    ctl = jax.device_put(host_ctl, ctl_sharding)

    if defense is not None:
        charged = bool(defense.charges_privacy(transport, schedule, pz))
        acct_cost = defense.round_dp_costs(transport, schedule, t0, t1, pz) \
            if charged else np.zeros(rounds)
    else:
        charged = bool(transport.charges_privacy(schedule, pz))
        acct_cost = transport.round_dp_costs(schedule, t0, t1, pz) \
            if charged else np.zeros(rounds)
    return ControlTrace(t0=t0, ctl=ctl, acct_cost=acct_cost, charged=charged,
                        host_masks=masks, host_stale=host_stale)


def affordable_rounds(accountant: PrivacyAccountant, trace: ControlTrace,
                      slack: float = 1e-6) -> int:
    """How many leading rounds of `trace` the DP budget affords.

    Pure lookahead — charges nothing. One `np.cumsum` over the cost vector,
    seeded with the current ledger so the accumulation is the same float64
    left fold the historical per-round `would_violate` loop performed
    (cumsum is strictly sequential): a mid-chunk trip lands on the
    bit-identical round under either engine and either implementation
    (tests/test_engine.py pins this against the reference loop).
    """
    if not trace.charged:
        return len(trace)
    costs = np.asarray(trace.acct_cost, dtype=np.float64)
    # cum[r] = ledger after charging rounds < r, starting from `spent`
    cum = np.cumsum(np.concatenate(([accountant.spent], costs)))
    over = np.flatnonzero(cum[1:] > accountant.budget * (1.0 + slack))
    return int(over[0]) if over.size else len(trace)


def charge_rounds(accountant: PrivacyAccountant, trace: ControlTrace,
                  n: int) -> None:
    """Charge the accountant for the first n rounds of the trace (what the
    loop does before each step, batched into one `spend_batch` call with
    the identical sequential-accumulation semantics)."""
    if not trace.charged or n <= 0:
        return
    accountant.spend_batch(np.asarray(trace.acct_cost[:n], dtype=np.float64))


# ---------------------------------------------------------------------------
# Batch staging (host → device, one transfer per chunk)
# ---------------------------------------------------------------------------

class BatchStager:
    """Preallocated, slot-rotated host staging for chunk batches.

    Per (slot, chunk shape) this keeps ONE host buffer per batch key; each
    chunk is stacked *into* the buffer in place and shipped with a single
    `jax.device_put` of the whole dict, carrying the target NamedShardings
    when a mesh is active — sharded placement happens at transfer time, not
    as a post-hoc reshard, and no per-key np.stack→jnp.asarray round trip
    ever materializes a second host copy.

    Slots exist because the prefetch thread prepares chunk i+1 while chunk
    i may still be in flight. NOTE the lifetime contract: on the CPU
    backend `device_put` may zero-copy ALIAS the host buffer, so staged
    arrays are valid only until their slot is rewritten (two `stage` calls
    later) — the driver guarantees safety by kicking chunk i+1's prep only
    after chunk i-1's execution has been synced (ChunkPrefetcher.kick);
    the belt-and-braces `block_until_ready` below additionally covers
    real-transfer backends where readiness lags the `device_put` call.
    """

    def __init__(self, pipeline, sharding_fn: Optional[Callable] = None,
                 slots: int = 2, tracer: ob.Tracer = ob.NULL_TRACER):
        self._pipeline = pipeline
        self._sharding_fn = sharding_fn
        self._slots: List[Dict] = [{"bufs": {}, "inflight": None}
                                   for _ in range(max(1, slots))]
        self._next = 0
        self._tracer = tracer

    def stage(self, t0: int, t1: int) -> Dict[str, jnp.ndarray]:
        """Stacked round batches [R, ...] for rounds [t0, t1), on device
        (labels dropped, exactly as the loop path feeds the step)."""
        with self._tracer.span("batch_stage", t0=t0, t1=t1):
            return self._stage(t0, t1)

    def _stage(self, t0: int, t1: int) -> Dict[str, jnp.ndarray]:
        slot = self._slots[self._next]
        self._next = (self._next + 1) % len(self._slots)
        if slot["inflight"] is not None:
            jax.block_until_ready(slot["inflight"])  # host buffer reusable
            slot["inflight"] = None
        per_round = [self._pipeline.batch(int(t)) for t in range(t0, t1)]
        rounds = len(per_round)
        host: Dict[str, np.ndarray] = {}
        for k, first in per_round[0].items():
            if k == "labels":
                continue
            shape = (rounds,) + np.shape(first)
            buf = slot["bufs"].get(k)
            if buf is None or buf.shape != shape:
                buf = np.empty(shape, dtype=np.asarray(first).dtype)
                slot["bufs"][k] = buf
            for r, b in enumerate(per_round):
                buf[r] = b[k]
            host[k] = buf
        sharding = self._sharding_fn(host) if self._sharding_fn else None
        out = jax.device_put(host, sharding)
        slot["inflight"] = out
        return out


def stack_batches(pipeline, t0: int, t1: int) -> Dict[str, jnp.ndarray]:
    """Stacked round batches [R, ...] for rounds [t0, t1) — one-shot
    convenience over `BatchStager` (no buffer reuse across calls)."""
    return BatchStager(pipeline, slots=1).stage(t0, t1)


# ---------------------------------------------------------------------------
# Chunk prefetch (host-side prep of chunk i+1 overlaps device compute of i)
# ---------------------------------------------------------------------------

class ChunkPrefetcher:
    """One-chunk-ahead host pipeline with an explicit safety handshake.

    `prepare(a, b)` does the host work for chunk [a, b) — control-trace
    build (which consumes the stateful FaultModel RNG, so chunks MUST be
    prepared in round order: one worker, submissions in sequence) plus
    batch staging. The driver calls `kick(i + 1)` only AFTER it has synced
    chunk i-1's metrics: chunk i-1's execution is then provably complete,
    so the stager slot that chunk shares with i+1 can be rewritten — this
    matters because `jax.device_put` may ZERO-COPY alias host buffers on
    the CPU backend, making "transfer complete" no guarantee that the
    execution stopped reading them.

    `get(i)` waits for the kicked prep (or runs it inline when nothing was
    kicked — chunk 0, or `overlap=False`); the wait time accumulates in
    `stall_s`, so the no-overlap control measures the full prep cost and
    the overlapped path only the residual.

    Telemetry: each prep runs inside a `chunk_prep` span (on the worker
    thread when kicked), every kick drops a `prefetch_kick` instant, and
    each `get` records a `prep_stall` span from the SAME perf_counter
    endpoints that feed `stall_s` — span sums equal the scalar exactly.

    Degradation: a kicked prep that died on the worker thread no longer
    aborts the run from `get()` — the failure is logged as a
    `prefetch_degraded` span and the prep is re-run inline ONCE (counted
    in `degraded`); only a second failure propagates. The inline re-run
    is deterministic because chunks are prepared in round order and an
    injected fault (`injector`, site "chunk_prep") fires at prep ENTRY —
    before the stateful FaultModel RNG is consumed.
    """

    def __init__(self, prepare: Callable[[int, int], Any],
                 bounds: Sequence[Tuple[int, int]], overlap: bool = True,
                 tracer: ob.Tracer = ob.NULL_TRACER,
                 injector: Optional[Any] = None):
        self._prepare = prepare
        self._bounds = list(bounds)
        self._overlap = overlap and len(self._bounds) > 0
        self._pool = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="chunk-prefetch") \
            if self._overlap else None
        self._fut: Optional[Future] = None
        self._fut_i = -1
        self._next = 0            # next chunk index the driver may get()
        self.stall_s = 0.0
        self.degraded = 0         # kicked preps recovered inline
        self._tracer = tracer
        self._injector = injector

    def _run_prepare(self, i: int, kicked: bool) -> Any:
        a, b = self._bounds[i]
        with self._tracer.span("chunk_prep", chunk=i, t0=a, t1=b,
                               kicked=kicked):
            if self._injector is not None:
                self._injector.fire("chunk_prep")
            return self._prepare(a, b)

    def kick(self, i: int) -> None:
        """Start chunk i's prep on the worker thread (no-op when overlap
        is off, i is out of range, or i was already kicked/consumed)."""
        if (self._overlap and self._fut is None and i == self._next
                and i < len(self._bounds)):
            self._fut_i = i
            self._tracer.instant("prefetch_kick", chunk=i)
            self._fut = self._pool.submit(self._run_prepare, i, True)

    def get(self, i: int) -> Any:
        """Prepared payload for chunk i (blocks; stall time recorded)."""
        assert i == self._next, "chunks must be consumed in order"
        self._next += 1
        t0 = time.perf_counter()
        if self._fut is not None:
            assert self._fut_i == i
            try:
                out = self._fut.result()
            except Exception as exc:  # noqa: BLE001 - degrade, don't abort
                self.degraded += 1
                with self._tracer.span("prefetch_degraded", chunk=i,
                                       error=type(exc).__name__):
                    out = self._run_prepare(i, False)  # inline re-run, once
            self._fut = None
        else:
            out = self._run_prepare(i, False)
        t1 = time.perf_counter()
        self.stall_s += t1 - t0
        self._tracer.add_span("prep_stall", t0, t1, chunk=i)
        return out

    def close(self) -> None:
        if self._pool is not None:
            if self._fut is not None:              # drain an abandoned prep
                try:
                    self._fut.result()
                except Exception:
                    pass
                self._fut = None
            self._pool.shutdown(wait=True)
            self._pool = None


# ---------------------------------------------------------------------------
# Executors: per-round dispatch (loop) and chunked lax.scan (scan)
# ---------------------------------------------------------------------------

def _specs_sig(*trees) -> tuple:
    """Hashable shape/dtype signature of ShapeDtypeStruct (or array) trees —
    the memoization key for analysis-only AOT compiles."""
    sig = []
    for tree in trees:
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        sig.append((str(treedef),
                    tuple((tuple(x.shape), str(x.dtype)) for x in leaves)))
    return tuple(sig)


class LoopExecutor:
    """Per-round dispatch over an already-jitted step — no chunk compile
    cost, and the bit-identity oracle for ScanExecutor.

    Consumes the same (trace rows, stacked batches) interface as the scan
    executor, so the driver in fedsim is engine-agnostic: loop and scan
    differ only in dispatch granularity, never in orchestration.
    """

    def __init__(self, step: Callable):
        self._step = step                   # jitted, carry donated
        self._aot: Dict[tuple, Any] = {}    # analysis-only compiles, by sig

    def aot_compiled(self, carry_spec: PyTree,
                     ctl_spec: Dict[str, Any],
                     batch_spec: Dict[str, Any]):
        """Compile (never run) the per-round step for these specs.

        Takes the same stacked trees `run()` consumes and slices one round
        off the stacks, so callers (repro.obs.hlo) stay engine-agnostic.
        The lowering re-enters the traced step body, so the retrace
        counters are suspended — introspection is not a driver recompile.
        Memoized per shape signature.
        """
        key = _specs_sig(carry_spec, ctl_spec, batch_spec)
        if key not in self._aot:
            def row(tree):
                return {k: jax.ShapeDtypeStruct(v.shape[1:], v.dtype)
                        for k, v in tree.items()}
            with retrace.suspended():
                lowered = self._step.lower(carry_spec, row(batch_spec),
                                           row(ctl_spec))
            self._aot[key] = lowered.compile()
        return self._aot[key]

    def run(self, carry: PyTree, ctl_stack: Dict[str, jnp.ndarray],
            batch_stack: Dict[str, jnp.ndarray]
            ) -> Tuple[PyTree, Dict[str, jnp.ndarray]]:
        rounds = int(ctl_stack["seed"].shape[0])
        collected: Optional[Dict[str, list]] = None
        for r in range(rounds):
            ctl = {k: v[r] for k, v in ctl_stack.items()}
            batch = {k: v[r] for k, v in batch_stack.items()}
            carry, metrics = self._step(carry, batch, ctl)
            if collected is None:
                collected = {k: [] for k in metrics}
            for k, v in metrics.items():
                collected[k].append(v)    # device arrays — no per-round sync
        # stacked device-side; the driver's flush path converts to host in
        # ONE np.asarray per metric (for the 1-round spans the loop engine
        # runs on, that flush is immediate, so on_round stays live)
        metrics = {} if collected is None else \
            {k: jnp.stack(v) for k, v in collected.items()}
        return carry, metrics


@functools.lru_cache(maxsize=64)
def get_loop_executor(step: Callable) -> "LoopExecutor":
    """Executor cache keyed on the jitted step object (mirrors
    `get_executor`) so identical configs share one executor."""
    retrace.bump(retrace.LOOP_EXEC_BUILD)   # lru MISS: a fresh executor
    return LoopExecutor(step)


class ScanExecutor:
    """Compiles lax.scan over a per-round step; one program per chunk length.

    `step(carry, batch, ctl) -> (carry, metrics)` is the *same* function the
    per-round loop jits (ZO: carry = params; FO: carry = (params, opt_state)
    via an adapter in fedsim). The carry buffer is donated, so parameters
    live on device across the whole chunk — the MeZO in-place chain extended
    over rounds.

    unroll=None (default) fully unrolls each chunk: XLA then compiles the
    round body exactly as it compiles the standalone per-round jit, which is
    what makes engine="scan" *bitwise* identical to engine="loop" (a rolled
    while-loop body fuses with slightly different fp rounding on CPU).
    Compile time grows with chunk length; pass an int (e.g. unroll=1) for an
    O(1)-size rolled program that is numerically equivalent only up to fp
    rounding — the right trade once chunks are long and models are large.
    """

    def __init__(self, step: Callable, unroll: Optional[int] = None):
        @functools.partial(jax.jit, donate_argnums=(0,),
                           static_argnums=(3,))
        def chunk(carry, ctl_stack, batch_stack, _unroll):
            # trace-time side effect only: fires once per XLA compilation
            # of this chunk program, never on cached executions
            retrace.bump(retrace.CHUNK_TRACE)

            def body(c, xs):
                ctl, batch = xs
                return step(c, batch, ctl)
            return jax.lax.scan(body, carry, (ctl_stack, batch_stack),
                                unroll=_unroll)

        self._chunk = chunk
        self._unroll = unroll
        self._aot: Dict[tuple, Any] = {}    # analysis-only compiles, by sig

    def aot_compiled(self, carry_spec: PyTree,
                     ctl_spec: Dict[str, Any],
                     batch_spec: Dict[str, Any]):
        """Compile (never run) the chunk program for these specs — the
        exact program `run()` would dispatch for stacks of this shape,
        including the mesh shardings riding on the specs. Lowering
        re-enters the traced chunk body, so the retrace counters are
        suspended (introspection must not perturb the cold/warm count
        pins). Memoized per shape signature.
        """
        rounds = int(ctl_spec["seed"].shape[0])
        unroll = rounds if self._unroll is None else min(self._unroll, rounds)
        key = _specs_sig(carry_spec, ctl_spec, batch_spec)
        if key not in self._aot:
            with retrace.suspended():
                lowered = self._chunk.lower(carry_spec, ctl_spec,
                                            batch_spec, unroll)
            self._aot[key] = lowered.compile()
        return self._aot[key]

    def run(self, carry: PyTree, ctl_stack: Dict[str, jnp.ndarray],
            batch_stack: Dict[str, jnp.ndarray]
            ) -> Tuple[PyTree, Dict[str, jnp.ndarray]]:
        """Execute one chunk; returns (carry, metrics stacked over rounds)."""
        rounds = int(ctl_stack["seed"].shape[0])
        unroll = rounds if self._unroll is None else min(self._unroll, rounds)
        return self._chunk(carry, ctl_stack, batch_stack, unroll)


@functools.lru_cache(maxsize=64)
def get_executor(step: Callable, unroll: Optional[int] = None
                 ) -> "ScanExecutor":
    """Executor cache keyed on the step function object. Paired with the
    memoized `pairzero.make_zo_step`, identical configs share one compiled
    chunk program across fedsim.run invocations."""
    retrace.bump(retrace.SCAN_EXEC_BUILD)   # lru MISS: a fresh executor
    return ScanExecutor(step, unroll=unroll)


def chunk_boundaries(start: int, stop: int, chunk_rounds: int,
                     align: Tuple[int, ...] = ()) -> list:
    """Split [start, stop) into chunks of ≤ chunk_rounds, additionally
    cutting at every multiple of each period in `align` (eval/checkpoint
    cadences), so host-side side effects fire at exactly the rounds the
    per-round loop fires them."""
    periods = [p for p in align if p and p > 0]
    bounds = []
    t = start
    while t < stop:
        nxt = min(t + max(1, chunk_rounds), stop)
        for p in periods:
            # next multiple of p strictly after t
            m = ((t // p) + 1) * p
            if t < m < nxt:
                nxt = m
        bounds.append((t, nxt))
        t = nxt
    return bounds
