"""Small-scale fading models: Rayleigh, Rician, Static, AR(1)-correlated.

All synthesis is host-side numpy with `np.random.default_rng(seed)` — the
channel is a base-station-side realization, drawn once per horizon, never a
jitted device computation. Draw *order* is part of the contract: Rayleigh
draws the [T, K] real parts then the [T, K] imaginary parts, and every
model below that generalizes Rayleigh reuses that exact order, which is
what makes the special cases (Rician K=0, AR(1) ρ=0) *bitwise* equal to
Rayleigh at the same seed — and the `rayleigh` model itself bitwise equal
to the historical `ota.draw_channels` trace, so PR-1/PR-2 trajectories
reproduce.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

from repro.channel.registry import ChannelModel, register
from repro.channel.trace import ChannelTrace


def _complex_normal_parts(rng: np.random.Generator, rounds: int,
                          n_clients: int) -> tuple:
    """([T,K], [T,K]) re/im parts of CN(0, 1): per-component std 1/√2."""
    re = rng.normal(size=(rounds, n_clients)) / np.sqrt(2.0)
    im = rng.normal(size=(rounds, n_clients)) / np.sqrt(2.0)
    return re, im


def bessel_j0(x: float) -> float:
    """Bessel J₀(x) — Abramowitz & Stegun 9.4.1/9.4.3 rational
    approximations (|err| < 5e-8; scipy is not a declared dependency)."""
    ax = abs(float(x))
    if ax < 3.0:
        t = (ax / 3.0) ** 2
        return (1.0 + t * (-2.2499997 + t * (1.2656208 + t * (-0.3163866
                + t * (0.0444479 + t * (-0.0039444 + t * 0.0002100))))))
    t = 3.0 / ax
    f0 = (0.79788456 + t * (-0.00000077 + t * (-0.00552740
          + t * (-0.00009512 + t * (0.00137237 + t * (-0.00072805
          + t * 0.00014476))))))
    theta0 = (ax - 0.78539816 + t * (-0.04166397 + t * (-0.00003954
              + t * (0.00262573 + t * (-0.00054125 + t * (-0.00029333
              + t * 0.00013558))))))
    return f0 * math.cos(theta0) / math.sqrt(ax)


def jakes_rho(doppler_hz: float, round_duration_s: float) -> float:
    """Jakes'-spectrum lag-1 fading correlation ρ = J₀(2π f_D τ).

    Maps a *physical* mobility scenario (maximum Doppler shift f_D, round
    period τ = T_round) onto the AR(1) model's correlation knob. Past the
    first J₀ zero (2π f_D τ ≈ 2.405) the true autocorrelation oscillates
    negative; the stationary AR(1) surrogate cannot represent that, so the
    mapping clamps to [0, 1): fast-enough mobility degenerates to i.i.d.
    block fading — which is the paper's baseline assumption anyway.
    """
    if doppler_hz < 0.0:
        raise ValueError(f"doppler_hz must be >= 0, got {doppler_hz}")
    if round_duration_s <= 0.0:
        raise ValueError(f"round_duration_s must be > 0, "
                         f"got {round_duration_s}")
    rho = bessel_j0(2.0 * math.pi * doppler_hz * round_duration_s)
    return float(min(max(rho, 0.0), 1.0 - 1e-9))


@register("rayleigh")
@dataclass(frozen=True)
class RayleighFading(ChannelModel):
    """i.i.d. block fading, h ~ CN(0, 1): |h| Rayleigh, E[|h|²] = 1
    (paper Sec. VII-A's simulated channel)."""

    def realize(self, seed: int, rounds: int,
                n_clients: int) -> ChannelTrace:
        rng = np.random.default_rng(seed)
        re, im = _complex_normal_parts(rng, rounds, n_clients)
        return ChannelTrace(h=np.sqrt(re * re + im * im),
                            meta={"model": self.name})


@register("static")
@dataclass(frozen=True)
class StaticChannel(ChannelModel):
    """h ≡ 1: AWGN-only channel (the fading-free ablation)."""

    def realize(self, seed: int, rounds: int,
                n_clients: int) -> ChannelTrace:
        return ChannelTrace(h=np.ones((rounds, n_clients)),
                            meta={"model": self.name})


@register("rician")
@dataclass(frozen=True)
class RicianFading(ChannelModel):
    """Rician block fading: a line-of-sight component of power K/(K+1) plus
    CN(0, 1/(K+1)) scatter, so E[|h|²] = 1 for every K-factor.

    K = 0 degenerates to Rayleigh — bitwise, at equal seed (the scatter
    draw reuses Rayleigh's order and the LOS/scale factors are exactly
    0.0/1.0).
    """
    k_factor: float = 3.0

    @classmethod
    def from_config(cls, cc) -> "RicianFading":
        return cls(k_factor=float(cc.rician_k))

    def realize(self, seed: int, rounds: int,
                n_clients: int) -> ChannelTrace:
        if self.k_factor < 0.0:
            raise ValueError(f"rician K-factor must be >= 0, "
                             f"got {self.k_factor}")
        rng = np.random.default_rng(seed)
        re, im = _complex_normal_parts(rng, rounds, n_clients)
        los = np.sqrt(self.k_factor / (self.k_factor + 1.0))
        scatter = np.sqrt(1.0 / (self.k_factor + 1.0))
        re = los + scatter * re
        im = scatter * im
        return ChannelTrace(h=np.sqrt(re * re + im * im),
                            meta={"model": self.name,
                                  "k_factor": self.k_factor})


@register("ar1")
@dataclass(frozen=True)
class AR1Correlated(ChannelModel):
    """Jakes-like temporally correlated Rayleigh fading.

    The underlying complex Gaussian follows a stationary AR(1) per client:

        x_0 = w_0,   x_t = ρ x_{t-1} + √(1-ρ²) w_t,   w_t ~ CN(0, 1)

    so E[|h|²] = 1 at every lag and corr(x_t, x_{t+1}) = ρ (power
    correlation ρ² — the discrete-time stand-in for Jakes' J₀(2πf_D τ)
    profile). ρ = 0 recovers i.i.d. block fading *bitwise* (the ρ·x term
    is exactly 0 and the √(1-ρ²) scale exactly 1), which is how block-
    fading independence becomes a special case rather than a separate
    code path.
    """
    rho: float = 0.9

    @classmethod
    def from_config(cls, cc) -> "AR1Correlated":
        # mobility specified physically: doppler_hz + round duration map to
        # ρ via Jakes' J₀(2π f_D τ). Unset keeps the raw ar1_rho knob —
        # bitwise-identical traces to the pre-Doppler config surface.
        if getattr(cc, "doppler_hz", None) is not None:
            return cls(rho=jakes_rho(cc.doppler_hz, cc.round_duration_s))
        return cls(rho=float(cc.ar1_rho))

    def realize(self, seed: int, rounds: int,
                n_clients: int) -> ChannelTrace:
        if not 0.0 <= self.rho < 1.0:
            raise ValueError(f"ar1 rho must be in [0, 1), got {self.rho}")
        rng = np.random.default_rng(seed)
        re_w, im_w = _complex_normal_parts(rng, rounds, n_clients)
        rho = self.rho
        innov = np.sqrt(1.0 - rho * rho)
        re = np.empty_like(re_w)
        im = np.empty_like(im_w)
        re[0], im[0] = re_w[0], im_w[0]
        for t in range(1, rounds):
            re[t] = rho * re[t - 1] + innov * re_w[t]
            im[t] = rho * im[t - 1] + innov * im_w[t]
        return ChannelTrace(h=np.sqrt(re * re + im * im),
                            meta={"model": self.name, "rho": rho})
