"""Small-scale fading models: Rayleigh, Rician, Static, AR(1)-correlated.

All synthesis is host-side numpy with `np.random.default_rng(seed)` — the
channel is a base-station-side realization, drawn once per horizon, never a
jitted device computation. Draw *order* is part of the contract: Rayleigh
draws the [T, K] real parts then the [T, K] imaginary parts, and every
model below that generalizes Rayleigh reuses that exact order, which is
what makes the special cases (Rician K=0, AR(1) ρ=0) *bitwise* equal to
Rayleigh at the same seed — and the `rayleigh` model itself bitwise equal
to the historical `ota.draw_channels` trace, so PR-1/PR-2 trajectories
reproduce.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.channel.registry import ChannelModel, register
from repro.channel.trace import ChannelTrace


def _complex_normal_parts(rng: np.random.Generator, rounds: int,
                          n_clients: int) -> tuple:
    """([T,K], [T,K]) re/im parts of CN(0, 1): per-component std 1/√2."""
    re = rng.normal(size=(rounds, n_clients)) / np.sqrt(2.0)
    im = rng.normal(size=(rounds, n_clients)) / np.sqrt(2.0)
    return re, im


@register("rayleigh")
@dataclass(frozen=True)
class RayleighFading(ChannelModel):
    """i.i.d. block fading, h ~ CN(0, 1): |h| Rayleigh, E[|h|²] = 1
    (paper Sec. VII-A's simulated channel)."""

    def realize(self, seed: int, rounds: int,
                n_clients: int) -> ChannelTrace:
        rng = np.random.default_rng(seed)
        re, im = _complex_normal_parts(rng, rounds, n_clients)
        return ChannelTrace(h=np.sqrt(re * re + im * im),
                            meta={"model": self.name})


@register("static")
@dataclass(frozen=True)
class StaticChannel(ChannelModel):
    """h ≡ 1: AWGN-only channel (the fading-free ablation)."""

    def realize(self, seed: int, rounds: int,
                n_clients: int) -> ChannelTrace:
        return ChannelTrace(h=np.ones((rounds, n_clients)),
                            meta={"model": self.name})


@register("rician")
@dataclass(frozen=True)
class RicianFading(ChannelModel):
    """Rician block fading: a line-of-sight component of power K/(K+1) plus
    CN(0, 1/(K+1)) scatter, so E[|h|²] = 1 for every K-factor.

    K = 0 degenerates to Rayleigh — bitwise, at equal seed (the scatter
    draw reuses Rayleigh's order and the LOS/scale factors are exactly
    0.0/1.0).
    """
    k_factor: float = 3.0

    @classmethod
    def from_config(cls, cc) -> "RicianFading":
        return cls(k_factor=float(cc.rician_k))

    def realize(self, seed: int, rounds: int,
                n_clients: int) -> ChannelTrace:
        if self.k_factor < 0.0:
            raise ValueError(f"rician K-factor must be >= 0, "
                             f"got {self.k_factor}")
        rng = np.random.default_rng(seed)
        re, im = _complex_normal_parts(rng, rounds, n_clients)
        los = np.sqrt(self.k_factor / (self.k_factor + 1.0))
        scatter = np.sqrt(1.0 / (self.k_factor + 1.0))
        re = los + scatter * re
        im = scatter * im
        return ChannelTrace(h=np.sqrt(re * re + im * im),
                            meta={"model": self.name,
                                  "k_factor": self.k_factor})


@register("ar1")
@dataclass(frozen=True)
class AR1Correlated(ChannelModel):
    """Jakes-like temporally correlated Rayleigh fading.

    The underlying complex Gaussian follows a stationary AR(1) per client:

        x_0 = w_0,   x_t = ρ x_{t-1} + √(1-ρ²) w_t,   w_t ~ CN(0, 1)

    so E[|h|²] = 1 at every lag and corr(x_t, x_{t+1}) = ρ (power
    correlation ρ² — the discrete-time stand-in for Jakes' J₀(2πf_D τ)
    profile). ρ = 0 recovers i.i.d. block fading *bitwise* (the ρ·x term
    is exactly 0 and the √(1-ρ²) scale exactly 1), which is how block-
    fading independence becomes a special case rather than a separate
    code path.
    """
    rho: float = 0.9

    @classmethod
    def from_config(cls, cc) -> "AR1Correlated":
        return cls(rho=float(cc.ar1_rho))

    def realize(self, seed: int, rounds: int,
                n_clients: int) -> ChannelTrace:
        if not 0.0 <= self.rho < 1.0:
            raise ValueError(f"ar1 rho must be in [0, 1), got {self.rho}")
        rng = np.random.default_rng(seed)
        re_w, im_w = _complex_normal_parts(rng, rounds, n_clients)
        rho = self.rho
        innov = np.sqrt(1.0 - rho * rho)
        re = np.empty_like(re_w)
        im = np.empty_like(im_w)
        re[0], im[0] = re_w[0], im_w[0]
        for t in range(1, rounds):
            re[t] = rho * re[t - 1] + innov * re_w[t]
            im[t] = rho * im[t - 1] + innov * im_w[t]
        return ChannelTrace(h=np.sqrt(re * re + im * im),
                            meta={"model": self.name, "rho": rho})
