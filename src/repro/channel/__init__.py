"""First-class wireless channel subsystem: models, traces, registry.

The paper's system model (Sec. III-B) reduces the physical layer to a
block-fading magnitude h_k(t) entering the superposition y = Σ h_k x_k + z
(Eq. 4) under perfect pre-compensation h_k α_k = c(t) (Eq. 5) — which is
exactly what `ota.draw_channels` hardcoded. This package makes the channel
a pluggable, composable model so Theorem 3's claim (privacy consistent
*regardless of channel conditions*) can actually be stressed:

  model            paper anchor                      what it realizes
  ---------------  --------------------------------  ------------------------
  rayleigh         Sec. VII-A simulation setup       h ~ CN(0,1), E[|h|²]=1,
                                                     i.i.d. block fading (the
                                                     fading entering Eq. 4)
  static           Eq. 38 noise-free ablations       h ≡ 1 (AWGN-only)
  rician           Sec. III-B fading generalization  LOS K/(K+1) + scatter
                                                     CN(0,1/(K+1)); K=0 ≡
                                                     rayleigh bitwise
  ar1              block-fading assumption relaxed   Jakes-like AR(1) complex
                                                     Gaussian; ρ=0 ≡ rayleigh
                                                     bitwise
  geometry         power constraint (C2)/(C4)        log-distance path loss →
                                                     per-client mean powers in
                                                     the power-cap min over k
  imperfect_csi    Eq. 5 pre-compensation residual   h_k α_k = c e^{jθ_k}; the
                                                     receiver superposes cos θ
                                                     weighted payloads (Eq. 4
                                                     no longer inverts exactly)
  outage           survival mask K_t (Sec. III-C)    deep-fade participation
                                                     mask → straggler-aware
                                                     uplink accounting

`ChannelModel.realize(seed, rounds, n_clients)` synthesizes a host-side
`ChannelTrace` (magnitudes, residual phases, participation); fedsim hands
the trace to the Transport's schedule solve and the engine packs its
per-round views (cos θ factors, participation masks) into the device-
resident ControlTrace consumed inside `lax.scan`. See README "Adding a
channel model".
"""
from repro.channel.models import (AR1Correlated, RayleighFading,
                                  RicianFading, StaticChannel, bessel_j0,
                                  jakes_rho)
from repro.channel.registry import (ChannelModel, available, from_config,
                                    get, realize_from_config, register)
from repro.channel.trace import ChannelTrace
from repro.channel.wrappers import ImperfectCSI, OutageModel, PathLossGeometry

__all__ = [
    "AR1Correlated", "ChannelModel", "ChannelTrace", "ImperfectCSI",
    "OutageModel", "PathLossGeometry", "RayleighFading", "RicianFading",
    "StaticChannel", "available", "bessel_j0", "from_config", "get",
    "jakes_rho", "realize_from_config", "register",
]
