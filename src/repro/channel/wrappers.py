"""Wrapper channel models: geometry, imperfect CSI, deep-fade outage.

Each wrapper is itself a registered ChannelModel holding a `base` model; it
realizes the base trace and post-processes exactly one physical aspect:

  PathLossGeometry  scales magnitudes by per-client large-scale gains from
                    a cell placement + log-distance path loss (breaks the
                    unit-mean-power symmetry the power-cap constraint
                    silently assumed),
  ImperfectCSI      adds residual phase error to the pre-compensation (the
                    h_k α_k = c alignment no longer holds exactly),
  OutageModel       thresholds instantaneous channel power into a per-round
                    participation mask (deep-fade stragglers).

Wrapper randomness uses seeds derived from the run seed with fixed XOR
tags, independent of the base draw — wrapping never perturbs the base
fading realization, so `ImperfectCSI(base).h == base.h` bitwise.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.channel.models import RayleighFading
from repro.channel.registry import ChannelModel, register
from repro.channel.trace import ChannelTrace

# seed tags: keep wrapper RNG streams disjoint from the base fading draw
# (which consumes the raw seed) and from each other
_GEOMETRY_TAG = 0x6E0
_CSI_TAG = 0xC51
_SHADOW_TAG = 0x5AD0


class _WrapperFromConfig:
    """Wrappers are registered (introspection, docs, direct construction)
    but are NOT base models: selecting one via ChannelConfig.model would
    silently ignore its config fields and then double-wrap it. Point the
    user at the config fields that compose the wrapper instead."""

    _select_via = "?"

    @classmethod
    def from_config(cls, cc) -> "ChannelModel":
        raise ValueError(
            f"channel model {cls.name!r} is a wrapper, not a base fading "
            f"model: pick a base (e.g. model='rayleigh') and set "
            f"{cls._select_via} to compose it (see "
            "repro.channel.registry.from_config)")


@register("geometry")
@dataclass(frozen=True)
class PathLossGeometry(_WrapperFromConfig, ChannelModel):
    """Cell geometry + 3GPP-style log-distance path loss over a base model.

    Clients are placed uniformly by area in the annulus
    [0.05·cell_radius, cell_radius] around the base station (placement is a
    function of the run seed — one cell layout per run, constant over
    rounds). Path loss follows the log-distance law

        PL_k ∝ pathloss_exp · 10 log10(d_k / d_ref)   [dB]

    and the resulting linear power gains are normalized to mean 1 across
    clients: the *relative* heterogeneity (near clients strong, edge
    clients weak) is what matters to the power-cap min over k in the
    Theorem-3/4 solves, while the absolute link budget stays comparable to
    the unit-power configs every baseline was tuned against.

    `shadow_std_db` > 0 adds correlated log-normal shadowing on top of the
    deterministic path loss: each client's dB loss gains

        X_k = σ_sh (√ρ · X₀ + √(1-ρ) · ξ_k),   X₀, ξ_k ~ N(0, 1)

    where ρ = `shadow_corr` is the inter-client correlation — clients in
    one cell share obstructions (the common component X₀), but each link
    also has its own clutter (ξ_k). σ_sh = 0 skips the draw entirely (a
    SEPARATE tagged RNG stream that is then never consumed), keeping the
    no-shadowing gains bitwise identical to the historical wrapper.
    """
    _select_via = "cell_radius > 0"
    base: ChannelModel = field(default_factory=RayleighFading)
    cell_radius: float = 100.0      # meters
    pathloss_exp: float = 3.76      # 3GPP UMa-style NLOS exponent
    shadow_std_db: float = 0.0      # log-normal shadowing std (dB)
    shadow_corr: float = 0.5        # inter-client shadowing correlation

    def client_gains(self, seed: int, n_clients: int) -> np.ndarray:
        """[K] linear per-client power gains (mean 1 across the cell)."""
        if self.cell_radius <= 0.0:
            raise ValueError(f"cell_radius must be > 0, "
                             f"got {self.cell_radius}")
        rng = np.random.default_rng(seed ^ _GEOMETRY_TAG)
        r_min = 0.05 * self.cell_radius
        # uniform by area on the annulus [r_min, cell_radius]
        u = rng.random(n_clients)
        d = np.sqrt(u * (self.cell_radius ** 2 - r_min ** 2) + r_min ** 2)
        pl_db = 10.0 * self.pathloss_exp * np.log10(d / r_min)
        if self.shadow_std_db > 0.0:
            if not 0.0 <= self.shadow_corr <= 1.0:
                raise ValueError(f"shadow_corr must be in [0, 1], "
                                 f"got {self.shadow_corr}")
            srng = np.random.default_rng(seed ^ _SHADOW_TAG)
            common = srng.normal()
            own = srng.normal(size=n_clients)
            pl_db = pl_db + self.shadow_std_db * (
                np.sqrt(self.shadow_corr) * common
                + np.sqrt(1.0 - self.shadow_corr) * own)
        g = 10.0 ** (-pl_db / 10.0)
        return g / np.mean(g)

    def realize(self, seed: int, rounds: int,
                n_clients: int) -> ChannelTrace:
        base = self.base.realize(seed, rounds, n_clients)
        g = self.client_gains(seed, n_clients)
        return ChannelTrace(h=base.h * np.sqrt(g)[None, :],
                            phase=base.phase,
                            participation=base.participation,
                            meta={**base.meta, "geometry": "pathloss",
                                  "cell_radius": self.cell_radius,
                                  "pathloss_exp": self.pathloss_exp,
                                  "shadow_std_db": self.shadow_std_db,
                                  "shadow_corr": self.shadow_corr,
                                  "client_gains": g})


@register("imperfect_csi")
@dataclass(frozen=True)
class ImperfectCSI(_WrapperFromConfig, ChannelModel):
    """Residual phase error in the OTA pre-compensation.

    Magnitude CSI stays perfect (the power-control solve still sees the
    true |h|), but each client's phase alignment misses by
    θ_k(t) ~ N(0, phase_err_std²) i.i.d. per slot. The coherent receiver's
    real part then superposes cos θ_k-weighted signals instead of perfectly
    aligned ones — an attenuation *and* a client-dependent bias the
    transports must read from the trace rather than recompute from
    magnitudes. phase_err_std = 0 draws θ ≡ 0 exactly, keeping the perfect-
    CSI path bitwise intact.
    """
    _select_via = "phase_err_std > 0"
    base: ChannelModel = field(default_factory=RayleighFading)
    phase_err_std: float = 0.1      # radians

    def realize(self, seed: int, rounds: int,
                n_clients: int) -> ChannelTrace:
        if self.phase_err_std < 0.0:
            raise ValueError(f"phase_err_std must be >= 0, "
                             f"got {self.phase_err_std}")
        base = self.base.realize(seed, rounds, n_clients)
        rng = np.random.default_rng(seed ^ _CSI_TAG)
        theta = self.phase_err_std * rng.normal(size=base.h.shape)
        return ChannelTrace(h=base.h, phase=base.phase + theta,
                            participation=base.participation,
                            meta={**base.meta,
                                  "phase_err_std": self.phase_err_std})


@register("outage")
@dataclass(frozen=True)
class OutageModel(_WrapperFromConfig, ChannelModel):
    """Deep-fade outage: clients whose instantaneous channel power drops
    below the threshold miss the round (straggle) instead of transmitting.

    participation_k(t) = 1{ |h_k(t)|² ≥ 10^(threshold_db/10) }.

    The threshold is absolute, in dB relative to unit mean power — for the
    unit-power Rayleigh base the per-slot outage probability is the
    analytic CDF 1 - exp(-10^(threshold_db/10)), and under a geometry
    wrapper the weak cell-edge clients straggle more often, exactly the
    heterogeneity a straggler-aware schedule has to survive. If every
    client of a round fades out, the strongest one is re-admitted (the
    server falls back to the best pilot — mirrors FaultModel's never-empty
    convention, and keeps OTA inversion by K_eff ≥ 1 meaningful).
    """
    _select_via = "outage_db"
    base: ChannelModel = field(default_factory=RayleighFading)
    threshold_db: float = -10.0

    def realize(self, seed: int, rounds: int,
                n_clients: int) -> ChannelTrace:
        base = self.base.realize(seed, rounds, n_clients)
        tau = 10.0 ** (self.threshold_db / 10.0)
        up = (base.h ** 2 >= tau).astype(np.float32)
        participation = base.participation * up
        empty = participation.sum(axis=1) == 0
        if np.any(empty):
            rows = np.flatnonzero(empty)
            participation[rows, np.argmax(base.h[rows], axis=1)] = 1.0
        return ChannelTrace(h=base.h, phase=base.phase,
                            participation=participation,
                            meta={**base.meta,
                                  "outage_db": self.threshold_db})
