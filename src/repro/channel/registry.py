"""ChannelModel protocol + registry (mirrors repro.core.transport).

A ChannelModel owns host-side trace synthesis: `realize(seed, rounds,
n_clients) -> ChannelTrace`. Models are frozen dataclasses — hashable, so
run configs that embed them stay hashable and memoized factories key on
them — registered by name:

  @register("rician")
  @dataclass(frozen=True)
  class RicianFading(ChannelModel):
      k_factor: float = 3.0
      ...

Composition is explicit: wrapper models (PathLossGeometry, ImperfectCSI,
OutageModel) hold a `base` ChannelModel field and post-process its trace.
`from_config(ChannelConfig)` builds the composed stack a run config asks
for; `realize_from_config` is the one-call convenience fedsim uses.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Type

from repro.channel.trace import ChannelTrace


@dataclass(frozen=True)
class ChannelModel:
    """One wireless channel model. Subclass + `@register(name)` to add one.

    Subclasses are frozen dataclasses: every parameter that changes the
    realized trace (K-factor, correlation, thresholds) is part of equality
    and hash.
    """

    #: registry name (set by @register)
    name = "?"

    @classmethod
    def from_config(cls, cc) -> "ChannelModel":
        """Build an instance from a ChannelConfig. The default suits
        parameter-free models; override to consume config fields."""
        return cls()

    def realize(self, seed: int, rounds: int,
                n_clients: int) -> ChannelTrace:
        """Synthesize the [T, K] channel trace for this seed/horizon."""
        raise NotImplementedError


_REGISTRY: Dict[str, Type[ChannelModel]] = {}


def register(name: str):
    """Class decorator: `@register("rayleigh")` adds a ChannelModel to the
    registry under `name` (and sets `cls.name`)."""
    def deco(cls: Type[ChannelModel]) -> Type[ChannelModel]:
        cls.name = name
        _REGISTRY[name] = cls
        return cls
    return deco


def available() -> tuple:
    return tuple(sorted(_REGISTRY))


def get(name: str) -> Type[ChannelModel]:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown channel model {name!r} "
                         f"(registered: {available()})") from None


def from_config(cc) -> ChannelModel:
    """Build the (possibly wrapped) ChannelModel a ChannelConfig asks for.

    `cc.model` names the small-scale fading base (falling back to the
    legacy `cc.fading` string); geometry / imperfect-CSI / outage wrappers
    are stacked on top when their config fields are set. Wrapper order is
    fixed — geometry scales magnitudes, CSI error rotates phases, outage
    thresholds the result — so equal configs compose identical stacks.
    """
    from repro.channel import wrappers as wr
    base_name = cc.model or cc.fading
    if getattr(cc, "doppler_hz", None) is not None and base_name != "ar1":
        # same convention as the wrapper guard below: a config field that
        # would be silently dropped is rejected, not ignored — Doppler
        # mobility only parameterizes the temporally-correlated model
        raise ValueError(
            f"doppler_hz is set but channel model is {base_name!r}: the "
            "Jakes mapping parameterizes the AR(1) correlation — select "
            "model='ar1' (or unset doppler_hz)")
    model = get(base_name).from_config(cc)
    if cc.cell_radius > 0.0:
        model = wr.PathLossGeometry(
            base=model, cell_radius=cc.cell_radius,
            pathloss_exp=cc.pathloss_exp,
            shadow_std_db=getattr(cc, "shadow_std_db", 0.0),
            shadow_corr=getattr(cc, "shadow_corr", 0.5))
    elif getattr(cc, "shadow_std_db", 0.0) > 0.0:
        # shadowing rides the geometry wrapper's large-scale gains: without
        # a cell layout there is no path loss to shadow — reject rather
        # than silently drop the field (same guard style as doppler_hz)
        raise ValueError(
            "shadow_std_db is set but cell_radius == 0: log-normal "
            "shadowing perturbs the PathLossGeometry gains — set "
            "cell_radius > 0 to enable the geometry wrapper")
    if cc.phase_err_std > 0.0:
        model = wr.ImperfectCSI(base=model, phase_err_std=cc.phase_err_std)
    if cc.outage_db is not None:
        model = wr.OutageModel(base=model, threshold_db=cc.outage_db)
    return model


def realize_from_config(cc, seed: int, rounds: int,
                        n_clients: int) -> ChannelTrace:
    """One-call convenience: config -> composed model -> realized trace."""
    return from_config(cc).realize(seed, rounds, n_clients)
