"""ChannelTrace: one realized wireless channel for a training horizon.

A trace is the *output* of a ChannelModel's host-side synthesis — everything
the base station learns (or mis-learns) about the physical layer before a
round executes:

  h             [T, K] true channel magnitudes |h_k(t)| — what the Theorem-3/4
                power-control solves consume (magnitude CSI is assumed known;
                the modeled imperfection is residual *phase* error).
  phase         [T, K] residual phase error θ_k(t) (radians) left over after
                pre-compensation. Perfect CSI ⇒ θ ≡ 0, and the standard OTA
                assumption h_k α_k = c(t) holds exactly. Imperfect CSI rotates
                each client's aligned signal by e^{jθ}; the coherent receiver
                keeps the real part, so the per-client effective-gain factor
                entering the superposition is cos θ (the `csi` view below).
  participation [T, K] 0/1 outage mask — 1 means client k's SNR clears the
                deep-fade threshold and it transmits in round t. Feeds the
                survival-mask plumbing (ota superposition, K_eff inversion,
                mask-aware uplink-bit accounting, straggler-aware TDMA).

The trace is host-side numpy (float64, like the power-control solves); the
engine packs the per-round slices it needs (csi factors, participation) into
the device-resident ControlTrace consumed inside `lax.scan`.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass(frozen=True)
class ChannelTrace:
    """Realized channel for T rounds and K clients (see module docstring)."""
    h: np.ndarray                    # [T, K] float64 magnitudes
    phase: np.ndarray = None         # [T, K] float64 residual phase error
    participation: np.ndarray = None  # [T, K] float32 0/1 outage mask
    meta: dict = field(default_factory=dict)   # model provenance (name, params)

    def __post_init__(self):
        h = np.asarray(self.h, dtype=np.float64)
        object.__setattr__(self, "h", h)
        if self.phase is None:
            object.__setattr__(self, "phase", np.zeros_like(h))
        if self.participation is None:
            object.__setattr__(
                self, "participation", np.ones(h.shape, dtype=np.float32))
        if self.phase.shape != h.shape or self.participation.shape != h.shape:
            raise ValueError(
                f"trace field shapes disagree: h{h.shape} "
                f"phase{self.phase.shape} "
                f"participation{self.participation.shape}")

    # -- shape ------------------------------------------------------------
    @property
    def rounds(self) -> int:
        return int(self.h.shape[0])

    @property
    def n_clients(self) -> int:
        return int(self.h.shape[1])

    # -- derived views ----------------------------------------------------
    @property
    def gain(self) -> np.ndarray:
        """[T, K] complex effective gains h·e^{jθ} after pre-compensation."""
        return self.h * np.exp(1j * self.phase)

    @property
    def csi(self) -> np.ndarray:
        """[T, K] per-client effective-gain factor cos θ ∈ [-1, 1].

        This is what the coherent OTA receiver actually sees per client:
        perfect CSI ⇒ exactly 1.0 (so multiplying by it is a bitwise no-op
        in the jitted step)."""
        return np.cos(self.phase)

    def mean_power(self) -> np.ndarray:
        """[K] per-client mean channel power E_t[|h_k|²] — the quantity a
        PathLossGeometry wrapper skews away from the unit-power symmetry."""
        return np.mean(self.h ** 2, axis=0)

    def outage_rate(self) -> float:
        """Fraction of (t, k) slots lost to deep fade."""
        return float(1.0 - np.mean(self.participation))
