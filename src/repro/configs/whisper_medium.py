"""whisper-medium [audio] — encoder–decoder with conv frontend STUB.

Assignment: 24L d_model=1024 16H (GQA kv=16) d_ff=4096 vocab=51865 —
enc-dec, conv frontend (stub).  [arXiv:2212.04356; unverified]

24 encoder + 24 decoder layers (the canonical medium layout). The conv
frontend is a stub: input_specs() supplies 1500 precomputed frame embeddings
(30 s of audio) at d_model. Decode shapes exercise the decoder (self cache +
fixed cross cache).
"""
from repro.configs.base import FrontendConfig, ModelConfig
from repro.models.arch_registry import register_arch


def build() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium",
        family="audio",
        n_layers=24,
        n_encoder_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_ff=4096,
        vocab_size=51865,
        head_dim=64,
        frontend=FrontendConfig(kind="audio", n_frontend_tokens=1500,
                                d_frontend=1024),
        tie_embeddings=True,
    )


register_arch("whisper-medium", build)
