"""Config system: typed dataclasses for models, shapes, meshes, and pAirZero.

Everything in the framework is driven from these configs; architecture files in
this package instantiate `ModelConfig` exactly per the assignment table and the
paper's own OPT-125M. Configs are plain frozen dataclasses (no dependencies) so
they can be hashed, diffed, and serialized into checkpoints/manifests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Model configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0              # routed experts
    n_experts_per_tok: int = 0      # top-k
    n_shared_experts: int = 0       # always-on experts (deepseek-style)
    d_expert: int = 0               # per-expert FFN hidden dim
    capacity_factor: float = 1.25
    chunk: int = 256                # dispatch-group length (bounds transients)

    @property
    def enabled(self) -> bool:
        return self.n_experts > 0


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3)."""
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    @property
    def enabled(self) -> bool:
        return self.kv_lora_rank > 0


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD block config."""
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64              # SSD head dim (nheads = d_inner // head_dim)
    chunk: int = 256                # SSD chunk length

    @property
    def enabled(self) -> bool:
        return self.d_state > 0


@dataclass(frozen=True)
class HybridConfig:
    """RecurrentGemma-style temporal-mixing pattern."""
    # block pattern, repeated/cycled over layers: 'r' = RG-LRU, 'a' = local attn
    pattern: str = ""
    lru_width: int = 0
    local_window: int = 2048
    conv1d_width: int = 4

    @property
    def enabled(self) -> bool:
        return bool(self.pattern)


@dataclass(frozen=True)
class FrontendConfig:
    """Modality frontend STUB: input_specs() supplies precomputed embeddings.

    kind='vision': `n_frontend_tokens` patch embeddings per sample prepended.
    kind='audio' : encoder consumes `n_frontend_tokens` frame embeddings.
    """
    kind: str = "none"              # none | vision | audio
    n_frontend_tokens: int = 0
    d_frontend: int = 0             # embedding dim delivered by the stub


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 → d_model // n_heads
    n_encoder_layers: int = 0       # enc-dec only
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    moe: MoEConfig = field(default_factory=MoEConfig)
    mla: MLAConfig = field(default_factory=MLAConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    hybrid: HybridConfig = field(default_factory=HybridConfig)
    frontend: FrontendConfig = field(default_factory=FrontendConfig)
    # sub-quadratic decode state ⇒ eligible for long_500k
    subquadratic: bool = False

    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    # ---- parameter counting (used by Table II + roofline MODEL_FLOPS) ----
    def param_count(self) -> int:
        from repro.models.registry import count_params
        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.registry import count_params
        return count_params(self, active_only=True)

    def reduced(self, **overrides) -> "ModelConfig":
        """A tiny same-family variant for CPU smoke tests."""
        kw: dict = dict(
            n_layers=min(self.n_layers, 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads else 0,
            d_ff=128,
            vocab_size=512,
            head_dim=16,
            n_encoder_layers=min(self.n_encoder_layers, 2),
        )
        if self.moe.enabled:
            kw["moe"] = MoEConfig(
                n_experts=4, n_experts_per_tok=2,
                n_shared_experts=min(self.moe.n_shared_experts, 1),
                d_expert=64)
        if self.mla.enabled:
            kw["mla"] = MLAConfig(kv_lora_rank=32, q_lora_rank=48,
                                  qk_nope_head_dim=16, qk_rope_head_dim=8,
                                  v_head_dim=16)
        if self.ssm.enabled:
            kw["ssm"] = SSMConfig(d_state=16, d_conv=4, expand=2,
                                  head_dim=16, chunk=32)
        if self.hybrid.enabled:
            kw["hybrid"] = HybridConfig(pattern=self.hybrid.pattern,
                                        lru_width=64, local_window=32,
                                        conv1d_width=4)
        if self.frontend.kind != "none":
            kw["frontend"] = FrontendConfig(kind=self.frontend.kind,
                                            n_frontend_tokens=8,
                                            d_frontend=64)
        kw.update(overrides)
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input-shape cells (assignment: 4 shapes per arch)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


# ---------------------------------------------------------------------------
# pAirZero configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ZOConfig:
    mu: float = 1e-3                # perturbation scale (paper Sec. VII-A)
    lr: float = 5e-7                # selected analog lr (Table I)
    clip_gamma: float = 100.0       # projection clip γ (paper Sec. VII-D3)
    n_perturb: int = 1              # perturbation directions per round
    dual_mode: str = "sequential"   # sequential | stacked (beyond-paper opt)


@dataclass(frozen=True)
class ChannelConfig:
    """Wireless channel (paper Sec. III-B), realized by repro.channel.

    `model` names a registered ChannelModel (rayleigh | rician | static |
    ar1 | anything user-registered); the geometry / imperfect-CSI / outage
    wrappers stack on top when their fields are set (see
    repro.channel.registry.from_config). `fading` is the DEPRECATED
    pre-registry spelling, kept one release as the fallback when `model`
    is None — the default config (rayleigh, perfect CSI, no outage)
    realizes the bit-identical trace the historical `ota.draw_channels`
    produced.
    """
    n0: float = 1.0                 # server noise power N0
    power: float = 100.0            # per-client power budget P
    fading: str = "rayleigh"        # DEPRECATED alias for `model`
    d: int = 1                      # model dimension (enters (C2) + SNR_max)
    model: Optional[str] = None     # channel-registry name; None → `fading`
    rician_k: float = 3.0           # K-factor for model="rician"
    ar1_rho: float = 0.9            # lag-1 correlation for model="ar1"
    # physical mobility spec for model="ar1": set doppler_hz to derive
    # ρ = J₀(2π f_D τ) (Jakes) from the Doppler shift and the round period
    # τ = round_duration_s; None keeps the raw ar1_rho path bitwise intact
    doppler_hz: Optional[float] = None
    round_duration_s: float = 1e-3  # τ: one communication round (seconds)
    phase_err_std: float = 0.0      # >0 → ImperfectCSI wrapper (radians)
    outage_db: Optional[float] = None   # set → OutageModel threshold (dB)
    cell_radius: float = 0.0        # >0 → PathLossGeometry wrapper (meters)
    pathloss_exp: float = 3.76      # log-distance path-loss exponent
    shadow_std_db: float = 0.0      # >0 → correlated log-normal shadowing
    shadow_corr: float = 0.5        # inter-client shadowing correlation ρ

    @property
    def snr_max(self) -> float:     # Eq. (37)
        return self.power / (self.d * self.n0)


@dataclass(frozen=True)
class DPConfig:
    epsilon: float = 5.0
    delta: float = 0.01
    enabled: bool = True


@dataclass(frozen=True)
class PowerControlConfig:
    scheme: str = "solution"        # solution | static | reversed | perfect
    contraction_a: float = 0.998    # A (analog) — paper Sec. VII-D2
    contraction_a_tilde: float = 0.998  # Ã (sign)
    e0: float = 0.4960              # sign-reversing probability bound
    bisect_tol: float = 1e-10
    bisect_iters: int = 200


@dataclass(frozen=True)
class TransportConfig:
    """Which uplink mechanism carries the round (repro.core.transport).

    `mechanism` names a registered Transport: analog | sign | perfect |
    digital | fo (plus anything user-registered). `scheme` selects the
    power-control schedule for the OTA mechanisms; `quant_bits` sizes the
    digital baseline's stochastic quantizer.
    """
    mechanism: str = "analog"
    scheme: str = "solution"        # solution | static | reversed | perfect
    quant_bits: int = 8             # digital: bits per uploaded coordinate


@dataclass(frozen=True)
class ByzantineConfig:
    """Active-adversary scenario (repro.byzantine): who attacks, how many,
    and what the server defends with.

    `behavior` names a registered ClientBehavior (sign_flip | scaled_poison
    | gaussian_noise | colluding_cohort | "none"); `fraction` is the share
    of clients running it (0.0 disables the attack entirely — the traced
    program is bit-identical to a config without a ByzantineConfig).
    `defense` names a registered Defense (clip | robust_decode | reweight |
    "none"). `scale` parameterizes the behavior (λ for scaled_poison, the
    noise std for gaussian_noise); `groups` is the number of orthogonal
    decode sub-slots for the robust defenses; `clip_factor` sets the
    transmit-clip defense bound γ_d = clip_factor·γ. `seed` salts the
    cohort selection (which clients are malicious) and the colluders'
    shared randomness.
    """
    behavior: str = "none"
    fraction: float = 0.0
    scale: float = 3.0
    defense: str = "none"
    groups: int = 4
    clip_factor: float = 0.5
    seed: int = 0


@dataclass(frozen=True)
class DesyncConfig:
    """Client synchronization-failure scenario (repro.runtime.desync).

    `fraction` is the per-round probability a client is *stale*: it
    missed the round-t seed broadcast and its scalar rides z_{t−d} in
    the superposition (the shared per-round lag d is drawn uniform in
    [1, `max_lag`]). `phase_std` is the std (radians) of each client's
    per-symbol timing/phase error: pAirZero's scalar payload is
    attenuated by cos θ, while the conventional d-symbol baseline's
    coherent gain collapses along the Dirichlet kernel with
    `frame_symbols` symbols per frame. `seed` salts the per-round
    draws. fraction 0 with phase_std 0 (or no DesyncConfig at all)
    reproduces the perfectly-synchronized program bit for bit.
    """
    fraction: float = 0.0
    max_lag: int = 4
    phase_std: float = 0.0
    frame_symbols: int = 1
    seed: int = 0


@dataclass(frozen=True)
class PairZeroConfig:
    """Run config. New code selects the uplink via `transport`; the legacy
    `variant` + `power.scheme` strings remain as a one-release deprecation
    shim (resolved through the same transport registry when `transport` is
    None)."""
    variant: str = "analog"         # DEPRECATED: analog | sign | fo
    n_clients: int = 5
    rounds: int = 8000
    zo: ZOConfig = field(default_factory=ZOConfig)
    channel: ChannelConfig = field(default_factory=ChannelConfig)
    dp: DPConfig = field(default_factory=DPConfig)
    power: PowerControlConfig = field(default_factory=PowerControlConfig)
    transport: Optional[TransportConfig] = None
    # active-adversary scenario (repro.byzantine); None (or fraction 0 with
    # defense "none") reproduces the honest-cohort program bit for bit
    byzantine: Optional[ByzantineConfig] = None
    # synchronization-failure scenario (repro.runtime.desync); None (or an
    # all-zero config) reproduces the synchronized program bit for bit
    desync: Optional[DesyncConfig] = None
    seed: int = 0
    # Pallas-fused dual forward: regenerate z inside the matmul/gather
    # consumers (kernels/perturbed_matmul.py) instead of materializing
    # θ±μz. Default off — the unfused trajectory is bitwise unchanged.
    # Supported for the dense/moe families; see docs/kernels.md.
    fused_perturbation: bool = False


# ---------------------------------------------------------------------------
# Mesh / runtime configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MeshConfig:
    multi_pod: bool = False
    # single pod: (data=16, model=16); multi-pod: (pod=2, data=16, model=16)

    @property
    def shape(self) -> Tuple[int, ...]:
        return (2, 16, 16) if self.multi_pod else (16, 16)

    @property
    def axes(self) -> Tuple[str, ...]:
        return ("pod", "data", "model") if self.multi_pod else ("data", "model")

    @property
    def n_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


@dataclass(frozen=True)
class HardwareSpec:
    """TPU v5e roofline constants (per assignment)."""
    peak_flops: float = 197e12      # bf16 FLOP/s per chip
    hbm_bw: float = 819e9           # bytes/s per chip
    ici_bw: float = 50e9            # bytes/s per link


TPU_V5E = HardwareSpec()
