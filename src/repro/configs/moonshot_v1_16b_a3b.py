"""moonshot-v1-16b-a3b [moe] — kimi/moonlight family, 64 experts top-6.

Assignment: 48L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=163840,
MoE 64e top-6.  [hf:moonshotai/Moonlight-16B-A3B; hf]
(d_ff is the per-expert FFN width; all layers are routed-MoE, no shared
experts — exactly as the assignment row specifies.)
"""
from repro.configs.base import MoEConfig, ModelConfig
from repro.models.arch_registry import register_arch


def build() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b",
        family="moe",
        n_layers=48,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1408,
        vocab_size=163840,
        head_dim=128,
        moe=MoEConfig(n_experts=64, n_experts_per_tok=6,
                      n_shared_experts=0, d_expert=1408),
    )


register_arch("moonshot-v1-16b-a3b", build)
