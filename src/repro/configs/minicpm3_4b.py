"""minicpm3-4b [dense] — MLA attention.

Assignment: 62L d_model=2560 40H (GQA kv=40) d_ff=6400 vocab=73448 — MLA.
[hf:openbmb/MiniCPM3-4B; hf]

MLA dims from the HF config: kv_lora_rank=256, q_lora_rank=768,
qk_nope=64, qk_rope=32, v_head=64.
"""
from repro.configs.base import MLAConfig, ModelConfig
from repro.models.arch_registry import register_arch


def build() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b",
        family="dense",
        n_layers=62,
        d_model=2560,
        n_heads=40,
        n_kv_heads=40,
        d_ff=6400,
        vocab_size=73448,
        head_dim=64,
        mla=MLAConfig(kv_lora_rank=256, q_lora_rank=768,
                      qk_nope_head_dim=64, qk_rope_head_dim=32,
                      v_head_dim=64),
    )


register_arch("minicpm3-4b", build)
