"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 1:2 pattern.

Assignment: 26L d_model=2560 10H (GQA kv=1) d_ff=7680 vocab=256000 —
RG-LRU + local attn, 1:2.  [arXiv:2402.19427; hf]

Pattern (r, r, a) cycled: 26 = 8×(r,r,a) + (r,r). lru_width=2560,
local window=2048, head_dim=256 (10 heads × 256 = 2560). Sub-quadratic
decode state ⇒ runs the long_500k cell.
"""
from repro.configs.base import HybridConfig, ModelConfig
from repro.models.arch_registry import register_arch


def build() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b",
        family="hybrid",
        n_layers=26,
        d_model=2560,
        n_heads=10,
        n_kv_heads=1,
        d_ff=7680,
        vocab_size=256000,
        head_dim=256,
        hybrid=HybridConfig(pattern="rra", lru_width=2560,
                            local_window=2048, conv1d_width=4),
        subquadratic=True,
        tie_embeddings=True,
    )


register_arch("recurrentgemma-2b", build)
