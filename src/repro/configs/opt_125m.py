"""OPT-125M — the paper's own experimental model (arXiv:2205.01068).

Used by the Fig. 2/3/7 and Table II reproductions. (Deviation: rotary
positions instead of OPT's learned absolute embeddings — positionality is
orthogonal to the ZO/OTA mechanics under study.)
"""
from repro.configs.base import ModelConfig
from repro.models.arch_registry import register_arch


def build() -> ModelConfig:
    return ModelConfig(
        name="opt-125m",
        family="dense",
        n_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=12,
        d_ff=3072,
        vocab_size=50272,
        head_dim=64,
    )


register_arch("opt-125m", build)
