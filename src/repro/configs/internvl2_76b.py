"""internvl2-76b [vlm] — InternViT frontend (STUB) + llama-3-70B-class LM.

Assignment: 80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256 —
InternViT + InternLM2.  [arXiv:2404.16821; unverified]

Per the assignment the modality frontend is a stub: input_specs() supplies
256 precomputed patch embeddings per sample at d_model, prepended to the
text sequence.
"""
from repro.configs.base import FrontendConfig, ModelConfig
from repro.models.arch_registry import register_arch


def build() -> ModelConfig:
    return ModelConfig(
        name="internvl2-76b",
        family="vlm",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=28672,
        vocab_size=128256,
        head_dim=128,
        frontend=FrontendConfig(kind="vision", n_frontend_tokens=256,
                                d_frontend=8192),
    )


register_arch("internvl2-76b", build)
