"""deepseek-v2-236b [moe] — MLA + 2 shared + 160 routed top-6.

Assignment: 60L d_model=5120 128H (GQA kv=128) d_ff=1536 vocab=102400,
MoE 160e top-6 — MLA kv_lora=512, 2 shared+160 routed top-6.
[arXiv:2405.04434; hf]

MLA dims from the paper: q_lora_rank=1536, qk_nope=128, qk_rope=64,
v_head=128. All 60 layers uniform MoE (the HF checkpoint makes layer 0
dense; kept homogeneous for scan-over-layers — <0.05% param delta, noted in
DESIGN.md). Total parameter check: 160·3·5120·1536·60 ≈ 226B routed
+ shared/attn/embed ≈ 236B ✓.
"""
from repro.configs.base import MLAConfig, MoEConfig, ModelConfig
from repro.models.arch_registry import register_arch


def build() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b",
        family="moe",
        n_layers=60,
        d_model=5120,
        n_heads=128,
        n_kv_heads=128,
        d_ff=1536,
        vocab_size=102400,
        head_dim=128,
        moe=MoEConfig(n_experts=160, n_experts_per_tok=6,
                      n_shared_experts=2, d_expert=1536),
        mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                      qk_nope_head_dim=128, qk_rope_head_dim=64,
                      v_head_dim=128),
    )


register_arch("deepseek-v2-236b", build)
