"""Config package: importing it registers every architecture.

Assigned architectures (10) + the paper's own OPT-125M. Each module holds
the exact assignment config with its source citation.
"""
from repro.configs import (  # noqa: F401
    deepseek_coder_33b,
    deepseek_v2_236b,
    granite_34b,
    internvl2_76b,
    mamba2_370m,
    minicpm3_4b,
    moonshot_v1_16b_a3b,
    opt_125m,
    recurrentgemma_2b,
    whisper_medium,
    yi_6b,
)
from repro.configs.base import (  # noqa: F401
    ALL_SHAPES,
    SHAPES_BY_NAME,
    ChannelConfig,
    DPConfig,
    MeshConfig,
    ModelConfig,
    PairZeroConfig,
    PowerControlConfig,
    ShapeConfig,
    TPU_V5E,
    ZOConfig,
)

ASSIGNED_ARCHS = (
    "moonshot-v1-16b-a3b",
    "deepseek-v2-236b",
    "recurrentgemma-2b",
    "internvl2-76b",
    "whisper-medium",
    "deepseek-coder-33b",
    "granite-34b",
    "minicpm3-4b",
    "yi-6b",
    "mamba2-370m",
)
