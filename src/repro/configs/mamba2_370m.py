"""mamba2-370m [ssm] — SSD (state-space duality), attention-free.

Assignment: 48L d_model=1024 (attn-free) d_ff=0 vocab=50280, ssm_state=128.
[arXiv:2405.21060; unverified]

expand=2 → d_inner=2048, head_dim=64 → 32 SSD heads, d_conv=4, ngroups=1.
O(1) decode state ⇒ runs the long_500k cell.
"""
from repro.configs.base import ModelConfig, SSMConfig
from repro.models.arch_registry import register_arch


def build() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m",
        family="ssm",
        n_layers=48,
        d_model=1024,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab_size=50280,
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                      chunk=256),
        subquadratic=True,
        tie_embeddings=True,
    )


register_arch("mamba2-370m", build)
