"""granite-34b [dense] — llama-arch MQA (kv=1), code model.

Assignment: 88L d_model=6144 48H (GQA kv=1) d_ff=24576 vocab=49152.
[arXiv:2405.04324; hf]
"""
from repro.configs.base import ModelConfig
from repro.models.arch_registry import register_arch


def build() -> ModelConfig:
    return ModelConfig(
        name="granite-34b",
        family="dense",
        n_layers=88,
        d_model=6144,
        n_heads=48,
        n_kv_heads=1,
        d_ff=24576,
        vocab_size=49152,
        head_dim=128,
    )


register_arch("granite-34b", build)
