"""Crash-safe checkpointing for federated ZO training.

ZO state is tiny by construction: (params, step, base seed, spent DP budget,
optional FO optimizer state). Saves are atomic (write to a temp dir, fsync,
rename) with a CRC-32 manifest so a torn write is detected at restore instead
of silently resuming from garbage. Privacy accounting is part of the state —
a crash can never reset the spent (ε, δ) budget.

Layout:
  <dir>/step_<N>/arrays.npz      one entry per pytree leaf ("path" keys)
  <dir>/step_<N>/manifest.json   {step, extra, crc32s, leaf paths/treedef}
"""
from __future__ import annotations

import json
import os
import shutil
import zlib
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from repro.obs import spans as ob

PyTree = Any


def _leaf_paths(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return names, leaves, treedef


def save(directory: str, step: int, params: PyTree,
         extra: Optional[Dict] = None, keep: int = 3) -> str:
    """Atomically persist (params, step, extra). Returns the final path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    names, leaves, _ = _leaf_paths(params)
    arrays = {n: np.asarray(leaf)
              for n, leaf in zip(names, leaves, strict=True)}
    npz_path = os.path.join(tmp, "arrays.npz")
    np.savez(npz_path, **arrays)

    crcs = {n: zlib.crc32(a.tobytes()) for n, a in arrays.items()}
    manifest = {
        "step": int(step),
        "extra": extra or {},
        "crc32": crcs,
        "dtypes": {n: str(a.dtype) for n, a in arrays.items()},
        "shapes": {n: list(a.shape) for n, a in arrays.items()},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())

    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _retain(directory, keep)
    return final


def _retain(directory: str, keep: int) -> None:
    ckpts = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for stale in ckpts[:-keep]:
        shutil.rmtree(os.path.join(directory, stale))


@jax.jit
def _device_snapshot(params: PyTree) -> PyTree:
    """Bit-exact on-device copy of the pytree into fresh (non-donated)
    buffers. The training carry is buffer-donated through the next chunk,
    so snapshotting the *carry itself* would either block the next dispatch
    (sync device_get) or race the donation; copying first decouples the
    checkpoint's device→host transfer from the training stream entirely.
    `optimization_barrier` (not a bare identity) defeats jit's input→output
    forwarding fast path, which would hand back the original buffers."""
    return jax.lax.optimization_barrier(params)


class AsyncCheckpointer:
    """Non-blocking checkpointing: double-buffered snapshot, write off-thread.

    Default (`double_buffer=True`) boundary cost on the training thread is
    one async dispatch: the params are copied on-device into fresh buffers,
    the device→host transfer is started with `copy_to_host_async`, and the
    worker thread materializes the host copy (blocking only itself until
    the transfer lands) before serializing + CRC + fsync. The donated carry
    is never touched after dispatch, so the next chunk launches without
    waiting for the snapshot — the historical synchronous `device_get`
    serialized compute-finish + D2H onto the training thread.

    `double_buffer=False` keeps that historical synchronous snapshot (the
    measurement baseline). `stall_s` accumulates the training-thread time
    spent inside `save()` either way, so the boundary stall attributable to
    the snapshot is directly comparable across modes. `wait()` joins the
    in-flight write (writes never interleave).
    """

    def __init__(self, directory: str, keep: int = 3,
                 double_buffer: bool = True,
                 tracer: ob.Tracer = ob.NULL_TRACER):
        self.directory = directory
        self.keep = keep
        self.double_buffer = double_buffer
        self.stall_s = 0.0
        self._thread = None
        self._tracer = tracer

    def _write(self, step: int, snap: PyTree, extra: Optional[Dict]) -> None:
        with self._tracer.span("ckpt_write", step=step):
            host_params = jax.tree_util.tree_map(lambda a: np.asarray(a),
                                                 snap)
            save(self.directory, step, host_params, extra=extra,
                 keep=self.keep)

    def _write_host(self, step: int, host_params: PyTree,
                    extra: Optional[Dict]) -> None:
        with self._tracer.span("ckpt_write", step=step):
            save(self.directory, step, host_params, extra=extra,
                 keep=self.keep)

    def save(self, step: int, params: PyTree,
             extra: Optional[Dict] = None) -> None:
        import threading
        import time

        t0 = time.perf_counter()
        self.wait()
        if self.double_buffer and any(
                isinstance(leaf, jax.Array)
                for leaf in jax.tree_util.tree_leaves(params)):
            snap = _device_snapshot(params)
            for leaf in jax.tree_util.tree_leaves(snap):
                leaf.copy_to_host_async()
            self._thread = threading.Thread(
                target=self._write, args=(step, snap, extra), daemon=True)
        else:
            host_params = jax.tree_util.tree_map(
                lambda a: np.asarray(a), params)     # sync D2H baseline
            self._thread = threading.Thread(
                target=self._write_host, args=(step, host_params, extra),
                daemon=True)
        self._thread.start()
        t1 = time.perf_counter()
        self.stall_s += t1 - t0
        # span == the exact stall_s increment (same endpoints): the
        # training-thread cost of dispatching this snapshot
        self._tracer.add_span("ckpt_snapshot", t0, t1, step=step)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest(directory: str) -> Optional[str]:
    if not os.path.isdir(directory):
        return None
    ckpts = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    return os.path.join(directory, ckpts[-1]) if ckpts else None


def restore(path: str, params_like: PyTree
            ) -> Tuple[PyTree, int, Dict]:
    """Load a checkpoint into the structure of `params_like` (verifying
    integrity). Returns (params, step, extra)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))

    names, leaves, treedef = _leaf_paths(params_like)
    restored = []
    for n, like in zip(names, leaves, strict=True):
        arr = data[n]
        crc = zlib.crc32(arr.tobytes())
        if crc != manifest["crc32"][n]:
            raise IOError(f"checkpoint corruption detected in leaf {n!r} "
                          f"(crc {crc} != {manifest['crc32'][n]})")
        if list(arr.shape) != list(like.shape):
            raise ValueError(f"leaf {n!r} shape {arr.shape} != expected "
                             f"{like.shape}")
        restored.append(jax.numpy.asarray(arr).astype(like.dtype))
    params = jax.tree_util.tree_unflatten(treedef, restored)
    return params, int(manifest["step"]), manifest["extra"]
