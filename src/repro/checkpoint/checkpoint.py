"""Crash-safe checkpointing for federated ZO training.

ZO state is tiny by construction: (params, step, base seed, spent DP budget,
optional FO optimizer state). Saves are atomic (write to a temp dir, fsync,
rename) with a CRC-32 manifest so a torn write is detected at restore instead
of silently resuming from garbage. Privacy accounting is part of the state —
a crash can never reset the spent (ε, δ) budget.

Layout:
  <dir>/step_<N>/arrays.npz      one entry per pytree leaf ("path" keys)
  <dir>/step_<N>/manifest.json   {step, extra, crc32s, leaf paths/treedef}
"""
from __future__ import annotations

import json
import os
import shutil
import zlib
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from repro.obs import spans as ob

PyTree = Any


def _leaf_paths(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return names, leaves, treedef


def save(directory: str, step: int, params: PyTree,
         extra: Optional[Dict] = None, keep: int = 3) -> str:
    """Atomically persist (params, step, extra). Returns the final path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    names, leaves, _ = _leaf_paths(params)
    arrays = {n: np.asarray(leaf)
              for n, leaf in zip(names, leaves, strict=True)}
    npz_path = os.path.join(tmp, "arrays.npz")
    np.savez(npz_path, **arrays)

    crcs = {n: zlib.crc32(a.tobytes()) for n, a in arrays.items()}
    manifest = {
        "step": int(step),
        "extra": extra or {},
        "crc32": crcs,
        "dtypes": {n: str(a.dtype) for n, a in arrays.items()},
        "shapes": {n: list(a.shape) for n, a in arrays.items()},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())

    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _retain(directory, keep)
    return final


def _retain(directory: str, keep: int) -> None:
    ckpts = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for stale in ckpts[:-keep]:
        shutil.rmtree(os.path.join(directory, stale))


@jax.jit
def _device_snapshot(params: PyTree) -> PyTree:
    """Bit-exact on-device copy of the pytree into fresh (non-donated)
    buffers. The training carry is buffer-donated through the next chunk,
    so snapshotting the *carry itself* would either block the next dispatch
    (sync device_get) or race the donation; copying first decouples the
    checkpoint's device→host transfer from the training stream entirely.
    `optimization_barrier` (not a bare identity) defeats jit's input→output
    forwarding fast path, which would hand back the original buffers."""
    return jax.lax.optimization_barrier(params)


class AsyncCheckpointer:
    """Non-blocking checkpointing: double-buffered snapshot, write off-thread.

    Default (`double_buffer=True`) boundary cost on the training thread is
    one async dispatch: the params are copied on-device into fresh buffers,
    the device→host transfer is started with `copy_to_host_async`, and the
    worker thread materializes the host copy (blocking only itself until
    the transfer lands) before serializing + CRC + fsync. The donated carry
    is never touched after dispatch, so the next chunk launches without
    waiting for the snapshot — the historical synchronous `device_get`
    serialized compute-finish + D2H onto the training thread.

    `double_buffer=False` keeps that historical synchronous snapshot (the
    measurement baseline). `stall_s` accumulates the training-thread time
    spent inside `save()` either way, so the boundary stall attributable to
    the snapshot is directly comparable across modes. `wait()` joins the
    in-flight write (writes never interleave).

    Degradation contract: `save` is atomic at the filesystem level AND
    best-effort at the run level. A failing write is retried
    `write_retries` times with backoff (`retry` spans via
    repro.runtime.inject); if it still fails the failure is swallowed,
    counted in `write_failures`, and the run keeps its last good
    checkpoint instead of aborting (resume picks it up via
    `latest_valid`). A failing snapshot dispatch likewise skips the
    boundary (`snapshot_failures`) rather than killing training.
    `injector` (repro.runtime.FaultInjector) arms the `ckpt_snapshot` /
    `ckpt_write` sites; its `torn_write` mode truncates the just-written
    `arrays.npz` to simulate bitrot that `restore` must reject and
    `latest_valid` must skip.
    """

    def __init__(self, directory: str, keep: int = 3,
                 double_buffer: bool = True,
                 tracer: ob.Tracer = ob.NULL_TRACER,
                 injector: Optional[Any] = None,
                 write_retries: int = 3):
        self.directory = directory
        self.keep = keep
        self.double_buffer = double_buffer
        self.stall_s = 0.0
        self.write_failures = 0
        self.snapshot_failures = 0
        self.retries: Dict[str, int] = {}
        self.write_retries = write_retries
        self._thread = None
        self._tracer = tracer
        self._injector = injector

    def _save_retrying(self, step: int, host_params: PyTree,
                       extra: Optional[Dict]) -> None:
        """save() with bounded retry + keep-last-good on final failure."""
        from repro.runtime import inject as inj

        def attempt():
            torn = None
            if self._injector is not None:
                torn = self._injector.fire("ckpt_write")
            path = save(self.directory, step, host_params, extra=extra,
                        keep=self.keep)
            if torn == "torn_write":
                tear_checkpoint(path)
                self._tracer.instant("ckpt_torn", step=step)

        try:
            inj.with_retries(attempt, site="ckpt_write",
                             attempts=self.write_retries,
                             tracer=self._tracer, retries=self.retries)
        except Exception as exc:  # noqa: BLE001 - keep-last-good
            self.write_failures += 1
            self._tracer.instant("ckpt_write_failed", step=step,
                                 error=type(exc).__name__)

    def _write(self, step: int, snap: PyTree, extra: Optional[Dict]) -> None:
        with self._tracer.span("ckpt_write", step=step):
            host_params = jax.tree_util.tree_map(lambda a: np.asarray(a),
                                                 snap)
            self._save_retrying(step, host_params, extra)

    def _write_host(self, step: int, host_params: PyTree,
                    extra: Optional[Dict]) -> None:
        with self._tracer.span("ckpt_write", step=step):
            self._save_retrying(step, host_params, extra)

    def save(self, step: int, params: PyTree,
             extra: Optional[Dict] = None) -> None:
        import threading
        import time

        t0 = time.perf_counter()
        self.wait()
        try:
            if self._injector is not None:
                self._injector.fire("ckpt_snapshot")
            if self.double_buffer and any(
                    isinstance(leaf, jax.Array)
                    for leaf in jax.tree_util.tree_leaves(params)):
                snap = _device_snapshot(params)
                for leaf in jax.tree_util.tree_leaves(snap):
                    leaf.copy_to_host_async()
                self._thread = threading.Thread(
                    target=self._write, args=(step, snap, extra),
                    daemon=True)
            else:
                host_params = jax.tree_util.tree_map(
                    lambda a: np.asarray(a), params)     # sync D2H baseline
                self._thread = threading.Thread(
                    target=self._write_host,
                    args=(step, host_params, extra), daemon=True)
            self._thread.start()
        except Exception as exc:  # noqa: BLE001 - skip boundary, don't abort
            self.snapshot_failures += 1
            self._tracer.instant("ckpt_skipped", step=step,
                                 error=type(exc).__name__)
        t1 = time.perf_counter()
        self.stall_s += t1 - t0
        # span == the exact stall_s increment (same endpoints): the
        # training-thread cost of dispatching this snapshot
        self._tracer.add_span("ckpt_snapshot", t0, t1, step=step)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest(directory: str) -> Optional[str]:
    """Path of the newest step_* checkpoint (no integrity check)."""
    if not os.path.isdir(directory):
        return None
    ckpts = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    return os.path.join(directory, ckpts[-1]) if ckpts else None


def valid_checkpoint(path: str) -> bool:
    """Whether `path` holds a complete, CRC-consistent checkpoint.

    Tolerant by design: any missing/undecodable manifest, unreadable or
    truncated npz, missing leaf or CRC mismatch makes the checkpoint
    invalid rather than raising — `latest_valid` uses this to fall back
    past torn writes.
    """
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        with np.load(os.path.join(path, "arrays.npz")) as data:
            for n, crc in manifest["crc32"].items():
                if n not in data.files:
                    return False
                if zlib.crc32(data[n].tobytes()) != int(crc):
                    return False
        return True
    except Exception:  # noqa: BLE001 - any damage means "not valid"
        return False


def latest_valid(directory: str) -> Optional[str]:
    """Path of the newest checkpoint that passes full CRC validation.

    Walks step_* newest-first, skipping torn/corrupt ones (a SIGKILL mid
    `os.rename`, simulated bitrot, a half-written npz) — the crash-
    consistent resume entry point: the atomic save protocol plus this
    fallback guarantee a resumable state whenever ANY save completed.
    """
    if not os.path.isdir(directory):
        return None
    ckpts = sorted((d for d in os.listdir(directory)
                    if d.startswith("step_") and not d.endswith(".tmp")),
                   reverse=True)
    for name in ckpts:
        path = os.path.join(directory, name)
        if valid_checkpoint(path):
            return path
    return None


def tear_checkpoint(path: str) -> None:
    """Truncate a checkpoint's arrays.npz in half (simulated torn write).

    The result keeps its manifest, so naive `latest` still returns it —
    `valid_checkpoint` must reject it and `latest_valid` must fall back
    to the previous intact checkpoint. Used by the chaos harness and the
    `torn_write` injection mode.
    """
    npz = os.path.join(path, "arrays.npz")
    size = os.path.getsize(npz)
    with open(npz, "r+b") as f:
        f.truncate(max(size // 2, 1))
        f.flush()
        os.fsync(f.fileno())


def restore(path: str, params_like: PyTree
            ) -> Tuple[PyTree, int, Dict]:
    """Load a checkpoint into the structure of `params_like` (verifying
    integrity). Returns (params, step, extra)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))

    names, leaves, treedef = _leaf_paths(params_like)
    restored = []
    for n, like in zip(names, leaves, strict=True):
        arr = data[n]
        crc = zlib.crc32(arr.tobytes())
        if crc != manifest["crc32"][n]:
            raise IOError(f"checkpoint corruption detected in leaf {n!r} "
                          f"(crc {crc} != {manifest['crc32'][n]})")
        if list(arr.shape) != list(like.shape):
            raise ValueError(f"leaf {n!r} shape {arr.shape} != expected "
                             f"{like.shape}")
        restored.append(jax.numpy.asarray(arr).astype(like.dtype))
    params = jax.tree_util.tree_unflatten(treedef, restored)
    return params, int(manifest["step"]), manifest["extra"]
