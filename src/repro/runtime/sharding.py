"""Sharding rules: param/activation/cache PartitionSpecs per architecture.

Strategy (DESIGN.md §6): clients ≡ (pod, data) axes; within a client the
model axis carries TP (attention heads / FFN columns / expert FFN columns)
while weights are additionally FSDP-sharded over the client axes — GSPMD
inserts the per-layer all-gathers under lax.scan, which is what lets the
236B config fit 512 × 16 GB chips.

Rules are name-based (the framework convention: projection matrices have
stable leaf names), rank-aware, and divisibility-guarded: a dim is only
sharded if the mesh axis divides it — otherwise that axis is dropped (GSPMD
could pad, but explicit fallback keeps memory analysis readable).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

# leaf names → role
_COL_PARALLEL = {  # [.., d_in, d_out]: FSDP on d_in, TP on d_out
    "wq", "wk", "wv", "wi", "wg", "wq_b", "wkv_b", "wq_a", "wkv_a",
    "router", "we_i", "we_g", "in_proj", "lin_x", "lin_gate",
    "w_rec_gate", "w_in_gate",
    # we_d is deliberately col-parallel (FSDP on F, TP on D): contracting a
    # TP-sharded F would psum the full pre-combine [E,B,C,D] tensor (k·cf×
    # larger than the token tensor); with TP on D the psum disappears and
    # only the combined [T, D] output is gathered (§Perf iteration 2).
    "we_d",
}
_ROW_PARALLEL = {  # [.., d_in, d_out]: TP on d_in, FSDP on d_out
    "wo", "wd", "out", "out_proj",
}
_EMBED = {"embed", "lm_head", "dec_embed"}   # [V, D]: TP on V, FSDP on D


def client_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def axis_size(mesh: Mesh, axes) -> int:
    """Product of the named axes' sizes; axes absent from the mesh count as
    size 1 (a client-only mesh has no 'model' axis, and vice versa)."""
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes
                        if a in mesh.axis_names], dtype=np.int64))


def _maybe(mesh: Mesh, axes, dim: int):
    """Use `axes` for this dim only if every axis exists on the mesh and
    their product divides the dim evenly (axes absent from the mesh — e.g.
    'model' on a client-only mesh — are dropped, preserving the original
    str/tuple spelling when nothing is filtered)."""
    if axes is None:
        return None
    as_tuple = (axes,) if isinstance(axes, str) else tuple(axes)
    present = tuple(a for a in as_tuple if a in mesh.axis_names)
    if not present:
        return None
    filtered = axes if len(present) == len(as_tuple) else present
    return filtered if dim % axis_size(mesh, present) == 0 else None


def param_spec(mesh: Mesh, path: Tuple, leaf, serve: bool = False) -> P:
    """PartitionSpec for one parameter leaf, from its tree path + shape.

    serve=True switches MoE expert tensors to the EP-resident decode layout
    (§Perf hillclimb cell 3): experts sharded over `model` and FSDP on the
    contraction dim — weights stay resident and only tiny token activations
    cross devices per decode step, instead of streaming ~1 GB/layer of
    expert weights per generated token.
    """
    names = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
    leaf_name = names[-1] if names[-1] != "w" else (
        names[-2] if len(names) >= 2 else names[-1])
    shape = leaf.shape
    fsdp = client_axes(mesh)
    tp = "model"

    if serve and leaf_name in ("we_i", "we_g", "we_d") and len(shape) >= 3:
        # [.., E, d_in, d_out]: E → model, contraction dim → fsdp
        spec = [None] * len(shape)
        spec[-3] = _maybe(mesh, tp, shape[-3])
        spec[-2] = _maybe(mesh, fsdp, shape[-2])
        return P(*spec)

    if leaf_name in _EMBED and len(shape) == 2:
        return P(_maybe(mesh, tp, shape[0]), _maybe(mesh, fsdp, shape[1]))
    if leaf_name in _COL_PARALLEL and len(shape) >= 2:
        spec = [None] * len(shape)
        spec[-2] = _maybe(mesh, fsdp, shape[-2])
        spec[-1] = _maybe(mesh, tp, shape[-1])
        return P(*spec)
    if leaf_name in _ROW_PARALLEL and len(shape) >= 2:
        spec = [None] * len(shape)
        spec[-2] = _maybe(mesh, tp, shape[-2])
        spec[-1] = _maybe(mesh, fsdp, shape[-1])
        return P(*spec)
    if leaf_name == "conv_w" and len(shape) >= 2:
        spec = [None] * len(shape)
        spec[-1] = _maybe(mesh, tp, shape[-1])
        return P(*spec)
    # norms, gains, scalars, biases: replicated
    return P(*([None] * len(shape)))


def params_sharding(mesh: Mesh, params_like: PyTree,
                    serve: bool = False) -> PyTree:
    """NamedSharding tree matching `params_like` (abstract or concrete)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_like)
    shardings = [NamedSharding(mesh, param_spec(mesh, path, leaf, serve))
                 for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, shardings)


# ---------------------------------------------------------------------------
# Batches / control / caches
# ---------------------------------------------------------------------------

def batch_sharding(mesh: Mesh, batch_like: PyTree) -> PyTree:
    """Train batches [K, b, S, ...]: client dim over (pod, data)."""
    cl = client_axes(mesh)

    def spec(leaf):
        k = leaf.shape[0]
        lead = _maybe(mesh, cl, k)
        return NamedSharding(mesh, P(lead, *([None] * (len(leaf.shape) - 1))))

    return jax.tree_util.tree_map(spec, batch_like)


def chunk_batch_sharding(mesh: Mesh, stack_like: PyTree) -> PyTree:
    """Stacked chunk batches [R, K, b, S, ...]: round dim replicated, client
    dim over (pod, data). This is the placement `BatchStager` hands to its
    single per-chunk `device_put`, so the scan engine's batches land sharded
    at transfer time — slicing round r inside the scanned step yields the
    [K, ...] layout `batch_sharding` describes, with no post-hoc reshard."""
    cl = client_axes(mesh)

    def spec(leaf):
        k = leaf.shape[1]
        return NamedSharding(mesh, P(None, _maybe(mesh, cl, k),
                                     *([None] * (len(leaf.shape) - 2))))

    return jax.tree_util.tree_map(spec, stack_like)


def control_sharding(mesh: Mesh, ctl_like: PyTree) -> PyTree:
    """Per-round control block: replicated everywhere (scalars + [K])."""
    def spec(leaf):
        return NamedSharding(mesh, P(*([None] * len(getattr(leaf, "shape",
                                                            ())))))
    return jax.tree_util.tree_map(spec, ctl_like)


def serve_batch_sharding(mesh: Mesh, tokens_like) -> NamedSharding:
    """Serve tokens [B, S]: batch over clients when divisible."""
    cl = client_axes(mesh)
    lead = _maybe(mesh, cl, tokens_like.shape[0])
    return NamedSharding(mesh, P(lead, None))


def cache_sharding(mesh: Mesh, cache_like: PyTree) -> PyTree:
    """Decode caches/states.

    Uniform rule (works for MQA/GQA/MLA/SSM/hybrid alike): leading layer dim
    replicated, batch dim over clients, and the *longest remaining dim*
    (sequence for KV caches, channels for SSM/LRU states) over `model` when
    divisible. Chosen for robustness; head-sharded variants are a §Perf
    lever.
    """
    cl = client_axes(mesh)

    def spec(leaf):
        shape = leaf.shape
        ndim = len(shape)
        out = [None] * ndim
        if ndim >= 2:
            out[1] = _maybe(mesh, cl, shape[1])      # batch dim (after L)
        if ndim >= 3:
            # pick the largest of the remaining dims for the model axis
            rest = list(range(2, ndim))
            big = max(rest, key=lambda i: shape[i])
            out[big] = _maybe(mesh, "model", shape[big])
        return NamedSharding(mesh, P(*out))

    return jax.tree_util.tree_map(spec, cache_like)


def replicated(mesh: Mesh, like: PyTree) -> PyTree:
    return jax.tree_util.tree_map(
        lambda l: NamedSharding(mesh, P(*([None] * len(l.shape)))), like)


# ---------------------------------------------------------------------------
# Activation sharding hints (model code → GSPMD, mesh-agnostic)
# ---------------------------------------------------------------------------
# Model code can't (and shouldn't) know mesh axis names. It calls
# `hint(x, "client", None, "model")` with per-dim *roles*; if a hint context
# is active (set by dryrun/train/serve launchers), the role resolves to a
# with_sharding_constraint; otherwise it is a no-op (CPU tests unaffected).

import contextvars
from contextlib import contextmanager

_HINT_MESH: "contextvars.ContextVar[Optional[Mesh]]" = \
    contextvars.ContextVar("repro_hint_mesh", default=None)
_BF16_REDUCE: "contextvars.ContextVar[bool]" = \
    contextvars.ContextVar("repro_bf16_reduce", default=False)
_MANUAL_AXES: "contextvars.ContextVar[frozenset]" = \
    contextvars.ContextVar("repro_manual_axes", default=frozenset())


@contextmanager
def manual_axes(axes):
    """Mark mesh axes as shard_map-manual for the duration.

    Inside a shard_map body the named axes are manual: a
    with_sharding_constraint mentioning them is illegal (and meaningless —
    the dim is already local). `hint()` and `current_client_axes()` drop
    manual axes, so model code written against the GSPMD-auto convention
    runs unchanged inside the client-sharded step."""
    token = _MANUAL_AXES.set(_MANUAL_AXES.get() | frozenset(axes))
    try:
        yield
    finally:
        _MANUAL_AXES.reset(token)


@contextmanager
def hints(mesh: Mesh, bf16_reduce: bool = False):
    """Activate model-side sharding hints (and optionally bf16 psums).

    bf16_reduce: row-parallel projections emit bf16 partials, so the TP
    all-reduce moves half the bytes (§Perf optimization; MXU accumulation
    stays f32 internally — only the cross-device combine is bf16)."""
    token = _HINT_MESH.set(mesh)
    token2 = _BF16_REDUCE.set(bf16_reduce)
    try:
        yield
    finally:
        _HINT_MESH.reset(token)
        _BF16_REDUCE.reset(token2)


def bf16_reduce_active() -> bool:
    return _BF16_REDUCE.get()


def current_client_axes():
    """Client mesh axes from the active hint context (None outside it).

    Used as vmap(spmd_axis_name=...) so per-row batched ops (e.g. MoE
    dispatch gather/scatter) keep their batch dim sharded over clients.
    Axes that are shard_map-manual are dropped — inside the client-sharded
    step the batch dim is already local."""
    mesh = _HINT_MESH.get()
    if mesh is None:
        return None
    manual = _MANUAL_AXES.get()
    axes = tuple(a for a in client_axes(mesh) if a not in manual)
    return axes if axes else None


def hint(x, *roles):
    """roles: one of "client" | "model" | None per dim of x.

    "client" dims stay divisibility-guarded (a ragged client split would be
    semantically wrong for pAirZero clients); "model" dims may shard
    unevenly — GSPMD pads internally, which is exactly what we want for odd
    vocab sizes (51865, 73448, ...) instead of a replicated logits tensor.
    """
    mesh = _HINT_MESH.get()
    if mesh is None:
        return x
    if _MANUAL_AXES.get():
        # Inside a shard_map body: client dims are already local, and on
        # jax 0.4.x a with_sharding_constraint inside a partial-auto body
        # trips an XLA manual-subgroup check — auto-axis (TP) layouts
        # propagate from the operands' shardings instead.
        return x
    assert len(roles) == x.ndim, (roles, x.shape)
    resolved = []
    for dim, role in zip(x.shape, roles, strict=True):
        if role == "client":
            resolved.append(_maybe(mesh, client_axes(mesh), dim))
        elif role == "model":
            resolved.append("model" if "model" in mesh.axis_names
                            and dim >= axis_size(mesh, "model") else None)
        else:
            resolved.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*resolved)))
