"""Fault-tolerance runtime: client dropout, stragglers, elastic membership.

OTA aggregation makes fault handling unusually clean: a failed or late client
simply *does not superpose its signal*. The server detects the surviving set
via pilot symbols (simulated here as the survival mask) and inverts by K_eff.
ZO makes *state* recovery trivial: a rejoining client needs only (w, t, seed)
— no optimizer state, no gradient history.

All randomness is seeded and replayable: a restarted coordinator regenerates
the identical fault trace, so checkpoint-resumed runs are bit-reproducible.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np


@dataclass
class FaultModel:
    """Per-round client availability model.

    dropout_p:    iid probability a client's uplink fails this round.
    straggler_p:  probability a client misses the OTA deadline this round.
    mtbf_rounds:  if set, clients also fail "hard" (mean time between
                  failures, exponential) and rejoin after `repair_rounds`.
    """
    n_clients: int
    dropout_p: float = 0.0
    straggler_p: float = 0.0
    mtbf_rounds: Optional[float] = None
    repair_rounds: int = 10
    seed: int = 0

    def __post_init__(self):
        """Validate probabilities and seed the replayable rng stream."""
        if self.n_clients < 1:
            raise ValueError(f"n_clients must be >= 1, got {self.n_clients}")
        for name in ("dropout_p", "straggler_p"):
            p = getattr(self, name)
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {p}")
        if self.dropout_p + self.straggler_p > 1.0:
            raise ValueError(
                f"dropout_p + straggler_p must be <= 1 (the per-round "
                f"keep-probability 1 - dropout_p - straggler_p would be "
                f"negative), got {self.dropout_p} + {self.straggler_p} = "
                f"{self.dropout_p + self.straggler_p}")
        self._rng = np.random.default_rng(self.seed)
        self._down_until = np.zeros(self.n_clients, dtype=np.int64)

    def survival_mask(self, t: int) -> np.ndarray:
        """[K] 0/1 mask of clients whose signal superposes in round t."""
        up = self._down_until <= t
        if self.mtbf_rounds:
            fails = self._rng.random(self.n_clients) < 1.0 / self.mtbf_rounds
            newly_down = up & fails
            self._down_until[newly_down] = t + self.repair_rounds
            up = self._down_until <= t
        transient = (self._rng.random(self.n_clients)
                     >= self.dropout_p + self.straggler_p)
        mask = (up & transient).astype(np.float32)
        if mask.sum() == 0:  # never let a round fully vanish
            mask[self._rng.integers(self.n_clients)] = 1.0
        return mask


@dataclass
class ElasticSchedule:
    """Deterministic membership schedule: K(t) clients active.

    Models planned scale-up/down (pods joining/leaving a fleet). Combine with
    FaultModel for unplanned failures. `events` is a list of (round, K_new);
    membership masks activate the first K_new client slots.
    """
    n_clients: int
    events: tuple = ()

    def active_k(self, t: int) -> int:
        """Planned number of active clients in round t (last event wins)."""
        k = self.n_clients
        for round_t, k_new in sorted(self.events):
            if t >= round_t:
                k = k_new
        return max(1, min(k, self.n_clients))

    def membership_mask(self, t: int) -> np.ndarray:
        """[K] 0/1 mask activating the first active_k(t) client slots."""
        mask = np.zeros(self.n_clients, dtype=np.float32)
        mask[: self.active_k(t)] = 1.0
        return mask


def combined_mask(t: int, fault: Optional[FaultModel] = None,
                  elastic: Optional[ElasticSchedule] = None,
                  n_clients: Optional[int] = None) -> np.ndarray:
    """[K] survival ∧ membership mask for round t (never all-zero).

    With neither model, ``n_clients`` is required to size the all-ones
    mask (a clear error here beats a TypeError deep in numpy).
    """
    if fault is None and elastic is None:
        if n_clients is None:
            raise ValueError(
                "combined_mask: n_clients is required when neither a "
                "FaultModel nor an ElasticSchedule is given")
        return np.ones(n_clients, dtype=np.float32)
    mask = None
    if elastic is not None:
        mask = elastic.membership_mask(t)
    if fault is not None:
        fm = fault.survival_mask(t)
        mask = fm if mask is None else mask * fm
    if mask.sum() == 0:
        mask[0] = 1.0
    return mask
