"""Deterministic fault injection + bounded retry for the host pipeline.

Chaos layer for the driver's host-side seams. A :class:`FaultInjector`
arms named *sites* — the places the training loop touches the outside
world — with seeded, replayable faults:

=================  ====================================================
site               where it fires
=================  ====================================================
``chunk_prep``     entry of ChunkPrefetcher's prepare (worker thread or
                   inline), before the control trace is built
``dispatch``       entry of an executor.run chunk dispatch
``ckpt_snapshot``  entry of AsyncCheckpointer.save's device snapshot
``ckpt_write``     entry of the checkpoint writer (thread or sync), per
                   attempt
=================  ====================================================

Modes form a small registry (mirroring the transport/channel/attack
registries): ``exception`` raises :class:`InjectedFault`, ``delay``
sleeps then proceeds, ``torn_write`` asks the site to truncate the file
it just wrote (only ``ckpt_write`` honors it — simulated bitrot that
``checkpoint.latest_valid`` must skip on resume).

Faults fire at site *entry* — before any stateful host RNG (FaultModel)
or device buffer is consumed — so a retry replays the site from a clean
slate and recovered runs stay bit-identical to undisturbed ones. Whether
a given invocation fires is a pure function of (injector seed, site,
invocation index): either an exact ``@i,j,...`` invocation selector or a
per-invocation Bernoulli draw. Nothing here ever enters jit or a memo
key.

:func:`with_retries` is the bounded retry-with-backoff wrapper the
driver uses around dispatch and checkpoint writes; each re-attempt is
span-instrumented (``retry`` spans through the PR-8 Tracer) and counted
into ``RunResult.retry_attempts``.
"""
from __future__ import annotations

import dataclasses
import time
import zlib
from typing import Callable, Dict, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.obs.spans import NULL_TRACER

SITES = ("chunk_prep", "dispatch", "ckpt_snapshot", "ckpt_write")

_MODES: Dict[str, "FaultMode"] = {}


def register_mode(name: str):
    """Class decorator: register a fault mode under ``name``."""
    def deco(cls):
        _MODES[name] = cls()
        cls.name = name
        return cls
    return deco


def available_modes() -> Tuple[str, ...]:
    """Registered fault-mode names."""
    return tuple(sorted(_MODES))


class InjectedFault(RuntimeError):
    """Raised by the ``exception`` mode at an armed site."""


class FaultMode:
    """A way for an armed site to misbehave; see the registry above."""

    name = "?"

    def trigger(self, site: str, invocation: int,
                fault: "SiteFault") -> Optional[str]:
        """Fire at ``site``; raise, sleep, or return a marker string."""
        raise NotImplementedError


@register_mode("exception")
class ExceptionMode(FaultMode):
    """Raise :class:`InjectedFault` — the site's caller must recover."""

    def trigger(self, site, invocation, fault):
        """Raise InjectedFault tagged with site and invocation index."""
        raise InjectedFault(
            f"injected fault at site {site!r} (invocation {invocation})")


@register_mode("delay")
class DelayMode(FaultMode):
    """Sleep ``delay_s`` then let the site proceed (straggler host op)."""

    def trigger(self, site, invocation, fault):
        """Block for fault.delay_s seconds, then return."""
        time.sleep(fault.delay_s)
        return "delay"


@register_mode("torn_write")
class TornWriteMode(FaultMode):
    """Ask the site to truncate its output file after writing it."""

    def trigger(self, site, invocation, fault):
        """Return the marker; the owning site performs the tear."""
        return "torn_write"


@dataclasses.dataclass(frozen=True)
class SiteFault:
    """One armed site: mode + when it fires.

    ``at`` (exact invocation indices) wins over ``p`` (per-invocation
    Bernoulli). ``delay_s`` only matters for the ``delay`` mode.
    """

    mode: str
    p: float = 1.0
    at: Tuple[int, ...] = ()
    delay_s: float = 0.02

    def __post_init__(self):
        """Validate mode name and probability."""
        if self.mode not in _MODES:
            raise ValueError(f"unknown fault mode {self.mode!r} "
                             f"(available: {available_modes()})")
        if not 0.0 <= self.p <= 1.0:
            raise ValueError(f"fault probability must be in [0, 1], "
                             f"got {self.p}")


class FaultInjector:
    """Seeded registry of armed sites; host-side only, fully replayable.

    ``fire(site)`` advances the site's invocation counter and — when the
    (seed, site, invocation) draw says so — triggers the armed mode.
    Returns the mode's marker string (``"torn_write"``/``"delay"``) or
    None when nothing fired; the ``exception`` mode raises instead.
    """

    def __init__(self, faults: Mapping[str, SiteFault], seed: int = 0,
                 tracer=NULL_TRACER):
        """Arm ``faults`` (site name -> SiteFault) under ``seed``."""
        for site in faults:
            if site not in SITES:
                raise ValueError(f"unknown injection site {site!r} "
                                 f"(available: {SITES})")
        self.faults = dict(faults)
        self.seed = int(seed)
        self.tracer = tracer
        self.counts: Dict[str, int] = {}
        self.fired: Dict[str, int] = {}

    @classmethod
    def from_specs(cls, specs: Sequence[str], seed: int = 0,
                   tracer=NULL_TRACER) -> "FaultInjector":
        """Build from CLI specs ``site:mode[:selector]``.

        The selector is either a probability (``0.25``) or exact
        invocation indices (``@2`` / ``@2,5``); omitted means every
        invocation. Example: ``--inject ckpt_write:exception:@1``.
        """
        faults: Dict[str, SiteFault] = {}
        for spec in specs:
            parts = spec.split(":")
            if len(parts) not in (2, 3):
                raise ValueError(f"bad --inject spec {spec!r} "
                                 "(want site:mode[:selector])")
            site, mode = parts[0], parts[1]
            p, at = 1.0, ()
            if len(parts) == 3:
                sel = parts[2]
                if sel.startswith("@"):
                    at = tuple(int(x) for x in sel[1:].split(","))
                else:
                    p = float(sel)
            faults[site] = SiteFault(mode=mode, p=p, at=at)
        return cls(faults, seed=seed, tracer=tracer)

    def armed(self, site: str) -> bool:
        """Whether ``site`` has a fault armed."""
        return site in self.faults

    def fire(self, site: str) -> Optional[str]:
        """Advance ``site``'s counter; trigger the armed mode if due."""
        n = self.counts.get(site, 0)
        self.counts[site] = n + 1
        fault = self.faults.get(site)
        if fault is None:
            return None
        if fault.at:
            hit = n in fault.at
        else:
            rng = np.random.default_rng(
                [self.seed & 0xFFFFFFFF, zlib.crc32(site.encode()), n])
            hit = bool(rng.random() < fault.p)
        if not hit:
            return None
        self.fired[site] = self.fired.get(site, 0) + 1
        self.tracer.instant("inject", site=site, mode=fault.mode,
                            invocation=n)
        return _MODES[fault.mode].trigger(site, n, fault)


def with_retries(fn: Callable, *, site: str, attempts: int = 3,
                 injector: Optional[FaultInjector] = None,
                 tracer=NULL_TRACER, backoff_s: float = 0.01,
                 retries: Optional[Dict[str, int]] = None):
    """Call ``fn`` with bounded retry-with-backoff, span-instrumented.

    The injector (when given) fires at each attempt's entry — i.e.
    before ``fn`` runs, so retried work is replayed from a clean slate.
    Each re-attempt is wrapped in a ``retry`` span carrying the site,
    attempt index and the exception class that forced it, and counted
    into ``retries[site]``. The last exception propagates once
    ``attempts`` is exhausted. ``attempts=1`` degenerates to a plain
    call (used for sites where a mid-flight failure is not replayable,
    e.g. dispatch with donated buffers when no injector is armed).
    """
    try:
        if injector is not None:
            injector.fire(site)
        return fn()
    except Exception as exc:  # noqa: BLE001 - bounded retry seam
        last = exc
    for attempt in range(1, attempts):
        if retries is not None:
            retries[site] = retries.get(site, 0) + 1
        with tracer.span("retry", site=site, attempt=attempt,
                         error=type(last).__name__):
            time.sleep(backoff_s * (2 ** (attempt - 1)))
            try:
                if injector is not None:
                    injector.fire(site)
                return fn()
            except Exception as exc:  # noqa: BLE001 - bounded retry seam
                last = exc
    raise last
