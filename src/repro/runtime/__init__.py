"""Host runtime: faults, desync, chaos injection, sharding rules.

The scenario + resilience layer wrapped around the device engines:

- `repro.runtime.fault` — stochastic client availability (`FaultModel`:
  dropout / stragglers / hard failures with repair) and planned elastic
  membership (`ElasticSchedule`); both feed `combined_mask` into the
  control traces the engines scan over.
- `repro.runtime.desync` — the synchronization-failure axis
  (`DesyncModel`): stale round seeds with lag d (a lagging client's
  scalar rides z_{t−d}) and fractional timing/phase misalignment
  entering `ota.superpose` as a per-client attenuation; plus the
  d-symbol frame-collapse row for the conventional-OTA baseline.
- `repro.runtime.inject` — deterministic seeded chaos (`FaultInjector`):
  exception / delay / torn-write faults at named host sites
  (chunk_prep, dispatch, ckpt_snapshot, ckpt_write) with the bounded
  `with_retries` recovery wrapper, span-instrumented via `repro.obs`.
- `repro.runtime.sharding` — param/activation PartitionSpec rules for
  the client mesh (see module docstring).

Everything here is host-side and structurally neutral: with no fault
model, no desync model and no injector armed, the engines trace the
bit-exact historical program.
"""
from repro.runtime.desync import DesyncModel
from repro.runtime.fault import ElasticSchedule, FaultModel, combined_mask
from repro.runtime.inject import (FaultInjector, InjectedFault, SiteFault,
                                  with_retries)

__all__ = [
    "DesyncModel", "ElasticSchedule", "FaultModel", "combined_mask",
    "FaultInjector", "InjectedFault", "SiteFault", "with_retries",
]
