"""Client desynchronization: stale round seeds + fractional misalignment.

The abstract's third robustness claim is that pAirZero "alleviates the
strict synchronization requirements that plague conventional OTA
methods". This module turns that sentence into a testable scenario axis
with two failure modes, both seeded and bit-reproducible:

1. **Stale rounds (compute stragglers).** A lagging client never saw the
   round-t seed broadcast; the scalar it transmits was computed against
   the perturbation of round t−d, so its contribution to the
   superposition points along z_{t−d} instead of z_t. Because the
   payload is ONE scalar, the server-side decode is unchanged — the
   stale client contributes bounded off-axis noise rather than
   corrupting a d-dimensional frame. Per round, a shared lag d_t is
   drawn in [1, max_lag] and each client goes stale with probability
   ``fraction`` (so one extra dual forward per step covers every stale
   client, not max_lag of them).

2. **Fractional timing / phase misalignment.** A client whose sampling
   clock is skewed by a fraction of a symbol superposes with amplitude
   cos θ_k instead of 1. The skew is a PERSISTENT per-device property
   (drawn once per trace, not per round). For pAirZero's single-symbol
   payload this is a mild, constant per-client attenuation entering
   :func:`repro.core.ota.superpose` alongside the realized CSI gains.
   For a conventional d-symbol analog OTA frame the same skew
   ACCUMULATES across the frame: the coordinate riding symbol slot k
   combines with gain cos(kθ) (:func:`conventional_frame`), so most of
   the d-dimensional payload is persistently annihilated or
   sign-flipped — the mean coherent gain collapses along the Dirichlet
   kernel |sin(nθ/2)/(n sin(θ/2))| and the lost energy reappears as
   inter-symbol interference — which is what
   ``benchmarks/fig_desync.py`` measures against the FO baseline.

Contract (mirrors `repro.byzantine`): when a :class:`DesyncModel` is
active, `engine.build_trace` ships four extra ctl rows —
``dsync_seed`` [R] u32 (the lagged round seed), ``dsync_stale`` [R,K],
``dsync_a`` [R,K] (scalar-payload alignment cos θ) and ``dsync_frame``
[R,K] (d-symbol frame gain, stale clients zeroed). When inactive the
rows are absent and every consumer traces the bit-exact historical
program (`ctl.get(...)` → None everywhere).

Host draws use ``np.random.default_rng([seed, _TRACE_TAG, t])`` — one
generator per round, so traces are invariant to chunking and resume.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import numpy as np

PyTree = Any

# host-side rng stream tags (keep distinct from 0x5EED / 0xB52 / 0xB52C0 /
# 0x51B5 / 0xC4A7 used by noise, byzantine, sub-slots and channels)
_TRACE_TAG = 0xD5CA1
# persistent per-client clock-skew draw (round-independent)
_SKEW_TAG = 0xD5CA2
# jit-side fold_in tag for the conventional-frame ICI noise
DESYNC_ICI_TAG = 0xD51C


@dataclasses.dataclass(frozen=True)
class DesyncModel:
    """Seeded per-round, per-client synchronization-state trace.

    fraction: probability a client-round is stale (rides z_{t-d}).
    max_lag: the shared per-round lag d_t is drawn uniform in [1, max_lag].
    phase_std: std of the persistent per-client clock-skew phase error
        θ_k (radians), drawn once per trace.
    frame_symbols: symbols per uplink frame for the *conventional* d-dim
        baseline row (1 ≡ pAirZero's scalar payload, where cos θ is the
        whole story).
    seed: host rng stream seed.
    """

    fraction: float = 0.0
    max_lag: int = 4
    phase_std: float = 0.0
    frame_symbols: int = 1
    seed: int = 0

    def __post_init__(self):
        """Validate the scenario parameters."""
        if not 0.0 <= self.fraction <= 1.0:
            raise ValueError(f"desync fraction must be in [0, 1], got "
                             f"{self.fraction}")
        if self.max_lag < 1:
            raise ValueError(f"desync max_lag must be >= 1, got "
                             f"{self.max_lag}")
        if self.phase_std < 0.0:
            raise ValueError(f"desync phase_std must be >= 0, got "
                             f"{self.phase_std}")
        if self.frame_symbols < 1:
            raise ValueError(f"desync frame_symbols must be >= 1, got "
                             f"{self.frame_symbols}")

    @classmethod
    def from_config(cls, cfg) -> "DesyncModel":
        """Build from a ``configs.base.DesyncConfig``."""
        return cls(fraction=cfg.fraction, max_lag=cfg.max_lag,
                   phase_std=cfg.phase_std,
                   frame_symbols=cfg.frame_symbols, seed=cfg.seed)

    @property
    def active(self) -> bool:
        """Whether the scenario perturbs anything at all."""
        return self.fraction > 0.0 or self.phase_std > 0.0

    def sync_trace(self, t0: int, t1: int, n_clients: int
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                              np.ndarray]:
        """Draw the sync state for rounds [t0, t1).

        Returns ``(stale [R,K] f32, lag [R] i64, align [R,K] f32,
        frame [R,K] f32)``. Stale is forced to 0 for rounds t < d_t
        (there is no round t−d to be stale against). The phase error
        θ_k is a PERSISTENT per-client clock skew (a device's
        sampling-clock offset is a calibration property, not per-round
        jitter) drawn once from the round-independent ``_SKEW_TAG``
        stream — per-round i.i.d. phase errors would average out over
        training and hide the conventional frame's structural collapse.
        The frame row already folds the stale mask in: a stale client's
        d-dim frame carries an old round's payload, i.e. zero useful
        signal.
        """
        rounds = t1 - t0
        stale = np.zeros((rounds, n_clients), dtype=np.float32)
        lag = np.zeros(rounds, dtype=np.int64)
        align = np.ones((rounds, n_clients), dtype=np.float32)
        frame = np.ones((rounds, n_clients), dtype=np.float32)
        theta = np.random.default_rng(
            [self.seed & 0xFFFFFFFF, _SKEW_TAG]).normal(
            0.0, 1.0, n_clients) * self.phase_std
        cos_theta = np.cos(theta).astype(np.float32)
        gain = frame_gain(theta, self.frame_symbols)
        for i, t in enumerate(range(t0, t1)):
            rng = np.random.default_rng(
                [self.seed & 0xFFFFFFFF, _TRACE_TAG, t])
            d = int(rng.integers(1, self.max_lag + 1))
            lag[i] = d
            s = (rng.random(n_clients) < self.fraction) & (t >= d)
            stale[i] = s.astype(np.float32)
            align[i] = cos_theta
            frame[i] = (gain * (1.0 - stale[i])).astype(np.float32)
        return stale, lag, align, frame


def frame_gain(theta: np.ndarray, n: int) -> np.ndarray:
    """Coherent gain of an n-symbol analog frame under per-symbol phase θ.

    The Dirichlet kernel |sin(nθ/2) / (n sin(θ/2))|: 1 at θ=0, and for
    large n collapsing rapidly — the d-dimensional conventional OTA
    payload loses its coherent combining gain long before the scalar
    payload's cos θ notices the misalignment.
    """
    th = np.asarray(theta, dtype=np.float64)
    half = th / 2.0
    num = np.sin(n * half)
    den = n * np.sin(half)
    out = np.where(np.abs(den) < 1e-12, 1.0,
                   num / np.where(np.abs(den) < 1e-12, 1.0, den))
    return np.abs(out)


def control_rows(model: DesyncModel, base_seed: int, t0: int, t1: int,
                 n_clients: int) -> Tuple[Dict[str, np.ndarray],
                                          np.ndarray]:
    """Host ctl rows for rounds [t0, t1) plus the raw stale matrix.

    ``dsync_seed`` is the *lagged* round seed zo.round_seed(base, t−d_t)
    (clamped at 0) — jit-side, a stale client's dual forward regenerates
    z_{t−d} from it exactly as the in-sync clients regenerate z_t.
    """
    from repro.core import zo  # local: keep numpy-only callers jax-free

    stale, lag, align, frame = model.sync_trace(t0, t1, n_clients)
    ts = np.arange(t0, t1, dtype=np.int64)
    src = np.maximum(ts - lag, 0).astype(np.uint32)
    seeds = np.asarray(zo.round_seed(base_seed, src), dtype=np.uint32)
    rows = {
        "dsync_seed": seeds,
        "dsync_stale": stale,
        "dsync_a": align,
        "dsync_frame": frame,
    }
    return rows, stale


def resolve(pz) -> Optional[DesyncModel]:
    """PairZeroConfig -> active DesyncModel, or None (historical program)."""
    cfg = getattr(pz, "desync", None)
    if cfg is None:
        return None
    model = DesyncModel.from_config(cfg)
    return model if model.active else None


def stale_payload(p_fresh, p_stale, ctl, offset=None):
    """Jit-side per-client select between fresh and stale projections.

    With ``offset`` (mesh shard), the full-[K] ``dsync_stale`` row is
    sliced at the shard's client offset so mesh and single-device
    programs see identical values.
    """
    import jax
    import jax.numpy as jnp

    stale = ctl["dsync_stale"].astype(p_fresh.dtype)
    if offset is not None:
        stale = jax.lax.dynamic_slice_in_dim(
            stale, offset, p_fresh.shape[-1], axis=-1)
    return jnp.where(stale > 0, p_stale, p_fresh)


def conventional_frame(grads: PyTree, ctl, n: int) -> PyTree:
    """Per-coordinate coherent gain of a misaligned d-dim frame (FO).

    A conventional analog OTA payload occupies an n-symbol frame, and a
    client whose timing/oscillator is off by θ sees that error
    *accumulate* across the frame: the coordinate riding symbol k
    combines with gain cos(kθ), recovered jit-side from the shipped
    ``dsync_a`` = cos θ row via the Chebyshev identity
    cos(kθ) = T_k(cos θ) (cos is even, so the sign of θ is irrelevant).
    Averaged over clients with independent θ ~ N(0, σ²) the late-frame
    coordinates random-phase out (E[cos kθ] = e^{−k²σ²/2}) — the server
    decodes a gradient whose coordinates beyond the first few symbol
    slots are annihilated, while others arrive sign-flipped. This is the
    structural collapse a single-symbol scalar payload (k = 0, gain
    cos θ) is immune to. Stale clients carry an old round's frame — zero
    useful signal — so they are dropped from the combining sum while the
    server still inverts by the full surviving count.

    Coordinates map to symbol slots in flattened leaf order with a
    global offset, so the gain profile tiles every ``n`` coordinates.
    """
    import jax
    import jax.numpy as jnp

    mask = ctl["mask"]
    theta = jnp.arccos(jnp.clip(ctl["dsync_a"], -1.0, 1.0))     # [K]
    w = mask * (1.0 - ctl["dsync_stale"])                       # [K]
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    out = []
    off = 0
    for leaf in leaves:
        k = (off + jnp.arange(leaf.size)) % n                   # [d_leaf]
        gain = (jnp.cos(jnp.outer(k.astype(theta.dtype), theta))
                @ w) / denom                                    # [d_leaf]
        out.append(leaf * gain.reshape(leaf.shape).astype(leaf.dtype))
        off += leaf.size
    return jax.tree_util.tree_unflatten(treedef, out)


def conventional_ici(grads: PyTree, ctl, noise_key,
                     ref: Optional[PyTree] = None) -> PyTree:
    """Inter-symbol interference a misaligned d-dim frame injects (FO).

    A conventional analog OTA server decodes the d-dimensional gradient
    frame by inverting the *nominal* coherent gain; the energy the
    misaligned clients lose (1 − a²) does not vanish — it lands across
    the frame as interference. Modeled as per-leaf Gaussian noise scaled
    by the misaligned energy fraction times the leaf's RMS, keyed off
    the round's noise_bits so it is reproducible and engine-invariant.
    ``ref`` supplies the RMS reference (the *transmitted* gradient);
    interference energy tracks what the clients radiated, not the
    attenuated decode it lands on.
    """
    import jax
    import jax.numpy as jnp

    mask = ctl["mask"]
    a = ctl["dsync_frame"]
    scale = (jnp.sqrt(jnp.sum(mask * (1.0 - a * a)))
             / jnp.maximum(jnp.sum(mask), 1.0))
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    refs = jax.tree_util.tree_leaves(ref) if ref is not None else leaves
    keys = jax.random.split(
        jax.random.fold_in(noise_key, DESYNC_ICI_TAG), len(leaves))
    noisy = []
    for leaf, r, key in zip(leaves, refs, keys):
        rms = jnp.sqrt(jnp.mean(jnp.square(r)) + 1e-12)
        noisy.append(leaf + (scale * rms).astype(leaf.dtype)
                     * jax.random.normal(key, leaf.shape, leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, noisy)
