"""Privacy subsystem: adversary, attacks, and the empirical DP audit.

The paper's third bird taken seriously: instead of only *asserting* the
(ε, δ)-DP guarantee analytically (core/dp.py, Lemma 1 / Eq. 16–17), this
package simulates the adversary and measures it — the third first-class
registry subsystem next to Transports (repro.core.transport) and
ChannelModels (repro.channel):

  adversary   the eavesdropper observation model. `Adversary.observe()`
              delegates to each Transport's `observe()` spec and rides the
              engines' metrics stream, so both executors capture — device-
              resident, bit-identically — exactly what an over-the-air
              listener sees: the superposed noisy scalar (analog/sign),
              per-slot quantized payloads (digital/smart_digital), raw
              gradients (fo).
  attacks     registry of attacks. Passive reconstruction: `dlg`
              (jit-compiled DLG-style gradient inversion against
              raw-gradient uplinks) and `seed_replay` (the ZO threat:
              replay the public round seed, estimate the projection
              through the Eq.-16 noise). Active: `steering` scores what a
              Byzantine cohort (repro.byzantine) CHANGES — trajectory
              displacement and defense gap recovery, the quantity the
              fig_robustness gate thresholds.
  audit       paired-trace canary hypothesis testing → a Clopper–Pearson
              ε̂ lower bound per run, checked against the analytic
              accountant (`dp.epsilon_for_budget`): ε̂ ≤ ε, always, on
              every DP transport × channel × power schedule.
  hooks       `AttackHook` — RoundHook that stacks the captured
              observations for post-hoc attacks/audits.

See README "Privacy & attacks" and benchmarks/fig_privacy.py for the
privacy-vs-utility sweep across the transport × channel grid.
"""
from repro.privacy.adversary import OBS_PREFIX, Adversary
from repro.privacy.attacks import (Attack, GradientInversion,
                                   SeedReplayAttack, TrajectorySteering,
                                   available, client_gradient, get,
                                   reconstruction_error, register,
                                   zo_gradient_estimate)
from repro.privacy.audit import (AuditResult, audit_transport,
                                 clopper_pearson_upper,
                                 paired_trace_statistics)
from repro.privacy.hooks import AttackHook

__all__ = [
    "OBS_PREFIX", "Adversary", "Attack", "AttackHook", "AuditResult",
    "GradientInversion", "SeedReplayAttack", "TrajectorySteering",
    "audit_transport",
    "available", "client_gradient", "clopper_pearson_upper", "get",
    "paired_trace_statistics", "reconstruction_error", "register",
    "zo_gradient_estimate",
]
