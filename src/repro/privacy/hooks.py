"""AttackHook: collect the adversary's observations from a live run.

Rides the existing `RoundHook` protocol (core/fedsim.py), so capture works
identically under both engines: the step emits `obs_*` metrics (the
Adversary's prefixed observation dict), the driver's flush path delivers
them per round in order, and this hook stacks them host-side alongside the
attack ground truth (the true per-client payloads `p_clients` and the
surviving-count `k_eff` the decode divided by). After `Experiment.run()`
the attacks (repro.privacy.attacks) and the benchmark consume
`hook.observations()` / `hook.payloads()` directly.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.core.fedsim import RoundHook
from repro.privacy.adversary import OBS_PREFIX


class AttackHook(RoundHook):
    """Per-round observation capture for post-hoc attacks and audits.

    `max_rounds` caps how many rounds are retained host-side — the OTA
    observations are scalars, but the FO uplink's obs_grad0 is a full [d]
    gradient per round, so an uncapped long run would hoard rounds × d
    floats for attacks that (today) only consume the first rounds. None
    keeps everything.
    """

    def __init__(self, prefix: str = OBS_PREFIX,
                 max_rounds: Optional[int] = None):
        self.prefix = prefix
        self.max_rounds = max_rounds
        self.rounds: List[int] = []
        self._obs: Dict[str, List[np.ndarray]] = {}
        self._payloads: List[np.ndarray] = []
        self._k_eff: List[float] = []

    def on_round(self, t: int, metrics: Dict[str, np.ndarray]) -> None:
        if self.max_rounds is not None and len(self.rounds) >= \
                self.max_rounds:
            return
        got = {k: v for k, v in metrics.items() if k.startswith(self.prefix)}
        if not got:
            return
        self.rounds.append(t)
        for k, v in got.items():
            self._obs.setdefault(k, []).append(np.asarray(v))
        if "p_clients" in metrics:
            self._payloads.append(np.asarray(metrics["p_clients"]))
        if "k_eff" in metrics:
            self._k_eff.append(float(metrics["k_eff"]))

    # -- the attacker's transcript ---------------------------------------
    def observations(self) -> Dict[str, np.ndarray]:
        """Stacked [T, ...] observation streams, keyed as captured."""
        return {k: np.stack(v) for k, v in self._obs.items()}

    def payloads(self) -> Optional[np.ndarray]:
        """[T, K] true per-client projections (attack ground truth)."""
        return np.stack(self._payloads) if self._payloads else None

    def k_eff(self) -> Optional[np.ndarray]:
        """[T] surviving-client counts the decode inverted by."""
        return np.asarray(self._k_eff) if self._k_eff else None
