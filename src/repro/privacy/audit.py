"""Empirical DP audit: a Clopper–Pearson ε̂ lower bound per run.

The analytic accountant (core/dp.py) *prices* each round from Lemma 1 /
Eq. 16 and promises (ε, δ)-DP; this module *measures* it. The audit plays
the canonical membership game against the mechanism exactly as executed:

  1. a canary client either transmits the worst-case payload the clip
     admits (`Transport.canary_payload`: ±γ for analog, a ±1 ballot for
     sign) — canary IN — or stays silent — canary OUT;
  2. both arms of each paired trace go through the *actual* observation
     path (the transport's own `observe()` — the same jit code the
     engines' capture runs, same key ⇒ coupled noise) under the run's
     realized power schedule c(t), σ(t), N0 — so channels, power-control
     schemes, and user-registered mechanisms are audited through what
     they actually radiate, not through an idealized Gaussian;
  3. the strongest adversary allowed by the threat model — it knows the
     schedule — aggregates the per-round log-likelihood ratios over the
     whole horizon into one test statistic per trial;
  4. acceptance rates over `trials` paired traces become exact
     Clopper–Pearson upper confidence bounds on the FPR/FNR, and

        ε̂ = max_τ  max( log((1 − δ − β̄(τ)) / ᾱ(τ)),
                         log((1 − δ − ᾱ(τ)) / β̄(τ)) )

     (Kairouz et al.'s DP hypothesis-testing region, thresholds
     Bonferroni-corrected) is a valid ε lower bound at the audit
     confidence.

The subsystem's contract — asserted per transport × channel × scheme in
tests/test_privacy.py — is ε̂ ≤ `dp.epsilon_for_budget(spent, δ)`: the
empirical leak never exceeds what the accountant charged. The audit shifts
the observation by c·canary while the accountant prices √2·c·γ per round,
so a healthy mechanism passes with margin; a broken schedule (noise
under-provisioned, cost mis-priced) fails loudly.

Pure numpy host-side statistics + one jitted mechanism simulation; no
scipy (Clopper–Pearson via bisection on the exact binomial log-CDF).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dp as dp_mod

# ---------------------------------------------------------------------------
# Exact binomial tails (no scipy)
# ---------------------------------------------------------------------------


def _log_comb(n: int, k: int) -> np.ndarray:
    """[k+1] log C(n, i) for i = 0..k — one vectorized log-factorial table
    (scipy is not a declared dependency)."""
    logfact = np.concatenate(
        ([0.0], np.cumsum(np.log(np.arange(1, n + 1, dtype=np.float64)))))
    i = np.arange(k + 1)
    return logfact[n] - logfact[i] - logfact[n - i]


def binom_logcdf(k: int, n: int, p: float) -> float:
    """log P[Bin(n, p) ≤ k], exact via log-pmf + logsumexp."""
    if k >= n or p <= 0.0:
        return 0.0
    if p >= 1.0:
        return -math.inf
    i = np.arange(k + 1, dtype=np.float64)
    logpmf = _log_comb(n, k) + i * math.log(p) + (n - i) * math.log1p(-p)
    m = logpmf.max()
    return float(m + np.log(np.sum(np.exp(logpmf - m))))


def clopper_pearson_upper(k: int, n: int, confidence: float = 0.95) -> float:
    """Exact upper confidence bound on a binomial proportion: the largest p
    still consistent with observing ≤ k successes in n trials."""
    if n <= 0:
        return 1.0
    if k >= n:
        return 1.0
    alpha = 1.0 - confidence
    log_alpha = math.log(alpha)
    # only log(p)/log1p(-p) depend on p — hoist everything else out of
    # the bisection (the audit takes two bounds per threshold per cell)
    logcomb = _log_comb(n, k)
    i = np.arange(k + 1, dtype=np.float64)

    def logcdf(p: float) -> float:
        logpmf = logcomb + i * math.log(p) + (n - i) * math.log1p(-p)
        m = logpmf.max()
        return float(m + np.log(np.sum(np.exp(logpmf - m))))

    lo, hi = k / n, 1.0
    for _ in range(60):
        mid = 0.5 * (lo + hi)
        if logcdf(mid) > log_alpha:
            lo = mid
        else:
            hi = mid
    return hi


# ---------------------------------------------------------------------------
# The audit
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AuditResult:
    """One audited run: the empirical bound vs the analytic ceiling."""
    eps_hat: float              # Clopper–Pearson empirical lower bound
    eps_analytic: float         # dp.epsilon_for_budget(spent, delta)
    spent: float                # Σ_t accountant cost over audited rounds
    delta: float
    trials: int
    confidence: float
    rounds: int                 # audited rounds (c > 0 only carry signal)
    fpr: float = 0.0            # at the best threshold
    fnr: float = 0.0
    threshold: float = 0.0
    meta: dict = field(default_factory=dict)

    @property
    def dominated(self) -> bool:
        """The subsystem's contract: empirical never exceeds analytic."""
        return self.eps_hat <= self.eps_analytic + 1e-9

    def to_dict(self) -> dict:
        return {"eps_hat": self.eps_hat, "eps_analytic": self.eps_analytic,
                "spent": self.spent, "delta": self.delta,
                "trials": self.trials, "confidence": self.confidence,
                "rounds": self.rounds, "fpr": self.fpr, "fnr": self.fnr,
                "dominated": self.dominated, **self.meta}


def _eps_from_rates(fp: int, fn: int, n: int, delta: float,
                    confidence: float) -> tuple:
    """(ε̂, ᾱ, β̄) at one threshold from raw FP/FN counts."""
    a_hi = clopper_pearson_upper(fp, n, confidence)
    b_hi = clopper_pearson_upper(fn, n, confidence)
    best = 0.0
    for num, den in ((1.0 - delta - b_hi, a_hi),
                     (1.0 - delta - a_hi, b_hi)):
        if num > 0.0 and den > 0.0 and num > den:
            best = max(best, math.log(num / den))
    return best, a_hi, b_hi


def paired_trace_statistics(transport, schedule, canary: float, *,
                            rounds: int, n_clients: int, trials: int,
                            seed: int = 0xA0D17) -> tuple:
    """(stat_in [trials], stat_out [trials]) — composed LLR statistics from
    paired canary-in/canary-out traces through the transport's OWN
    observation model (`Transport.observe` — the same jit code the
    engines' capture runs, so the audited mechanism is the transmitted
    one, not an idealized stand-in; a user-registered DP transport is
    audited through whatever its observe() actually radiates).

    One jitted vmap over (trials × rounds): each (i, t) cell draws the
    mechanism noise from fold_in(fold_in(key, i), t) and observes both
    arms with the SAME key (paired traces — coupled noise, exact marginal
    distributions). Rounds with c = 0 are silent and carry no signal.

    The decision statistic is the schedule-aware Gaussian LLR — optimal
    for the OTA superposition; for any other observe() it is merely *a*
    statistic, and the Clopper–Pearson construction keeps ε̂ a valid
    lower bound regardless (only power, not validity, depends on it).
    """
    if "y" not in transport.observation_spec(n_clients):
        raise ValueError(
            f"transport {transport.name!r} exposes no scalar 'y' "
            "observation stream — the paired-trace audit needs one "
            "(override Transport.observe/observation_spec)")
    c = jnp.asarray(np.asarray(schedule.c[:rounds]), jnp.float32)
    sigma = jnp.asarray(np.asarray(schedule.sigma[:rounds]), jnp.float32)
    n0 = jnp.float32(schedule.n0)
    k = n_clients
    p_in = jnp.zeros((k,), jnp.float32).at[0].set(jnp.float32(canary))
    p_out = jnp.zeros((k,), jnp.float32)
    ones = jnp.ones((k,), jnp.float32)
    # known-schedule LLR weights: shift s_t = c_t·canary, noise var m_t²
    s = c * jnp.float32(canary)
    m2 = c * c * jnp.sum(sigma * sigma, axis=1) + n0
    active = (c > 0).astype(jnp.float32)

    @jax.jit
    def stats(base):
        def per_round(key_t, c_t, sig_t, s_t, m2_t, act_t):
            ctl = {"c": c_t, "sigma": sig_t, "n0": n0, "mask": ones}
            y_in = transport.observe(p_in, ctl, key_t)["y"]
            y_out = transport.observe(p_out, ctl, key_t)["y"]
            llr = lambda y: s_t * (y - 0.5 * s_t) / m2_t
            return act_t * llr(y_in), act_t * llr(y_out)

        def per_trial(i):
            keys = jax.vmap(
                lambda t: jax.random.fold_in(jax.random.fold_in(base, i), t)
            )(jnp.arange(c.shape[0]))
            li, lo = jax.vmap(per_round)(keys, c, sigma, s, m2, active)
            return jnp.sum(li), jnp.sum(lo)

        return jax.vmap(per_trial)(jnp.arange(trials))

    stat_in, stat_out = stats(jax.random.key(seed))
    return np.asarray(stat_in, np.float64), np.asarray(stat_out, np.float64)


def audit_transport(transport, schedule, pz, *, rounds: Optional[int] = None,
                    trials: int = 2000, confidence: float = 0.95,
                    thresholds: int = 9, seed: int = 0xA0D17,
                    spent: Optional[float] = None
                    ) -> AuditResult:
    """Audit one (transport, realized schedule) pair; ε̂ vs the analytic ε.

    `rounds` limits the audit to the horizon actually executed (a privacy
    stop means later rounds never transmitted — they cost nothing and leak
    nothing). The threshold grid is Bonferroni-corrected, so ε̂ stays a
    valid lower bound at `confidence` despite the post-hoc max.

    `spent` feeds the analytic side directly from a run's accountant
    ledger (`RunResult.privacy_spent` / `privacy_spent_per_round[-1]`) so
    the audit and the trilemma ledger read the same numbers; None keeps
    the standalone behaviour of re-deriving the Eq.-16 sum from the
    schedule (identical for a clean full-horizon run — the accountant
    charges exactly these per-round costs).
    """
    rounds = int(schedule.c.shape[0] if rounds is None else rounds)
    canary = transport.canary_payload(pz)
    delta = pz.dp.delta
    if spent is None:
        charged = transport.charges_privacy(schedule, pz)
        spent = float(np.sum(
            transport.round_dp_costs(schedule, 0, rounds, pz))) \
            if charged else 0.0
    else:
        spent = float(spent)
    if canary is None:
        # no DP mechanism → nothing to audit; ε̂ = ∞ is the honest verdict
        # for an uplink that exposes payloads exactly (digital/fo)
        return AuditResult(eps_hat=math.inf, eps_analytic=math.inf,
                           spent=spent, delta=delta, trials=0,
                           confidence=confidence, rounds=rounds,
                           meta={"transport": transport.name,
                                 "auditable": False})

    stat_in, stat_out = paired_trace_statistics(
        transport, schedule, canary, rounds=rounds,
        n_clients=pz.n_clients, trials=trials, seed=seed)

    # threshold grid: Bayes point 0 plus pooled quantiles, Bonferroni over
    # the grid so the max stays a valid bound. TWO Clopper–Pearson bounds
    # (FPR and FNR) are taken jointly per threshold, so the error budget
    # splits over 2·|grid| events.
    pooled = np.concatenate([stat_in, stat_out])
    grid = np.unique(np.concatenate(
        [[0.0], np.quantile(pooled, np.linspace(0.05, 0.95, thresholds))]))
    conf_each = 1.0 - (1.0 - confidence) / (2 * len(grid))

    best = (0.0, 0.0, 0.0, 0.0)     # (eps, tau, fpr, fnr)
    n = trials
    for tau in grid:
        fp = int(np.sum(stat_out > tau))     # out, flagged in
        fn = int(np.sum(stat_in <= tau))     # in, flagged out
        eps, a_hi, b_hi = _eps_from_rates(fp, fn, n, delta, conf_each)
        if eps > best[0]:
            best = (eps, float(tau), a_hi, b_hi)

    return AuditResult(
        eps_hat=best[0],
        eps_analytic=dp_mod.epsilon_for_budget(spent, delta),
        spent=spent, delta=delta, trials=trials, confidence=confidence,
        rounds=rounds, fpr=best[2], fnr=best[3], threshold=best[1],
        meta={"transport": transport.name, "auditable": True,
              "canary": canary})
