"""Attack registry: gradient inversion (DLG) and seed-replay reconstruction.

Attacks are frozen dataclasses registered by name (mirroring the Transport /
ChannelModel designs): `get("dlg")(steps=300).run(...)`. Each consumes the
observations an `Adversary` captured through a run (repro.privacy.hooks) and
produces reconstruction metrics — the empirical counterpart of the paper's
privacy claim:

  seed_replay  the ZO-specific threat. The round seed is *broadcast in the
               clear* (that is the whole communication trick), so an
               eavesdropper replays z(seed) exactly and only needs the
               scalar to own the full d-dimensional update. Against the
               digital uplinks the scalar arrives per client and exact (to
               quantizer resolution) — reconstruction succeeds. Against
               pAirZero's OTA superposition the listener gets one noisy
               SUM: the best unbiased estimate of the projection is
               y/(K_eff·c), corrupted by the Eq.-16 effective noise m/(K·c)
               that the power control keeps large enough for (ε, δ)-DP.

  dlg          DLG-style iterative gradient inversion [Zhu et al. 2019]
               against the FO baseline's raw-gradient uplink (and any
               reconstructed ZO gradient estimate): jit-compiled gradient
               matching that optimizes a soft token distribution until its
               induced gradient matches the observed one, then reads the
               tokens back off the argmax. Labels/mask are assumed known
               (the iDLG simplification); the paper-relevant signal is the
               *gap* between transports, not attack optimality.

`client_gradient` / `reconstruction_error` are the shared evaluation
oracle: every transport's observation is mapped to a gradient estimate ĝ
and scored as ‖ĝ − g‖/‖g‖ against the victim client's true first-order
gradient — one number comparable across fo / digital / smart_digital /
analog / sign (benchmarks/fig_privacy.py plots it against ε̂ and utility).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Type

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree

from repro.core import zo
from repro.optim import fo as fo_opt

PyTree = Any

# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Type["Attack"]] = {}


def register(name: str):
    """Class decorator: `@register("dlg")` adds an Attack under `name`."""
    def deco(cls: Type["Attack"]) -> Type["Attack"]:
        cls.name = name
        _REGISTRY[name] = cls
        return cls
    return deco


def available() -> tuple:
    return tuple(sorted(_REGISTRY))


def get(name: str) -> Type["Attack"]:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown attack {name!r} "
                         f"(registered: {available()})") from None


@dataclass(frozen=True)
class Attack:
    """One reconstruction attack. Subclass + `@register(name)` to add one.

    Frozen dataclass: every knob that changes the attack computation is
    part of equality/hash, so jitted attack programs cache per-config."""

    #: registry name (set by @register)
    name = "?"

    def run(self, **kwargs) -> Dict[str, Any]:
        raise NotImplementedError


# ---------------------------------------------------------------------------
# Shared evaluation oracle
# ---------------------------------------------------------------------------

def client_gradient(model_cfg, params: PyTree, batch: Dict,
                    client: int = 0) -> jnp.ndarray:
    """Flat f32 first-order gradient of ONE client's loss — the ground
    truth every reconstruction is scored against (and exactly what the FO
    uplink radiates for that client)."""
    from repro.core.pairzero import make_loss_fn
    loss_fn = make_loss_fn(model_cfg)
    g = jax.grad(lambda p: loss_fn(p, batch)[client])(params)
    return ravel_pytree(jax.tree_util.tree_map(
        lambda x: x.astype(jnp.float32), g))[0]


def zo_gradient_estimate(params: PyTree, seed, scalar) -> jnp.ndarray:
    """Seed-replay gradient estimate ĝ = p̃ · z(seed), flat f32.

    `seed` is the broadcast round seed (public); `scalar` the attacker's
    projection estimate. The z streams match training bitwise (same
    per-leaf hash as `zo.perturb`)."""
    z = zo.draw_z(params, jnp.asarray(seed).astype(jnp.uint32))
    flat = ravel_pytree(jax.tree_util.tree_map(
        lambda x: x.astype(jnp.float32), z))[0]
    return jnp.float32(scalar) * flat


def reconstruction_error(g_hat: jnp.ndarray, g_true: jnp.ndarray) -> float:
    """Relative gradient reconstruction error ‖ĝ − g‖ / ‖g‖ (0 = perfect
    inversion; ≈ √2 for an uncorrelated unit-scaled guess)."""
    g_hat = np.asarray(g_hat, dtype=np.float64)
    g_true = np.asarray(g_true, dtype=np.float64)
    denom = float(np.linalg.norm(g_true))
    return float(np.linalg.norm(g_hat - g_true)) / max(denom, 1e-30)


# ---------------------------------------------------------------------------
# Seed-replay scalar reconstruction (the ZO threat model)
# ---------------------------------------------------------------------------

@register("seed_replay")
@dataclass(frozen=True)
class SeedReplayAttack(Attack):
    """Estimate the transmitted projection from the uplink observation.

    The attacker knows everything broadcast or publicly scheduled: the
    round seeds, the schedule (c(t), K) and the channel statistics. Per
    round it inverts its observation to a scalar estimate p̃ and scores it
    against the true payload(s):

      OTA ("y" observations)      p̃ = y / (K_eff · c) — estimates only the
                                  *mean* projection, through the Eq.-16
                                  noise (per-client payloads unrecoverable);
      digital ("q" observations)  p̃_k = q_k per client, exact to quantizer
                                  resolution — each client individually
                                  exposed.
    """
    victim: int = 0     # client index scored by per-client metrics

    def run(self, observations: Dict[str, np.ndarray],
            payloads: np.ndarray, c: np.ndarray,
            k_eff: Optional[np.ndarray] = None) -> Dict[str, Any]:
        """Score scalar reconstruction over a captured horizon.

        observations: stacked AttackHook capture ({"obs_y": [T]} or
          {"obs_q": [T, K]}); payloads: the per-client payloads as
          TRANSMITTED [T, K] — run `Transport.transmitted` over the
          captured projections first (±1 ballots for sign, identity
          otherwise) so estimates are scored against the right ground
          truth; c: schedule gains [T]; k_eff: surviving counts [T].
        """
        payloads = np.asarray(payloads, dtype=np.float64)
        rounds, k = payloads.shape
        c = np.asarray(c, dtype=np.float64)[:rounds]
        k_eff = np.full(rounds, float(k)) if k_eff is None \
            else np.asarray(k_eff, dtype=np.float64)[:rounds]
        mean_true = payloads.mean(axis=1)
        out: Dict[str, Any] = {"rounds": rounds}

        if "obs_q" in observations:                  # digital: per client
            q = np.asarray(observations["obs_q"], dtype=np.float64)[:rounds]
            # unscheduled slots radiate nothing (masked to exactly 0) —
            # average over the k_eff clients that actually transmitted,
            # and score the victim only on rounds its slot was live (slot
            # occupancy is observable in a TDMA schedule). q == 0 is an
            # exact liveness sentinel: the 2^b−1-level dither grid spans
            # [−clip, +clip] with an even number of points, so a LIVE slot
            # can never quantize to exactly 0.0.
            est_mean = q.sum(axis=1) / np.maximum(k_eff, 1.0)
            live = q[:, self.victim] != 0.0
            err_v = q[live, self.victim] - payloads[live, self.victim]
            out["victim_rmse"] = float(np.sqrt(np.mean(err_v ** 2))) \
                if live.any() else float("inf")
            out["per_client_exposed"] = True
        elif "obs_y" in observations:                # OTA: noisy sum only
            y = np.asarray(observations["obs_y"], dtype=np.float64)[:rounds]
            active = c > 0
            est_mean = np.where(active, y / (k_eff * np.where(active, c, 1.0)),
                                0.0)
            # the victim is hidden in the superposition — best guess is the
            # (noisy) mean, so per-client exposure degenerates to crowd noise
            err_v = est_mean - payloads[:, self.victim]
            out["victim_rmse"] = float(np.sqrt(np.mean(err_v[active] ** 2))) \
                if active.any() else float("inf")
            out["per_client_exposed"] = False
        else:
            raise ValueError(f"no usable observation stream in "
                             f"{sorted(observations)} (want obs_y or obs_q)")

        err_m = est_mean - mean_true
        out["mean_rmse"] = float(np.sqrt(np.mean(err_m ** 2)))
        out["mean_corr"] = float(np.corrcoef(est_mean, mean_true)[0, 1]) \
            if rounds > 1 and np.std(est_mean) > 0 and np.std(mean_true) > 0 \
            else 0.0
        out["estimates"] = est_mean
        return out


# ---------------------------------------------------------------------------
# Trajectory steering (the ACTIVE threat model, repro.byzantine)
# ---------------------------------------------------------------------------

@register("steering")
@dataclass(frozen=True)
class TrajectorySteering(Attack):
    """Score an ACTIVE adversary by what it does to the training
    trajectory — the Byzantine counterpart of the passive reconstruction
    attacks above.

    Eavesdroppers are scored by what they LEARN; Byzantine cohorts
    (repro.byzantine behaviors) by what they CHANGE. Given matched-round
    loss series this computes the displacement the attack achieved and —
    when a defended series is supplied — the fraction of the utility gap
    the defense recovered, the exact quantity the robustness gate in
    benchmarks/fig_robustness.py thresholds:

      steering_rmse   per-round RMS displacement of the attacked
                      trajectory from the clean one;
      final_gap       mean clean-vs-attacked loss gap over the last
                      `tail` rounds (> 0 means the attack hurt);
      gap_recovery    (und − def) / (und − clean) on the tail means —
                      1 is a full repair, 0 no effect, < 0 worse than
                      undefended. None without a defended series or when
                      the attack did not move the tail.
    """
    tail: int = 10      # rounds averaged for final-gap statistics

    def run(self, clean, attacked, defended=None) -> Dict[str, Any]:
        """Score steering over matched-round loss series (lower=better)."""
        clean = np.asarray(clean, dtype=np.float64)
        attacked = np.asarray(attacked, dtype=np.float64)
        rounds = min(len(clean), len(attacked))
        if rounds == 0:
            raise ValueError("steering needs non-empty loss series")
        t = min(self.tail, rounds)
        clean, attacked = clean[:rounds], attacked[:rounds]
        gap = float(attacked[-t:].mean() - clean[-t:].mean())
        out: Dict[str, Any] = {
            "rounds": rounds,
            "steering_rmse": float(np.sqrt(np.mean(
                (attacked - clean) ** 2))),
            "final_gap": gap,
            "gap_recovery": None,
        }
        if defended is not None and abs(gap) > 1e-12:
            defended = np.asarray(defended, dtype=np.float64)[:rounds]
            out["gap_recovery"] = float(
                (attacked[-t:].mean() - defended[-t:].mean()) / gap)
        return out


# ---------------------------------------------------------------------------
# DLG-style gradient inversion (the FO / digital threat model)
# ---------------------------------------------------------------------------

@register("dlg")
@dataclass(frozen=True)
class GradientInversion(Attack):
    """Iterative gradient matching: recover the victim's tokens from an
    observed gradient.

    A dummy continuous input is optimized with Adam until the gradient it
    induces through the model matches the observation (`steps` fixed
    iterations under one `lax.scan` — the whole attack is a single jitted
    program, deterministic at fixed `seed`). Two search spaces:

      space="embed" (default)  dummy input embeddings X [b, S, D], cosine
        gradient matching [Geiping et al. 2020], tokens read back by
        nearest-embedding-row snap — the stronger variant on LMs;
      space="token"  dummy soft-token logits D [b, S, V], the soft input is
        softmax(D) @ W_embed and tokens are the final argmax — the
        original DLG [Zhu et al. 2019] parameterization.

    Targets and loss mask are assumed known (the iDLG simplification).
    """
    steps: int = 600
    lr: float = 0.02
    seed: int = 0
    space: str = "embed"        # embed | token
    objective: str = "cosine"   # cosine | l2

    def run(self, model_cfg, params: PyTree, g_star: jnp.ndarray,
            targets: np.ndarray, mask: np.ndarray,
            true_tokens: Optional[np.ndarray] = None) -> Dict[str, Any]:
        """Invert a flat observed gradient for one client's [b, S] batch."""
        if model_cfg.family != "dense":
            raise NotImplementedError(
                "gradient inversion drives the dense-transformer "
                f"embedding path; got family={model_cfg.family!r}")
        if self.space not in ("embed", "token"):
            raise ValueError(f"unknown search space: {self.space!r}")
        from repro.models import transformer as tf
        targets = jnp.asarray(targets)
        lmask = jnp.asarray(mask)
        b, s = targets.shape
        v = model_cfg.vocab_size
        g_star = jnp.asarray(g_star, jnp.float32)
        w_embed = params["embed"]["w"].astype(jnp.float32)

        def induced_gradient(x):
            def victim_loss(p):
                nll = tf.token_nll(p, model_cfg, tokens=None,
                                   targets=targets, mask=lmask,
                                   inputs_embeds=x.astype(
                                       p["embed"]["w"].dtype))
                return jnp.mean(nll)

            g = jax.grad(victim_loss)(params)
            return ravel_pytree(jax.tree_util.tree_map(
                lambda t: t.astype(jnp.float32), g))[0]

        def match_loss(dummy):
            x = jax.nn.softmax(dummy, axis=-1) @ w_embed \
                if self.space == "token" else dummy
            g = induced_gradient(x)
            if self.objective == "l2":
                diff = g - g_star
                return jnp.sum(diff * diff)
            cos = jnp.sum(g * g_star) / (
                jnp.linalg.norm(g) * jnp.linalg.norm(g_star) + 1e-12)
            return 1.0 - cos

        def read_tokens(dummy):
            if self.space == "token":
                return jnp.argmax(dummy, axis=-1)
            # nearest embedding row by cosine similarity
            xn = dummy / (jnp.linalg.norm(dummy, axis=-1,
                                          keepdims=True) + 1e-12)
            wn = w_embed / (jnp.linalg.norm(w_embed, axis=-1,
                                            keepdims=True) + 1e-12)
            return jnp.argmax(xn @ wn.T, axis=-1)

        opt = fo_opt.Adam(lr=self.lr)
        dim = v if self.space == "token" else model_cfg.d_model

        @jax.jit
        def attack(key):
            dummy0 = 0.02 * jax.random.normal(key, (b, s, dim), jnp.float32)

            def step(carry, _):
                dummy, state = carry
                val, grad = jax.value_and_grad(match_loss)(dummy)
                dummy, state = opt.update(dummy, grad, state)
                return (dummy, state), val

            (dummy, _), residuals = jax.lax.scan(
                step, (dummy0, opt.init(dummy0)), None, length=self.steps)
            return read_tokens(dummy), residuals

        tokens_hat, residuals = attack(jax.random.key(self.seed))
        out: Dict[str, Any] = {
            "tokens": np.asarray(tokens_hat),
            "residuals": np.asarray(residuals),
            "final_residual": float(residuals[-1]),
        }
        if true_tokens is not None:
            true_tokens = np.asarray(true_tokens)
            out["token_accuracy"] = float(
                np.mean(np.asarray(tokens_hat) == true_tokens))
            out["chance_accuracy"] = 1.0 / v
        return out
