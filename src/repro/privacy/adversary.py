"""Eavesdropper observation model: what an over-the-air listener records.

The threat model (paper Sec. IV-C's motivation): an honest-but-curious
listener at the receiver front-end — the base station itself, or anything
within radio range with the same channel knowledge — records the uplink
every round. What it sees is *transport-dependent*, and that difference IS
the trilemma's privacy axis:

  analog / sign OTA   one superposed noisy scalar per round (Eq. 4) — the
                      quantity Lemma 1 privatizes; individual clients are
                      never separable over the air,
  digital / smart_digital
                      every scheduled client's quantized payload decoded
                      individually (orthogonal slots have no crowd to
                      hide in),
  fo                  the attacked client's raw d-dimensional gradient —
                      the classic gradient-inversion surface.

`Adversary` is a frozen dataclass (hashable — it rides the memoized
`pairzero.make_zo_step` cache key): its `observe()` delegates to the round
Transport's own observation model (`Transport.observe`, called with the
SAME per-round key as the decode, so noise draws are bit-identical to the
signal the server actually inverted) and prefixes the keys so the capture
rides the engines' existing metrics stream. Both executors stack metrics
identically, which is what makes scan/loop observation capture bitwise
equal for free — and because `observe()` is pure and passive, capture
never perturbs the training trajectory (tests/test_privacy.py pins both).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import jax
import jax.numpy as jnp

#: metric-key prefix under which observations ride the engines' capture path
OBS_PREFIX = "obs_"


@dataclass(frozen=True)
class Adversary:
    """Over-the-air eavesdropper at the receiver front-end.

    This is the worst-case listener for privacy: exactly as capable as the
    base station itself (same front-end, same channel knowledge) — the
    vantage the DP analysis must survive and the one the empirical audit
    assumes. Weaker or differently-positioned listeners (extra thermal
    noise, near-client pre-superposition taps, colluding sets) are
    deliberately NOT modeled yet — see the ROADMAP privacy follow-ons —
    rather than half-modeled inconsistently across transports.
    """

    def observe(self, transport, p: jnp.ndarray,
                ctl: Dict[str, jnp.ndarray], key: jax.Array
                ) -> Dict[str, jnp.ndarray]:
        """Prefixed observation dict for one round's [K] payload vector."""
        obs = transport.observe(p, ctl, key)
        return {OBS_PREFIX + k: v for k, v in obs.items()}

    def observation_spec(self, transport, n_clients: int
                         ) -> Dict[str, jax.ShapeDtypeStruct]:
        """Abstract shapes of `observe()` (mesh out-specs, dry-run cells)."""
        return {OBS_PREFIX + k: v
                for k, v in transport.observation_spec(n_clients).items()}
