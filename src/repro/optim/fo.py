"""First-order optimizer baselines (raw JAX — no optax dependency).

These are the comparison points of the paper's Table II: FO-SGD (grads only),
FO-Adam (grads + 2 moments), and signSGD [Bernstein et al. 2018], the
element-wise 1-bit compressor the paper contrasts with its O(1) scheme.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclass(frozen=True)
class SGD:
    lr: float = 1e-3
    momentum: float = 0.0

    def init(self, params: PyTree) -> PyTree:
        if self.momentum == 0.0:
            return ()
        return jax.tree_util.tree_map(jnp.zeros_like, params)

    def update(self, params: PyTree, grads: PyTree, state: PyTree
               ) -> Tuple[PyTree, PyTree]:
        if self.momentum == 0.0:
            new = jax.tree_util.tree_map(
                lambda p, g: (p - self.lr * g.astype(p.dtype)).astype(p.dtype),
                params, grads)
            return new, ()
        vel = jax.tree_util.tree_map(
            lambda v, g: self.momentum * v + g.astype(v.dtype), state, grads)
        new = jax.tree_util.tree_map(
            lambda p, v: (p - self.lr * v).astype(p.dtype), params, vel)
        return new, vel


@dataclass(frozen=True)
class Adam:
    lr: float = 1e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8

    def init(self, params: PyTree) -> PyTree:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree_util.tree_map(zeros, params),
                "v": jax.tree_util.tree_map(zeros, params),
                "t": jnp.zeros((), jnp.int32)}

    def update(self, params: PyTree, grads: PyTree, state: PyTree
               ) -> Tuple[PyTree, PyTree]:
        t = state["t"] + 1
        m = jax.tree_util.tree_map(
            lambda m_, g: self.b1 * m_ + (1 - self.b1) * g.astype(jnp.float32),
            state["m"], grads)
        v = jax.tree_util.tree_map(
            lambda v_, g: self.b2 * v_
            + (1 - self.b2) * jnp.square(g.astype(jnp.float32)),
            state["v"], grads)
        bc1 = 1 - self.b1 ** t.astype(jnp.float32)
        bc2 = 1 - self.b2 ** t.astype(jnp.float32)
        new = jax.tree_util.tree_map(
            lambda p, m_, v_: (p - self.lr * (m_ / bc1)
                               / (jnp.sqrt(v_ / bc2) + self.eps)
                               ).astype(p.dtype),
            params, m, v)
        return new, {"m": m, "v": v, "t": t}


@dataclass(frozen=True)
class SignSGD:
    """Element-wise 1-bit compression baseline (paper ref [3]); per-iteration
    upload is d bits — compare Sign-pAirZero's 1 bit total."""
    lr: float = 1e-4

    def init(self, params: PyTree) -> PyTree:
        return ()

    def update(self, params: PyTree, grads: PyTree, state: PyTree
               ) -> Tuple[PyTree, PyTree]:
        new = jax.tree_util.tree_map(
            lambda p, g: (p - self.lr * jnp.sign(g).astype(p.dtype)
                          ).astype(p.dtype),
            params, grads)
        return new, ()


def make(name: str, lr: float):
    return {"sgd": SGD, "adam": Adam, "signsgd": SignSGD}[name](lr=lr)
