"""pAirZero: ZO + over-the-air federated LLM fine-tuning, multi-pod JAX.

Subpackages: core (the paper), channel (wireless channel registry),
privacy (adversary/attacks/DP audit), models (architecture zoo), kernels
(Pallas), configs (assigned archs), runtime (sharding/faults), launch
(mesh/dryrun/train/serve), data, optim, checkpoint. See README.md /
DESIGN.md.
"""
