"""OTA-compatible Byzantine defenses: what the server/base station can do.

The OTA superposition hands the server ONE noisy scalar per resource block
— it cannot inspect per-client payloads, so classical Byzantine filters
(Krum, per-client trimmed means over full gradients) are physically
unavailable. A `Defense` is a server/PHY-side countermeasure the air
interface actually permits, registered like Transports and priced through
the same accounting:

  clip          — transmit-side norm clipping folded into the Theorem-3/4
                  power-control solve: the PA saturates every payload at
                  γ_d = clip_factor·γ (host-side, the attacker can't see
                  the solve), bounding per-attacker steering AND shrinking
                  the DP sensitivity, so the re-solved schedule affords a
                  higher channel-inversion gain at the same (ε, δ).
  robust_decode — chunked re-transmissions: clients are randomly assigned
                  to `groups` orthogonal sub-slots each round (digital:
                  TDMA sub-frames; analog: repeated OTA blocks), the server
                  decodes each sub-slot with the mechanism's own decode and
                  takes the masked MEDIAN of the group estimates —
                  median-of-means across the cohort, breakdown point
                  ⌊(m-1)/2⌋ corrupted groups.
  reweight      — anomaly-triggered re-weighting fed by the round-level
                  decode residual: sub-slot estimates whose residual vs the
                  robust center exceeds `thresh`·MAD are dropped, the rest
                  are averaged (recovers the mean's variance when the round
                  is clean, the median's robustness when it is not).

Every hook that prices privacy or communication delegates to the run's
Transport with a (possibly defense-adjusted) config, so Table II's
accounting stays computed, never hard-coded: clipping tightens the DP
sensitivity (γ → γ_d) through `power_control.defended_config`; the group
decodes keep one transmission per client per round (payload bits ×1) at
the cost of `groups` orthogonal resource blocks, and each client still
appears in exactly ONE observation per round with the same inversion gain
and receiver noise floor — per-round DP cost is unchanged under the
σ*=0 schedules the Theorem-3/4 solvers emit.

`resolve(pz)` returns None for a missing/"none" defense — the step factory
then traces the historical aggregate call unchanged (structural
neutrality, pinned in tests/test_byzantine.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Type

import jax
import jax.numpy as jnp

from repro.core import ota
from repro.core import power_control as pc
from repro.core import transport as tp

#: fold_in tag for the per-round sub-slot group assignment draw
_GROUP_TAG = 0xD3F0


@dataclass(frozen=True)
class Defense:
    """One server/PHY-side countermeasure. Subclass + `@register(name)`.

    Frozen/hashable — part of the memoized step-factory key, so a defended
    run retraces exactly when the defense changes. The base class is the
    identity defense: every hook delegates to the Transport untouched;
    subclasses override only the surfaces they actually change.
    """

    #: registry name (set by @register)
    name = "?"

    @classmethod
    def from_config(cls, bz, pz) -> "Defense":
        """Build an instance from a ByzantineConfig + run config."""
        return cls()

    # -- jit side ---------------------------------------------------------
    def transmit(self, p: jnp.ndarray, ctl: Dict) -> jnp.ndarray:
        """Client-side PHY constraint applied to EVERY payload (honest and
        malicious alike — the defense cannot tell them apart). Identity by
        default."""
        return p

    def aggregate(self, transport: tp.Transport, p: jnp.ndarray, ctl: Dict,
                  key: jax.Array) -> jnp.ndarray:
        """Server-side decode. Default: the mechanism's own aggregate."""
        return transport.aggregate(p, ctl, key)

    def aggregate_mesh(self, transport: tp.Transport, p_local: jnp.ndarray,
                       ctl: Dict, key: jax.Array, axis_names: tuple,
                       offset) -> jnp.ndarray:
        """Mesh-path decode: reassemble the full payload with the same ONE
        client-axis psum the default Transport path uses, then run this
        defense's single-device decode — bit-identical to the
        single-device engines by construction."""
        k_total = ctl["mask"].shape[-1]
        p = tp.client_all_gather(p_local, axis_names, offset, k_total)
        return self.aggregate(transport, p, ctl, key)

    # -- host side (schedule + DP accounting) -----------------------------
    def make_schedule(self, transport: tp.Transport, trace, pz):
        """Solve the transmit plan, with any defense-induced change to the
        power-control inputs folded in. Default: delegate."""
        return transport.make_schedule(trace, pz)

    def charges_privacy(self, transport: tp.Transport, schedule, pz) -> bool:
        """Whether defended rounds spend (ε, δ). Default: delegate."""
        return transport.charges_privacy(schedule, pz)

    def round_dp_costs(self, transport: tp.Transport, schedule,
                       t0: int, t1: int, pz):
        """Per-round DP cost under this defense. Default: delegate."""
        return transport.round_dp_costs(schedule, t0, t1, pz)

    def audited_pz(self, pz):
        """The config the empirical DP audit should run against — e.g. the
        canary's worst-case payload shrinks when transmissions are clipped.
        Default: unchanged."""
        return pz

    # -- communication accounting -----------------------------------------
    def payload_bits_factor(self, pz) -> float:
        """Multiplier on per-client uplink payload bits (re-transmission
        defenses that repeat payloads would exceed 1). Default 1.0."""
        return 1.0

    def extra_bits_per_round(self, pz, d: int) -> int:
        """Defense side-channel bits per round (e.g. anomaly feedback),
        billed on top of the Transport's payload accounting. Default 0."""
        return 0

    def resource_blocks(self) -> int:
        """Orthogonal PHY resource blocks consumed per round (the OTA
        mechanisms use 1; group decodes use `groups`)."""
        return 1


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Type[Defense]] = {}


def register(name: str):
    """Class decorator: `@register("clip")` adds a Defense to the registry
    under `name` (and sets `cls.name`)."""
    def deco(cls: Type[Defense]) -> Type[Defense]:
        cls.name = name
        _REGISTRY[name] = cls
        return cls
    return deco


def available() -> tuple:
    """Sorted names of every registered defense."""
    return tuple(sorted(_REGISTRY))


def get(name: str) -> Type[Defense]:
    """Look up a registered Defense class by name."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown defense {name!r} "
                         f"(registered: {available()})") from None


def resolve(pz) -> Optional[Defense]:
    """Build the defense a PairZeroConfig asks for — or None ("none" /
    no ByzantineConfig), which traces the historical program unchanged."""
    bz = getattr(pz, "byzantine", None)
    if bz is None or bz.defense == "none":
        return None
    return get(bz.defense).from_config(bz, pz)


# ---------------------------------------------------------------------------
# Built-in defenses
# ---------------------------------------------------------------------------

@register("clip")
@dataclass(frozen=True)
class TransmitClip(Defense):
    """Per-client norm clipping folded into the power-control solve.

    The PA saturates every transmitted scalar at ±γ_d (γ_d =
    clip_factor·γ): amplified poisons collapse to the boundary, and the
    Theorem-3/4 solve re-runs with the tightened sensitivity
    (`power_control.defended_config`), so the same (ε, δ) budget affords a
    HIGHER channel-inversion gain c — the defended run decodes at a better
    SNR than the undefended one. Host-side: the attacker observes only the
    broadcast schedule, never the solve."""
    clip: float = 1.0

    @classmethod
    def from_config(cls, bz, pz) -> "TransmitClip":
        """γ_d = clip_factor · γ from the run's clip range."""
        return cls(clip=float(bz.clip_factor) * float(pz.zo.clip_gamma))

    def transmit(self, p, ctl):
        """Saturate every payload at the defended boundary."""
        half = jnp.asarray(self.clip, p.dtype)
        return jnp.clip(p, -half, half)

    def make_schedule(self, transport, trace, pz):
        """Re-solve Theorem 3/4 with the tightened clip range γ_d."""
        return transport.make_schedule(trace, pc.defended_config(pz,
                                                                 self.clip))

    def charges_privacy(self, transport, schedule, pz):
        """Delegate under the tightened sensitivity."""
        return transport.charges_privacy(schedule,
                                         pc.defended_config(pz, self.clip))

    def round_dp_costs(self, transport, schedule, t0, t1, pz):
        """DP spend with sensitivity γ_d — clipping never costs extra
        privacy; it tightens the Lemma-1 sensitivity."""
        return transport.round_dp_costs(schedule, t0, t1,
                                        pc.defended_config(pz, self.clip))

    def audited_pz(self, pz):
        """Audit (and canary) against the clipped worst case γ_d."""
        return pc.defended_config(pz, self.clip)


def _group_assignment(key: jax.Array, k_total: int, groups: int
                      ) -> jnp.ndarray:
    """[K] int32 sub-slot index per client — a fresh seeded permutation
    each round (attackers cannot position themselves in a known slot)."""
    perm = jax.random.permutation(jax.random.fold_in(key, _GROUP_TAG),
                                  k_total)
    slots = jnp.arange(k_total, dtype=jnp.int32) % groups
    return jnp.zeros((k_total,), jnp.int32).at[perm].set(slots)


def _group_estimates(transport: tp.Transport, p: jnp.ndarray, ctl: Dict,
                     key: jax.Array, groups: int):
    """Decode each sub-slot with the mechanism's own aggregate.

    Returns ([m] estimates, [m] validity): a sub-slot is valid when at
    least one scheduled (mask-surviving) client landed in it. Each sub-slot
    consumes its own noise key (`ota.subslot_keys`) — independent channel
    uses, exactly as chunked re-transmission behaves on the air."""
    group_of = _group_assignment(key, ctl["mask"].shape[-1], groups)
    ests, valid = [], []
    for g, gkey in enumerate(ota.subslot_keys(key, groups)):
        gmask = ctl["mask"] * (group_of == g).astype(ctl["mask"].dtype)
        ests.append(transport.aggregate(p, tp.masked_ctl(ctl, gmask), gkey))
        valid.append(jnp.sum(gmask) > 0)
    return jnp.stack(ests), jnp.stack(valid)


def _masked_median(values: jnp.ndarray, valid: jnp.ndarray) -> jnp.ndarray:
    """Median over the valid entries (sort-with-sentinel; the full survival
    mask is never empty, so at least one sub-slot is always valid)."""
    srt = jnp.sort(jnp.where(valid, values, jnp.inf))
    n = jnp.maximum(jnp.sum(valid.astype(jnp.int32)), 1)
    return 0.5 * (srt[(n - 1) // 2] + srt[n // 2])


@register("robust_decode")
@dataclass(frozen=True)
class RobustDecode(Defense):
    """Median over `groups` chunked re-transmission sub-slots.

    Clients are permuted into orthogonal sub-slots each round; the server
    decodes every sub-slot with the mechanism's own decode (digital: TDMA
    sub-frame average; analog/sign: a separate OTA superposition block) and
    takes the masked median of the estimates — median-of-means over the
    cohort. Tolerates up to ⌊(m-1)/2⌋ corrupted sub-slots, so robustness
    grows with `groups` at a linear resource-block cost (`groups` blocks
    per round; per-client payload bits unchanged). DP is unchanged under
    the σ*=0 solved schedules: every client still appears in exactly one
    observation per round at the same c and N0."""
    groups: int = 4

    @classmethod
    def from_config(cls, bz, pz) -> "RobustDecode":
        """Sub-slot count from ByzantineConfig.groups (≤ K is sensible)."""
        return cls(groups=int(bz.groups))

    def aggregate(self, transport, p, ctl, key):
        """Masked median over the sub-slot decodes."""
        est, valid = _group_estimates(transport, p, ctl, key, self.groups)
        return _masked_median(est, valid)

    def resource_blocks(self):
        """One orthogonal block per sub-slot."""
        return self.groups


@register("reweight")
@dataclass(frozen=True)
class ResidualReweight(Defense):
    """Anomaly-triggered re-weighting fed by the decode residual.

    Two-pass sub-slot decode: the robust center is the masked median of
    the `groups` estimates; sub-slots whose residual exceeds
    `thresh` · MAD are flagged anomalous and dropped; the survivors are
    AVERAGED. Clean rounds keep (nearly) the plain mean's variance;
    attacked rounds degrade gracefully to the median. The per-round
    accept/reject bitmap is fed back downlink — `groups` bits per round,
    billed through `extra_bits_per_round`."""
    groups: int = 4
    thresh: float = 3.0

    @classmethod
    def from_config(cls, bz, pz) -> "ResidualReweight":
        """Sub-slot count from ByzantineConfig.groups."""
        return cls(groups=int(bz.groups))

    def aggregate(self, transport, p, ctl, key):
        """Drop sub-slots with residual > thresh·MAD, average the rest."""
        est, valid = _group_estimates(transport, p, ctl, key, self.groups)
        center = _masked_median(est, valid)
        resid = jnp.abs(est - center)
        mad = _masked_median(resid, valid)
        keep = valid & (resid <= jnp.asarray(self.thresh, resid.dtype) * mad
                        + jnp.asarray(1e-12, resid.dtype))
        w = keep.astype(est.dtype)
        nk = jnp.sum(w)
        return jnp.where(nk > 0,
                         jnp.sum(w * est) / jnp.maximum(nk, 1.0), center)

    def resource_blocks(self):
        """One orthogonal block per sub-slot."""
        return self.groups

    def extra_bits_per_round(self, pz, d):
        """The anomaly accept/reject bitmap: one downlink bit per
        sub-slot per round."""
        return self.groups
