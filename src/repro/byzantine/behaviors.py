"""Byzantine client behaviors: what an ACTIVE adversarial client radiates.

A `ClientBehavior` rewrites the [K] per-client payload vector p_k *before*
the Transport's aggregate — the malicious payload then flows through the
real `ota.superpose` exactly like honest traffic (attacks and honest
signals are physically superposed on the air; the server never sees
per-client payloads on the OTA mechanisms, which is precisely why steering
is the right threat model there).

Mechanics mirror the Transport/ChannelModel/Adversary registries: frozen
dataclasses (hashable — the memoized step factory keys on them) registered
by name. WHICH clients misbehave is decided host-side, once per run, by
`client_mask` (a seeded cohort draw) and rides the device-resident
ControlTrace as a [R, K] mask (`ctl["byz"]`) next to the survival mask —
so the same traced program serves loop, scan and the shard_map'd mesh
engine bit-identically (the mask is data, not structure). HOW they
misbehave is jit-side: `apply` is traced into the round body, keyed by a
per-round fold of the shared noise key so every engine (and every mesh
shard) derives identical attack randomness.

Built-ins:

  sign_flip        — the paper's Fig. 4 adversary: transmit -p_k.
  scaled_poison    — amplified flip: transmit -λ·p_k (λ > 1 exceeds the
                     honest clip range — what transmit-clipping catches).
  gaussian_noise   — jam with N(0, std²) instead of a gradient payload.
  colluding_cohort — shared-seed coordinated flip: all colluders transmit
                     the SAME clip-boundary payload with a common random
                     sign each round (maximum coherent steering power).

Zero-config neutrality is structural: `resolve(pz)` returns None when no
ByzantineConfig is set, the behavior is "none", or the fraction is 0 — the
step factory then traces the exact historical program (pinned in
tests/test_byzantine.py the same way PR 6 pins the fused flag off).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Type

import jax
import jax.numpy as jnp
import numpy as np

#: fold_in tag deriving the per-round attack key from the round noise key
#: (shared across engines and mesh shards — it comes from the control block)
BYZ_KEY_TAG = 0xB52
#: host-side RNG tag for the cohort draw (which clients are malicious)
_COHORT_TAG = 0xB52C0


@dataclass(frozen=True)
class ClientBehavior:
    """One active-adversary payload rewrite. Subclass + `@register(name)`.

    `fraction` of the K clients run the behavior; the cohort is drawn once
    per run from `seed` (host-side, `client_mask`) and shipped to the
    device as the ctl["byz"] indicator row. Frozen/hashable so the
    lru-cached step factories retrace exactly when the scenario changes.
    """

    #: registry name (set by @register)
    name = "?"
    #: share of clients running this behavior (cohort size = round(f·K))
    fraction: float = 0.25
    #: salts the cohort draw + any shared attack randomness
    seed: int = 0

    @classmethod
    def from_config(cls, bz, pz) -> "ClientBehavior":
        """Build an instance from a ByzantineConfig + run config. Override
        to consume extra fields (scale, payload magnitude, ...)."""
        return cls(fraction=float(bz.fraction), seed=int(bz.seed))

    # -- host side --------------------------------------------------------
    def client_mask(self, n_clients: int) -> np.ndarray:
        """[K] float32 indicator of the malicious cohort (1 = attacker).

        A seeded permutation draw — deterministic per (seed, K), identical
        across engines, chunks and resumed runs; broadcast over rounds by
        `engine.build_trace` into ctl["byz"]."""
        m = min(max(int(round(self.fraction * n_clients)), 0), n_clients)
        mask = np.zeros((n_clients,), dtype=np.float32)
        if m:
            rng = np.random.default_rng(
                (int(self.seed) & 0xFFFFFFFF) ^ _COHORT_TAG)
            mask[rng.permutation(n_clients)[:m]] = 1.0
        return mask

    # -- jit side ---------------------------------------------------------
    def apply(self, p: jnp.ndarray, byz: jnp.ndarray, ctl: Dict,
              key: jax.Array, offset, k_total: int) -> jnp.ndarray:
        """Rewrite the (possibly shard-local) payload slice `p` given its
        aligned cohort indicator `byz` ∈ {0,1}. `key` is the shared
        per-round attack key; `offset`/`k_total` locate the slice in the
        global client axis (offset is None on the single-device path).
        Honest entries (byz == 0) MUST pass through bitwise unchanged."""
        raise NotImplementedError


def apply_behavior(behavior: ClientBehavior, p: jnp.ndarray, ctl: Dict,
                   round_key: jax.Array, offset=None) -> jnp.ndarray:
    """Apply `behavior` to the payload vector inside the round body.

    Slices the device-resident cohort row ctl["byz"] to this shard's
    clients (when `offset` is given — the mesh path) and derives the
    per-round attack key from the shared noise key, so every engine and
    every mesh shard computes bit-identical malicious payloads.
    """
    byz = ctl["byz"].astype(p.dtype)
    k_total = byz.shape[-1]
    if offset is not None:
        byz = jax.lax.dynamic_slice_in_dim(byz, offset, p.shape[-1], axis=-1)
    key = jax.random.fold_in(round_key, BYZ_KEY_TAG)
    return behavior.apply(p, byz, ctl, key, offset, k_total)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Type[ClientBehavior]] = {}


def register(name: str):
    """Class decorator: `@register("sign_flip")` adds a ClientBehavior to
    the registry under `name` (and sets `cls.name`)."""
    def deco(cls: Type[ClientBehavior]) -> Type[ClientBehavior]:
        cls.name = name
        _REGISTRY[name] = cls
        return cls
    return deco


def available() -> tuple:
    """Sorted names of every registered client behavior."""
    return tuple(sorted(_REGISTRY))


def get(name: str) -> Type[ClientBehavior]:
    """Look up a registered ClientBehavior class by name."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown behavior {name!r} "
                         f"(registered: {available()})") from None


def resolve(pz) -> Optional[ClientBehavior]:
    """Build the behavior a PairZeroConfig asks for — or None.

    None (no ByzantineConfig, behavior "none", or fraction 0) means the
    step factory traces the historical honest-cohort program unchanged:
    neutrality is structural, not an all-zeros multiply."""
    bz = getattr(pz, "byzantine", None)
    if bz is None or bz.behavior == "none" or bz.fraction <= 0.0:
        return None
    return get(bz.behavior).from_config(bz, pz)


# ---------------------------------------------------------------------------
# Built-in behaviors
# ---------------------------------------------------------------------------

@register("sign_flip")
@dataclass(frozen=True)
class SignFlip(ClientBehavior):
    """The paper's Fig. 4 adversary: malicious clients transmit -p_k,
    steering the aggregate against the descent direction while staying
    inside the honest clip range (undetectable by magnitude)."""

    def apply(self, p, byz, ctl, key, offset, k_total):
        """Flip the cohort's payload sign; honest entries untouched."""
        return jnp.where(byz > 0, -p, p)


@register("scaled_poison")
@dataclass(frozen=True)
class ScaledPoison(ClientBehavior):
    """Amplified flip: transmit -λ·p_k. With λ > 1 the malicious payload
    exceeds the honest ±γ clip range — more steering power per attacker,
    but exactly what the transmit-clip defense saturates away."""
    scale: float = 3.0

    @classmethod
    def from_config(cls, bz, pz) -> "ScaledPoison":
        """λ comes from ByzantineConfig.scale."""
        return cls(fraction=float(bz.fraction), seed=int(bz.seed),
                   scale=float(bz.scale))

    def apply(self, p, byz, ctl, key, offset, k_total):
        """Amplify-and-flip the cohort's payload."""
        return jnp.where(byz > 0, -jnp.asarray(self.scale, p.dtype) * p, p)


@register("gaussian_noise")
@dataclass(frozen=True)
class GaussianNoise(ClientBehavior):
    """Jamming: malicious clients add N(0, std²) garbage to their payload
    instead of steering — degrades SNR without a preferred direction."""
    std: float = 3.0

    @classmethod
    def from_config(cls, bz, pz) -> "GaussianNoise":
        """The noise std comes from ByzantineConfig.scale."""
        return cls(fraction=float(bz.fraction), seed=int(bz.seed),
                   std=float(bz.scale))

    def apply(self, p, byz, ctl, key, offset, k_total):
        """Add seeded noise on the cohort's entries. The draw is always the
        full [K] vector, sliced to the shard — so mesh and single-device
        paths consume bit-identical per-client noise."""
        noise = jnp.asarray(self.std, p.dtype) * jax.random.normal(
            jax.random.fold_in(key, 1), (k_total,), p.dtype)
        if offset is not None:
            noise = jax.lax.dynamic_slice_in_dim(
                noise, offset, p.shape[-1], axis=-1)
        return p + byz * noise


@register("colluding_cohort")
@dataclass(frozen=True)
class ColludingCohort(ClientBehavior):
    """Shared-seed coordinated attack: every colluder transmits the SAME
    clip-boundary payload with a common per-round random sign — the
    cohort's transmissions add coherently in the superposition (K_bad·γ of
    steering per round, the OTA worst case). Needs no cross-shard
    collective: the shared sign derives from the broadcast round key."""
    payload: float = 5.0

    @classmethod
    def from_config(cls, bz, pz) -> "ColludingCohort":
        """Colluders transmit at the honest clip boundary γ."""
        return cls(fraction=float(bz.fraction), seed=int(bz.seed),
                   payload=float(pz.zo.clip_gamma))

    def apply(self, p, byz, ctl, key, offset, k_total):
        """Replace the cohort's payload with the shared signed boundary."""
        flip = jax.random.bernoulli(jax.random.fold_in(key, 2))
        s = jnp.where(flip, -1.0, 1.0).astype(p.dtype)
        return jnp.where(byz > 0, s * jnp.asarray(self.payload, p.dtype), p)
