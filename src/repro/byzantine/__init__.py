"""Byzantine robustness: active-adversary behaviors + OTA-compatible
defenses — the fourth first-class registry axis.

PR 5 (repro.privacy) measures whether a passive adversary can READ the
uplink; this subsystem measures whether an active one can STEER it. It
mirrors the Transport / ChannelModel / Adversary design exactly: frozen
dataclasses registered by name, host-side scenario state riding the
device-resident ControlTrace, jit-side math traced into the same round
body all three engines (loop / scan / scan_mesh) share bit-identically.

Two registries:

  behaviors (`repro.byzantine.behaviors`) — `ClientBehavior` rewrites the
    [K] payload vector BEFORE the Transport aggregate, so malicious
    payloads flow through the real `ota.superpose`: sign_flip (the paper's
    Fig. 4 adversary), scaled_poison(λ), gaussian_noise, colluding_cohort
    (shared-seed coordinated flip). WHICH clients attack is a seeded
    host-side cohort mask (ctl["byz"]); HOW they attack is traced jit-side
    with per-round keys derived from the shared noise key.

  defenses (`repro.byzantine.defenses`) — `Defense` countermeasures the
    OTA constraint permits: transmit clipping folded into the Theorem-3/4
    power-control solve (`clip`), median over chunked re-transmission
    sub-slots (`robust_decode`), residual-triggered sub-slot re-weighting
    (`reweight`). Each prices its DP and communication deltas through the
    run's Transport — defenses must not silently break the privacy story
    (benchmarks/fig_robustness.py re-runs the PR 5 ε̂ audit under attack).

Config surface: `configs.base.ByzantineConfig` on `PairZeroConfig`
(CLI: `train.py --byzantine/--byzantine-frac/--defense`). `resolve_*`
return None for absent/"none"/zero-fraction scenarios — the step factory
then traces the exact historical program (structural neutrality, pinned
bitwise in tests/test_byzantine.py on all three engines).
"""
from repro.byzantine.behaviors import (
    BYZ_KEY_TAG,
    ClientBehavior,
    ColludingCohort,
    GaussianNoise,
    ScaledPoison,
    SignFlip,
    apply_behavior,
)
from repro.byzantine.behaviors import available as available_behaviors
from repro.byzantine.behaviors import get as get_behavior
from repro.byzantine.behaviors import register as register_behavior
from repro.byzantine.behaviors import resolve as resolve_behavior
from repro.byzantine.defenses import (
    Defense,
    ResidualReweight,
    RobustDecode,
    TransmitClip,
)
from repro.byzantine.defenses import available as available_defenses
from repro.byzantine.defenses import get as get_defense
from repro.byzantine.defenses import register as register_defense
from repro.byzantine.defenses import resolve as resolve_defense

__all__ = [
    "BYZ_KEY_TAG",
    "ClientBehavior",
    "SignFlip",
    "ScaledPoison",
    "GaussianNoise",
    "ColludingCohort",
    "apply_behavior",
    "available_behaviors",
    "get_behavior",
    "register_behavior",
    "resolve_behavior",
    "Defense",
    "TransmitClip",
    "RobustDecode",
    "ResidualReweight",
    "available_defenses",
    "get_defense",
    "register_defense",
    "resolve_defense",
]
