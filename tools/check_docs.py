"""Docs consistency gate: CLI coverage, docstrings, stale examples, links.

    python tools/check_docs.py [--repo ROOT]

Checks, in order:
  1. CLI coverage — every `--flag` declared by the train.py / dryrun.py
     argument parsers appears in docs/cli.md. Flags are extracted by
     REGEX over the source, never by importing the modules (dryrun.py
     sets XLA_FLAGS at import time to emulate a multi-device host, which
     would poison this process's jax).
  2. Module docstrings — the documented public modules
     (repro, repro.core.transport, repro.channel, repro.privacy,
     repro.byzantine, repro.kernels, repro.obs, repro.runtime and its
     desync/inject submodules) carry a module docstring and every public
     top-level class/function (and public method of a public class)
     carries one.
     AST-based: no imports, works without ruff (CI additionally runs
     ruff's pydocstyle rules on the same files — see pyproject.toml).
  3. Stale examples — `examples/` must not use the deprecated
     string-dispatched `variant=` spelling anywhere, nor pass `scheme=`
     to `fedsim.run(...)` (both are one-release shims; the supported
     spelling is TransportConfig / a Transport instance).
  4. Links — every `docs/*.md` page referenced from README.md exists,
     and every page of the docs/ tree is reachable from README.md.
Exit code 0 on pass; 1 with every violation listed on failure.
"""
from __future__ import annotations

import argparse
import ast
import re
import sys
from pathlib import Path

CLI_SOURCES = ("src/repro/launch/train.py", "src/repro/launch/dryrun.py")
DOCSTRING_MODULES = (
    "src/repro/__init__.py",
    "src/repro/core/transport.py",
    "src/repro/channel/__init__.py",
    "src/repro/privacy/__init__.py",
    "src/repro/byzantine/__init__.py",
    "src/repro/kernels/__init__.py",
    "src/repro/obs/__init__.py",
    "src/repro/obs/profile.py",
    "src/repro/obs/hlo.py",
    "src/repro/obs/health.py",
    "src/repro/runtime/__init__.py",
    "src/repro/runtime/desync.py",
    "src/repro/runtime/inject.py",
)

FLAG_RE = re.compile(r"add_argument\(\s*\n?\s*\"(--[a-z0-9][a-z0-9-]*)\"")


def cli_flags(src: str) -> set:
    """Every --flag the file's parser declares (regex, no import)."""
    return set(FLAG_RE.findall(src))


def missing_docstrings(path: Path) -> list:
    """Public defs/classes (incl. public methods) without a docstring."""
    tree = ast.parse(path.read_text())
    out = []
    if not ast.get_docstring(tree):
        out.append(f"{path}: missing module docstring")

    def walk(nodes, prefix=""):
        for node in nodes:
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                     ast.ClassDef)):
                continue
            if node.name.startswith("_"):
                continue
            if not ast.get_docstring(node):
                out.append(f"{path}: {prefix}{node.name} (line "
                           f"{node.lineno}) missing docstring")
            if isinstance(node, ast.ClassDef):
                walk(node.body, prefix=f"{node.name}.")

    walk(tree.body)
    return out


def fedsim_run_calls(src: str):
    """Yield the paren-balanced text of every fedsim.run(...) call."""
    for m in re.finditer(r"fedsim\.run\(", src):
        depth, i = 1, m.end()
        while i < len(src) and depth:
            depth += {"(": 1, ")": -1}.get(src[i], 0)
            i += 1
        yield src[m.start():i]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--repo", default=Path(__file__).resolve().parent.parent,
                    type=Path)
    args = ap.parse_args()
    root = args.repo
    errors = []

    # 1. CLI coverage ----------------------------------------------------
    cli_md = (root / "docs/cli.md").read_text() \
        if (root / "docs/cli.md").exists() else ""
    if not cli_md:
        errors.append("docs/cli.md missing")
    for rel in CLI_SOURCES:
        src = (root / rel).read_text()
        for flag in sorted(cli_flags(src)):
            if flag not in cli_md:
                errors.append(f"docs/cli.md: {rel} flag {flag} undocumented")

    # 2. module docstrings -----------------------------------------------
    for rel in DOCSTRING_MODULES:
        errors.extend(missing_docstrings(root / rel))

    # 3. stale examples --------------------------------------------------
    for py in sorted((root / "examples").glob("*.py")):
        src = py.read_text()
        for m in re.finditer(r"\bvariant\s*=", src):
            line = src[:m.start()].count("\n") + 1
            errors.append(f"{py.relative_to(root)}:{line}: deprecated "
                          "string-dispatched variant= spelling (use "
                          "TransportConfig / a Transport instance)")
        for call in fedsim_run_calls(src):
            if re.search(r"\bscheme\s*=", call):
                errors.append(f"{py.relative_to(root)}: fedsim.run(... "
                              "scheme=...) is the deprecated shim (put the "
                              "scheme in TransportConfig)")

    # 4. README <-> docs links -------------------------------------------
    readme = (root / "README.md").read_text()
    referenced = set(re.findall(r"docs/[a-z_]+\.md", readme))
    for ref in sorted(referenced):
        if not (root / ref).exists():
            errors.append(f"README.md links to missing {ref}")
    for page in sorted((root / "docs").glob("*.md")):
        rel = f"docs/{page.name}"
        if rel not in referenced:
            errors.append(f"{rel} not linked from README.md")

    if errors:
        print(f"check_docs: FAIL ({len(errors)} violation(s))")
        for e in errors:
            print(f"  {e}")
        sys.exit(1)
    n_flags = sum(len(cli_flags((root / rel).read_text()))
                  for rel in CLI_SOURCES)
    print(f"check_docs: OK ({n_flags} CLI flags documented, "
          f"{len(DOCSTRING_MODULES)} modules docstring-complete, "
          f"examples clean, {len(referenced)} docs pages linked)")


if __name__ == "__main__":
    main()
