"""Perf-history ledger: append-only JSONL of benchmark headline numbers.

    python tools/bench_history.py show results/bench_history.jsonl
        [--kind engine]

One row per benchmark invocation (schema "bench_history/v1"):

    {"schema": "bench_history/v1", "kind": "engine" | "kernels",
     "created_unix": ..., "git_sha": "<short sha or 'unknown'>",
     "host": {"platform": "cpu", "devices": 8, "machine": "x86_64"},
     "metrics": {...headline numbers...}}

The benchmarks append via `append_row` (`engine_throughput.py --history`
and `kernel_memory.py --history` do `sys.path.insert(0, "tools")` and
import this module — tools/ is not a package on purpose). The committed
`results/bench_history.jsonl` is the repo's performance memory:
`tools/check_bench.py --history` validates every row and gates the
newest row of each (kind, host-signature) group against the rolling best
of its OWN group — numbers from a different machine or device count
never gate each other, so a laptop row can't fail CI's container.

Metrics are free-form per kind, but the gate metric must be present:
`engine` rows carry `scan_rounds_per_s` (plus loop baseline + stall
ratios), `kernels` rows carry `fused_duals_per_s` (plus the memory
overhead ratio). Append-only by design: history rewrites would erase
exactly the evidence a regression gate exists to keep.
"""
from __future__ import annotations

import argparse
import json
import platform as _platform
import subprocess
import time
from typing import Any, Dict, List

SCHEMA = "bench_history/v1"
KINDS = ("engine", "kernels")
# per-kind headline metric the regression gate compares (higher = better)
GATE_METRIC = {"engine": "scan_rounds_per_s",
               "kernels": "fused_duals_per_s"}


def git_sha() -> str:
    """Short commit sha of the working tree, or 'unknown' outside git."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10)
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else "unknown"
    except Exception:
        return "unknown"


def host_signature() -> Dict[str, Any]:
    """The grouping key for the regression gate: rows only compare
    against rows captured on the same platform / device count / arch."""
    try:
        import jax
        devices = len(jax.devices())
        plat = jax.devices()[0].platform
    except Exception:
        devices, plat = 0, "unknown"
    return {"platform": plat, "devices": devices,
            "machine": _platform.machine()}


def make_row(kind: str, metrics: Dict[str, Any]) -> Dict[str, Any]:
    """One schema'd history row (validates kind + gate metric presence)."""
    if kind not in KINDS:
        raise ValueError(f"unknown history kind {kind!r}; one of {KINDS}")
    gate = GATE_METRIC[kind]
    if gate not in metrics:
        raise ValueError(f"{kind} history row must carry the gate metric "
                         f"{gate!r}; got {sorted(metrics)}")
    return {
        "schema": SCHEMA,
        "kind": kind,
        "created_unix": int(time.time()),
        "git_sha": git_sha(),
        "host": host_signature(),
        "metrics": dict(metrics),
    }


def append_row(path: str, kind: str, metrics: Dict[str, Any]
               ) -> Dict[str, Any]:
    """Append one row to the JSONL ledger; returns the row written."""
    row = make_row(kind, metrics)
    with open(path, "a") as f:
        f.write(json.dumps(row) + "\n")
    return row


def read_history(path: str) -> List[Dict[str, Any]]:
    """All rows of a history file (raises on any unparsable line — the
    ledger is append-only and fsync-free writes are tiny, so a torn line
    means a bad merge, not a crash: fix it, don't tolerate it)."""
    rows = []
    with open(path) as f:
        for i, ln in enumerate(f):
            if not ln.strip():
                continue
            try:
                rows.append(json.loads(ln))
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}: corrupt history line "
                                 f"{i + 1}: {e}") from e
    return rows


def group_key(row: Dict[str, Any]) -> tuple:
    """(kind, platform, devices, machine) — the gate's comparison group."""
    host = row.get("host", {})
    return (row.get("kind"), host.get("platform"),
            host.get("devices"), host.get("machine"))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("cmd", choices=("show",))
    ap.add_argument("path")
    ap.add_argument("--kind", default=None, choices=KINDS,
                    help="only rows of this kind")
    args = ap.parse_args()
    rows = read_history(args.path)
    if args.kind:
        rows = [r for r in rows if r.get("kind") == args.kind]
    for r in rows:
        host = r.get("host", {})
        gate = GATE_METRIC.get(r.get("kind"), "")
        val = r.get("metrics", {}).get(gate)
        print(f"{r.get('created_unix')} {r.get('git_sha'):>9s} "
              f"{r.get('kind'):7s} {host.get('platform')}/"
              f"{host.get('devices')}dev/{host.get('machine')} "
              f"{gate}={val}")
    print(f"{len(rows)} row(s)")


if __name__ == "__main__":
    main()
