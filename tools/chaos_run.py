"""Process-level crash consistency harness: SIGKILL, resume, compare.

    python tools/chaos_run.py [--engine loop|scan] [--rounds 24]
        [--ckpt-every 4] [--seed 0] [--tear] [--keep-dirs]

The contract under test — crash-consistent resume end to end, across a
real process boundary (no in-process mocking):

  1. run the training CLI uninterrupted to completion (the reference),
  2. run the identical command in a fresh checkpoint directory and
     SIGKILL the process the moment a sampled early checkpoint lands
     (the round is drawn from the run's own boundary grid, seeded),
  3. optionally (--tear) truncate the newest surviving checkpoint's
     arrays.npz in half — the torn-write a SIGKILL mid-save leaves —
     so resume must fall back to the last CRC-valid one
     (checkpoint.latest_valid),
  4. re-run the identical command: it must resume (summary.resumed_from
     > 0) and reach the final round,
  5. the final checkpoints of the reference and the killed+resumed run
     must hold bitwise-identical parameters (the manifests' CRC-32
     maps are compared leaf by leaf — CRC equality over identical leaf
     names IS byte equality of the saved arrays),
  6. every run streams the trilemma ledger (--metrics-out): the KILLED
     run's ledger must parse under the crash-consistent reader
     (`read_ledger(strict=False)` — at most one torn trailing record),
     and the resumed run's must parse strictly: a run that completes
     `close()` fsyncs, so a completed run's ledger has no torn lines.

Works because everything the run consumes is derived from the config
seed over the PLANNED horizon: the channel trace, the power schedule,
the per-round ZO seeds and the data order all replay identically from
any resume point. Exit 0 on pass; 1 on any violation; 2 if the child
finished before the kill landed twice in a row (raise --rounds).
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import signal
import subprocess
import sys
import tempfile
import time

import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

from repro.checkpoint import checkpoint as ckpt  # noqa: E402
from repro.obs import ledger as obs_ledger  # noqa: E402


def train_cmd(args, ckpt_dir: str, out: str) -> list:
    """The training CLI invocation under test (identical across runs)."""
    return [
        sys.executable, "-m", "repro.launch.train",
        "--arch", args.arch, "--reduced",
        "--rounds", str(args.rounds), "--engine", args.engine,
        "--chunk-rounds", str(args.ckpt_every),
        "--clients", "4", "--batch", "4", "--seq-len", "16",
        "--eval-every", "0", "--seed", str(args.seed),
        "--checkpoint-dir", ckpt_dir,
        "--checkpoint-every", str(args.ckpt_every),
        "--metrics-out", os.path.join(ckpt_dir, "metrics.jsonl"),
        "--out", out,
    ]


def run_to_completion(args, ckpt_dir: str) -> dict:
    """Run the CLI to completion; return its --out summary."""
    out = os.path.join(ckpt_dir, "summary.json")
    env = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}
    proc = subprocess.run(train_cmd(args, ckpt_dir, out), env=env,
                          capture_output=True, text=True)
    if proc.returncode != 0:
        print(proc.stdout)
        print(proc.stderr, file=sys.stderr)
        raise SystemExit(f"chaos_run: FAIL (training exited "
                         f"{proc.returncode})")
    with open(out) as f:
        return json.load(f)


def kill_at_checkpoint(args, ckpt_dir: str, kill_step: int,
                       timeout_s: float = 600.0) -> bool:
    """Launch the CLI; SIGKILL it once step_<kill_step> lands.

    Returns True if the kill landed mid-run, False if the child finished
    first (the caller retries with an earlier kill step).
    """
    target = os.path.join(ckpt_dir, f"step_{kill_step:08d}")
    out = os.path.join(ckpt_dir, "summary.json")
    env = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}
    child = subprocess.Popen(train_cmd(args, ckpt_dir, out), env=env,
                             stdout=subprocess.DEVNULL,
                             stderr=subprocess.DEVNULL)
    deadline = time.monotonic() + timeout_s
    try:
        while time.monotonic() < deadline:
            if os.path.isdir(target):
                child.send_signal(signal.SIGKILL)
                child.wait()
                return True
            if child.poll() is not None:
                return False        # finished before the kill landed
            time.sleep(0.05)
        raise SystemExit("chaos_run: FAIL (child timed out before "
                         f"checkpoint {kill_step})")
    finally:
        if child.poll() is None:
            child.kill()
            child.wait()


def final_manifest(ckpt_dir: str, step: int) -> dict:
    """The CRC-32 map of the final checkpoint (leaf name -> crc)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}", "manifest.json")
    with open(path) as f:
        return json.load(f)["crc32"]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--engine", default="loop", choices=["loop", "scan"])
    ap.add_argument("--arch", default="opt-125m")
    ap.add_argument("--rounds", type=int, default=24)
    ap.add_argument("--ckpt-every", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tear", action="store_true",
                    help="truncate the newest surviving checkpoint before "
                         "resume (the torn write a SIGKILL mid-save "
                         "leaves); resume must fall back past it")
    ap.add_argument("--keep-dirs", action="store_true",
                    help="keep the work directories for inspection")
    args = ap.parse_args()
    if args.rounds % args.ckpt_every != 0:
        raise SystemExit("chaos_run: --rounds must be a multiple of "
                         "--ckpt-every (the final checkpoint is compared)")

    work = tempfile.mkdtemp(prefix="chaos_run_")
    ref_dir = os.path.join(work, "ref")
    chaos_dir = os.path.join(work, "chaos")
    os.makedirs(ref_dir)
    os.makedirs(chaos_dir)
    errors = []
    try:
        print(f"chaos_run: engine={args.engine} rounds={args.rounds} "
              f"ckpt_every={args.ckpt_every} tear={args.tear}", flush=True)
        ref = run_to_completion(args, ref_dir)
        print(f"chaos_run: reference done "
              f"(final_loss={ref['final_loss']:.4f})", flush=True)

        # the kill round: seeded draw from the EARLY boundary grid, so the
        # killed run still has >= half the horizon left to replay. With
        # --tear the newest survivor is destroyed, so at least TWO
        # checkpoints must have landed for the fallback to have a target.
        rng = np.random.default_rng([args.seed, 0xC4A05])
        first = args.ckpt_every * (2 if args.tear else 1)
        grid = list(range(first, max(args.rounds // 2, first) + 1,
                          args.ckpt_every))
        kill_step = int(rng.choice(grid))
        killed = kill_at_checkpoint(args, chaos_dir, kill_step)
        if not killed:          # child won the race: retry once, earliest
            print("chaos_run: child finished before the kill; retrying "
                  "at the first boundary", flush=True)
            shutil.rmtree(chaos_dir)
            os.makedirs(chaos_dir)
            kill_step = first
            if not kill_at_checkpoint(args, chaos_dir, kill_step):
                raise SystemExit(2)
        print(f"chaos_run: SIGKILLed at checkpoint {kill_step}", flush=True)

        # the killed run's ledger: a SIGKILL mid-append may leave one
        # torn trailing record and nothing worse — the crash-consistent
        # reader must get every completed row back
        metrics_path = os.path.join(chaos_dir, "metrics.jsonl")
        try:
            led = obs_ledger.read_ledger(metrics_path, strict=False)
            print(f"chaos_run: killed run's ledger parseable "
                  f"({len(led['rows'])} rows, "
                  f"truncated={led['truncated']})", flush=True)
        except Exception as e:  # noqa: BLE001 — any parse failure is the bug
            errors.append(f"killed run's ledger unreadable even with "
                          f"strict=False: {type(e).__name__}: {e}")

        if args.tear:
            newest = ckpt.latest(chaos_dir)
            ckpt.tear_checkpoint(newest)
            print(f"chaos_run: tore {os.path.basename(newest)}",
                  flush=True)
            if ckpt.latest_valid(chaos_dir) == newest:
                errors.append("latest_valid returned the torn checkpoint")

        resumed = run_to_completion(args, chaos_dir)
        if resumed["resumed_from"] <= 0:
            errors.append("resume run did not restore a checkpoint "
                          f"(resumed_from={resumed['resumed_from']})")
        elif args.tear and resumed["resumed_from"] >= kill_step:
            # survivors are <= kill_step; tearing the newest must push
            # the resume point strictly earlier
            errors.append(f"resume started at {resumed['resumed_from']} "
                          f"but the newest checkpoint (<= {kill_step}) "
                          "was torn")
        print(f"chaos_run: resumed from round {resumed['resumed_from']}",
              flush=True)

        # the resumed run completed, so its (rewritten) ledger was
        # flushed + fsynced on close: strict parsing must succeed and
        # cover every executed round
        try:
            led = obs_ledger.read_ledger(metrics_path, strict=True)
            if len(led["rows"]) != int(resumed["rounds"]):
                errors.append(
                    f"resumed run's ledger has {len(led['rows'])} rows "
                    f"but the summary reports {resumed['rounds']} rounds")
        except Exception as e:  # noqa: BLE001
            errors.append(f"resumed run's ledger does not parse strictly "
                          f"({type(e).__name__}: {e}) — the close() "
                          "fsync contract is broken")

        ref_crc = final_manifest(ref_dir, args.rounds)
        chaos_crc = final_manifest(chaos_dir, args.rounds)
        if set(ref_crc) != set(chaos_crc):
            errors.append("final checkpoints hold different leaf sets")
        else:
            bad = [n for n in ref_crc if ref_crc[n] != chaos_crc[n]]
            if bad:
                errors.append(
                    f"{len(bad)}/{len(ref_crc)} leaves differ bitwise "
                    f"after kill+resume (e.g. {bad[0]!r})")
    finally:
        if args.keep_dirs:
            print(f"chaos_run: dirs kept at {work}", flush=True)
        else:
            shutil.rmtree(work, ignore_errors=True)

    if errors:
        print(f"chaos_run: FAIL ({len(errors)} violation(s))")
        for e in errors:
            print(f"  {e}")
        sys.exit(1)
    print(f"chaos_run: OK (kill+resume bitwise-equal over "
          f"{len(ref_crc)} leaves, engine={args.engine})")


if __name__ == "__main__":
    main()
