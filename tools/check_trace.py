"""Telemetry artifact gate: trace-event JSON + trilemma ledger schemas.

    python tools/check_trace.py trace.json [--ledger metrics.jsonl]
        [--summary run.json] [--expect-chunk-traces N]
        [--expect-step-builds N] [--stall-tol 1e-3]
        [--require-spans retry,prefetch_degraded] [--require-device-lane]

Checks, in order:
  1. Trace structure — Chrome trace-event JSON ({traceEvents, otherData});
     every event carries ph/name/pid/tid/ts, complete ("X") events a
     non-negative dur, and the driver's core span names are present
     (chunk, dispatch, chunk_prep, prep_stall, metrics_flush).
  2. Nesting — per host tracer lane (cat "obs"), "X" spans are properly
     nested (contained or disjoint, never partially overlapping): the
     tracer records via nested context managers, so a violation means a
     broken clock. Merged device-op events are exempt (the runtime
     overlaps executions by design).
  3. Stall attribution — the sum of prep_stall (and ckpt_snapshot) span
     durations equals otherData's legacy prep_stall_s/ckpt_stall_s
     counters within --stall-tol seconds (default 1ms): spans are the
     single source of truth, the scalars its derived sums.
  4. Prefetch overlap — when otherData.overlap is true, every kicked
     chunk_prep span for chunk i starts at/after its prefetch_kick
     instant, and that kick fires inside chunk i-1's driver span: the
     pipeline's next-chunk prep really overlaps the current chunk.
  5. Compile watermarks — with --expect-chunk-traces/--expect-step-builds,
     otherData.compile_stats must match exactly (a CI cold run compiles a
     known number of programs; more means a cache-key break).
  6. Ledger (--ledger) — line 1 is the trilemma_ledger/v2 header; every
     row carries the full record schema (v2: k_sync/stale_frac desync
     columns, with 0 <= k_sync <= k_eff and stale_frac their consistent
     ratio); rounds strictly increase and the cumulative columns
     (bits_cum, dp_spent_cum, eps_cum) never decrease. A torn TRAILING
     line (SIGKILL mid-row) is reported as a truncation note, not a
     crash; a torn line anywhere else is corruption and fails.
  7. Required extra spans (--require-spans) — each named span must appear
     at least once (the chaos lane asserts the retry/degradation path
     really fired: retry, prefetch_degraded).
  8. Device lane (--require-device-lane) — the trace carries profiler-
     merged device-op events on a pid distinct from the host spans'
     pid 0, their time window overlaps the host span window (the clocks
     were actually aligned), and otherData.profile records the merge.
  9. Summary cross-check (--summary, needs --ledger) — the final row's
     bits_cum / dp_spent_cum / peak_bytes equal the run summary's
     uplink_bits / privacy_spent / peak_bytes EXACTLY, and the row count
     equals the executed rounds: the ledger and RunResult are one
     accounting, not two.
Exit code 0 on pass; 1 with every violation listed on failure.
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict

REQUIRED_SPANS = ("chunk", "dispatch", "chunk_prep", "prep_stall",
                  "metrics_flush")
LEDGER_SCHEMA = "trilemma_ledger/v2"
LEDGER_KEYS = ("round", "loss", "k_eff", "k_sync", "stale_frac",
               "bits_round", "bits_cum", "dp_cost", "dp_spent_cum",
               "eps_cum", "peak_bytes", "wall_s")


def _spans(events, name=None):
    return [e for e in events if e.get("ph") == "X"
            and (name is None or e.get("name") == name)]


def check_trace(doc, errors, stall_tol):
    """Checks 1-4 over a parsed trace document; appends to `errors`."""
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        errors.append("trace: not a trace-event document "
                      "(missing traceEvents)")
        return
    events = doc["traceEvents"]
    meta = doc.get("otherData", {})
    if not isinstance(meta, dict):
        errors.append("trace: otherData must be an object")
        meta = {}

    # 1. structure ------------------------------------------------------
    for i, e in enumerate(events):
        # profiler-merged metadata records may omit tid (process_name
        # entries label a whole device pid); host M events carry both
        keys = ("ph", "name", "pid") if e.get("ph") == "M" \
            else ("ph", "name", "pid", "tid", "ts")
        for key in keys:
            if key not in e:
                errors.append(f"trace: event {i} missing {key!r}")
        if e.get("ph") == "X" and not (e.get("dur", -1) >= 0):
            errors.append(f"trace: X event {i} ({e.get('name')}) has no "
                          "non-negative dur")
    names = {e.get("name") for e in events}
    for want in REQUIRED_SPANS:
        if want not in names:
            errors.append(f"trace: required span {want!r} absent")

    # 2. nesting per thread lane — host tracer spans only (cat "obs").
    # Merged device-op events legitimately overlap within a lane: the
    # runtime pipelines executions, and only the context-manager tracer
    # guarantees strict nesting.
    lanes = defaultdict(list)
    for e in _spans(events):
        if e.get("cat") != "obs":
            continue
        lanes[e["tid"]].append((float(e["ts"]), float(e["ts"]) +
                                float(e.get("dur", 0)), e["name"]))
    eps = 1.0  # µs slack for equal perf_counter quanta
    for tid, spans in lanes.items():
        spans.sort(key=lambda s: (s[0], -(s[1] - s[0])))
        stack = []
        for (a, b, nm) in spans:
            while stack and a >= stack[-1][1] - eps:
                stack.pop()
            if stack and b > stack[-1][1] + eps:
                errors.append(
                    f"trace: span {nm!r} [{a:.1f}, {b:.1f}] partially "
                    f"overlaps {stack[-1][2]!r} [{stack[-1][0]:.1f}, "
                    f"{stack[-1][1]:.1f}] on tid {tid}")
                continue
            stack.append((a, b, nm))

    # 3. stall attribution ---------------------------------------------
    for span_name, scalar in (("prep_stall", "prep_stall_s"),
                              ("ckpt_snapshot", "ckpt_stall_s")):
        if scalar not in meta:
            continue
        total = sum(e["dur"] for e in _spans(events, span_name)) * 1e-6
        want = float(meta[scalar])
        if abs(total - want) > stall_tol:
            errors.append(
                f"trace: Σ {span_name} spans = {total:.6f}s but "
                f"otherData.{scalar} = {want:.6f}s "
                f"(tol {stall_tol}s) — the scalar is no longer the "
                "span-derived sum")

    # 4. prefetch overlap ----------------------------------------------
    if meta.get("overlap"):
        kicks = {e["args"]["chunk"]: float(e["ts"]) for e in events
                 if e.get("ph") == "i" and e.get("name") == "prefetch_kick"}
        chunks = {e["args"]["chunk"]:
                  (float(e["ts"]), float(e["ts"]) + float(e["dur"]))
                  for e in _spans(events, "chunk")}
        kicked = [e for e in _spans(events, "chunk_prep")
                  if e.get("args", {}).get("kicked")]
        if not kicked and len(chunks) > 1:
            errors.append("trace: overlap on but no kicked chunk_prep "
                          "spans recorded")
        for e in kicked:
            i = e["args"]["chunk"]
            ts = float(e["ts"])
            if i in kicks and ts < kicks[i] - eps:
                errors.append(f"trace: chunk_prep {i} starts before its "
                              "prefetch_kick")
            prev = chunks.get(i - 1)
            if i in kicks and prev and not \
                    (prev[0] - eps <= kicks[i] <= prev[1] + eps):
                errors.append(
                    f"trace: prefetch_kick {i} at {kicks[i]:.1f} fired "
                    f"outside chunk {i - 1}'s span {prev} — prep does "
                    "not overlap the previous chunk")
    return meta


def check_compile(meta, args, errors):
    """Check 5: exact compile-count assertions vs otherData."""
    stats = meta.get("compile_stats", {})
    for flag, key in ((args.expect_chunk_traces, "scan_chunk_trace"),
                      (args.expect_step_builds, "zo_step_build")):
        if flag is None:
            continue
        got = int(stats.get(key, 0))
        if got != flag:
            errors.append(f"trace: compile_stats[{key!r}] = {got}, "
                          f"expected exactly {flag} — the step/executor "
                          "memoization keys changed")


def check_device_lane(doc, meta, errors):
    """Check 8: profiler-merged device events share the host timeline.

    Host spans always live on pid 0; `--profile-out` appends device-op
    events on their own pids. Requires: at least one non-host "X" event,
    a window overlap between device and host events (the anchor offset
    really mapped the profiler clock onto the tracer epoch), and the
    otherData.profile meta the exporter records for the merge.
    """
    events = doc.get("traceEvents", []) if isinstance(doc, dict) else []
    host = [(float(e["ts"]), float(e["ts"]) + float(e.get("dur", 0)))
            for e in _spans(events) if e.get("pid") == 0]
    dev = [(float(e["ts"]), float(e["ts"]) + float(e.get("dur", 0)))
           for e in _spans(events) if e.get("pid") != 0]
    if not dev:
        errors.append("trace: no device-lane X events (pid != 0) — "
                      "was the trace exported with --profile-out?")
        return
    profile = meta.get("profile")
    if not isinstance(profile, dict):
        errors.append("trace: otherData.profile missing — exporter did "
                      "not record the profiler merge")
    elif "error" in profile:
        errors.append(f"trace: profiler capture errored: "
                      f"{profile['error']}")
    if host:
        h0, h1 = min(a for a, _ in host), max(b for _, b in host)
        d0, d1 = min(a for a, _ in dev), max(b for _, b in dev)
        if d1 < h0 or d0 > h1:
            errors.append(
                f"trace: device window [{d0:.1f}, {d1:.1f}]µs does not "
                f"overlap host window [{h0:.1f}, {h1:.1f}]µs — clock "
                "alignment failed")


def check_ledger(path, errors, notes):
    """Check 6: schema + monotonicity. Returns (header, rows).

    Tolerates a torn TRAILING line (SIGKILL mid-row append) by dropping
    it and recording a truncation note; a torn line anywhere else is
    corruption and fails the gate.
    """
    try:
        with open(path) as f:
            raw = [ln for ln in f if ln.strip()]
    except OSError as e:
        errors.append(f"ledger: unreadable ({e})")
        return None, []
    lines = []
    for i, ln in enumerate(raw):
        try:
            lines.append(json.loads(ln))
        except json.JSONDecodeError as e:
            if i == len(raw) - 1:
                notes.append(f"ledger: torn trailing record dropped "
                             f"(crash mid-append at line {i + 1})")
            else:
                errors.append(f"ledger: corrupt line {i + 1} ({e}) — "
                              "torn records are only legal at the tail")
    if not lines or lines[0].get("schema") != LEDGER_SCHEMA:
        errors.append(f"ledger: line 1 must carry schema={LEDGER_SCHEMA!r}")
        return None, []
    header, rows = lines[0], lines[1:]
    prev_round, prev = None, {}
    for i, row in enumerate(rows):
        missing = [k for k in LEDGER_KEYS if k not in row]
        if missing:
            errors.append(f"ledger: row {i} missing keys {missing}")
            continue
        if prev_round is not None and row["round"] <= prev_round:
            errors.append(f"ledger: rounds not strictly increasing at "
                          f"row {i}")
        for cum in ("bits_cum", "dp_spent_cum", "eps_cum", "peak_bytes"):
            if prev and row[cum] < prev[cum]:
                errors.append(f"ledger: {cum} decreases at row {i}")
        # v2 desync columns: k_sync is a sub-count of k_eff and
        # stale_frac is exactly their ratio
        if not 0.0 <= row["k_sync"] <= row["k_eff"]:
            errors.append(f"ledger: row {i} k_sync={row['k_sync']} "
                          f"outside [0, k_eff={row['k_eff']}]")
        want_frac = ((row["k_eff"] - row["k_sync"]) / row["k_eff"]
                     if row["k_eff"] > 0 else 0.0)
        if abs(row["stale_frac"] - want_frac) > 1e-9:
            errors.append(f"ledger: row {i} stale_frac={row['stale_frac']}"
                          f" != (k_eff-k_sync)/k_eff = {want_frac}")
        prev_round, prev = row["round"], row
    return header, rows


def check_summary(path, rows, errors):
    """Check 9: the final ledger row equals the run summary exactly."""
    try:
        with open(path) as f:
            summary = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        errors.append(f"summary: unreadable ({e})")
        return
    if not rows:
        errors.append("summary: cross-check requested but ledger has no "
                      "rows")
        return
    final = rows[-1]
    for row_key, sum_key in (("bits_cum", "uplink_bits"),
                             ("dp_spent_cum", "privacy_spent"),
                             ("peak_bytes", "peak_bytes")):
        if sum_key not in summary:
            errors.append(f"summary: missing {sum_key!r}")
        elif final[row_key] != summary[sum_key]:
            errors.append(
                f"summary: ledger {row_key} = {final[row_key]!r} != "
                f"summary {sum_key} = {summary[sum_key]!r} (exact match "
                "required — one accounting, not two)")
    if "rounds" in summary and len(rows) != int(summary["rounds"]):
        errors.append(f"summary: {len(rows)} ledger rows != "
                      f"{summary['rounds']} executed rounds")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", help="Chrome trace-event JSON (--trace-out)")
    ap.add_argument("--ledger", default=None,
                    help="trilemma JSONL ledger (--metrics-out)")
    ap.add_argument("--summary", default=None,
                    help="run summary JSON (train.py --out); requires "
                         "--ledger — cross-checked exactly")
    ap.add_argument("--expect-chunk-traces", type=int, default=None,
                    help="assert compile_stats.scan_chunk_trace == N")
    ap.add_argument("--expect-step-builds", type=int, default=None,
                    help="assert compile_stats.zo_step_build == N")
    ap.add_argument("--stall-tol", type=float, default=1e-3,
                    help="span-sum vs legacy stall counter tolerance (s)")
    ap.add_argument("--require-spans", default=None,
                    help="comma-separated extra span names that must each "
                         "appear at least once (chaos lane: "
                         "retry,prefetch_degraded)")
    ap.add_argument("--require-device-lane", action="store_true",
                    help="assert profiler-merged device-op events are "
                         "present (pid != 0), window-overlap the host "
                         "spans, and otherData.profile records the merge")
    args = ap.parse_args()
    errors = []
    notes = []

    try:
        with open(args.trace) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"check_trace: FAIL (trace unreadable: {e})")
        sys.exit(1)

    meta = check_trace(doc, errors, args.stall_tol) or {}
    check_compile(meta, args, errors)
    if args.require_spans:
        names = {e.get("name") for e in doc.get("traceEvents", [])}
        for want in args.require_spans.split(","):
            want = want.strip()
            if want and want not in names:
                errors.append(f"trace: required span {want!r} absent "
                              "(--require-spans)")
    if args.require_device_lane:
        check_device_lane(doc, meta, errors)
    rows = []
    if args.ledger:
        _, rows = check_ledger(args.ledger, errors, notes)
    if args.summary:
        check_summary(args.summary, rows, errors)

    if errors:
        print(f"check_trace: FAIL ({len(errors)} violation(s))")
        for e in errors:
            print(f"  {e}")
        sys.exit(1)
    for note in notes:
        print(f"check_trace: note: {note}")
    n_events = len(doc.get("traceEvents", []))
    print(f"check_trace: OK ({n_events} trace events"
          + (f", {len(rows)} ledger rows" if args.ledger else "")
          + (", summary cross-checked" if args.summary else "") + ")")


if __name__ == "__main__":
    main()
