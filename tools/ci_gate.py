"""CI gate: "no worse than the checked-in baseline".

    python tools/ci_gate.py <junit.xml> <known_failures.txt>

Parses a pytest junit report and compares the set of failing/erroring test
ids against the baseline file. Exit 1 iff a test OUTSIDE the baseline
failed (a regression). Tests in the baseline that now pass are reported so
the baseline can be shrunk — the gate never requires them to keep failing.

Baseline format: one test id per line ("tests/test_x.py::test_y[param]"),
'#' comments and blank lines ignored.
"""
from __future__ import annotations

import os
import sys
import xml.etree.ElementTree as ET


def _classname_to_id(classname: str, name: str) -> str:
    """pytest junit classname is "tests.test_foo[.TestClass[.Nested]]".
    Resolve the module/class split against the filesystem (run from the
    repo root, as CI does): the longest dotted prefix that exists as a .py
    file is the module; the rest are class qualifiers."""
    parts = classname.split(".") if classname else []
    for cut in range(len(parts), 0, -1):
        mod = "/".join(parts[:cut]) + ".py"
        if os.path.exists(mod):
            return "::".join([mod, *parts[cut:], name])
    return (classname.replace(".", "/") + ".py::" + name) if classname \
        else f"?::{name}"


def junit_failures(path: str) -> set:
    ids = set()
    root = ET.parse(path).getroot()
    for case in root.iter("testcase"):
        if case.find("failure") is None and case.find("error") is None:
            continue
        ids.add(_classname_to_id(case.get("classname", ""),
                                 case.get("name", "")))
    return ids


def load_baseline(path: str) -> set:
    out = set()
    with open(path) as f:
        for line in f:
            line = line.split("#", 1)[0].strip()
            if line:
                out.add(line)
    return out


def main() -> int:
    if len(sys.argv) != 3:
        print(__doc__)
        return 2
    failing = junit_failures(sys.argv[1])
    baseline = load_baseline(sys.argv[2])

    new = sorted(failing - baseline)
    fixed = sorted(baseline - failing)
    known = sorted(failing & baseline)

    if known:
        print(f"known failures still failing ({len(known)}):")
        for t in known:
            print(f"  [known] {t}")
    if fixed:
        print(f"baseline entries now passing ({len(fixed)}) — consider "
              "removing them from known_failures.txt:")
        for t in fixed:
            print(f"  [fixed] {t}")
    if new:
        print(f"NEW failures not in the baseline ({len(new)}):")
        for t in new:
            print(f"  [NEW]   {t}")
        # per-module roll-up: a whole new failing module (collection error,
        # missing dep) reads as one line instead of a wall of ids
        by_module: dict = {}
        for t in new:
            by_module[t.split("::", 1)[0]] = \
                by_module.get(t.split("::", 1)[0], 0) + 1
        print("by module: " + ", ".join(
            f"{m} ({n})" for m, n in sorted(by_module.items())))
        print("\ngate: FAIL (regressions above)")
        return 1
    print(f"\ngate: PASS ({len(failing)} failing, all within the "
          f"{len(baseline)}-entry baseline)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
