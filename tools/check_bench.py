"""Validate BENCH_engine.json (schema "bench_engine/v1") and gate CI on it.

    python tools/check_bench.py BENCH_engine.json --min-speedup 1.3

Checks, in order:
  1. schema shape: required top-level keys, grid rows, overlap breakdown —
     a benchmark refactor that silently changes the artifact fails here;
  2. correctness: every engine row is bit-identical to the loop engine;
  3. performance gates:
       - scan speedup_vs_loop >= --min-speedup at --gate-size (default
         opt-125m-reduced, falling back to the first benchmarked size),
       - the prefetch thread reduces the chunk-boundary prep stall vs the
         no-overlap control,
       - the double-buffered checkpoint snapshot stalls the driver less
         than the synchronous device_get baseline.
Exit code 0 on pass; 1 with a reason on any failure.
"""
from __future__ import annotations

import argparse
import json
import sys

REQUIRED_TOP = ("schema", "created_unix", "host", "config", "sizes",
                "grid", "overlap")
REQUIRED_ROW = ("size", "engine", "rounds_per_s", "speedup_vs_loop",
                "bit_identical_to_loop", "mesh")
ENGINES = ("loop", "scan", "scan_mesh")


def fail(msg: str) -> None:
    print(f"check_bench: FAIL: {msg}")
    sys.exit(1)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("path")
    ap.add_argument("--min-speedup", type=float, default=1.0,
                    help="required scan speedup over loop at --gate-size")
    ap.add_argument("--gate-size", default="opt-125m-reduced")
    args = ap.parse_args()

    with open(args.path) as f:
        rep = json.load(f)

    # 1. schema ----------------------------------------------------------
    for key in REQUIRED_TOP:
        if key not in rep:
            fail(f"missing top-level key {key!r}")
    if rep["schema"] != "bench_engine/v1":
        fail(f"unknown schema {rep['schema']!r}")
    if not isinstance(rep["grid"], list) or not rep["grid"]:
        fail("empty grid")
    for row in rep["grid"]:
        for key in REQUIRED_ROW:
            if key not in row:
                fail(f"grid row {row.get('size')}/{row.get('engine')} "
                     f"missing {key!r}")
        if row["engine"] not in ENGINES:
            fail(f"unknown engine {row['engine']!r}")
        if not (isinstance(row["rounds_per_s"], (int, float))
                and row["rounds_per_s"] > 0):
            fail(f"non-positive rounds_per_s in {row}")
    ov = rep["overlap"]
    for section, keys in (("prefetch", ("on", "off")),
                          ("checkpoint", ("double_buffer", "sync"))):
        if section not in ov:
            fail(f"overlap missing {section!r}")
        for k in keys:
            if k not in ov[section]:
                fail(f"overlap.{section} missing {k!r}")
    for name, meta in rep["sizes"].items():
        if "param_count" not in meta:
            fail(f"sizes[{name!r}] missing param_count")

    # 2. correctness -----------------------------------------------------
    for row in rep["grid"]:
        if not row["bit_identical_to_loop"]:
            fail(f"{row['size']}/{row['engine']} diverged from loop")

    # 3. performance gates -----------------------------------------------
    gate_size = args.gate_size if any(
        r["size"] == args.gate_size for r in rep["grid"]) \
        else rep["grid"][0]["size"]
    scan_rows = [r for r in rep["grid"]
                 if r["size"] == gate_size and r["engine"] == "scan"]
    if not scan_rows:
        fail(f"no scan row at gate size {gate_size!r}")
    speedup = scan_rows[0]["speedup_vs_loop"]
    if speedup < args.min_speedup:
        fail(f"scan speedup {speedup:.2f}x < required "
             f"{args.min_speedup:.2f}x at {gate_size}")

    pf = ov["prefetch"]
    if pf["on"]["prep_stall_s"] > pf["off"]["prep_stall_s"]:
        fail(f"prefetch did not reduce the boundary prep stall "
             f"(on={pf['on']['prep_stall_s']}s, "
             f"off={pf['off']['prep_stall_s']}s)")
    ck = ov["checkpoint"]
    if ck["double_buffer"]["ckpt_stall_s"] > ck["sync"]["ckpt_stall_s"]:
        fail(f"double-buffered snapshot did not reduce the checkpoint "
             f"stall (db={ck['double_buffer']['ckpt_stall_s']}s, "
             f"sync={ck['sync']['ckpt_stall_s']}s)")

    print(f"check_bench: OK ({args.path}: scan {speedup:.2f}x loop at "
          f"{gate_size}; prefetch stall "
          f"{pf['off']['prep_stall_s']}s -> {pf['on']['prep_stall_s']}s; "
          f"ckpt stall {ck['sync']['ckpt_stall_s']}s -> "
          f"{ck['double_buffer']['ckpt_stall_s']}s)")


if __name__ == "__main__":
    main()
