"""Validate committed benchmark artifacts and gate CI on them.

    python tools/check_bench.py BENCH_engine.json --min-speedup 1.3
    python tools/check_bench.py BENCH_kernels.json --kernels

Default mode (BENCH_engine.json, schema "bench_engine/v1") checks, in order:
  1. schema shape: required top-level keys, grid rows, overlap breakdown —
     a benchmark refactor that silently changes the artifact fails here;
  2. correctness: every engine row is bit-identical to the loop engine;
  3. performance gates:
       - scan speedup_vs_loop >= --min-speedup at --gate-size (default
         opt-125m-reduced, falling back to the first benchmarked size),
       - the prefetch thread reduces the chunk-boundary prep stall vs the
         no-overlap control,
       - the double-buffered checkpoint snapshot stalls the driver less
         than the synchronous device_get baseline.

`--kernels` mode (BENCH_kernels.json, schema "bench_kernels/v1",
produced by benchmarks/kernel_memory.py) checks:
  1. schema shape: chained/fresh/fused rows at every size, per-size
     theta/forward-temp metadata, the gate block;
  2. correctness: fused dual losses bitwise-equal to the fresh (unfused)
     oracle at every size;
  3. performance gates at gate.size:
       - memory_overhead_fused_vs_chained <= --max-mem-ratio (default 0.5):
         the fused path must at least halve what the default unfused mode
         adds over plain inference,
       - dual_speed_fused_vs_fresh >= --min-dual-speed (default 1.0): no
         slowdown vs the mode-matched unfused baseline.
Exit code 0 on pass; 1 with a reason on any failure.
"""
from __future__ import annotations

import argparse
import json
import sys

REQUIRED_TOP = ("schema", "created_unix", "host", "config", "sizes",
                "grid", "overlap")
REQUIRED_ROW = ("size", "engine", "rounds_per_s", "speedup_vs_loop",
                "bit_identical_to_loop", "mesh")
ENGINES = ("loop", "scan", "scan_mesh")

KERNEL_TOP = ("schema", "created_unix", "host", "config", "sizes",
              "grid", "gate", "notes")
KERNEL_ROW = ("size", "mode", "dual_ms", "duals_per_s", "dual_temp_bytes",
              "zo_overhead_bytes", "rounds_per_s", "fused_bitwise_eq_fresh")
KERNEL_MODES = ("chained", "fresh", "fused")
KERNEL_GATE = ("size", "memory_overhead_fused_vs_chained",
               "dual_speed_fused_vs_fresh", "rounds_fused_vs_chained",
               "rounds_fused_vs_fresh")


def fail(msg: str) -> None:
    print(f"check_bench: FAIL: {msg}")
    sys.exit(1)


def check_kernels(rep: dict, args) -> None:
    """Validate + gate BENCH_kernels.json (see module docstring)."""
    # 1. schema ----------------------------------------------------------
    for key in KERNEL_TOP:
        if key not in rep:
            fail(f"missing top-level key {key!r}")
    if rep["schema"] != "bench_kernels/v1":
        fail(f"unknown kernels schema {rep['schema']!r}")
    if not isinstance(rep["grid"], list) or not rep["grid"]:
        fail("empty grid")
    by_size: dict = {}
    for row in rep["grid"]:
        for key in KERNEL_ROW:
            if key not in row:
                fail(f"grid row {row.get('size')}/{row.get('mode')} "
                     f"missing {key!r}")
        if row["mode"] not in KERNEL_MODES:
            fail(f"unknown mode {row['mode']!r}")
        for key in ("dual_ms", "duals_per_s", "rounds_per_s"):
            if not (isinstance(row[key], (int, float)) and row[key] > 0):
                fail(f"non-positive {key} in {row['size']}/{row['mode']}")
        by_size.setdefault(row["size"], {})[row["mode"]] = row
    for name, modes in by_size.items():
        missing = set(KERNEL_MODES) - set(modes)
        if missing:
            fail(f"size {name!r} missing modes {sorted(missing)}")
        for key in ("param_count", "theta_bytes", "forward_temp_bytes"):
            if key not in rep["sizes"].get(name, {}):
                fail(f"sizes[{name!r}] missing {key!r}")
    for key in KERNEL_GATE:
        if key not in rep["gate"]:
            fail(f"gate block missing {key!r}")

    # 2. correctness: fused is bitwise the fresh oracle everywhere -------
    for name, modes in by_size.items():
        if modes["fused"]["fused_bitwise_eq_fresh"] is not True:
            fail(f"{name}: fused dual losses not bitwise-equal to fresh")

    # 3. performance gates at gate.size ----------------------------------
    gate = rep["gate"]
    mem = gate["memory_overhead_fused_vs_chained"]
    if mem > args.max_mem_ratio:
        fail(f"fused ZO memory overhead {mem:.3f}x chained > allowed "
             f"{args.max_mem_ratio:.2f}x at {gate['size']}")
    spd = gate["dual_speed_fused_vs_fresh"]
    if spd < args.min_dual_speed:
        fail(f"fused dual-forward speed {spd:.3f}x fresh < required "
             f"{args.min_dual_speed:.2f}x at {gate['size']}")

    print(f"check_bench: OK ({args.path}: fused ZO overhead {mem:.2f}x "
          f"chained (<= {args.max_mem_ratio:.2f}), dual speed {spd:.2f}x "
          f"fresh (>= {args.min_dual_speed:.2f}) at {gate['size']}; "
          f"fused bitwise-equal to fresh at "
          f"{len(by_size)} size(s))")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("path")
    ap.add_argument("--kernels", action="store_true",
                    help="validate BENCH_kernels.json instead of "
                         "BENCH_engine.json")
    ap.add_argument("--min-speedup", type=float, default=1.0,
                    help="required scan speedup over loop at --gate-size")
    ap.add_argument("--gate-size", default="opt-125m-reduced")
    ap.add_argument("--max-mem-ratio", type=float, default=0.5,
                    help="[--kernels] max fused/chained ZO memory overhead")
    ap.add_argument("--min-dual-speed", type=float, default=1.0,
                    help="[--kernels] min fused/fresh dual-forward speed")
    args = ap.parse_args()

    with open(args.path) as f:
        rep = json.load(f)

    if args.kernels:
        check_kernels(rep, args)
        return

    # 1. schema ----------------------------------------------------------
    for key in REQUIRED_TOP:
        if key not in rep:
            fail(f"missing top-level key {key!r}")
    if rep["schema"] != "bench_engine/v1":
        fail(f"unknown schema {rep['schema']!r}")
    if not isinstance(rep["grid"], list) or not rep["grid"]:
        fail("empty grid")
    for row in rep["grid"]:
        for key in REQUIRED_ROW:
            if key not in row:
                fail(f"grid row {row.get('size')}/{row.get('engine')} "
                     f"missing {key!r}")
        if row["engine"] not in ENGINES:
            fail(f"unknown engine {row['engine']!r}")
        if not (isinstance(row["rounds_per_s"], (int, float))
                and row["rounds_per_s"] > 0):
            fail(f"non-positive rounds_per_s in {row}")
    ov = rep["overlap"]
    for section, keys in (("prefetch", ("on", "off")),
                          ("checkpoint", ("double_buffer", "sync"))):
        if section not in ov:
            fail(f"overlap missing {section!r}")
        for k in keys:
            if k not in ov[section]:
                fail(f"overlap.{section} missing {k!r}")
    for name, meta in rep["sizes"].items():
        if "param_count" not in meta:
            fail(f"sizes[{name!r}] missing param_count")

    # 2. correctness -----------------------------------------------------
    for row in rep["grid"]:
        if not row["bit_identical_to_loop"]:
            fail(f"{row['size']}/{row['engine']} diverged from loop")

    # 3. performance gates -----------------------------------------------
    gate_size = args.gate_size if any(
        r["size"] == args.gate_size for r in rep["grid"]) \
        else rep["grid"][0]["size"]
    scan_rows = [r for r in rep["grid"]
                 if r["size"] == gate_size and r["engine"] == "scan"]
    if not scan_rows:
        fail(f"no scan row at gate size {gate_size!r}")
    speedup = scan_rows[0]["speedup_vs_loop"]
    if speedup < args.min_speedup:
        fail(f"scan speedup {speedup:.2f}x < required "
             f"{args.min_speedup:.2f}x at {gate_size}")

    pf = ov["prefetch"]
    if pf["on"]["prep_stall_s"] > pf["off"]["prep_stall_s"]:
        fail(f"prefetch did not reduce the boundary prep stall "
             f"(on={pf['on']['prep_stall_s']}s, "
             f"off={pf['off']['prep_stall_s']}s)")
    ck = ov["checkpoint"]
    if ck["double_buffer"]["ckpt_stall_s"] > ck["sync"]["ckpt_stall_s"]:
        fail(f"double-buffered snapshot did not reduce the checkpoint "
             f"stall (db={ck['double_buffer']['ckpt_stall_s']}s, "
             f"sync={ck['sync']['ckpt_stall_s']}s)")

    print(f"check_bench: OK ({args.path}: scan {speedup:.2f}x loop at "
          f"{gate_size}; prefetch stall "
          f"{pf['off']['prep_stall_s']}s -> {pf['on']['prep_stall_s']}s; "
          f"ckpt stall {ck['sync']['ckpt_stall_s']}s -> "
          f"{ck['double_buffer']['ckpt_stall_s']}s)")


if __name__ == "__main__":
    main()
