"""Validate committed benchmark artifacts and gate CI on them.

    python tools/check_bench.py BENCH_engine.json --min-speedup 1.3
    python tools/check_bench.py BENCH_kernels.json --kernels
    python tools/check_bench.py results/bench_history.jsonl --history

Default mode (BENCH_engine.json, schema "bench_engine/v3") checks, in order:
  1. schema shape: required top-level keys (including `spans_version` —
     since v2 the overlap stall numbers are sums over the run's
     repro.obs span timeline, not ad-hoc counters), grid rows — since v3
     every row carries a `cost` block from the compiled executable's own
     cost/memory analysis (flops, bytes_accessed, peak_bytes, collective
     census; see repro.obs.hlo) — and the overlap breakdown; a benchmark
     refactor that silently changes the artifact fails here;
  2. correctness: every engine row is bit-identical to the loop engine,
     and each row's `cost` block (when analysis is available) reports
     positive flops and peak_bytes — an all-zero cost block means the
     introspection silently broke;
  3. performance gates:
       - scan speedup_vs_loop >= --min-speedup at --gate-size (default
         opt-125m-reduced, falling back to the first benchmarked size),
       - the prefetch thread reduces the chunk-boundary prep stall vs the
         no-overlap control,
       - the double-buffered checkpoint snapshot stalls the driver less
         than the synchronous device_get baseline.

`--robustness` mode (results/fig_robustness.json, schema
"fig_robustness/v1", produced by benchmarks/fig_robustness.py) checks:
  1. schema shape: config block, per-transport clean rows, grid rows with
     utility + privacy + comm fields;
  2. the gated claim: at the claim cell (25% sign-flip on analog) the best
     registered defense recovered >= the recorded threshold (0.8) of the
     clean-vs-undefended utility gap, and `claim.holds` is true;
  3. privacy under attack: eps_hat <= analytic eps on every audited row
     (`dominated` is never false).

`--desync` mode (results/fig_desync.json, schema "fig_desync/v1",
produced by benchmarks/fig_desync.py) checks:
  1. schema shape: config block, zo/fo cell rows with retained-progress
     fields, the claim block, the torn_fallback block;
  2. the gated claim: at the claim cell (50% stale clients + the recorded
     phase error) the seed-broadcast ZO uplink retained >= its recorded
     threshold of clean-run loss progress while the n-symbol FO frame
     retained <= its collapse threshold, and `claim.holds` is true;
  3. crash consistency: the torn-checkpoint fallback rehearsal was
     exercised, fell back past the torn write, and resumed to a final
     state bitwise-equal to the uninterrupted run.

`--kernels` mode (BENCH_kernels.json, schema "bench_kernels/v1",
produced by benchmarks/kernel_memory.py) checks:
  1. schema shape: chained/fresh/fused rows at every size, per-size
     theta/forward-temp metadata, the gate block;
  2. correctness: fused dual losses bitwise-equal to the fresh (unfused)
     oracle at every size;
  3. performance gates at gate.size:
       - memory_overhead_fused_vs_chained <= --max-mem-ratio (default 0.5):
         the fused path must at least halve what the default unfused mode
         adds over plain inference,
       - dual_speed_fused_vs_fresh >= --min-dual-speed (default 1.0): no
         slowdown vs the mode-matched unfused baseline.

`--history` mode (results/bench_history.jsonl, schema "bench_history/v1",
appended by `engine_throughput.py --history` / `kernel_memory.py
--history` via tools/bench_history.py) checks:
  1. schema shape: every row carries kind/git_sha/host/metrics and the
     per-kind gate metric (engine: scan_rounds_per_s; kernels:
     fused_duals_per_s) as a positive number;
  2. the regression gate: within each (kind, host-signature) group —
     rows from different machines or device counts never compare — the
     NEWEST row's gate metric must be >= (1 - --max-regression) of the
     rolling best of the earlier rows in its group (default 0.3: a >30%
     throughput drop on the same hardware fails CI).
Exit code 0 on pass; 1 with a reason on any failure.
"""
from __future__ import annotations

import argparse
import json
import sys

REQUIRED_TOP = ("schema", "spans_version", "created_unix", "host",
                "config", "sizes", "grid", "overlap")
REQUIRED_ROW = ("size", "engine", "rounds_per_s", "speedup_vs_loop",
                "bit_identical_to_loop", "mesh", "cost")
ENGINES = ("loop", "scan", "scan_mesh")

HISTORY_SCHEMA = "bench_history/v1"
HISTORY_ROW = ("schema", "kind", "created_unix", "git_sha", "host",
               "metrics")
HISTORY_GATE = {"engine": "scan_rounds_per_s",
                "kernels": "fused_duals_per_s"}

KERNEL_TOP = ("schema", "created_unix", "host", "config", "sizes",
              "grid", "gate", "notes")
KERNEL_ROW = ("size", "mode", "dual_ms", "duals_per_s", "dual_temp_bytes",
              "zo_overhead_bytes", "rounds_per_s", "fused_bitwise_eq_fresh")
KERNEL_MODES = ("chained", "fresh", "fused")
KERNEL_GATE = ("size", "memory_overhead_fused_vs_chained",
               "dual_speed_fused_vs_fresh", "rounds_fused_vs_chained",
               "rounds_fused_vs_fresh")


DESYNC_TOP = ("schema", "created_unix", "config", "zo", "fo", "claim",
              "torn_fallback")
DESYNC_ROW = ("mechanism", "stale_fraction", "phase_std", "frame_symbols",
              "rounds", "first_loss", "final_loss", "uplink_bits",
              "retained")
DESYNC_CLAIM = ("stale_fraction", "phase_std", "frame_symbols",
                "zo_retained", "zo_threshold", "fo_retained",
                "fo_threshold", "holds")
DESYNC_TORN = ("exercised", "fell_back", "resumed_from", "torn_step",
               "bitwise_equal")

ROBUST_TOP = ("schema", "created_unix", "config", "clean", "rows", "claim")
ROBUST_ROW = ("transport", "behavior", "fraction", "defense", "rounds",
              "final_loss", "accuracy", "uplink_bits", "privacy_spent",
              "eps_hat", "eps_analytic", "dominated")
ROBUST_CLAIM = ("transport", "behavior", "fraction", "best_defense",
                "gap_recovery", "metric", "threshold", "holds")


def fail(msg: str) -> None:
    print(f"check_bench: FAIL: {msg}")
    sys.exit(1)


def check_robustness(rep: dict, args) -> None:
    """Validate + gate results/fig_robustness.json (see module docstring)."""
    # 1. schema ----------------------------------------------------------
    for key in ROBUST_TOP:
        if key not in rep:
            fail(f"missing top-level key {key!r}")
    if rep["schema"] != "fig_robustness/v1":
        fail(f"unknown robustness schema {rep['schema']!r}")
    if not isinstance(rep["rows"], list) or not rep["rows"]:
        fail("empty rows")
    for tname in rep["config"].get("transports", ()):
        if tname not in rep["clean"]:
            fail(f"no clean reference row for transport {tname!r}")
    for row in rep["rows"]:
        for key in ROBUST_ROW:
            if key not in row:
                fail(f"row {row.get('transport')}/{row.get('behavior')}/"
                     f"{row.get('defense')} missing {key!r}")
        if not (isinstance(row["final_loss"], (int, float))
                and row["final_loss"] > 0):
            fail(f"non-positive final_loss in {row['transport']}/"
                 f"{row['behavior']}/{row['defense']}")

    # 2. the gated claim -------------------------------------------------
    claim = rep["claim"]
    for key in ROBUST_CLAIM:
        if key not in claim:
            fail(f"claim block missing {key!r}")
    if claim["holds"] is not True:
        fail(f"robustness claim does not hold: best defense "
             f"{claim.get('best_defense')!r} recovered "
             f"{claim.get('gap_recovery')} of the {claim.get('metric')} "
             f"gap (threshold {claim.get('threshold')})")
    if claim["gap_recovery"] < claim["threshold"]:
        fail(f"claim.holds is true but gap_recovery "
             f"{claim['gap_recovery']:.3f} < threshold "
             f"{claim['threshold']:.2f} — inconsistent artifact")

    # 3. privacy under attack --------------------------------------------
    for row in rep["rows"]:
        if row["dominated"] is False:
            fail(f"{row['transport']}/{row['behavior']}/{row['defense']}: "
                 "eps_hat exceeds analytic eps under attack")

    audited = sum(1 for r in rep["rows"] if r["dominated"] is True)
    print(f"check_bench: OK ({args.path}: {claim['best_defense']} recovers "
          f"{claim['gap_recovery']:.2f} of the {claim['metric']} gap at "
          f"{claim['fraction']:.0%} {claim['behavior']} on "
          f"{claim['transport']} (>= {claim['threshold']:.2f}); "
          f"eps_hat <= analytic eps on {audited} audited row(s))")


def check_desync(rep: dict, args) -> None:
    """Validate + gate results/fig_desync.json (see module docstring)."""
    # 1. schema ----------------------------------------------------------
    for key in DESYNC_TOP:
        if key not in rep:
            fail(f"missing top-level key {key!r}")
    if rep["schema"] != "fig_desync/v1":
        fail(f"unknown desync schema {rep['schema']!r}")
    for block in ("zo", "fo"):
        if not isinstance(rep[block], list) or not rep[block]:
            fail(f"empty {block} rows")
        for row in rep[block]:
            for key in DESYNC_ROW:
                if key not in row:
                    fail(f"{block} row stale={row.get('stale_fraction')} "
                         f"missing {key!r}")
            if not (isinstance(row["final_loss"], (int, float))
                    and row["final_loss"] > 0):
                fail(f"non-positive final_loss in {block} row "
                     f"stale={row.get('stale_fraction')}")

    # 2. the gated claim -------------------------------------------------
    claim = rep["claim"]
    for key in DESYNC_CLAIM:
        if key not in claim:
            fail(f"claim block missing {key!r}")
    if claim["holds"] is not True:
        fail(f"desync claim does not hold: zo retained "
             f"{claim.get('zo_retained')}, fo retained "
             f"{claim.get('fo_retained')}")
    if claim["zo_retained"] < claim["zo_threshold"]:
        fail(f"claim.holds is true but zo_retained "
             f"{claim['zo_retained']:.3f} < threshold "
             f"{claim['zo_threshold']:.2f} — inconsistent artifact")
    if claim["fo_retained"] > claim["fo_threshold"]:
        fail(f"claim.holds is true but fo_retained "
             f"{claim['fo_retained']:.3f} > threshold "
             f"{claim['fo_threshold']:.2f} — inconsistent artifact")

    # 3. crash consistency ----------------------------------------------
    torn = rep["torn_fallback"]
    for key in DESYNC_TORN:
        if key not in torn:
            fail(f"torn_fallback block missing {key!r}")
    if torn["exercised"] is not True:
        fail("torn_fallback rehearsal was not exercised")
    if torn["fell_back"] is not True:
        fail("torn checkpoint did not force a fallback (latest_valid "
             "returned the torn one)")
    if torn["bitwise_equal"] is not True:
        fail("torn-fallback resume diverged bitwise from the "
             "uninterrupted run")

    print(f"check_bench: OK ({args.path}: zo retains "
          f"{claim['zo_retained']:.2f} (>= {claim['zo_threshold']:.2f}) "
          f"vs fo {claim['fo_retained']:.2f} "
          f"(<= {claim['fo_threshold']:.2f}) at "
          f"{claim['stale_fraction']:.0%} stale; torn fallback resumed "
          f"from {torn['resumed_from']} bitwise-equal)")


def check_history(path: str, args) -> None:
    """Validate + gate results/bench_history.jsonl (see module docstring)."""
    rows = []
    with open(path) as f:
        for i, ln in enumerate(f):
            if not ln.strip():
                continue
            try:
                rows.append(json.loads(ln))
            except json.JSONDecodeError as e:
                fail(f"history line {i + 1} unparsable ({e}) — the "
                     "ledger is append-only; fix the bad merge")
    if not rows:
        fail("empty history — run a benchmark with --history first")

    # 1. schema ----------------------------------------------------------
    for i, row in enumerate(rows):
        for key in HISTORY_ROW:
            if key not in row:
                fail(f"history row {i} missing {key!r}")
        if row["schema"] != HISTORY_SCHEMA:
            fail(f"history row {i}: unknown schema {row['schema']!r}")
        if row["kind"] not in HISTORY_GATE:
            fail(f"history row {i}: unknown kind {row['kind']!r}")
        for key in ("platform", "devices", "machine"):
            if key not in row["host"]:
                fail(f"history row {i}: host missing {key!r}")
        gate = HISTORY_GATE[row["kind"]]
        val = row["metrics"].get(gate)
        if not (isinstance(val, (int, float)) and val > 0):
            fail(f"history row {i} ({row['kind']}): gate metric "
                 f"{gate!r} must be a positive number, got {val!r}")

    # 2. regression gate within each (kind, host-signature) group --------
    groups: dict = {}
    for row in rows:
        host = row["host"]
        key = (row["kind"], host["platform"], host["devices"],
               host["machine"])
        groups.setdefault(key, []).append(row)
    gated = 0
    for key, grp in groups.items():
        if len(grp) < 2:
            continue            # first row on this hardware: baseline only
        gate = HISTORY_GATE[key[0]]
        newest = grp[-1]["metrics"][gate]
        best = max(r["metrics"][gate] for r in grp[:-1])
        floor = best * (1.0 - args.max_regression)
        if newest < floor:
            fail(f"{key[0]} on {key[1]}/{key[2]}dev/{key[3]}: newest "
                 f"{gate} = {newest:.2f} < {floor:.2f} "
                 f"(rolling best {best:.2f}, allowed regression "
                 f"{args.max_regression:.0%}) — sha "
                 f"{grp[-1].get('git_sha')} regressed vs "
                 f"{max(grp[:-1], key=lambda r: r['metrics'][gate]).get('git_sha')}")
        gated += 1
    print(f"check_bench: OK ({path}: {len(rows)} history row(s), "
          f"{len(groups)} host group(s), {gated} regression-gated, "
          f"max allowed drop {args.max_regression:.0%})")


def check_kernels(rep: dict, args) -> None:
    """Validate + gate BENCH_kernels.json (see module docstring)."""
    # 1. schema ----------------------------------------------------------
    for key in KERNEL_TOP:
        if key not in rep:
            fail(f"missing top-level key {key!r}")
    if rep["schema"] != "bench_kernels/v1":
        fail(f"unknown kernels schema {rep['schema']!r}")
    if not isinstance(rep["grid"], list) or not rep["grid"]:
        fail("empty grid")
    by_size: dict = {}
    for row in rep["grid"]:
        for key in KERNEL_ROW:
            if key not in row:
                fail(f"grid row {row.get('size')}/{row.get('mode')} "
                     f"missing {key!r}")
        if row["mode"] not in KERNEL_MODES:
            fail(f"unknown mode {row['mode']!r}")
        for key in ("dual_ms", "duals_per_s", "rounds_per_s"):
            if not (isinstance(row[key], (int, float)) and row[key] > 0):
                fail(f"non-positive {key} in {row['size']}/{row['mode']}")
        by_size.setdefault(row["size"], {})[row["mode"]] = row
    for name, modes in by_size.items():
        missing = set(KERNEL_MODES) - set(modes)
        if missing:
            fail(f"size {name!r} missing modes {sorted(missing)}")
        for key in ("param_count", "theta_bytes", "forward_temp_bytes"):
            if key not in rep["sizes"].get(name, {}):
                fail(f"sizes[{name!r}] missing {key!r}")
    for key in KERNEL_GATE:
        if key not in rep["gate"]:
            fail(f"gate block missing {key!r}")

    # 2. correctness: fused is bitwise the fresh oracle everywhere -------
    for name, modes in by_size.items():
        if modes["fused"]["fused_bitwise_eq_fresh"] is not True:
            fail(f"{name}: fused dual losses not bitwise-equal to fresh")

    # 3. performance gates at gate.size ----------------------------------
    gate = rep["gate"]
    mem = gate["memory_overhead_fused_vs_chained"]
    if mem > args.max_mem_ratio:
        fail(f"fused ZO memory overhead {mem:.3f}x chained > allowed "
             f"{args.max_mem_ratio:.2f}x at {gate['size']}")
    spd = gate["dual_speed_fused_vs_fresh"]
    if spd < args.min_dual_speed:
        fail(f"fused dual-forward speed {spd:.3f}x fresh < required "
             f"{args.min_dual_speed:.2f}x at {gate['size']}")

    print(f"check_bench: OK ({args.path}: fused ZO overhead {mem:.2f}x "
          f"chained (<= {args.max_mem_ratio:.2f}), dual speed {spd:.2f}x "
          f"fresh (>= {args.min_dual_speed:.2f}) at {gate['size']}; "
          f"fused bitwise-equal to fresh at "
          f"{len(by_size)} size(s))")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("path")
    ap.add_argument("--kernels", action="store_true",
                    help="validate BENCH_kernels.json instead of "
                         "BENCH_engine.json")
    ap.add_argument("--robustness", action="store_true",
                    help="validate results/fig_robustness.json instead of "
                         "BENCH_engine.json")
    ap.add_argument("--desync", action="store_true",
                    help="validate results/fig_desync.json instead of "
                         "BENCH_engine.json")
    ap.add_argument("--history", action="store_true",
                    help="validate + regression-gate a bench_history "
                         "JSONL ledger instead of BENCH_engine.json")
    ap.add_argument("--max-regression", type=float, default=0.3,
                    help="[--history] allowed fractional drop of the gate "
                         "metric vs the rolling best on the same "
                         "hardware (default 0.3)")
    ap.add_argument("--min-speedup", type=float, default=1.0,
                    help="required scan speedup over loop at --gate-size")
    ap.add_argument("--gate-size", default="opt-125m-reduced")
    ap.add_argument("--max-mem-ratio", type=float, default=0.5,
                    help="[--kernels] max fused/chained ZO memory overhead")
    ap.add_argument("--min-dual-speed", type=float, default=1.0,
                    help="[--kernels] min fused/fresh dual-forward speed")
    args = ap.parse_args()

    if args.history:            # JSONL ledger, not a single JSON doc
        check_history(args.path, args)
        return

    with open(args.path) as f:
        rep = json.load(f)

    if args.kernels:
        check_kernels(rep, args)
        return
    if args.robustness:
        check_robustness(rep, args)
        return
    if args.desync:
        check_desync(rep, args)
        return

    # 1. schema ----------------------------------------------------------
    for key in REQUIRED_TOP:
        if key not in rep:
            fail(f"missing top-level key {key!r}")
    if rep["schema"] != "bench_engine/v3":
        fail(f"unknown schema {rep['schema']!r}")
    if not (isinstance(rep["spans_version"], int)
            and rep["spans_version"] >= 1):
        fail(f"spans_version must be a positive int, got "
             f"{rep['spans_version']!r}")
    if not isinstance(rep["grid"], list) or not rep["grid"]:
        fail("empty grid")
    for row in rep["grid"]:
        for key in REQUIRED_ROW:
            if key not in row:
                fail(f"grid row {row.get('size')}/{row.get('engine')} "
                     f"missing {key!r}")
        if row["engine"] not in ENGINES:
            fail(f"unknown engine {row['engine']!r}")
        if not (isinstance(row["rounds_per_s"], (int, float))
                and row["rounds_per_s"] > 0):
            fail(f"non-positive rounds_per_s in {row}")
    ov = rep["overlap"]
    for section, keys, span_key in (
            ("prefetch", ("on", "off"), "prep_stall_spans"),
            ("checkpoint", ("double_buffer", "sync"),
             "ckpt_snapshot_spans")):
        if section not in ov:
            fail(f"overlap missing {section!r}")
        for k in keys:
            if k not in ov[section]:
                fail(f"overlap.{section} missing {k!r}")
            if span_key not in ov[section][k]:
                fail(f"overlap.{section}.{k} missing {span_key!r} — "
                     "v2 stall numbers must be span-derived")
    for name, meta in rep["sizes"].items():
        if "param_count" not in meta:
            fail(f"sizes[{name!r}] missing param_count")

    # 2. correctness -----------------------------------------------------
    for row in rep["grid"]:
        if not row["bit_identical_to_loop"]:
            fail(f"{row['size']}/{row['engine']} diverged from loop")
        # v3: compiled-executor introspection rode along; an all-zero
        # block means the analysis silently broke (None = unavailable on
        # this backend, which is legal)
        cost = row["cost"]
        if cost is not None:
            for key in ("flops", "peak_bytes"):
                if not (isinstance(cost.get(key), (int, float))
                        and cost[key] > 0):
                    fail(f"{row['size']}/{row['engine']}: cost.{key} must "
                         f"be positive, got {cost.get(key)!r} — HLO "
                         "introspection broke")

    # 3. performance gates -----------------------------------------------
    gate_size = args.gate_size if any(
        r["size"] == args.gate_size for r in rep["grid"]) \
        else rep["grid"][0]["size"]
    scan_rows = [r for r in rep["grid"]
                 if r["size"] == gate_size and r["engine"] == "scan"]
    if not scan_rows:
        fail(f"no scan row at gate size {gate_size!r}")
    speedup = scan_rows[0]["speedup_vs_loop"]
    if speedup < args.min_speedup:
        fail(f"scan speedup {speedup:.2f}x < required "
             f"{args.min_speedup:.2f}x at {gate_size}")

    pf = ov["prefetch"]
    if pf["on"]["prep_stall_s"] > pf["off"]["prep_stall_s"]:
        fail(f"prefetch did not reduce the boundary prep stall "
             f"(on={pf['on']['prep_stall_s']}s, "
             f"off={pf['off']['prep_stall_s']}s)")
    ck = ov["checkpoint"]
    if ck["double_buffer"]["ckpt_stall_s"] > ck["sync"]["ckpt_stall_s"]:
        fail(f"double-buffered snapshot did not reduce the checkpoint "
             f"stall (db={ck['double_buffer']['ckpt_stall_s']}s, "
             f"sync={ck['sync']['ckpt_stall_s']}s)")

    print(f"check_bench: OK ({args.path}: scan {speedup:.2f}x loop at "
          f"{gate_size}; prefetch stall "
          f"{pf['off']['prep_stall_s']}s -> {pf['on']['prep_stall_s']}s; "
          f"ckpt stall {ck['sync']['ckpt_stall_s']}s -> "
          f"{ck['double_buffer']['ckpt_stall_s']}s)")


if __name__ == "__main__":
    main()
