"""Generate the EXPERIMENTS.md §Dry-run + §Roofline tables from results/.

    PYTHONPATH=src python -m benchmarks.report [--baseline results/dryrun_baseline.json]
                                               [--current results/dryrun.json]

Prints markdown; EXPERIMENTS.md embeds the output.
"""
from __future__ import annotations

import argparse
import json


def fmt_gb(b):
    return f"{b / 1e9:.2f}"


def dryrun_table(rows):
    print("| arch | shape | mesh | status | peak GB/dev | compile s |")
    print("|---|---|---|---|---|---|")
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r["status"] == "ok":
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
                  f"{fmt_gb(r['memory']['peak_bytes_per_device'])} | "
                  f"{r['compile_s']} |")
        else:
            note = "skip (long_500k/full-attn)" if r["status"] == "skipped" \
                else f"FAIL {r.get('error', '')[:60]}"
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | {note} | "
                  f"- | - |")


def roofline_table(rows, base=None):
    base_map = {}
    if base:
        base_map = {(r["arch"], r["shape"]): r for r in base
                    if r.get("roofline") and r["mesh"] == "pod16x16"}
    print("| arch | shape | compute s | memory s | collective s | dominant "
          "| MODEL/HLO | vs baseline (dom. term) |")
    print("|---|---|---|---|---|---|---|---|")
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if not r.get("roofline") or r["mesh"] != "pod16x16":
            continue
        rf = r["roofline"]
        delta = ""
        b = base_map.get((r["arch"], r["shape"]))
        if b:
            bf = b["roofline"]
            dom = bf["dominant"] + "_s"
            before, after = bf[dom], rf[dom]
            if before > 0 and abs(before - after) / before > 0.02:
                delta = f"{before / max(after, 1e-9):.1f}× better"
            else:
                delta = "="
        print(f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.3f} | "
              f"{rf['memory_s']:.3f} | {rf['collective_s']:.3f} | "
              f"{rf['dominant']} | {rf['useful_ratio']:.2f} | {delta} |")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--current", default="results/dryrun.json")
    ap.add_argument("--baseline", default="results/dryrun_baseline.json")
    ap.add_argument("--section", default="all",
                    choices=["all", "dryrun", "roofline"])
    args = ap.parse_args()
    rows = json.load(open(args.current))
    base = None
    try:
        base = json.load(open(args.baseline))
    except OSError:
        pass

    ok = sum(1 for r in rows if r["status"] == "ok")
    sk = sum(1 for r in rows if r["status"] == "skipped")
    fail = sum(1 for r in rows if r["status"] == "failed")
    print(f"**{ok} compiled, {sk} skipped (documented), {fail} failed** "
          f"of {len(rows)} cells.\n")
    if args.section in ("all", "dryrun"):
        dryrun_table(rows)
        print()
    if args.section in ("all", "roofline"):
        roofline_table(rows, base)


if __name__ == "__main__":
    main()
