"""Roofline report: renders results/dryrun.json into the §Roofline table.

    PYTHONPATH=src python -m benchmarks.roofline [--json results/dryrun.json]

Per (arch × shape) single-pod cell: the three roofline terms (seconds), the
dominant bottleneck, MODEL_FLOPS/HLO_FLOPs, bytes/device, and one line on
what would move the dominant term (the §Perf worklist).
"""
from __future__ import annotations

import argparse
import json

MOVES = {
    ("compute",): "raise per-chip batch or quantize (int8) — MXU-bound",
    ("memory",): "Pallas flash attention / fused scans cut HBM traffic "
                 "(XLA fallback materializes attention block transients)",
    ("collective",): "bf16 psums + sequence-sharded activations cut TP "
                     "all-reduce bytes; overlap FSDP gathers under scan",
}


def move_hint(dom: str) -> str:
    return MOVES.get((dom,), "")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="results/dryrun.json")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()

    rows = json.load(open(args.json))
    cells = [r for r in rows if r.get("roofline") and r["mesh"] == "pod16x16"]
    cells.sort(key=lambda r: (r["arch"], r["shape"]))

    hdr = (f"{'arch':24s} {'shape':12s} {'compute_s':>10s} {'memory_s':>9s} "
           f"{'coll_s':>8s} {'dominant':>10s} {'useful':>7s} {'peakGB':>7s}")
    print(hdr)
    print("-" * len(hdr))
    for r in cells:
        rf = r["roofline"]
        print(f"{r['arch']:24s} {r['shape']:12s} {rf['compute_s']:10.3f} "
              f"{rf['memory_s']:9.3f} {rf['collective_s']:8.3f} "
              f"{rf['dominant']:>10s} {rf['useful_ratio']:7.2f} "
              f"{r['memory']['peak_bytes_per_device'] / 1e9:7.2f}")
    sk = [r for r in rows if r["status"] == "skipped"
          and r["mesh"] == "pod16x16"]
    print(f"\n{len(cells)} baselined cells, {len(sk)} skipped "
          f"(long_500k × full-attention archs)")

    # dominant-term census → the hillclimb worklist
    census = {}
    for r in cells:
        census.setdefault(r["roofline"]["dominant"], []).append(
            f"{r['arch']}×{r['shape']}")
    print("\nbottleneck census:")
    for dom, items in sorted(census.items()):
        print(f"  {dom}: {len(items)} cells — fix: {move_hint(dom)}")


if __name__ == "__main__":
    main()
