"""Fig. 2 reproduction (reduced scale): pAirZero / Sign-pAirZero vs SNR_max.

Paper setting: OPT-125M, SST-2 + SQuAD, K=5, ε=5, δ=0.01, T=8000, lr grid of
Table I. Reduced setting (CPU): tiny same-family transformer, synthetic
task analogues, T configurable (default 400), lr grid scaled to the model.

    PYTHONPATH=src python -m benchmarks.fig2_main_results \
        [--rounds 400] [--task sst2] [--snrs 0,10,20] [--grid] \
        [--channel rician] [--csi-phase-err 0.1] [--mechanisms analog,sign]

The run grid speaks TransportConfig + ChannelConfig, so any registered
transport or channel model appears in Fig. 2 by naming it — no legacy
variant/scheme strings, no shims.

Writes results/fig2_<task>.json and prints a summary table: for each SNR,
accuracy of each mechanism point (default: Perfect, pAirZero(Solution),
Sign-pAirZero(Solution)).
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from repro.configs.base import (ChannelConfig, DPConfig, ModelConfig,
                                PairZeroConfig, PowerControlConfig,
                                TransportConfig, ZOConfig)
from repro.core import fedsim
from repro.data.pipeline import FederatedPipeline
from repro.data.tasks import TaskSpec

TINY = ModelConfig(name="tiny-opt", family="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=4, d_ff=256, vocab_size=64,
                   head_dim=16)

# The Fig. 2 curves as (label, TransportConfig) points. Any registered
# mechanism slots in here (or via --mechanisms) without touching run_point.
CURVES = {
    "perfect": TransportConfig("perfect", "perfect"),
    "pairzero": TransportConfig("analog", "solution"),
    "sign_pairzero": TransportConfig("sign", "solution"),
    "analog": TransportConfig("analog", "solution"),
    "sign": TransportConfig("sign", "solution"),
    "digital": TransportConfig("digital", quant_bits=8),
    # FedZO-style seed-and-scalar digital: the strongest digital competitor
    # on comm (b bits/slot instead of b·d) — still no privacy (Fig. privacy)
    "smart_digital": TransportConfig("smart_digital", quant_bits=8),
}

# Table I analogue, scaled to the reduced model (paper grid spans 1.5 orders
# of magnitude around the selected value; ours does the same)
LR_GRID = {"sign": (5e-3, 2e-2, 5e-2)}
LR_GRID_DEFAULT = (2e-3, 5e-3, 1e-2)


def run_point(task, tc: TransportConfig, snr_db, rounds, lr, seed=0,
              epsilon=5.0, channel_kw=None):
    d = 1  # payload dimension per round (one scalar)
    n0 = 1.0
    power = n0 * d * (10 ** (snr_db / 10.0))
    pz = PairZeroConfig(
        n_clients=5, rounds=rounds,
        zo=ZOConfig(mu=1e-3, lr=lr, clip_gamma=5.0, n_perturb=4),
        channel=ChannelConfig(n0=n0, power=power, d=d, **(channel_kw or {})),
        dp=DPConfig(epsilon=epsilon, delta=0.01),
        power=PowerControlConfig(scheme=tc.scheme),
        transport=tc, seed=seed)
    pipe = FederatedPipeline(task=task, spec=TaskSpec(task, 64, 24),
                             n_clients=5, per_client_batch=8, seed=seed)
    res = fedsim.run(TINY, pz, pipe, rounds=rounds,
                     eval_every=rounds, eval_n=512)
    return res.accuracies[-1], float(np.mean(res.losses[-20:]))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=400)
    ap.add_argument("--task", default="sst2", choices=["sst2", "squad"])
    ap.add_argument("--snrs", default="0,10,20")
    ap.add_argument("--grid", action="store_true",
                    help="grid-search lr per point (Table I protocol)")
    ap.add_argument("--epsilon", type=float, default=5.0,
                    help="paper setting ε=5 requires its T=8000 horizon; "
                         "ε=50 shows the SNR trend at the reduced T")
    ap.add_argument("--trials", type=int, default=1)
    ap.add_argument("--mechanisms",
                    default="perfect,pairzero,sign_pairzero",
                    help=f"comma-separated curve labels from {list(CURVES)}")
    ap.add_argument("--channel", default=None,
                    help="channel-registry model for every point "
                         "(default rayleigh)")
    ap.add_argument("--rician-k", type=float, default=3.0)
    ap.add_argument("--csi-phase-err", type=float, default=0.0)
    ap.add_argument("--outage-db", type=float, default=None)
    args = ap.parse_args()
    snrs = [float(s) for s in args.snrs.split(",")]
    channel_kw = dict(model=args.channel, rician_k=args.rician_k,
                      phase_err_std=args.csi_phase_err,
                      outage_db=args.outage_db)

    rows = []
    for snr in snrs:
        row = {"snr_db": snr}
        for label in args.mechanisms.split(","):
            tc = CURVES[label]
            lrs = LR_GRID.get(tc.mechanism, LR_GRID_DEFAULT)
            if not args.grid:
                lrs = lrs[1:2]
            best = None
            for lr in lrs:
                accs = []
                for trial in range(args.trials):
                    acc, loss = run_point(args.task, tc, snr,
                                          args.rounds, lr, seed=trial,
                                          epsilon=args.epsilon,
                                          channel_kw=channel_kw)
                    accs.append(acc)
                acc = float(np.mean(accs))
                if best is None or acc > best[0]:
                    best = (acc, loss, lr)
            row[label] = {"acc": best[0], "loss": best[1], "lr": best[2]}
            print(f"snr={snr:5.1f}dB {label:14s} acc={best[0]:.3f} "
                  f"(lr={best[2]})", flush=True)
        rows.append(row)

    os.makedirs("results", exist_ok=True)
    out = f"results/fig2_{args.task}_eps{args.epsilon:g}.json"
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"\nwrote {out}")


if __name__ == "__main__":
    main()
