"""Benchmark harness: one entry per paper table/figure + substrate micros.

Prints ``name,us_per_call,derived`` CSV. Each fig*/table* row is a REDUCED
but faithful version of the corresponding paper artifact (deep versions live
in the sibling modules: fig2_main_results, fig3_power_allocation,
fig4_sign_reversing, fig7_projection_dist, table2_memory_comm, roofline).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, time_call


def _tiny():
    from repro.configs.base import ModelConfig
    return ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=4, d_ff=256, vocab_size=64,
                       head_dim=16)


def bench_zo_step():
    """ZO train-step wall time (tiny model) + payload accounting."""
    from repro.configs.base import (PairZeroConfig, PowerControlConfig,
                                    ZOConfig)
    from repro.core import pairzero, power_control as pc
    from repro.models import registry
    cfg = _tiny()
    pz = PairZeroConfig(variant="analog", n_clients=5,
                        zo=ZOConfig(mu=1e-3, lr=5e-3, clip_gamma=5.0),
                        power=PowerControlConfig(scheme="perfect"))
    params = registry.init_params(jax.random.key(0), cfg, jnp.float32)
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, 64, (5, 8, 24)), jnp.int32),
        "targets": jnp.asarray(rng.integers(0, 64, (5, 8, 24)), jnp.int32),
        "mask": jnp.ones((5, 8, 24), jnp.float32),
    }
    sched = pc.PowerSchedule(c=np.ones(4), sigma=np.zeros((4, 5)),
                             scheme="perfect", n0=0.0)
    ctl = pairzero.make_control(0, sched, 0, 5)
    step = jax.jit(pairzero.make_zo_step(cfg, pz))
    us = time_call(lambda: step(params, batch, ctl)[1]["loss"])
    d = registry.count_params(cfg)
    print(csv_row("zo_train_step_tiny", us,
                  f"uplink=16bits vs FO={2 * d}B ({d}params)"))


def bench_fo_step():
    from repro.configs.base import PairZeroConfig, ZOConfig
    from repro.core import pairzero, power_control as pc
    from repro.models import registry
    from repro.optim import fo
    cfg = _tiny()
    params = registry.init_params(jax.random.key(0), cfg, jnp.float32)
    opt = fo.Adam(lr=1e-3)
    opt_state = opt.init(params)
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, 64, (5, 8, 24)), jnp.int32),
        "targets": jnp.asarray(rng.integers(0, 64, (5, 8, 24)), jnp.int32),
        "mask": jnp.ones((5, 8, 24), jnp.float32),
    }
    sched = pc.PowerSchedule(c=np.ones(4), sigma=np.zeros((4, 5)),
                             scheme="perfect", n0=0.0)
    ctl = pairzero.make_control(0, sched, 0, 5)
    step = jax.jit(pairzero.make_fo_step(cfg, opt))
    us = time_call(lambda: step(params, opt_state, batch, ctl)[2]["loss"])
    print(csv_row("fo_adam_step_tiny", us, "baseline(backprop+2moments)"))


def bench_ota():
    from repro.core import ota
    p = jnp.asarray(np.random.default_rng(0).normal(size=32), jnp.float32)
    sig = jnp.zeros(32)
    fn = jax.jit(lambda p, k: ota.analog_ota(p, jnp.float32(1.0), sig,
                                             jnp.float32(1.0), k)[0])
    us = time_call(lambda: fn(p, jax.random.key(1)))
    print(csv_row("ota_aggregate_k32", us, "1 scalar psum/round"))


def bench_power_control():
    from repro import channel
    from repro.core import power_control as pc
    h = channel.RayleighFading().realize(0, 8000, 5).h  # paper horizon T=8000

    def solve():
        return pc.solve_analog(h, power=100.0, n0=1.0, gamma=100.0,
                               contraction_a=0.998, epsilon=5.0, delta=0.01)
    us = time_call(solve, warmup=1, iters=3)
    sched = solve()
    print(csv_row("thm3_power_solve_T8000", us,
                  f"zeta={sched.zeta:.3e};budget_active={sched.zeta > 0}"))

    def solve_sign():
        return pc.solve_sign(h, power=100.0, n0=1.0, n_clients=5, e0=0.496,
                             contraction_a_tilde=0.998, epsilon=5.0,
                             delta=0.01)
    us = time_call(solve_sign, warmup=1, iters=3)
    print(csv_row("thm4_power_solve_T8000", us, ""))


def bench_kernels():
    from repro.kernels import ops
    w = jax.random.normal(jax.random.key(0), (1024, 1024))
    fn = jax.jit(lambda w: ops.seeded_axpy(w, 3, 1e-3, impl="xla"))
    us = time_call(lambda: fn(w))
    print(csv_row("seeded_axpy_1M_xla", us, "z-regen;0-HBM-z"))

    q = jax.random.normal(jax.random.key(1), (1, 8, 512, 64))
    k = jax.random.normal(jax.random.key(2), (1, 2, 512, 64))
    v = jax.random.normal(jax.random.key(3), (1, 2, 512, 64))
    fn = jax.jit(lambda q, k, v: ops.attention(q, k, v, causal=True,
                                               impl="xla_chunked"))
    us = time_call(lambda: fn(q, k, v))
    flops = 2 * 2 * 8 * 512 * 512 * 64
    print(csv_row("attention_512_gqa", us, f"{flops / us / 1e3:.1f}GFLOPs"))

    a = jax.nn.sigmoid(jax.random.normal(jax.random.key(4), (2, 512, 256)))
    x = jax.random.normal(jax.random.key(5), (2, 512, 256))
    fn = jax.jit(lambda a, x: ops.linear_recurrence(a, x, impl="xla")[0])
    us = time_call(lambda: fn(a, x))
    print(csv_row("rglru_scan_512", us, "assoc_scan"))

    B, S, H, P, N = 1, 512, 4, 32, 64
    xs = jax.random.normal(jax.random.key(6), (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.key(7), (B, S, H)))
    aa = -jnp.exp(jax.random.normal(jax.random.key(8), (H,)) * 0.3)
    bb = jax.random.normal(jax.random.key(9), (B, S, N)) * 0.3
    cc = jax.random.normal(jax.random.key(10), (B, S, N)) * 0.3
    fn = jax.jit(lambda *a: ops.ssd(*a, chunk=128, impl="xla")[0])
    us = time_call(lambda: fn(xs, dt, aa, bb, cc))
    print(csv_row("ssd_scan_512", us, "chunked"))


def bench_fig2_point():
    """One Fig-2 point (reduced): Perfect vs Solution accuracy at 10 dB."""
    from benchmarks.fig2_main_results import run_point
    import time
    t0 = time.time()
    # quick point uses ε=50 so the DP regime is learnable at T=150 (the
    # paper's ε=5 needs its T=8000 horizon; see fig2_main_results for that)
    acc_p, _ = run_point("sst2", "analog", "perfect", 10.0, 150, 5e-3)
    acc_s, _ = run_point("sst2", "analog", "solution", 10.0, 150, 5e-3,
                         epsilon=50.0)
    us = (time.time() - t0) * 1e6
    print(csv_row("fig2_point_T150", us,
                  f"acc_perfect={acc_p:.2f};acc_solution_eps50={acc_s:.2f}"))


def bench_fig3_point():
    from benchmarks.fig2_main_results import run_point
    import time
    t0 = time.time()
    _, l_sol = run_point("sst2", "analog", "solution", 15.0, 150, 5e-3,
                         epsilon=50.0)
    _, l_sta = run_point("sst2", "analog", "static", 15.0, 150, 5e-3,
                         epsilon=50.0)
    us = (time.time() - t0) * 1e6
    print(csv_row("fig3_point_T150", us,
                  f"loss_solution={l_sol:.3f};loss_static={l_sta:.3f}"))


def bench_table2():
    from benchmarks.table2_memory_comm import analytic_table
    import time
    t0 = time.time()
    t = analytic_table()
    us = (time.time() - t0) * 1e6
    print(csv_row("table2_memory_opt125m", us,
                  f"zo={t['pAirZero']['memory_mb']}MB;"
                  f"adam={t['FO Adam']['memory_mb']}MB;"
                  f"upload_zo=16bits;upload_fo={t['model_size_mb']}MB"))


def bench_fig4_quick():
    """Quick e0 sanity: batch-projection sign-flip rate < 0.5."""
    from repro.core import zo
    from repro.core.pairzero import make_loss_fn
    from repro.data.pipeline import FederatedPipeline
    from repro.data.tasks import TaskSpec
    from repro.models import registry
    import time
    t0 = time.time()
    cfg = _tiny()
    params = registry.init_params(jax.random.key(0), cfg, jnp.float32)
    pipe = FederatedPipeline(task="sst2", spec=TaskSpec("sst2", 64, 24),
                             n_clients=5, per_client_batch=8, seed=0)
    loss_fn = make_loss_fn(cfg)
    seed = zo.round_seed(7, 0)

    def proj(b):
        batch = {k2: jnp.asarray(v) for k2, v in b.items()
                 if k2 != "labels"}
        lp, lm, _ = zo.dual_forward(lambda p: loss_fn(p, batch).mean(),
                                    params, seed, 1e-3, mode="fresh")
        return float((lp - lm) / 2e-3)

    full = np.mean([proj(pipe.batch(1000 + i)) for i in range(8)])
    flips = np.mean([np.sign(proj(pipe.batch(2000 + i))) != np.sign(full)
                     for i in range(24)])
    us = (time.time() - t0) * 1e6
    print(csv_row("fig4_e0_quick", us, f"e_k={flips:.3f}(<0.5)"))


def bench_fig7_quick():
    from repro.core import zo
    from repro.core.pairzero import make_loss_fn
    from repro.data.pipeline import FederatedPipeline
    from repro.data.tasks import TaskSpec
    from repro.models import registry
    import time
    t0 = time.time()
    cfg = _tiny()
    params = registry.init_params(jax.random.key(0), cfg, jnp.float32)
    pipe = FederatedPipeline(task="sst2", spec=TaskSpec("sst2", 64, 24),
                             n_clients=5, per_client_batch=8, seed=0)
    loss_fn = make_loss_fn(cfg)
    ps = []
    for s in range(24):
        batch = {k2: jnp.asarray(v) for k2, v in pipe.batch(s).items()
                 if k2 != "labels"}
        lp, lm, _ = zo.dual_forward(lambda p: loss_fn(p, batch).mean(),
                                    params, zo.round_seed(0, s), 1e-3,
                                    mode="fresh")
        ps.append(float((lp - lm) / 2e-3))
    p97 = float(np.percentile(np.abs(ps), 97))
    us = (time.time() - t0) * 1e6
    print(csv_row("fig7_projection_dist_quick", us,
                  f"abs_p97={p97:.2f};std={np.std(ps):.2f}"))


def main() -> None:
    print("name,us_per_call,derived")
    bench_table2()
    bench_power_control()
    bench_ota()
    bench_kernels()
    bench_zo_step()
    bench_fo_step()
    bench_fig4_quick()
    bench_fig7_quick()
    bench_fig2_point()
    bench_fig3_point()


if __name__ == "__main__":
    main()
