"""Table II reproduction: memory overhead + per-iteration upload, OPT-125M.

Two sources, cross-checked:
  * analytic accounting (the paper's own FP16 method): params / grads /
    optimizer states / ZO's inference-level footprint;
  * the COMPILER: XLA memory_analysis() of the compiled ZO step vs the FO
    SGD/Adam steps (run in a subprocess so device-count flags stay local).

    PYTHONPATH=src python -m benchmarks.table2_memory_comm [--compiled]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

from repro.models import registry

FP16 = 2  # bytes, as in the paper's Table II


def analytic_table(arch: str = "opt-125m") -> dict:
    cfg = registry.get_arch(arch)
    d = registry.count_params(cfg)
    model_mb = d * FP16 / 1e6
    # inference-level footprint: params + one layer's activations (~5%)
    zo_mb = model_mb * 1.05
    rows = {
        "model_size_mb": round(model_mb, 2),
        "params": d,
        "Sign-pAirZero": {"memory_mb": round(zo_mb, 1),
                          "upload_per_iter": "1 bit"},
        "pAirZero": {"memory_mb": round(zo_mb, 1),
                     "upload_per_iter": "16 bits"},
        "FO SGD": {"memory_mb": round(model_mb * 2.5, 1),   # +grads+acts
                   "upload_per_iter": f"{model_mb:.2f} MB"},
        "FO Adam": {"memory_mb": round(model_mb * 4.0, 1),  # +m,v
                    "upload_per_iter": f"{model_mb:.2f} MB"},
    }
    return rows


_COMPILED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
from jax.sharding import AxisType
import repro.launch.dryrun as dr
from repro.configs import base

def small_mesh(*, multi_pod=False):
    return jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)

dr.make_production_mesh = small_mesh
dr.SHAPES_BY_NAME["train_4k"] = base.ShapeConfig("train_4k", 256, 8, "train")

out = {}
for variant in ("zo", "fo_sgd", "fo"):
    r = dr.run_cell("opt-125m", "train_4k", False, variant,
                    with_roofline=False)
    key = {"zo": "pAirZero(ZO)", "fo_sgd": "FO SGD", "fo": "FO Adam"}[variant]
    if r["status"] == "ok":
        m = r["memory"]
        out[key] = {
            "peak_bytes_per_device": m["peak_bytes_per_device"],
            "peak_mb_total_8dev": round(
                m["peak_bytes_per_device"] * 8 / 1e6, 1)}
    else:
        out[key] = {"error": r.get("error", "?")[:300]}
print("TABLE2" + json.dumps(out))
"""


def compiled_table() -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    res = subprocess.run([sys.executable, "-c", _COMPILED_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1200)
    for line in res.stdout.splitlines():
        if line.startswith("TABLE2"):
            return json.loads(line[len("TABLE2"):])
    raise RuntimeError(res.stderr[-2000:])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--compiled", action="store_true",
                    help="also measure via XLA memory_analysis (slow)")
    args = ap.parse_args()

    table = {"analytic": analytic_table()}
    a = table["analytic"]
    print(f"OPT-125M: {a['params'] / 1e6:.1f}M params, model "
          f"{a['model_size_mb']:.1f} MB (fp16)")
    for k in ("Sign-pAirZero", "pAirZero", "FO SGD", "FO Adam"):
        print(f"  {k:14s} memory ≈ {a[k]['memory_mb']:8.1f} MB   upload/iter "
              f"= {a[k]['upload_per_iter']}")

    if args.compiled:
        table["compiled"] = compiled_table()
        print("\ncompiled (XLA memory_analysis, bf16, 8-device mesh):")
        for k, v in table["compiled"].items():
            print(f"  {k:14s} {v}")

    os.makedirs("results", exist_ok=True)
    with open("results/table2_memory_comm.json", "w") as f:
        json.dump(table, f, indent=1)


if __name__ == "__main__":
    main()
