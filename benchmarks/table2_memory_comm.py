"""Table II reproduction: memory overhead + per-iteration upload, OPT-125M.

Three sources, cross-checked:
  * analytic memory accounting (the paper's own FP16 method): params /
    grads / optimizer states / ZO's inference-level footprint;
  * the TRANSPORT registry: the communication column is computed from each
    mechanism's `Transport.payload_bits` / `bits_per_round` (uplink payload
    x clients), never hard-coded — including the conventional digital
    quantized baseline the paper compares against;
  * the COMPILER: XLA memory_analysis() of the compiled ZO step vs the FO
    SGD/Adam steps (run in a subprocess so device-count flags stay local).

    PYTHONPATH=src python -m benchmarks.table2_memory_comm [--compiled]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

from repro.configs.base import PairZeroConfig, TransportConfig, ZOConfig
from repro.core import transport as tp
from repro.models import registry

FP16 = 2  # bytes, as in the paper's Table II

# Table II rows -> (transport mechanism, analytic memory multiplier vs the
# fp16 model). Memory: ZO is inference-level (params + ~5% activations);
# digital transmits quantized ZO updates so its footprint matches ZO's;
# FO SGD adds grads+acts, FO Adam adds the two moments on top.
ROWS = (
    ("Sign-pAirZero", "sign", 1.05),
    ("pAirZero", "analog", 1.05),
    ("Digital-ZO (8-bit)", "digital", 1.05),
    ("FO SGD", "fo", 2.5),
    ("FO Adam", "fo", 4.0),
)


def _fmt_bits(bits: int) -> str:
    if bits < 8 * 1024:
        return f"{bits} bits"
    if bits < 8e6:
        return f"{bits / 8e3:.2f} KB"
    return f"{bits / 8e6:.2f} MB"


def analytic_table(arch: str = "opt-125m", n_clients: int = 5) -> dict:
    cfg = registry.get_arch(arch)
    d = registry.count_params(cfg)
    model_mb = d * FP16 / 1e6
    pz = PairZeroConfig(n_clients=n_clients, zo=ZOConfig(n_perturb=1),
                        transport=TransportConfig())
    rows = {"model_size_mb": round(model_mb, 2), "params": d,
            "n_clients": n_clients}
    for label, mechanism, mem_mult in ROWS:
        t = tp.get(mechanism).from_config(TransportConfig(mechanism), pz)
        rows[label] = {
            "memory_mb": round(model_mb * mem_mult, 1),
            "upload_per_iter": _fmt_bits(t.payload_bits(pz, d)),
            "bits_per_round": t.bits_per_round(pz, d),
        }
    return rows


_COMPILED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
from jax.sharding import AxisType
import repro.launch.dryrun as dr
from repro.configs import base

def small_mesh(*, multi_pod=False):
    return jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)

dr.make_production_mesh = small_mesh
dr.SHAPES_BY_NAME["train_4k"] = base.ShapeConfig("train_4k", 256, 8, "train")

out = {}
for variant in ("zo", "fo_sgd", "fo"):
    r = dr.run_cell("opt-125m", "train_4k", False, variant,
                    with_roofline=False)
    key = {"zo": "pAirZero(ZO)", "fo_sgd": "FO SGD", "fo": "FO Adam"}[variant]
    if r["status"] == "ok":
        m = r["memory"]
        out[key] = {
            "peak_bytes_per_device": m["peak_bytes_per_device"],
            "peak_mb_total_8dev": round(
                m["peak_bytes_per_device"] * 8 / 1e6, 1)}
    else:
        out[key] = {"error": r.get("error", "?")[:300]}
print("TABLE2" + json.dumps(out))
"""


def compiled_table() -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    res = subprocess.run([sys.executable, "-c", _COMPILED_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1200)
    for line in res.stdout.splitlines():
        if line.startswith("TABLE2"):
            return json.loads(line[len("TABLE2"):])
    raise RuntimeError(res.stderr[-2000:])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--compiled", action="store_true",
                    help="also measure via XLA memory_analysis (slow)")
    args = ap.parse_args()

    table = {"analytic": analytic_table()}
    a = table["analytic"]
    print(f"OPT-125M: {a['params'] / 1e6:.1f}M params, model "
          f"{a['model_size_mb']:.1f} MB (fp16), K={a['n_clients']} clients")
    for label, _, _ in ROWS:
        r = a[label]
        print(f"  {label:19s} memory ≈ {r['memory_mb']:8.1f} MB   "
              f"upload/iter = {r['upload_per_iter']:>10s}   "
              f"total/round = {_fmt_bits(r['bits_per_round'])}")

    if args.compiled:
        table["compiled"] = compiled_table()
        print("\ncompiled (XLA memory_analysis, bf16, 8-device mesh):")
        for k, v in table["compiled"].items():
            print(f"  {k:14s} {v}")

    os.makedirs("results", exist_ok=True)
    with open("results/table2_memory_comm.json", "w") as f:
        json.dump(table, f, indent=1)


if __name__ == "__main__":
    main()
