"""Fused ZO dual forward: peak-memory and throughput vs the unfused modes.

    PYTHONPATH=src python benchmarks/kernel_memory.py \
        [--rounds 48] [--clients 4] [--sizes tiny,opt-125m-reduced] \
        [--json BENCH_kernels.json]

Measures the three dual-forward modes at each size with an identical config:

  chained  the default unfused path: MeZO in-place walk w -> w+mu z -> w-mu z,
           each step a theta-sized seeded axpy
  fresh    unfused, both rollouts perturbed directly from w (the bitwise
           oracle for the fused mode -- identical update semantics)
  fused    PairZeroConfig.fused_perturbation: leaves tagged lazily
           (zo.tag_perturbed), z regenerated inside the consuming
           matmul/gather (kernels.ops.perturbed_*), both rollouts under one
           vmap over eps = (+mu, -mu)

Reported per (size, mode):

  dual_ms / duals_per_s   jit'd dual-forward latency (best-of, steady state)
  dual_temp_bytes         XLA temp allocation of the undonated dual forward
                          (jax .lower().compile().memory_analysis())
  zo_overhead_bytes       dual_temp_bytes minus the plain single-forward temp
                          -- what the ZO machinery adds over inference, i.e.
                          the quantity the paper's "inference-level memory"
                          claim is about
  rounds_per_s            end-to-end fedsim rounds (scan engine)

Gates (enforced by `tools/check_bench.py --kernels`), at --gate-size:

  memory   fused zo_overhead <= 0.5x the DEFAULT unfused mode (chained) --
           the fused path must halve what ZO adds over inference;
  speed    fused duals_per_s >= 1.0x the mode-matched unfused baseline
           (fresh) -- at comparable memory, fused must not be slower;
  oracle   fused dual losses bitwise-equal to fresh at every size.

Baseline notes (also embedded in the JSON): on a single-core CPU host the
chained walk amortizes ONE materialized z across the whole round via XLA CSE
-- that theta-sized temporary is exactly what the fused path exists to
eliminate, so chained buys its rounds/s with 2x the memory overhead. All
three modes' rounds/s are reported so the tradeoff is visible; the fused
TPU kernel (kernels/perturbed_matmul.py) regenerates z per tile in VMEM and
pays neither cost. See docs/kernels.md.

`--history PATH` appends the headline numbers as one bench_history/v1 row
(tools/bench_history.py); `tools/check_bench.py --history` gates the
committed results/bench_history.jsonl against same-hardware regressions.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

sys.path.insert(0, "src")
sys.path.insert(0, os.path.join(os.path.dirname(__file__) or ".", "..",
                                "tools"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

import bench_history  # noqa: E402

from repro.configs.base import (ChannelConfig, DPConfig, ModelConfig,  # noqa: E402
                                PairZeroConfig, PowerControlConfig, ZOConfig)
from repro.core import fedsim, pairzero, zo  # noqa: E402
from repro.data.pipeline import FederatedPipeline  # noqa: E402
from repro.data.tasks import TaskSpec  # noqa: E402
from repro.models import registry  # noqa: E402

SCHEMA = "bench_kernels/v1"
MODES = ("chained", "fresh", "fused")


def model_sizes() -> dict:
    """Size ladder (all CPU-runnable; subset of engine_throughput's)."""
    return {
        "tiny": ModelConfig(name="tiny", family="dense", n_layers=2,
                            d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                            vocab_size=64, head_dim=16),
        "opt-125m-reduced": registry.get_arch("opt-125m").reduced(),
    }


def build_pz(args, mode: str) -> PairZeroConfig:
    pz = PairZeroConfig(
        variant="analog", n_clients=args.clients, rounds=args.rounds,
        zo=ZOConfig(mu=1e-3, lr=5e-3, clip_gamma=5.0),
        channel=ChannelConfig(n0=1.0, power=100.0),
        dp=DPConfig(epsilon=5.0, delta=0.01),
        power=PowerControlConfig(scheme="solution"), seed=0)
    if mode == "fused":
        return dataclasses.replace(pz, fused_perturbation=True)
    if mode == "fresh":
        return dataclasses.replace(
            pz, zo=dataclasses.replace(pz.zo, dual_mode="fresh"))
    return pz


def make_pipe(cfg, args) -> FederatedPipeline:
    return FederatedPipeline(
        task="sst2", spec=TaskSpec("sst2", cfg.vocab_size, args.seq),
        n_clients=args.clients, per_client_batch=args.batch, seed=0)


def synth_batch(cfg, args):
    k = jax.random.key(1)
    tokens = jax.random.randint(
        k, (args.clients, args.batch, args.seq), 0, cfg.vocab_size)
    return {"tokens": tokens, "targets": jnp.roll(tokens, -1, -1),
            "mask": jnp.ones(tokens.shape, jnp.float32)}


def best_of_ms(f, *a, repeats: int, inner: int = 20) -> float:
    r = f(*a)
    jax.block_until_ready(r)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(inner):
            r = f(*a)
        jax.block_until_ready(r)
        best = min(best, (time.perf_counter() - t0) / inner)
    return best * 1e3


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--rounds", type=int, default=48)
    ap.add_argument("--chunk-rounds", type=int, default=16)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=16)
    ap.add_argument("--repeats", type=int, default=3,
                    help="timed passes per config (best-of)")
    ap.add_argument("--sizes", default="tiny,opt-125m-reduced",
                    help=f"comma list from {sorted(model_sizes())}")
    ap.add_argument("--gate-size", default="opt-125m-reduced")
    ap.add_argument("--json", default=None,
                    help="write BENCH_kernels.json here")
    ap.add_argument("--history", default=None, metavar="PATH",
                    help="append a bench_history/v1 row (headline "
                         "numbers) to this JSONL ledger")
    args = ap.parse_args()

    sizes = {name: model_sizes()[name] for name in args.sizes.split(",")}
    mu = 1e-3
    seed = jnp.uint32(7)

    print(f"== fused-kernel bench: {args.clients} clients x {args.batch} x "
          f"seq {args.seq}, {args.rounds} rounds, "
          f"platform={jax.devices()[0].platform} ==")

    grid, size_meta = [], {}
    for name, cfg in sizes.items():
        mod = registry.get_module(cfg)
        params = mod.init(jax.random.key(0), cfg)
        theta = sum(x.size * x.dtype.itemsize
                    for x in jax.tree_util.tree_leaves(params))
        batch = synth_batch(cfg, args)
        loss_fn = pairzero.make_loss_fn(cfg)

        fwd = jax.jit(lambda p: loss_fn(p, batch))
        fwd_temp = fwd.lower(params).compile().memory_analysis() \
            .temp_size_in_bytes
        size_meta[name] = {
            "param_count": int(cfg.param_count()),
            "theta_bytes": int(theta),
            "forward_temp_bytes": int(fwd_temp),
        }

        duals, losses = {}, {}
        for mode in MODES:
            f = jax.jit(lambda p, s, m=mode: zo.dual_forward(
                lambda q: loss_fn(q, batch), p, s, mu, mode=m)[:2])
            temp = f.lower(params, seed).compile().memory_analysis() \
                .temp_size_in_bytes
            losses[mode] = f(params, seed)
            ms = best_of_ms(f, params, seed, repeats=args.repeats)
            duals[mode] = {"dual_ms": ms, "dual_temp_bytes": int(temp),
                           "zo_overhead_bytes": int(temp - fwd_temp)}

        bitwise = bool(
            jnp.all(losses["fused"][0] == losses["fresh"][0])
            and jnp.all(losses["fused"][1] == losses["fresh"][1]))

        rps = {}
        for mode in MODES:
            pz = build_pz(args, mode)
            run = lambda pz_=pz: fedsim.run(
                cfg, pz_, make_pipe(cfg, args), rounds=args.rounds,
                engine="scan", chunk_rounds=args.chunk_rounds)
            run()                                           # warmup/compile
            best = 0.0
            for _ in range(args.repeats):
                t0 = time.perf_counter()
                run()
                best = max(best, args.rounds / (time.perf_counter() - t0))
            rps[mode] = best

        for mode in MODES:
            d = duals[mode]
            row = {
                "size": name, "mode": mode,
                "dual_ms": round(d["dual_ms"], 3),
                "duals_per_s": round(1e3 / d["dual_ms"], 1),
                "dual_temp_bytes": d["dual_temp_bytes"],
                "zo_overhead_bytes": d["zo_overhead_bytes"],
                "rounds_per_s": round(rps[mode], 2),
                "fused_bitwise_eq_fresh": bitwise if mode == "fused"
                else None,
            }
            grid.append(row)
            print(f"  {name:18s} {mode:8s} dual {row['dual_ms']:6.2f} ms  "
                  f"overhead {row['zo_overhead_bytes']:9d} B "
                  f"({d['zo_overhead_bytes'] / theta:.2f}x theta)  "
                  f"{row['rounds_per_s']:7.1f} r/s")

    gate_size = args.gate_size if args.gate_size in sizes \
        else next(iter(sizes))
    by = {r["mode"]: r for r in grid if r["size"] == gate_size}
    gate = {
        "size": gate_size,
        "memory_overhead_fused_vs_chained": round(
            by["fused"]["zo_overhead_bytes"]
            / by["chained"]["zo_overhead_bytes"], 3),
        "dual_speed_fused_vs_fresh": round(
            by["fused"]["duals_per_s"] / by["fresh"]["duals_per_s"], 3),
        "rounds_fused_vs_chained": round(
            by["fused"]["rounds_per_s"] / by["chained"]["rounds_per_s"], 3),
        "rounds_fused_vs_fresh": round(
            by["fused"]["rounds_per_s"] / by["fresh"]["rounds_per_s"], 3),
    }
    print(f"-- gates @ {gate_size}: mem overhead fused/chained "
          f"{gate['memory_overhead_fused_vs_chained']:.2f}x (<= 0.5), "
          f"dual speed fused/fresh "
          f"{gate['dual_speed_fused_vs_fresh']:.2f}x (>= 1.0) --")

    report = {
        "schema": SCHEMA,
        "created_unix": int(time.time()),
        "host": {"devices": len(jax.devices()),
                 "platform": jax.devices()[0].platform},
        "config": {"rounds": args.rounds, "chunk_rounds": args.chunk_rounds,
                   "clients": args.clients, "batch": args.batch,
                   "seq": args.seq, "repeats": args.repeats},
        "sizes": size_meta,
        "grid": grid,
        "gate": gate,
        "notes": (
            "zo_overhead_bytes = dual-forward temp minus plain-forward temp "
            "(what ZO adds over inference). Memory gate: fused vs the "
            "default unfused mode (chained). Speed gate: fused vs the "
            "mode-matched unfused baseline (fresh; bitwise-equal losses). "
            "chained's rounds/s lead on single-core CPU comes from XLA "
            "CSE-ing one materialized z across the round -- the theta-sized "
            "temporary the fused path eliminates; on TPU the Pallas kernel "
            "regenerates z per tile in VMEM instead."),
    }
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {args.json}")
    if args.history:
        row = bench_history.append_row(args.history, "kernels", {
            "size": gate_size,
            "fused_duals_per_s": by["fused"]["duals_per_s"],
            "fresh_duals_per_s": by["fresh"]["duals_per_s"],
            "memory_overhead_fused_vs_chained":
                gate["memory_overhead_fused_vs_chained"],
            "dual_speed_fused_vs_fresh":
                gate["dual_speed_fused_vs_fresh"],
        })
        print(f"appended history row (sha {row['git_sha']}, "
              f"{row['host']['platform']}/{row['host']['devices']}dev) "
              f"to {args.history}")


if __name__ == "__main__":
    main()
