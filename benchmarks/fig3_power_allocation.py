"""Fig. 3 reproduction: Solution vs Static vs Reversed vs Perfect (analog).

    PYTHONPATH=src python -m benchmarks.fig3_power_allocation [--rounds 400]

Reproduces the ablation claim: Solution ≈ Perfect > Reversed >> Static
(Static collapses because Eq. (40) forces a vanishing channel gain when T
is large). Writes results/fig3.json.
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from benchmarks.fig2_main_results import TINY, run_point


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=400)
    ap.add_argument("--snr", type=float, default=15.0)
    ap.add_argument("--task", default="sst2")
    ap.add_argument("--epsilons", default="5,50",
                    help="paper's ε=5 shows the ordering; ε=50 shows "
                         "Solution tracking Perfect at the reduced horizon "
                         "(the paper's T=8000 run achieves this at ε=5)")
    args = ap.parse_args()

    rows = {}
    for eps in (float(e) for e in args.epsilons.split(",")):
        for scheme in ("perfect", "solution", "reversed", "static"):
            lr = 5e-3 if scheme == "perfect" or eps > 10 else 1e-3
            acc, loss = run_point(args.task, "analog", scheme, args.snr,
                                  args.rounds, lr=lr, epsilon=eps)
            rows[f"{scheme}@eps{eps:g}"] = {"acc": acc, "final_loss": loss}
            print(f"eps={eps:4g} {scheme:10s} acc={acc:.3f} "
                  f"loss={loss:.3f}", flush=True)

    os.makedirs("results", exist_ok=True)
    with open("results/fig3.json", "w") as f:
        json.dump(rows, f, indent=1)
    for eps in set(k.split("@")[1] for k in rows):
        order = [f"{s}@{eps}" for s in
                 ("perfect", "solution", "reversed", "static")]
        losses = [rows[o]["final_loss"] for o in order]
        print(f"\nloss ordering @{eps} (expect nondecreasing):",
              " <= ".join(f"{o.split('@')[0]}:{v:.3f}"
                          for o, v in zip(order, losses, strict=True)))


if __name__ == "__main__":
    main()
