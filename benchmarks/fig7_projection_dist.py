"""Fig. 7 reproduction: distribution of gradient projections over training.

The paper finds >97% of projections within [−γ, γ] for γ=100 on OPT-125M;
the histogram justifies the clip threshold. We record the same histogram on
the reduced model and report the equivalent percentile-based γ.

    PYTHONPATH=src python -m benchmarks.fig7_projection_dist
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from repro.configs.base import (ModelConfig, PairZeroConfig,
                                PowerControlConfig, ZOConfig)
from repro.core import fedsim
from repro.data.pipeline import FederatedPipeline
from repro.data.tasks import TaskSpec

TINY = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=4, d_ff=256, vocab_size=64,
                   head_dim=16)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=400)
    args = ap.parse_args()

    pipe = FederatedPipeline(task="sst2", spec=TaskSpec("sst2", 64, 24),
                             n_clients=5, per_client_batch=8, seed=0)
    # lr chosen so the run stays in the stable regime while measuring
    # (the paper records projections along a converging trajectory); the
    # clip is disabled so the RAW distribution is observed (Fig. 7's point)
    pz = PairZeroConfig(variant="analog", n_clients=5,
                        zo=ZOConfig(mu=1e-3, lr=1e-3, clip_gamma=1e9,
                                    n_perturb=4),
                        power=PowerControlConfig(scheme="perfect"))

    projections = []

    def on_round(t, metrics):
        projections.extend(np.asarray(metrics["p_clients"]).ravel().tolist())

    fedsim.run(TINY, pz, pipe, rounds=args.rounds, on_round=on_round)
    p = np.asarray(projections)
    pct = {q: float(np.percentile(np.abs(p), q)) for q in (50, 90, 97, 99)}
    hist, edges = np.histogram(p, bins=60)
    os.makedirs("results", exist_ok=True)
    with open("results/fig7_projection_dist.json", "w") as f:
        json.dump({"n": len(p), "mean": float(p.mean()),
                   "std": float(p.std()), "abs_percentiles": pct,
                   "hist": hist.tolist(), "edges": edges.tolist()}, f,
                  indent=1)
    print(f"n={len(p)} mean={p.mean():.4f} std={p.std():.4f}")
    print(f"|p| percentiles: {pct}")
    print(f"γ covering 97% of projections: {pct[97]:.2f} "
          f"(paper's γ=100 covers 97% on OPT-125M)")


if __name__ == "__main__":
    main()
