"""Byzantine robustness sweep: active adversaries vs OTA-compatible defenses.

For each (transport, behavior, fraction, defense) cell this runs a short
federated fine-tune with the behavior injected through the registered
repro.byzantine path (the malicious payload rides the real ControlTrace →
ota.superpose pipeline, bit-identical across engines) and reports the
three axes the robustness story turns on:

  utility       final training loss + held-out accuracy at matched rounds,
                against a clean (no attack, no defense) reference run and
                an undefended-under-attack run of the same transport;
  gap_recovery  how much of the clean-vs-undefended utility gap the
                defense wins back: (m_und - m_def) / (m_und - m_clean)
                on the final training loss (mean of the last 10 rounds) —
                the quantity the attack directly steers. Held-out accuracy
                is reported per row but NOT gated: at this CI scale
                (2-layer d=64 model, 256-example eval) accuracy is not
                monotone with utility — a diverged run can post the
                highest accuracy by chance — so the gate would be noise;
  eps_hat       the PR-5 empirical Clopper-Pearson audit re-run on the
                DEFENDED configuration (clip audits against the tightened
                gamma_d schedule via Defense.audited_pz), checked against
                the analytic accountant's eps;
  comm          uplink bits vs the clean run (robust group decodes price
                their re-transmissions through Transport accounting).

The gated claim (also enforced by tools/check_bench.py --robustness and
pinned in CI): at 25% sign-flip clients on the analog OTA transport, the
best registered defense recovers >= 80% of the clean-vs-undefended
final-loss gap, while eps_hat stays <= the analytic eps on every audited
cell.
The script exits non-zero if the claim fails, so it doubles as a gate.

    PYTHONPATH=src python -m benchmarks.fig_robustness \
        [--rounds 60] [--behaviors sign_flip,scaled_poison] \
        [--defenses none,clip,robust_decode,reweight] \
        [--transports analog] [--fractions 0.25] [--trials 400]

Writes results/fig_robustness.json.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro import byzantine as byz
from repro import privacy as pv
from repro.configs.base import (ByzantineConfig, ChannelConfig, DPConfig,
                                ModelConfig, PairZeroConfig,
                                PowerControlConfig, TransportConfig,
                                ZOConfig)
from repro.core import fedsim
from repro.data.pipeline import FederatedPipeline
from repro.data.tasks import TaskSpec

TINY = ModelConfig(name="tiny-opt", family="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=4, d_ff=256, vocab_size=64,
                   head_dim=16)

TRANSPORTS = {
    "analog": TransportConfig("analog", "solution"),
    "sign": TransportConfig("sign", "solution"),
    "digital": TransportConfig("digital", quant_bits=8),
    "smart_digital": TransportConfig("smart_digital", quant_bits=8),
}

N_CLIENTS = 8

# the claim cell (see module docstring); groups = n_clients gives the
# robust decode singleton sub-slots — a coordinate median across clients,
# which tolerates floor((K-1)/2) = 3 attackers at K = 8
CLAIM = {"transport": "analog", "behavior": "sign_flip", "fraction": 0.25}


def build_pz(tc: TransportConfig, rounds: int, seed: int,
             byzcfg: ByzantineConfig | None) -> PairZeroConfig:
    return PairZeroConfig(
        n_clients=N_CLIENTS, rounds=rounds,
        zo=ZOConfig(mu=1e-3, lr=5e-3, clip_gamma=5.0, n_perturb=1),
        channel=ChannelConfig(n0=1.0, power=100.0),
        dp=DPConfig(epsilon=5.0, delta=0.01),
        power=PowerControlConfig(scheme=tc.scheme),
        transport=tc, byzantine=byzcfg, seed=seed)


def run_cell(tname: str, rounds: int, trials: int, seed: int,
             byzcfg: ByzantineConfig | None) -> dict:
    pz = build_pz(TRANSPORTS[tname], rounds, seed, byzcfg)
    pipe = FederatedPipeline(task="sst2", spec=TaskSpec("sst2", 64, 24),
                             n_clients=N_CLIENTS, per_client_batch=4,
                             seed=seed)
    exp = fedsim.Experiment(TINY, pz, pipe, rounds=rounds, engine="scan",
                            chunk_rounds=max(rounds // 4, 1),
                            hooks=[fedsim.EvalHook(rounds, 256)])
    res = exp.run()
    row = {
        "transport": tname,
        "behavior": byzcfg.behavior if byzcfg else "none",
        "fraction": byzcfg.fraction if byzcfg else 0.0,
        "defense": byzcfg.defense if byzcfg else "none",
        "rounds": res.steps,
        "final_loss": float(np.mean(res.losses[-10:])),
        "accuracy": res.accuracies[-1] if res.accuracies else None,
        "uplink_bits": res.uplink_bits,
        "privacy_spent": res.privacy_spent,
    }
    if exp.transport.canary_payload(pz) is not None:
        audit_pz = pz
        defense = byz.resolve_defense(pz)
        if defense is not None:
            audit_pz = defense.audited_pz(pz)
        audit = pv.audit_transport(exp.transport, exp.schedule, audit_pz,
                                   rounds=max(res.steps, 1), trials=trials)
        row.update({"eps_hat": audit.eps_hat,
                    "eps_analytic": audit.eps_analytic,
                    "dominated": audit.dominated})
    else:
        row.update({"eps_hat": None, "eps_analytic": None,
                    "dominated": None})
    return row


def utility_gap_recovery(clean: dict, und: dict, dfd: dict) -> tuple:
    """(recovery, metric): fraction of the clean-vs-undefended final-loss
    gap the defense wins back (see module docstring for why held-out
    accuracy is reported but not gated at this scale)."""
    gap = und["final_loss"] - clean["final_loss"]
    if gap <= 1e-9:                     # attack did not hurt: fully "recovered"
        return 1.0, "loss"
    return (und["final_loss"] - dfd["final_loss"]) / gap, "loss"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--behaviors", default="sign_flip,scaled_poison",
                    help=f"comma-separated from {byz.available_behaviors()}")
    ap.add_argument("--defenses", default="none,clip,robust_decode,reweight",
                    help=f"'none' plus {byz.available_defenses()}")
    ap.add_argument("--transports", default="analog",
                    help=f"comma-separated labels from {list(TRANSPORTS)}")
    ap.add_argument("--fractions", default="0.25",
                    help="comma-separated Byzantine client fractions")
    ap.add_argument("--trials", type=int, default=400,
                    help="paired canary traces per eps_hat audit")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    transports = args.transports.split(",")
    behaviors = args.behaviors.split(",")
    defenses = args.defenses.split(",")
    fractions = [float(x) for x in args.fractions.split(",")]

    rows, clean = [], {}
    for tname in transports:
        clean[tname] = run_cell(tname, args.rounds, args.trials, args.seed,
                                None)
        c = clean[tname]
        print(f"{tname:9s} clean           loss={c['final_loss']:.4f} "
              f"acc={c['accuracy']}", flush=True)
        for behavior in behaviors:
            for frac in fractions:
                for defense in defenses:
                    bz = ByzantineConfig(
                        behavior=behavior, fraction=frac, defense=defense,
                        groups=N_CLIENTS, seed=args.seed)
                    row = run_cell(tname, args.rounds, args.trials,
                                   args.seed, bz)
                    rows.append(row)
                    eps = "-" if row["eps_hat"] is None else \
                        f"{row['eps_hat']:.2f}<={row['eps_analytic']:.2f}"
                    print(f"{tname:9s} {behavior:15s} f={frac:.2f} "
                          f"{defense:13s} loss={row['final_loss']:.4f} "
                          f"acc={row['accuracy']} eps={eps}", flush=True)

    # gated claim: best defense at the claim cell recovers >= 80% of the
    # utility gap; eps_hat dominated on every audited cell
    def cell(defense):
        for r in rows:
            if (r["transport"] == CLAIM["transport"]
                    and r["behavior"] == CLAIM["behavior"]
                    and r["fraction"] == CLAIM["fraction"]
                    and r["defense"] == defense):
                return r
        return None

    failures = []
    claim: dict = dict(CLAIM)
    und = cell("none")
    defended = [(d, cell(d)) for d in defenses if d != "none"]
    defended = [(d, r) for d, r in defended if r is not None]
    if und is None or not defended:
        claim.update({"holds": None, "note": "claim cell not in grid"})
    else:
        scored = []
        for d, r in defended:
            rec, metric = utility_gap_recovery(
                clean[CLAIM["transport"]], und, r)
            r["gap_recovery"] = rec
            scored.append((rec, d, metric))
        best_rec, best_d, metric = max(scored)
        claim.update({"best_defense": best_d, "gap_recovery": best_rec,
                      "metric": metric, "threshold": 0.8,
                      "holds": bool(best_rec >= 0.8)})
        if not claim["holds"]:
            failures.append(
                f"best defense {best_d} recovers only {best_rec:.2f} "
                f"of the {metric} gap (< 0.80)")
    for r in rows:
        if r["dominated"] is False:
            failures.append(f"{r['transport']}/{r['behavior']}/"
                            f"{r['defense']}: eps_hat exceeds analytic eps")

    os.makedirs("results", exist_ok=True)
    out = "results/fig_robustness.json"
    with open(out, "w") as f:
        json.dump({"schema": "fig_robustness/v1",
                   "created_unix": int(time.time()),
                   "config": {"rounds": args.rounds,
                              "n_clients": N_CLIENTS,
                              "transports": transports,
                              "behaviors": behaviors,
                              "defenses": defenses,
                              "fractions": fractions,
                              "trials": args.trials,
                              "seed": args.seed},
                   "clean": clean, "rows": rows, "claim": claim},
                  f, indent=1)
    print(f"\nwrote {out}")
    if failures:
        raise SystemExit("ROBUSTNESS CLAIMS VIOLATED: "
                         + "; ".join(failures))
    print(f"claim holds: {claim.get('best_defense')} recovers "
          f"{claim.get('gap_recovery', 0):.2f} of the "
          f"{claim.get('metric')} gap at 25% sign-flip on analog; "
          "eps_hat <= analytic eps on every audited cell")


if __name__ == "__main__":
    main()
