"""Privacy-vs-utility sweep: the trilemma's third axis, measured.

For each (transport, channel) cell of the grid this runs a short federated
fine-tune with eavesdropper capture on (repro.privacy), then reports the
three quantities the paper's privacy story turns on:

  recon_err   reconstruction error ‖ĝ − g_leak‖/‖g_leak‖ of the victim's
              round-0 *transmitted update content*: the raw d-dim gradient
              for fo, the seed-decodable p₀·z for the ZO uplinks (the
              public round seed makes the scalar worth a full gradient).
              fo and the digital slots reconstruct it near-exactly; the
              OTA superposition buries it in Eq.-16 noise. Lower = better
              for the attacker. `grad_vs_true_err` scores the same ĝ
              against the victim's true first-order gradient (the paper's
              matched-rounds comparison across fo vs OTA).
  eps_hat     the empirical Clopper–Pearson ε̂ lower bound from the
              paired-trace canary audit, vs the analytic accountant's ε
              (∞ for the no-DP digital/fo uplinks — payloads are exposed
              exactly, there is nothing to bound).
  utility     final training loss + held-out accuracy at matched rounds.

The headline assertions (also pinned in tests/test_privacy.py): the FO
uplink's reconstruction error is measurably LOWER (attacker wins) than
pAirZero's analog OTA at matched rounds, and ε̂ never exceeds the analytic
ε on any audited cell — printed per row and summarized at the end; the
script exits non-zero if either ever fails, so it doubles as a gate.

    PYTHONPATH=src python -m benchmarks.fig_privacy \
        [--rounds 100] [--mechanisms fo,digital,smart_digital,analog,sign] \
        [--channels rayleigh,static] [--trials 1500] [--dlg]

Writes results/fig_privacy.json.
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro import privacy as pv
from repro.configs.base import (ChannelConfig, DPConfig, ModelConfig,
                                PairZeroConfig, PowerControlConfig,
                                TransportConfig, ZOConfig)
from repro.core import fedsim, zo
from repro.data.pipeline import FederatedPipeline
from repro.data.tasks import TaskSpec
from repro.models import registry

TINY = ModelConfig(name="tiny-opt", family="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=4, d_ff=256, vocab_size=64,
                   head_dim=16)

MECHANISMS = {
    "fo": TransportConfig("fo"),
    "digital": TransportConfig("digital", quant_bits=8),
    "smart_digital": TransportConfig("smart_digital", quant_bits=8),
    "analog": TransportConfig("analog", "solution"),
    "sign": TransportConfig("sign", "solution"),
}

CHANNELS = {
    "rayleigh": {},
    "static": {"model": "static"},
    "rician": {"model": "rician", "rician_k": 4.0},
    "ar1": {"model": "ar1", "ar1_rho": 0.7},
    # cells where the physical layer actually bites the schedule/masks:
    # path loss skews the power-cap min over clients; deep fades straggle
    "geometry": {"cell_radius": 150.0},
    "outage": {"outage_db": -10.0},
}


def build_pz(tc: TransportConfig, channel_kw: dict, rounds: int,
             seed: int = 0) -> PairZeroConfig:
    return PairZeroConfig(
        n_clients=5, rounds=rounds,
        zo=ZOConfig(mu=1e-3, lr=5e-3, clip_gamma=5.0, n_perturb=1),
        channel=ChannelConfig(n0=1.0, power=100.0, **channel_kw),
        dp=DPConfig(epsilon=5.0, delta=0.01),
        power=PowerControlConfig(scheme=tc.scheme),
        transport=tc, seed=seed)


def victim_gradient_estimate(mech: str, hook: pv.AttackHook, exp,
                             params0, pz) -> tuple:
    """(ĝ, g_leak): the attacker's best flat gradient estimate for client
    0 at round 0, and the victim's actually-transmitted update content the
    estimate is scored against (fo: the raw gradient — returned as None,
    the caller owns the FO oracle; ZO: the seed-decodable p₀·z)."""
    obs = hook.observations()
    if mech == "fo":
        return np.asarray(obs["obs_grad0"][0]), None
    # ZO transports: replay the public perturbation seed for round 0, j=0
    seed0 = zo.perturb_seed(zo.round_seed(pz.seed, 0), 0)
    if "obs_q" in obs:                        # digital: exact per-client
        scalar = float(obs["obs_q"][0][0])
    else:                                     # OTA: noisy mean only
        y0 = float(obs["obs_y"][0])
        c0 = float(exp.schedule.c[0])
        k0 = float(hook.k_eff()[0])
        scalar = y0 / (k0 * c0) if c0 > 0 else 0.0
    # ground truth = what the victim actually radiated (sign: its ±1
    # ballot; scalar transports: the clipped projection itself)
    radiated = np.asarray(exp.transport.transmitted(hook.payloads()))
    p0 = float(radiated[0][0])
    g_hat = np.asarray(pv.zo_gradient_estimate(params0, seed0, scalar))
    g_leak = np.asarray(pv.zo_gradient_estimate(params0, seed0, p0))
    return g_hat, g_leak


def run_cell(mech: str, chan: str, rounds: int, trials: int,
             with_dlg: bool, seed: int = 0) -> dict:
    tc = MECHANISMS[mech]
    pz = build_pz(tc, CHANNELS[chan], rounds, seed)
    pipe = FederatedPipeline(task="sst2", spec=TaskSpec("sst2", 64, 24),
                             n_clients=5, per_client_batch=4, seed=seed)
    # FO's per-round observation is a full [d] gradient — keep only the
    # early rounds the attacks consume
    hook = pv.AttackHook(max_rounds=8 if mech == "fo" else None)
    exp = fedsim.Experiment(TINY, pz, pipe, rounds=rounds, engine="scan",
                            chunk_rounds=max(rounds // 4, 1),
                            hooks=[hook, fedsim.EvalHook(rounds, 256)],
                            adversary=pv.Adversary())
    res = exp.run()

    params0 = registry.init_params(jax.random.key(pz.seed), TINY,
                                   jnp.float32)
    batch0 = pipe.batch(0)
    batch_j = {k: jnp.asarray(v) for k, v in batch0.items()
               if k != "labels"}
    g_true = pv.client_gradient(TINY, params0, batch_j)
    g_hat, g_leak = victim_gradient_estimate(mech, hook, exp, params0, pz)
    if g_leak is None:                        # fo: the leak IS the gradient
        g_leak = g_true
    row = {
        "mechanism": mech, "channel": chan, "rounds": res.steps,
        "recon_err": pv.reconstruction_error(g_hat, g_leak),
        "grad_vs_true_err": pv.reconstruction_error(g_hat, g_true),
        "final_loss": float(np.mean(res.losses[-10:])),
        "accuracy": res.accuracies[-1] if res.accuracies else None,
        "uplink_bits": res.uplink_bits,
        "privacy_spent": res.privacy_spent,
    }

    if exp.transport.canary_payload(pz) is not None:
        audit = pv.audit_transport(exp.transport, exp.schedule, pz,
                                   rounds=max(res.steps, 1), trials=trials)
        row.update({"eps_hat": audit.eps_hat,
                    "eps_analytic": audit.eps_analytic,
                    "dominated": audit.dominated})
    else:
        row.update({"eps_hat": None, "eps_analytic": None,
                    "dominated": None})

    if with_dlg and mech == "fo":
        dlg = pv.get("dlg")(steps=400)
        out = dlg.run(TINY, params0, g_hat,
                      targets=batch0["targets"][0],
                      mask=batch0["mask"][0],
                      true_tokens=batch0["tokens"][0])
        row["dlg_token_acc"] = out["token_accuracy"]
        row["dlg_chance_acc"] = out["chance_accuracy"]
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--mechanisms",
                    default="fo,digital,smart_digital,analog,sign",
                    help=f"comma-separated labels from {list(MECHANISMS)}")
    ap.add_argument("--channels", default="rayleigh,static",
                    help=f"comma-separated labels from {list(CHANNELS)}")
    ap.add_argument("--trials", type=int, default=1500,
                    help="paired canary traces per eps_hat audit")
    ap.add_argument("--dlg", action="store_true",
                    help="additionally run the DLG token-reconstruction "
                         "attack on the FO cells")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    rows = []
    for chan in args.channels.split(","):
        for mech in args.mechanisms.split(","):
            row = run_cell(mech, chan, args.rounds, args.trials,
                           args.dlg, args.seed)
            eps = "inf (no DP)" if row["eps_hat"] is None else \
                f"{row['eps_hat']:.3f}<={row['eps_analytic']:.3f}"
            print(f"{chan:9s} {mech:14s} recon_err={row['recon_err']:8.4f} "
                  f"eps_hat={eps:18s} loss={row['final_loss']:.4f}",
                  flush=True)
            rows.append(row)

    # the two headline claims, checked over the whole grid
    by = {(r["channel"], r["mechanism"]): r for r in rows}
    failures = []
    for chan in args.channels.split(","):
        fo, an = by.get((chan, "fo")), by.get((chan, "analog"))
        if fo and an and not (fo["recon_err"] < an["recon_err"]
                              and fo["grad_vs_true_err"]
                              < an["grad_vs_true_err"]):
            failures.append(f"{chan}: fo recon_err !< analog recon_err")
    for r in rows:
        if r["dominated"] is False:
            failures.append(f"{r['channel']}/{r['mechanism']}: "
                            "eps_hat exceeds analytic eps")

    os.makedirs("results", exist_ok=True)
    out = "results/fig_privacy.json"
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"\nwrote {out}")
    if failures:
        raise SystemExit("PRIVACY CLAIMS VIOLATED: " + "; ".join(failures))
    print("claims hold: fo inverts, OTA does not; eps_hat <= analytic eps "
          "on every audited cell")


if __name__ == "__main__":
    main()
