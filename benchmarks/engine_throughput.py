"""Rounds/sec: scan-over-rounds engine vs. per-round dispatch.

    PYTHONPATH=src python benchmarks/engine_throughput.py \
        [--rounds 96] [--chunk-rounds 16] [--n-perturb 1] [--json out.json]

Measures the end-to-end federated driver (`fedsim.run`) on the paper's own
architecture reduced to CPU scale (`opt-125m --reduced`), identical config
for both engines. The first run of each engine is a throwaway warmup that
pays tracing + XLA compile (cached across runs via the memoized step
factory); the timed run is steady-state throughput — what a long training
horizon actually sees per round.

The scan engine's win is pure dispatch economics: the loop pays a
host→device control-block rebuild, a kernel launch, and a blocking metric
sync every round; scan pays them once per chunk. The loss trajectories are
asserted bit-identical, so the speedup is free.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

sys.path.insert(0, "src")

from repro.configs.base import (ChannelConfig, DPConfig, PairZeroConfig,
                                PowerControlConfig, ZOConfig)
from repro.core import fedsim
from repro.data.pipeline import FederatedPipeline
from repro.data.tasks import TaskSpec
from repro.models import registry


def build(args):
    cfg = registry.get_arch("opt-125m").reduced()
    pz = PairZeroConfig(
        variant="analog", n_clients=args.clients, rounds=args.rounds,
        zo=ZOConfig(mu=1e-3, lr=5e-3, clip_gamma=5.0,
                    n_perturb=args.n_perturb),
        channel=ChannelConfig(n0=1.0, power=100.0),
        dp=DPConfig(epsilon=5.0, delta=0.01),
        power=PowerControlConfig(scheme="solution"), seed=0)

    def pipe():
        return FederatedPipeline(
            task="sst2", spec=TaskSpec("sst2", cfg.vocab_size, args.seq),
            n_clients=args.clients, per_client_batch=args.batch, seed=0)

    return cfg, pz, pipe


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--rounds", type=int, default=128)
    ap.add_argument("--chunk-rounds", type=int, default=32)
    ap.add_argument("--clients", type=int, default=3)
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--seq", type=int, default=16)
    ap.add_argument("--n-perturb", type=int, default=1)
    ap.add_argument("--repeats", type=int, default=5,
                    help="timed passes per engine (interleaved, best-of)")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()

    cfg, pz, pipe = build(args)
    print(f"== engine throughput: {cfg.name} (reduced, "
          f"{cfg.param_count() / 1e3:.0f}k params), {args.rounds} rounds, "
          f"{args.clients} clients, chunk={args.chunk_rounds}, "
          f"n_perturb={args.n_perturb} ==")

    engines = {"loop": dict(engine="loop"),
               "scan": dict(engine="scan", chunk_rounds=args.chunk_rounds)}
    losses = {}
    for name, kw in engines.items():       # warmup: tracing + XLA compile
        losses[name] = fedsim.run(cfg, pz, pipe(), rounds=args.rounds,
                                  **kw).losses
    identical = losses["scan"] == losses["loop"]

    # interleaved best-of-N so machine drift hits both engines equally
    best = {name: 0.0 for name in engines}
    for _ in range(args.repeats):
        for name, kw in engines.items():
            t0 = time.perf_counter()
            fedsim.run(cfg, pz, pipe(), rounds=args.rounds, **kw)
            best[name] = max(best[name],
                             args.rounds / (time.perf_counter() - t0))
    loop_rps, scan_rps = best["loop"], best["scan"]
    speedup = scan_rps / loop_rps
    print(f"loop (per-round dispatch): {loop_rps:8.1f} rounds/s")
    print(f"scan (chunked, device-resident): {scan_rps:8.1f} rounds/s")
    print(f"speedup: {speedup:.2f}x   loss traces bit-identical: {identical}")

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"loop_rounds_per_s": loop_rps,
                       "scan_rounds_per_s": scan_rps,
                       "speedup": speedup,
                       "bit_identical": identical,
                       "chunk_rounds": args.chunk_rounds,
                       "rounds": args.rounds}, f, indent=2)

    if not identical:
        raise SystemExit("FAIL: scan and loop trajectories diverged")
    if speedup < 2.0:
        print("WARNING: speedup below the 2x acceptance target "
              "(contended machine?)")


if __name__ == "__main__":
    main()
