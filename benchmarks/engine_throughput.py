"""Engine throughput: loop vs scan vs scan+mesh, with overlap breakdown.

    PYTHONPATH=src python benchmarks/engine_throughput.py \
        [--rounds 128] [--chunk-rounds 32] [--clients 8] \
        [--sizes tiny,opt-125m-reduced] [--json BENCH_engine.json]

Measures the end-to-end federated driver (`fedsim.run`) at 2-3 model sizes,
identical config across engines:

  loop       per-round dispatch (the bit-identity oracle)
  scan       chunked lax.scan, device-resident params, prefetch overlap
  scan_mesh  scan + clients shard_map'd over a ('data',) device mesh (runs
             when >1 device is visible and divides --clients; on CPU set
             XLA_FLAGS=--xla_force_host_platform_device_count=8)

plus the chunk-boundary overlap breakdown at the primary size:

  prefetch    scan with the chunk-prep thread on vs off (`overlap=`),
              reporting the driver's boundary stall as the sum of the
              run's `prep_stall` spans (repro.obs span timeline — the
              single source of truth; RunResult.prep_stall_s is asserted
              equal to the span sum within 1ms)
  checkpoint  scan + checkpoint_every=chunk_rounds with the double-buffered
              snapshot vs the synchronous device_get baseline
              (CheckpointHook(double_buffer=)), reporting the summed
              `ckpt_snapshot` spans the same way

The first run of each config is a throwaway warmup that pays tracing + XLA
compile (cached via the memoized step factories); timed passes are
interleaved best-of-N so machine drift hits every engine equally. Loss
trajectories are asserted bit-identical to the loop engine, so every
speedup is free.

Each grid row also carries a `cost` block from the compiled executable's
own cost/memory analysis (repro.obs.hlo via `Telemetry(cost=True)` on
the warmup pass): flops, bytes_accessed, peak HBM bytes, and the HLO
collective census — the measured-throughput row and the compiler's view
of the same program, side by side.

`--json` writes the machine-readable BENCH_engine.json
(schema "bench_engine/v3", spans_version 1: stall numbers are
span-derived; v3 added the per-row `cost` block); `tools/check_bench.py`
validates it and gates the scan speedup + stall reductions in CI.
`--history PATH` additionally appends the headline numbers as one
bench_history/v1 row (tools/bench_history.py) — the committed
`results/bench_history.jsonl` is gated by `check_bench --history`.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

sys.path.insert(0, "src")
sys.path.insert(0, os.path.join(os.path.dirname(__file__) or ".", "..",
                                "tools"))

import jax  # noqa: E402

import bench_history  # noqa: E402

from repro import obs  # noqa: E402
from repro.configs.base import (ChannelConfig, DPConfig, ModelConfig,  # noqa: E402
                                PairZeroConfig, PowerControlConfig, ZOConfig)
from repro.core import fedsim  # noqa: E402
from repro.data.pipeline import FederatedPipeline  # noqa: E402
from repro.data.tasks import TaskSpec  # noqa: E402
from repro.launch.mesh import make_client_mesh  # noqa: E402
from repro.models import registry  # noqa: E402

SCHEMA = "bench_engine/v3"      # v3: per-row `cost` introspection block
SPANS_VERSION = 1       # stall numbers derive from the repro.obs timeline


def model_sizes() -> dict:
    """The benchmark's size ladder (all CPU-runnable)."""
    return {
        "tiny": ModelConfig(name="tiny", family="dense", n_layers=2,
                            d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                            vocab_size=64, head_dim=16),
        "opt-125m-reduced": registry.get_arch("opt-125m").reduced(),
        "opt-125m-wide": registry.get_arch("opt-125m").reduced(
            d_model=128, d_ff=256, vocab_size=2048, head_dim=32),
    }


def build_pz(args) -> PairZeroConfig:
    return PairZeroConfig(
        variant="analog", n_clients=args.clients, rounds=args.rounds,
        zo=ZOConfig(mu=1e-3, lr=5e-3, clip_gamma=5.0,
                    n_perturb=args.n_perturb),
        channel=ChannelConfig(n0=1.0, power=100.0),
        dp=DPConfig(epsilon=5.0, delta=0.01),
        power=PowerControlConfig(scheme="solution"), seed=0)


def make_pipe(cfg, args) -> FederatedPipeline:
    return FederatedPipeline(
        task="sst2", spec=TaskSpec("sst2", cfg.vocab_size, args.seq),
        n_clients=args.clients, per_client_batch=args.batch, seed=0)


def bench_mesh(args):
    """Client mesh for the scan_mesh lane — exactly the mesh that
    `train.py --mesh auto` would build — or None on a 1-device host."""
    mesh = make_client_mesh("auto", n_clients=args.clients)
    return mesh if mesh.devices.size > 1 else None


def timed(fn, rounds: int, repeats: int):
    """Best-of-N rounds/s plus the RunResult of the best pass."""
    best_rps, best_res = 0.0, None
    for _ in range(repeats):
        t0 = time.perf_counter()
        res = fn()
        rps = rounds / (time.perf_counter() - t0)
        if rps > best_rps:
            best_rps, best_res = rps, res
    return best_rps, best_res


def span_stall(tel, span_name: str, legacy_s: float):
    """Span-derived stall: Σ `span_name` durations from the run's tracer.

    The spans are the single source of truth; the legacy RunResult scalar
    must agree within 1ms or the timeline instrumentation has drifted
    from the driver's accounting (SystemExit — this is a gate, not a
    warning). Returns (stall_s, span_count)."""
    total = tel.tracer.total_s(span_name)
    if abs(total - legacy_s) > 1e-3:
        raise SystemExit(
            f"FAIL: sum of {span_name} spans = {total:.6f}s but legacy "
            f"counter = {legacy_s:.6f}s — span timeline diverged from "
            "the driver's stall accounting")
    return total, len(tel.tracer.spans(span_name))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--rounds", type=int, default=128)
    ap.add_argument("--chunk-rounds", type=int, default=32)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--seq", type=int, default=16)
    ap.add_argument("--n-perturb", type=int, default=1)
    ap.add_argument("--repeats", type=int, default=3,
                    help="timed passes per config (interleaved, best-of)")
    ap.add_argument("--sizes", default="tiny,opt-125m-reduced",
                    help=f"comma list from {sorted(model_sizes())}")
    ap.add_argument("--no-mesh", action="store_true",
                    help="skip the scan_mesh lane even when devices allow")
    ap.add_argument("--json", default=None,
                    help="write BENCH_engine.json here")
    ap.add_argument("--history", default=None, metavar="PATH",
                    help="append a bench_history/v1 row (headline "
                         "numbers) to this JSONL ledger")
    args = ap.parse_args()

    sizes = {name: model_sizes()[name] for name in args.sizes.split(",")}
    pz = build_pz(args)
    mesh = None if args.no_mesh else bench_mesh(args)

    def runner(cfg, engine, mesh_=None, overlap=True, cost=False):
        """`cost=True` rides the HLO introspection on the pass (warmup
        only — the analysis lowers the program once, off the clock)."""
        def go():
            tel = obs.Telemetry(cost=True) if cost else None
            return fedsim.run(cfg, pz, make_pipe(cfg, args),
                              rounds=args.rounds, engine=engine,
                              chunk_rounds=args.chunk_rounds,
                              mesh=mesh_, overlap=overlap, telemetry=tel)
        return go

    print(f"== engine throughput: {args.rounds} rounds, "
          f"{args.clients} clients, chunk={args.chunk_rounds}, "
          f"n_perturb={args.n_perturb}, devices={len(jax.devices())}, "
          f"mesh={'off' if mesh is None else dict(mesh.shape)} ==")

    grid = []
    for name, cfg in sizes.items():
        specs = {"loop": ("loop", None), "scan": ("scan", None)}
        if mesh is not None:
            specs["scan_mesh"] = ("scan", mesh)
        lanes = {lane: runner(cfg, eng, mesh_=m)
                 for lane, (eng, m) in specs.items()}
        # warmup pays tracing + compile AND captures the compiled
        # executable's cost/memory analysis for the row's `cost` block
        warm = {lane: runner(cfg, eng, mesh_=m, cost=True)()
                for lane, (eng, m) in specs.items()}
        losses = {lane: res.losses for lane, res in warm.items()}
        costs = {lane: res.cost_stats for lane, res in warm.items()}
        best = {}
        for _ in range(args.repeats):       # interleaved best-of
            for lane, fn in lanes.items():
                t0 = time.perf_counter()
                fn()
                best[lane] = max(best.get(lane, 0.0),
                                 args.rounds / (time.perf_counter() - t0))
        for lane in lanes:
            cost = costs[lane]
            if cost is not None and "error" in cost:
                cost = None             # analysis unavailable, not broken
            row = {
                "size": name, "engine": lane,
                "rounds_per_s": round(best[lane], 2),
                "speedup_vs_loop": round(best[lane] / best["loop"], 3),
                "bit_identical_to_loop": losses[lane] == losses["loop"],
                "mesh": dict(mesh.shape) if lane == "scan_mesh" else None,
                "cost": cost,
            }
            grid.append(row)
            cdesc = "n/a" if cost is None else (
                f"{cost['flops'] / 1e6:.1f} MFLOP, "
                f"peak {cost['peak_bytes'] / 1e6:.2f} MB, "
                f"{sum(c['count'] for c in cost['collectives'].values())}"
                " collective(s)")
            print(f"  {name:18s} {lane:10s} {row['rounds_per_s']:8.1f} r/s "
                  f"({row['speedup_vs_loop']:.2f}x loop, bitwise="
                  f"{row['bit_identical_to_loop']}; {cdesc})")
        if not all(r["bit_identical_to_loop"] for r in grid
                   if r["size"] == name):
            raise SystemExit(f"FAIL: {name}: an engine diverged from loop")

    # -- overlap breakdown at the primary size ---------------------------
    primary = "opt-125m-reduced" if "opt-125m-reduced" in sizes \
        else next(iter(sizes))
    cfg = sizes[primary]
    print(f"-- overlap breakdown @ {primary} --")

    def traced_run(overlap: bool):
        """Fresh tracer per pass so span sums cover exactly one run."""
        def go():
            tel = obs.Telemetry.on()
            res = fedsim.run(cfg, pz, make_pipe(cfg, args),
                             rounds=args.rounds, engine="scan",
                             chunk_rounds=args.chunk_rounds,
                             overlap=overlap, telemetry=tel)
            return res, tel
        return go

    runner(cfg, "scan")()                                   # warm
    prefetch = {}
    for label, ov in (("on", True), ("off", False)):
        rps, (res, tel) = timed(traced_run(ov), args.rounds, args.repeats)
        stall, n_spans = span_stall(tel, "prep_stall", res.prep_stall_s)
        prefetch[label] = {"rounds_per_s": round(rps, 2),
                           "prep_stall_s": round(stall, 4),
                           "prep_stall_spans": n_spans}
        print(f"  prefetch {label:3s}: {rps:8.1f} r/s, "
              f"boundary prep stall {stall * 1e3:7.1f} ms "
              f"({n_spans} spans)")

    def ckpt_runner(double_buffer: bool):
        def go():
            tel = obs.Telemetry.on()
            with tempfile.TemporaryDirectory() as d:
                hooks = [fedsim.CheckpointHook(
                    d, every=args.chunk_rounds,
                    double_buffer=double_buffer)]
                res = fedsim.Experiment(
                    cfg, pz, make_pipe(cfg, args), args.rounds,
                    engine="scan", chunk_rounds=args.chunk_rounds,
                    hooks=hooks, telemetry=tel).run()
            return res, tel
        return go

    ckpt_runner(True)()                                     # warm
    checkpoint = {}
    for label, db in (("double_buffer", True), ("sync", False)):
        rps, (res, tel) = timed(ckpt_runner(db), args.rounds, args.repeats)
        stall, n_spans = span_stall(tel, "ckpt_snapshot", res.ckpt_stall_s)
        checkpoint[label] = {"rounds_per_s": round(rps, 2),
                             "ckpt_stall_s": round(stall, 4),
                             "ckpt_snapshot_spans": n_spans}
        print(f"  checkpoint {label:13s}: {rps:8.1f} r/s, "
              f"snapshot stall {stall * 1e3:7.1f} ms ({n_spans} spans)")

    report = {
        "schema": SCHEMA,
        "spans_version": SPANS_VERSION,
        "created_unix": int(time.time()),
        "host": {"devices": len(jax.devices()),
                 "platform": jax.devices()[0].platform},
        "config": {"rounds": args.rounds, "chunk_rounds": args.chunk_rounds,
                   "clients": args.clients, "batch": args.batch,
                   "seq": args.seq, "n_perturb": args.n_perturb,
                   "repeats": args.repeats},
        "sizes": {name: {"param_count": int(cfg_.param_count())}
                  for name, cfg_ in sizes.items()},
        "grid": grid,
        "overlap": {"size": primary, "prefetch": prefetch,
                    "checkpoint": checkpoint},
    }
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {args.json}")
    if args.history:
        by = {(r["size"], r["engine"]): r for r in grid}
        loop = by[(primary, "loop")]
        scan = by[(primary, "scan")]
        row = bench_history.append_row(args.history, "engine", {
            "size": primary,
            "rounds": args.rounds,
            "scan_rounds_per_s": scan["rounds_per_s"],
            "loop_rounds_per_s": loop["rounds_per_s"],
            "scan_speedup": scan["speedup_vs_loop"],
            "prep_stall_on_s": prefetch["on"]["prep_stall_s"],
            "prep_stall_off_s": prefetch["off"]["prep_stall_s"],
            "ckpt_stall_db_s": checkpoint["double_buffer"]["ckpt_stall_s"],
            "ckpt_stall_sync_s": checkpoint["sync"]["ckpt_stall_s"],
        })
        print(f"appended history row (sha {row['git_sha']}, "
              f"{row['host']['platform']}/{row['host']['devices']}dev) "
              f"to {args.history}")


if __name__ == "__main__":
    main()
