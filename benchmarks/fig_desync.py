"""Desync tolerance sweep: seed-broadcast ZO vs conventional analog OTA.

The paper's scalar uplink has a structural synchronization advantage this
figure quantifies. A pAirZero client transmits ONE symbol per round and
the perturbation itself travels as a broadcast seed, so imperfect
synchronization can only (a) attenuate the scalar by cos(theta) of its
persistent clock-skew phase error, or (b) make a straggler's scalar ride
a stale round seed z_{t-d} — a bounded-noise contribution the server's
inversion averages away. A conventional first-order analog-OTA baseline
uploads d-dimensional gradients over n symbols per frame: the SAME skew
theta accumulates across the frame, so the coordinate riding symbol slot
k combines with gain cos(k*theta) — across clients most late-frame
coordinates are persistently annihilated or sign-flipped (mean coherent
gain collapses along the Dirichlet kernel |sin(n*theta/2) /
(n*sin(theta/2))|) plus inter-symbol interference. Both mechanisms
report the TRUE masked-mean loss (the degraded decode drives only the
gradient), so retained-progress ratios are comparable.

Cells (all matched rounds/seed/channel):
  zo   analog pAirZero at stale fractions {0, 0.25, 0.5} with the same
       per-client clock-skew std the baseline sees;
  fo   the FO analog baseline, clean and under the same desync trace
       with an n-symbol frame (frame_symbols) per-coordinate gain + ICI.

The gated claim (enforced by tools/check_bench.py --desync and pinned in
CI): at 50% stale clients + 0.3 rad clock skew, ZO retains >= 30% of
its clean-run loss progress and keeps descending, while the misaligned
FO baseline retains <= 10% of its own (measured: its loss RISES — the
persistently sign-flipped coordinates diverge) — the seed-broadcast
design degrades gracefully where the d-dimensional frame collapses.

The same artifact also records a `torn_fallback` block: an in-process
kill-free rehearsal of the crash-consistency contract — a checkpoint is
torn (truncated npz), resume falls back to the last CRC-valid one via
checkpoint.latest_valid, and the re-run's final parameters are compared
bitwise to an uninterrupted run's (the process-level SIGKILL version
lives in tools/chaos_run.py).

    PYTHONPATH=src python -m benchmarks.fig_desync \
        [--rounds 60] [--fractions 0,0.25,0.5] [--phase-std 0.3] \
        [--frame-symbols 64] [--seed 0]

Writes results/fig_desync.json.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import tempfile
import time

import jax
import numpy as np

from repro.checkpoint import checkpoint as ckpt
from repro.configs.base import (ChannelConfig, DesyncConfig, DPConfig,
                                ModelConfig, PairZeroConfig,
                                PowerControlConfig, TransportConfig,
                                ZOConfig)
from repro.core import fedsim

TINY = ModelConfig(name="tiny-opt", family="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=4, d_ff=256, vocab_size=64,
                   head_dim=16)

N_CLIENTS = 8

# the claim cell (see module docstring)
CLAIM_FRACTION = 0.5
ZO_RETAIN_MIN = 0.30
FO_RETAIN_MAX = 0.10


def build_pz(mechanism: str, rounds: int, seed: int,
             desync: DesyncConfig | None) -> PairZeroConfig:
    return PairZeroConfig(
        n_clients=N_CLIENTS, rounds=rounds,
        zo=ZOConfig(mu=1e-3, lr=5e-3, clip_gamma=5.0, n_perturb=4),
        channel=ChannelConfig(n0=1.0, power=100.0),
        dp=DPConfig(epsilon=50.0, delta=0.01),
        power=PowerControlConfig(scheme="solution"),
        transport=TransportConfig(mechanism, "solution"),
        desync=desync, seed=seed)


def make_pipeline(seed: int):
    from repro.data.pipeline import FederatedPipeline
    from repro.data.tasks import TaskSpec
    return FederatedPipeline(task="sst2", spec=TaskSpec("sst2", 64, 24),
                             n_clients=N_CLIENTS, per_client_batch=4,
                             seed=seed)


def run_cell(mechanism: str, rounds: int, seed: int,
             desync: DesyncConfig | None) -> dict:
    pz = build_pz(mechanism, rounds, seed, desync)
    res = fedsim.run(TINY, pz, make_pipeline(seed), rounds=rounds,
                     engine="scan", chunk_rounds=max(rounds // 4, 1))
    return {
        "mechanism": mechanism,
        "stale_fraction": desync.fraction if desync else 0.0,
        "phase_std": desync.phase_std if desync else 0.0,
        "frame_symbols": desync.frame_symbols if desync else 1,
        "rounds": res.steps,
        "first_loss": float(np.mean(res.losses[:5])),
        "final_loss": float(np.mean(res.losses[-10:])),
        "uplink_bits": res.uplink_bits,
    }


def retained(cell: dict, clean: dict) -> float:
    """Fraction of the clean run's loss progress a desynced run keeps."""
    progress_clean = clean["first_loss"] - clean["final_loss"]
    if progress_clean <= 1e-9:
        return 1.0
    return (cell["first_loss"] - cell["final_loss"]) / progress_clean


def torn_fallback_check(rounds: int, every: int, seed: int) -> dict:
    """In-process torn-checkpoint fallback rehearsal (bitwise contract).

    Uninterrupted run vs: partial run, newest checkpoint torn, resume
    (latest_valid falls back past the tear), run to completion — final
    params must match leaf-for-leaf bitwise.
    """
    pz = build_pz("analog", rounds, seed, None)
    work = tempfile.mkdtemp(prefix="fig_desync_torn_")
    d_ref, d_torn = os.path.join(work, "ref"), os.path.join(work, "torn")
    try:
        ref = fedsim.run(TINY, pz, make_pipeline(seed), rounds=rounds,
                         checkpoint_dir=d_ref, checkpoint_every=every,
                         eval_every=0)
        fedsim.run(TINY, pz, make_pipeline(seed), rounds=rounds // 2,
                   checkpoint_dir=d_torn, checkpoint_every=every,
                   eval_every=0)
        newest = ckpt.latest(d_torn)
        ckpt.tear_checkpoint(newest)
        fell_back = ckpt.latest_valid(d_torn) != newest
        res = fedsim.run(TINY, pz, make_pipeline(seed), rounds=rounds,
                         checkpoint_dir=d_torn, checkpoint_every=every,
                         eval_every=0)
        equal = all(
            (np.asarray(a) == np.asarray(b)).all()
            for a, b in zip(jax.tree_util.tree_leaves(ref.params),
                            jax.tree_util.tree_leaves(res.params)))
        return {"exercised": True, "fell_back": bool(fell_back),
                "resumed_from": int(res.resumed_from),
                "torn_step": int(os.path.basename(newest).split("_")[1]),
                "bitwise_equal": bool(equal)}
    finally:
        shutil.rmtree(work, ignore_errors=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--fractions", default="0,0.25,0.5",
                    help="comma-separated stale-client fractions")
    ap.add_argument("--phase-std", type=float, default=0.3,
                    help="fractional-timing phase-error std (radians), "
                         "applied identically to both mechanisms")
    ap.add_argument("--frame-symbols", type=int, default=64,
                    help="symbols per frame for the FO baseline's "
                         "Dirichlet gain (the d-dim payload duration)")
    ap.add_argument("--max-lag", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    fractions = [float(x) for x in args.fractions.split(",")]

    def desync_for(frac: float, frame: int) -> DesyncConfig | None:
        if frac == 0.0:
            return None
        return DesyncConfig(fraction=frac, max_lag=args.max_lag,
                            phase_std=args.phase_std, frame_symbols=frame,
                            seed=args.seed)

    zo_rows, fo_rows = [], []
    for frac in fractions:
        row = run_cell("analog", args.rounds, args.seed,
                       desync_for(frac, 1))
        zo_rows.append(row)
        print(f"zo  stale={frac:.2f} first={row['first_loss']:.4f} "
              f"final={row['final_loss']:.4f}", flush=True)
    for frac in (0.0, CLAIM_FRACTION):
        row = run_cell("fo", args.rounds, args.seed,
                       desync_for(frac, args.frame_symbols))
        fo_rows.append(row)
        print(f"fo  stale={frac:.2f} first={row['first_loss']:.4f} "
              f"final={row['final_loss']:.4f}", flush=True)

    zo_clean = zo_rows[0]
    fo_clean = fo_rows[0]
    for row in zo_rows:
        row["retained"] = retained(row, zo_clean)
    for row in fo_rows:
        row["retained"] = retained(row, fo_clean)

    zo_claim = next(r for r in zo_rows
                    if r["stale_fraction"] == CLAIM_FRACTION)
    fo_claim = next(r for r in fo_rows
                    if r["stale_fraction"] == CLAIM_FRACTION)
    claim = {
        "stale_fraction": CLAIM_FRACTION,
        "phase_std": args.phase_std,
        "frame_symbols": args.frame_symbols,
        "zo_retained": zo_claim["retained"],
        "zo_threshold": ZO_RETAIN_MIN,
        "fo_retained": fo_claim["retained"],
        "fo_threshold": FO_RETAIN_MAX,
        "holds": bool(zo_claim["retained"] >= ZO_RETAIN_MIN
                      and fo_claim["retained"] <= FO_RETAIN_MAX),
    }

    print("running torn-fallback rehearsal...", flush=True)
    torn = torn_fallback_check(rounds=16, every=4, seed=args.seed)

    os.makedirs("results", exist_ok=True)
    out = "results/fig_desync.json"
    with open(out, "w") as f:
        json.dump({"schema": "fig_desync/v1",
                   "created_unix": int(time.time()),
                   "config": {"rounds": args.rounds,
                              "n_clients": N_CLIENTS,
                              "fractions": fractions,
                              "phase_std": args.phase_std,
                              "frame_symbols": args.frame_symbols,
                              "max_lag": args.max_lag,
                              "seed": args.seed},
                   "zo": zo_rows, "fo": fo_rows, "claim": claim,
                   "torn_fallback": torn}, f, indent=1)
    print(f"\nwrote {out}")

    failures = []
    if not claim["holds"]:
        failures.append(
            f"zo retains {claim['zo_retained']:.2f} "
            f"(need >= {ZO_RETAIN_MIN}) / fo retains "
            f"{claim['fo_retained']:.2f} (need <= {FO_RETAIN_MAX})")
    if not (torn["fell_back"] and torn["bitwise_equal"]):
        failures.append(f"torn fallback: {torn}")
    if failures:
        raise SystemExit("DESYNC CLAIMS VIOLATED: " + "; ".join(failures))
    print(f"claim holds: zo retains {claim['zo_retained']:.2f} of clean "
          f"progress at {CLAIM_FRACTION:.0%} stale clients; the "
          f"{args.frame_symbols}-symbol FO frame retains only "
          f"{claim['fo_retained']:.2f}; torn-checkpoint resume is "
          "bitwise-equal")


if __name__ == "__main__":
    main()
