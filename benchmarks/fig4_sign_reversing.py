"""Figs. 4–6 reproduction: the sign-reversing probability study.

Measures e_k = P(sign(z^T ∇F(w; batch)) ≠ sign(z^T ∇F(w))) over training —
the paper's empirical justification for e₀ = 0.4960 < 1/2 (Lemma 2 needs
e₀ ≤ 1/2) — plus the near-symmetric distribution of batch projections
around the full-data projection (Fig. 6).

`--byzantine-frac > 0` adds the ACTIVE companion study: the training arm
runs with that fraction of clients executing the registered `sign_flip`
behavior (repro.byzantine) — a worst-case, adversarial version of the
statistical sign reversals this figure quantifies — and the e_k
measurement is repeated on the attacked trajectory's checkpoints. The
attack rides the registry (no inline adversary here): the bitwise pin of
registered `sign_flip` against a hand-written negation lives in
tests/test_byzantine.py.

    PYTHONPATH=src python -m benchmarks.fig4_sign_reversing
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import numpy as np

from repro.configs.base import (ByzantineConfig, ModelConfig,
                                PairZeroConfig, TransportConfig, ZOConfig)
from repro.core import fedsim, zo
from repro.core.pairzero import make_loss_fn
from repro.data.pipeline import FederatedPipeline
from repro.data.tasks import TaskSpec

TINY = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=4, d_ff=256, vocab_size=64,
                   head_dim=16)


def measure_e_k(params, pipe, n_seeds=8, n_batches=64):
    """For each direction seed: full-data projection sign vs batch signs."""
    import jax
    import jax.numpy as jnp
    loss_fn = make_loss_fn(TINY)

    @jax.jit
    def proj_fn(p, batch, seed):
        lp, lm, _ = zo.dual_forward(
            lambda q: loss_fn(q, batch).mean(), p, seed, 1e-3, mode="fresh")
        return (lp - lm) / 2e-3

    def proj(b, seed):
        batch = {k2: jnp.asarray(v) for k2, v in b.items()
                 if k2 != "labels"}
        return float(proj_fn(params, batch, seed))

    results = []
    big = [pipe.batch(10_000 + i) for i in range(16)]   # "full data" proxy
    for s in range(n_seeds):
        seed = zo.round_seed(77, s)
        full = float(np.mean([proj(b, seed) for b in big]))
        batch_projs = [proj(pipe.batch(20_000 + i), seed)
                       for i in range(n_batches)]
        flips = np.mean([np.sign(p) != np.sign(full) for p in batch_projs])
        results.append({"seed": s, "full_proj": full,
                        "batch_proj_mean": float(np.mean(batch_projs)),
                        "batch_proj_std": float(np.std(batch_projs)),
                        "e_k": float(flips)})
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--checkpoints", type=int, default=3)
    ap.add_argument("--byzantine-frac", type=float, default=0.0,
                    help="fraction of clients running the registered "
                         "sign_flip behavior during training (active "
                         "sign-reversing arm); 0 reproduces the passive "
                         "figure bitwise")
    args = ap.parse_args()

    pipe = FederatedPipeline(task="sst2", spec=TaskSpec("sst2", 64, 24),
                             n_clients=5, per_client_batch=8, seed=0)
    byz = (ByzantineConfig(behavior="sign_flip",
                           fraction=args.byzantine_frac)
           if args.byzantine_frac > 0.0 else None)
    pz = PairZeroConfig(n_clients=5,
                        zo=ZOConfig(mu=1e-3, lr=5e-3, clip_gamma=5.0,
                                    n_perturb=4),
                        transport=TransportConfig("analog", "perfect"),
                        byzantine=byz)

    all_rows = []
    params = None
    per = max(args.rounds // args.checkpoints, 1)
    for ci in range(args.checkpoints):
        res = fedsim.run(TINY, pz, pipe, rounds=per, params=params)
        params = res.params
        rows = measure_e_k(params, pipe)
        e_max = max(r["e_k"] for r in rows)
        print(f"after {(ci + 1) * per} rounds: max e_k = {e_max:.4f} "
              f"(paper: 0.4968 max; must stay < 0.5)", flush=True)
        all_rows.append({"round": (ci + 1) * per, "measurements": rows})

    e0 = max(r["e_k"] for blk in all_rows for r in blk["measurements"])
    os.makedirs("results", exist_ok=True)
    with open("results/fig4_sign_reversing.json", "w") as f:
        json.dump({"e0_measured": e0, "paper_e0": 0.4960,
                   "byzantine_frac": args.byzantine_frac,
                   "blocks": all_rows}, f, indent=1)
    print(f"\nmeasured e0 = {e0:.4f} (< 0.5 ⇒ Lemma 2 applies)")


if __name__ == "__main__":
    main()
