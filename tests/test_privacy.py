"""Privacy subsystem: adversary capture, attacks, and the empirical audit.

The contracts under test:

  * observation capture is engine-invariant (scan ≡ loop, bitwise) and
    PASSIVE (training trajectories reproduce bit-for-bit with capture on,
    off, or absent — the historical program is the adversary=None trace);
  * the acceptance criterion: gradient-inversion reconstruction error on
    the FO uplink is measurably LOWER (attacker wins) than on pAirZero's
    analog OTA at matched rounds;
  * the audit contract: the empirical Clopper–Pearson ε̂ lower bound never
    exceeds the analytic accountant's ε on any DP transport × channel ×
    power-schedule combination;
  * the DLG attack is deterministic at fixed seed and reconstructs tokens
    measurably above chance from a raw FO gradient.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import channel as ch
from repro import privacy as pv
from repro.configs.base import (ChannelConfig, DPConfig, PairZeroConfig,
                                PowerControlConfig, TransportConfig,
                                ZOConfig)
from repro.core import dp, fedsim, pairzero, zo
from repro.core import transport as tp
from repro.models import registry


def make_tpz(mechanism, scheme="solution", rounds=12, n_perturb=1,
             lr=5e-3, gamma=5.0, eps=5.0, seed=0, n_clients=5,
             channel_kw=None):
    """PairZeroConfig speaking TransportConfig (new-style, no shims)."""
    return PairZeroConfig(
        n_clients=n_clients, rounds=rounds,
        zo=ZOConfig(mu=1e-3, lr=lr, clip_gamma=gamma, n_perturb=n_perturb),
        channel=ChannelConfig(n0=1.0, power=100.0, **(channel_kw or {})),
        dp=DPConfig(epsilon=eps, delta=0.01),
        power=PowerControlConfig(scheme=scheme),
        transport=TransportConfig(mechanism, scheme), seed=seed)


def run_with_capture(model, pz, pipeline, rounds, engine="scan", chunk=5,
                     **kw):
    hook = pv.AttackHook()
    exp = fedsim.Experiment(model, pz, pipeline, rounds=rounds,
                            engine=engine, chunk_rounds=chunk,
                            adversary=pv.Adversary(), hooks=[hook], **kw)
    return exp, hook, exp.run()


# ---------------------------------------------------------------------------
# Registries & protocol
# ---------------------------------------------------------------------------

def test_attack_registry():
    assert "dlg" in pv.available()
    assert "seed_replay" in pv.available()
    assert "steering" in pv.available()
    assert pv.get("dlg") is pv.GradientInversion
    assert pv.get("steering") is pv.TrajectorySteering
    with pytest.raises(ValueError, match="unknown attack"):
        pv.get("rubber_hose")


def test_steering_attack_scores_gap_recovery():
    """The active-adversary scorer: displacement, final gap, and the
    defended fraction the fig_robustness gate thresholds."""
    clean = np.linspace(5.0, 1.0, 20)
    attacked = clean + 2.0                    # uniform steering
    defended = clean + 0.2                    # 90% repaired
    out = pv.get("steering")(tail=5).run(clean, attacked, defended)
    assert out["rounds"] == 20
    assert out["steering_rmse"] == pytest.approx(2.0)
    assert out["final_gap"] == pytest.approx(2.0)
    assert out["gap_recovery"] == pytest.approx(0.9)
    # no defended series -> no recovery score
    assert pv.get("steering")().run(clean, attacked)["gap_recovery"] is None
    # a harmless "attack" leaves recovery undefined rather than divergent
    assert pv.get("steering")().run(clean, clean,
                                    defended)["gap_recovery"] is None
    with pytest.raises(ValueError, match="non-empty"):
        pv.get("steering")().run([], [])


def test_adversary_is_hashable_memo_key(tiny_model):
    adv = pv.Adversary()
    assert hash(adv) == hash(pv.Adversary())
    pz = make_tpz("analog")
    s1 = pairzero.make_zo_step(tiny_model, pz, adversary=adv)
    s2 = pairzero.make_zo_step(tiny_model, pz, adversary=pv.Adversary())
    assert s1 is s2                       # lru_cache hit on equal adversary
    s3 = pairzero.make_zo_step(tiny_model, pz)
    assert s3 is not s1                   # capture-off is a distinct program


def test_smart_digital_registered_with_scalar_payload():
    assert "smart_digital" in tp.available()
    pz = make_tpz("smart_digital", n_perturb=4)
    smart = tp.get("smart_digital").from_config(pz.transport, pz)
    naive = tp.DigitalTDMA(clip=float(pz.zo.clip_gamma))
    d = 100_000
    assert smart.payload_bits(pz, d) == 8 * 4       # b bits per direction
    assert naive.payload_bits(pz, d) == 8 * d       # b bits per coordinate
    assert not smart.charges_privacy(None, pz)
    assert smart.canary_payload(pz) is None         # nothing to audit


def test_transport_observation_specs_cover_builtins():
    pz = make_tpz("analog")
    k = pz.n_clients
    assert set(tp.AnalogOTA().observation_spec(k)) == {"y"}
    assert set(tp.SignOTA().observation_spec(k)) == {"y"}
    spec = tp.DigitalTDMA().observation_spec(k)
    assert spec["q"].shape == (k,)
    assert tp.Transport().observation_spec(k) == {}
    adv = pv.Adversary()
    assert set(adv.observation_spec(tp.AnalogOTA(), k)) == {"obs_y"}


# ---------------------------------------------------------------------------
# Capture: engine-invariant and passive
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mechanism", ["analog", "smart_digital"])
def test_capture_bitwise_scan_vs_loop(tiny_model, make_pipeline, mechanism):
    pz = make_tpz(mechanism, rounds=11)
    _, h_scan, r_scan = run_with_capture(
        tiny_model, pz, make_pipeline(), 11, engine="scan", chunk=4)
    _, h_loop, r_loop = run_with_capture(
        tiny_model, pz, make_pipeline(), 11, engine="loop")
    o_scan, o_loop = h_scan.observations(), h_loop.observations()
    assert sorted(o_scan) == sorted(o_loop)
    for k in o_scan:
        np.testing.assert_array_equal(o_scan[k], o_loop[k], err_msg=k)
    np.testing.assert_array_equal(h_scan.payloads(), h_loop.payloads())
    assert r_scan.losses == r_loop.losses


def test_capture_is_passive(tiny_model, make_pipeline):
    """Trajectories reproduce bit-for-bit with capture on, off, or absent
    (the adversary=None program is the historical golden path)."""
    pz = make_tpz("analog", rounds=10)
    _, _, r_on = run_with_capture(tiny_model, pz, make_pipeline(), 10)
    r_off = fedsim.run(tiny_model, pz, make_pipeline(), rounds=10,
                       engine="scan", chunk_rounds=5)
    r_off2 = fedsim.run(tiny_model, pz, make_pipeline(), rounds=10,
                        engine="scan", chunk_rounds=5)
    assert r_on.losses == r_off.losses == r_off2.losses
    assert r_on.p_hats == r_off.p_hats


def test_ota_observation_matches_decode(tiny_model, make_pipeline):
    """The captured superposed scalar is the exact signal the server
    inverted: p_hat == y / (k_eff · c) round for round."""
    pz = make_tpz("analog", rounds=8)
    exp, hook, res = run_with_capture(tiny_model, pz, make_pipeline(), 8)
    y = hook.observations()["obs_y"].astype(np.float32)
    k_eff = hook.k_eff().astype(np.float32)
    c = np.asarray(exp.schedule.c[:8], dtype=np.float32)
    p_hat = np.where(c > 0, y / (k_eff * np.where(c > 0, c, 1.0)), 0.0)
    np.testing.assert_allclose(p_hat, np.asarray(res.p_hats,
                                                 dtype=np.float32),
                               rtol=1e-6)


def test_digital_capture_exposes_each_client(tiny_model, make_pipeline):
    """Orthogonal slots leak per-client payloads to quantizer resolution."""
    pz = make_tpz("smart_digital", rounds=6)
    _, hook, _ = run_with_capture(tiny_model, pz, make_pipeline(), 6)
    q = hook.observations()["obs_q"]
    p = hook.payloads()
    assert q.shape == p.shape
    cell = 2.0 * pz.zo.clip_gamma / (2 ** 8 - 1)    # quantizer step
    assert np.max(np.abs(q - np.clip(p, -5.0, 5.0))) <= cell + 1e-6


# ---------------------------------------------------------------------------
# Seed replay: digital exposes the victim, OTA hides it in noise
# ---------------------------------------------------------------------------

def test_seed_replay_exposure_ordering(tiny_model, make_pipeline):
    attack = pv.get("seed_replay")()
    out = {}
    for mech in ("smart_digital", "analog"):
        pz = make_tpz(mech, rounds=10)
        exp, hook, res = run_with_capture(tiny_model, pz, make_pipeline(),
                                          10)
        out[mech] = attack.run(hook.observations(), hook.payloads(),
                               exp.schedule.c, hook.k_eff())
    assert out["smart_digital"]["per_client_exposed"]
    assert not out["analog"]["per_client_exposed"]
    # quantizer-resolution recovery vs Eq.-16 noise: orders of magnitude
    assert out["smart_digital"]["victim_rmse"] < 0.05
    assert out["analog"]["victim_rmse"] > \
        10.0 * out["smart_digital"]["victim_rmse"]


# ---------------------------------------------------------------------------
# Acceptance criterion: FO inverts, analog OTA does not
# ---------------------------------------------------------------------------

def test_fo_reconstruction_beats_analog(tiny_model, make_pipeline):
    """Gradient-inversion reconstruction error on the FO uplink is
    measurably lower (better for the attacker) than on pAirZero's analog
    OTA at matched rounds — the ISSUE's acceptance assertion."""
    pipe = make_pipeline()
    params0 = registry.init_params(jax.random.key(0), tiny_model,
                                   jnp.float32)
    batch0 = pipe.batch(0)
    g_true = pv.client_gradient(
        tiny_model, params0,
        {k: jnp.asarray(v) for k, v in batch0.items() if k != "labels"})

    # FO: the observation IS the victim's gradient
    pz_fo = make_tpz("fo", rounds=2)
    _, hook_fo, _ = run_with_capture(tiny_model, pz_fo, make_pipeline(), 2,
                                     engine="loop")
    err_fo = pv.reconstruction_error(
        hook_fo.observations()["obs_grad0"][0], g_true)

    # analog OTA: best estimate is seed replay through the Eq.-16 noise
    pz_an = make_tpz("analog", rounds=2)
    exp, hook_an, _ = run_with_capture(tiny_model, pz_an, make_pipeline(),
                                       2, engine="loop")
    y0 = float(hook_an.observations()["obs_y"][0])
    c0 = float(exp.schedule.c[0])
    k0 = float(hook_an.k_eff()[0])
    scalar = y0 / (k0 * c0) if c0 > 0 else 0.0
    seed0 = zo.perturb_seed(zo.round_seed(pz_an.seed, 0), 0)
    g_hat = pv.zo_gradient_estimate(params0, seed0, scalar)
    err_analog = pv.reconstruction_error(g_hat, g_true)

    assert err_fo < 1e-3                  # raw gradient: near-exact
    assert err_analog > 0.5               # rank-1 + DP noise: not invertible
    assert err_fo < err_analog


def test_dlg_deterministic_and_beats_chance(tiny_model, make_pipeline):
    """DLG on a raw FO gradient: token recovery ≫ chance, bit-identical
    across repeated runs at fixed seed."""
    pipe = make_pipeline(task="lm", batch=1, seq=16)
    params0 = registry.init_params(jax.random.key(0), tiny_model,
                                   jnp.float32)
    batch0 = pipe.batch(0)
    g_star = pv.client_gradient(
        tiny_model, params0,
        {k: jnp.asarray(v) for k, v in batch0.items() if k != "labels"})
    dlg = pv.get("dlg")(steps=400)
    out1 = dlg.run(tiny_model, params0, g_star,
                   targets=batch0["targets"][0], mask=batch0["mask"][0],
                   true_tokens=batch0["tokens"][0])
    out2 = dlg.run(tiny_model, params0, g_star,
                   targets=batch0["targets"][0], mask=batch0["mask"][0],
                   true_tokens=batch0["tokens"][0])
    np.testing.assert_array_equal(out1["tokens"], out2["tokens"])
    assert out1["final_residual"] == out2["final_residual"]
    assert out1["token_accuracy"] >= 10.0 * out1["chance_accuracy"]


# ---------------------------------------------------------------------------
# Empirical audit: ε̂ ≤ analytic ε on every DP transport × channel × scheme
# ---------------------------------------------------------------------------

AUDIT_GRID = [
    ("analog", "solution", {}),
    ("analog", "static", {}),
    ("analog", "reversed", {}),
    ("analog", "solution", {"model": "rician", "rician_k": 4.0}),
    ("analog", "solution", {"model": "ar1", "ar1_rho": 0.7}),
    ("sign", "solution", {}),
    ("sign", "static", {"model": "static"}),
    ("sign", "solution", {"model": "rician", "rician_k": 2.0}),
]


@pytest.mark.parametrize("mech,scheme,channel_kw", AUDIT_GRID)
def test_eps_hat_never_exceeds_analytic(mech, scheme, channel_kw):
    """The subsystem's core contract, per transport × power schedule ×
    channel: paired-trace ε̂ ≤ dp.epsilon_for_budget(spent, δ). No model
    run needed — the audit exercises the mechanism through its realized
    schedule, exactly as the engines would transmit it."""
    rounds = 24
    pz = make_tpz(mech, scheme, rounds=rounds, channel_kw=channel_kw)
    transport = tp.resolve(pz)
    trace = ch.from_config(pz.channel).realize(pz.seed ^ 0xC4A7, rounds,
                                               pz.n_clients)
    schedule = transport.make_schedule(trace, pz)
    result = pv.audit_transport(transport, schedule, pz, rounds=rounds,
                                trials=600)
    assert result.meta["auditable"]
    assert np.isfinite(result.eps_hat) and result.eps_hat >= 0.0
    assert result.spent > 0.0
    assert result.dominated, (
        f"{mech}/{scheme}/{channel_kw}: empirical eps_hat "
        f"{result.eps_hat} exceeds analytic {result.eps_analytic}")


def test_audit_scales_with_rounds():
    """Fewer executed rounds ⇒ less spent ⇒ a smaller analytic ceiling;
    the audit must track the executed horizon, not the planned one."""
    pz = make_tpz("analog", rounds=32)
    transport = tp.resolve(pz)
    trace = ch.from_config(pz.channel).realize(pz.seed ^ 0xC4A7, 32,
                                               pz.n_clients)
    schedule = transport.make_schedule(trace, pz)
    full = pv.audit_transport(transport, schedule, pz, trials=400)
    half = pv.audit_transport(transport, schedule, pz, rounds=16,
                              trials=400)
    assert half.spent < full.spent
    assert half.eps_analytic < full.eps_analytic
    assert half.dominated and full.dominated


def test_non_dp_transport_is_unauditable():
    pz = make_tpz("smart_digital")
    transport = tp.resolve(pz)
    trace = ch.from_config(pz.channel).realize(0, 12, pz.n_clients)
    schedule = transport.make_schedule(trace, pz)
    result = pv.audit_transport(transport, schedule, pz, rounds=12)
    assert result.eps_hat == np.inf          # payloads exposed exactly
    assert not result.meta["auditable"]


def test_epsilon_for_budget_inverts_r_dp():
    for eps in (0.25, 1.0, 5.0, 50.0):
        for delta in (0.1, 0.01, 1e-4):
            spent = dp.r_dp(eps, delta)
            back = dp.epsilon_for_budget(spent, delta)
            assert back == pytest.approx(eps, rel=1e-9)
    assert dp.epsilon_for_budget(0.0, 0.01) == 0.0
    with pytest.raises(ValueError):
        dp.epsilon_for_budget(-1.0, 0.01)


def test_clopper_pearson_upper_bound():
    # rule-of-three sanity: 0 successes in n at 95% ⇒ ≈ 3/n
    assert pv.clopper_pearson_upper(0, 100, 0.95) == \
        pytest.approx(1.0 - 0.05 ** (1 / 100), rel=1e-3)
    assert pv.clopper_pearson_upper(100, 100, 0.95) == 1.0
    # monotone in observed successes, shrinks with more trials
    a = pv.clopper_pearson_upper(5, 100)
    b = pv.clopper_pearson_upper(10, 100)
    assert a < b
    assert pv.clopper_pearson_upper(50, 1000) < \
        pv.clopper_pearson_upper(5, 100)


def test_paired_trace_statistics_separate_under_signal():
    """With a huge canary and tiny noise the two arms must separate; with
    a zero canary they coincide (coupled draws, identical statistics)."""
    from repro.core.power_control import PowerSchedule
    sched = PowerSchedule(c=np.ones(8), sigma=np.full((8, 5), 0.01),
                          scheme="static", n0=1e-4)
    s_in, s_out = pv.paired_trace_statistics(tp.AnalogOTA(), sched, 5.0,
                                             rounds=8, n_clients=5,
                                             trials=64)
    assert np.min(s_in) > np.max(s_out)
    z_in, z_out = pv.paired_trace_statistics(tp.AnalogOTA(), sched, 0.0,
                                             rounds=8, n_clients=5,
                                             trials=64)
    np.testing.assert_array_equal(z_in, z_out)
    # the audit goes through the transport's OWN observe(): a mechanism
    # with no scalar observation stream is rejected, not mis-audited
    with pytest.raises(ValueError, match="observation stream"):
        pv.paired_trace_statistics(tp.DigitalTDMA(), sched, 5.0, rounds=8,
                                   n_clients=5, trials=8)


def test_seed_replay_sign_scores_transmitted_ballots(tiny_model,
                                                     make_pipeline):
    """The sign transport radiates ±1 ballots — attack metrics must score
    against Transport.transmitted(p), not raw γ-scale projections."""
    pz = make_tpz("sign", rounds=8)
    exp, hook, _ = run_with_capture(tiny_model, pz, make_pipeline(), 8)
    radiated = np.asarray(exp.transport.transmitted(hook.payloads()))
    assert set(np.unique(radiated)).issubset({-1.0, 0.0, 1.0})
    out = pv.get("seed_replay")().run(hook.observations(), radiated,
                                      exp.schedule.c, hook.k_eff())
    # the noisy mean-ballot estimate lives on the ballot scale, so its
    # error is bounded by ballots + Eq.-16 noise, never γ-scale
    assert out["mean_rmse"] < 10.0
    assert not out["per_client_exposed"]


# ---------------------------------------------------------------------------
# CI plumbing
# ---------------------------------------------------------------------------

def test_ci_gate_recognizes_privacy_module_ids(monkeypatch):
    """tools/ci_gate.py resolves this module's junit classnames to real
    test ids (the filesystem-backed module/class split)."""
    import importlib.util
    import os
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    spec = importlib.util.spec_from_file_location(
        "ci_gate", os.path.join(root, "tools", "ci_gate.py"))
    gate = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(gate)
    monkeypatch.chdir(root)
    assert gate._classname_to_id("tests.test_privacy", "test_x") == \
        "tests/test_privacy.py::test_x"
    assert gate._classname_to_id("tests.test_channel", "test_y[a-b]") == \
        "tests/test_channel.py::test_y[a-b]"
