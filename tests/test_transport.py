"""Transport protocol: registry, digital baseline, bit accounting, shims."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TransportConfig
from repro.core import fedsim, ota
from repro.core import transport as tp
from repro.core.transport import stochastic_quantize
from repro.models import registry


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def test_registry_has_builtin_mechanisms():
    assert set(tp.available()) >= {"analog", "sign", "perfect", "digital",
                                   "fo"}
    with pytest.raises(ValueError, match="unknown transport"):
        tp.get("carrier-pigeon")


def test_resolve_prefers_transport_config(make_pz):
    import dataclasses
    pz = dataclasses.replace(make_pz(variant="analog"),
                             transport=TransportConfig("sign", "static"))
    t = tp.resolve(pz)
    assert isinstance(t, tp.SignOTA) and t.scheme == "static"


def test_resolve_legacy_strings(make_pz):
    t = tp.resolve(make_pz(variant="sign", scheme="reversed"))
    assert isinstance(t, tp.SignOTA) and t.scheme == "reversed"
    assert isinstance(tp.resolve(make_pz(variant="fo")), tp.FirstOrder)


def test_transports_are_hashable_config_keys():
    """Frozen dataclasses: equal configs hit the memoized step factories."""
    assert tp.AnalogOTA("static") == tp.AnalogOTA("static")
    assert hash(tp.DigitalTDMA(8, 5.0)) == hash(tp.DigitalTDMA(8, 5.0))
    assert tp.DigitalTDMA(8, 5.0) != tp.DigitalTDMA(4, 5.0)


def test_control_spec_owned_by_transport():
    spec = tp.AnalogOTA().control_spec(5)
    assert set(spec) == {"seed", "c", "sigma", "n0", "mask", "g",
                         "noise_bits"}
    assert spec["sigma"].shape == (5,)
    assert spec["g"].shape == (5,)


# ---------------------------------------------------------------------------
# Digital baseline: quantizer, trajectory, accounting
# ---------------------------------------------------------------------------

def test_stochastic_quantization_unbiased():
    """Mean over draws ≈ identity on the clip range (QSGD dithering)."""
    p = jnp.asarray([0.37, -1.62, 4.9, 0.0, -3.141, 5.0, -5.0])
    draws = np.stack([
        np.asarray(stochastic_quantize(p, jax.random.key(i), bits=4,
                                       clip=5.0))
        for i in range(6000)])
    np.testing.assert_allclose(draws.mean(axis=0), np.asarray(p), atol=0.02)
    # every draw lands on a quantizer level
    levels = np.linspace(-5.0, 5.0, 2 ** 4)
    dist = np.abs(draws[:100, :, None] - levels[None, None, :]).min(axis=-1)
    assert dist.max() < 1e-5


def test_stochastic_quantization_clips_outliers():
    p = jnp.asarray([123.0, -456.0])
    q = np.asarray(stochastic_quantize(p, jax.random.key(0), bits=8,
                                       clip=1.0))
    np.testing.assert_allclose(q, [1.0, -1.0])


def test_digital_bit_accounting_exact(make_pz):
    """bits_per_round == K * payload_bits, and payload scales with model d
    (the conventional baseline uploads one full quantized update per round,
    regardless of how many perturbation directions produced it)."""
    pz = make_pz(n_perturb=2)
    t = tp.DigitalTDMA(quant_bits=8, clip=pz.zo.clip_gamma)
    d = 12345
    assert t.payload_bits(pz, d) == 8 * d
    assert t.bits_per_round(pz, d) == pz.n_clients * t.payload_bits(pz, d)


def test_digital_comm_dwarfs_ota_at_opt125m_reduced(make_pz):
    """Table II at opt-125m-reduced scale: the digital baseline's per-round
    communication exceeds both OTA mechanisms by orders of magnitude."""
    cfg = registry.get_arch("opt-125m").reduced()
    d = cfg.param_count()
    pz = make_pz()
    digital = tp.DigitalTDMA(quant_bits=8).bits_per_round(pz, d)
    analog = tp.AnalogOTA().bits_per_round(pz, d)
    sign = tp.SignOTA().bits_per_round(pz, d)
    assert digital > 1000 * analog
    assert digital > 1000 * sign
    # and the FO baseline is even heavier (fp16 vs 8-bit coordinates)
    assert tp.FirstOrder().bits_per_round(pz, d) > digital


def test_digital_runs_and_spends_no_privacy(tiny_model, make_pz,
                                            make_pipeline):
    """The digital transport trains (finite losses), charges nothing to the
    DP accountant (no mechanism — the trilemma's third corner), and is
    bit-identical across engines."""
    import dataclasses
    pz = dataclasses.replace(make_pz(rounds=6),
                             transport=TransportConfig("digital",
                                                       quant_bits=8))
    res_l = fedsim.run(tiny_model, pz, make_pipeline(), rounds=6,
                       engine="loop")
    res_s = fedsim.run(tiny_model, pz, make_pipeline(), rounds=6,
                       engine="scan", chunk_rounds=4)
    assert np.isfinite(res_l.losses).all() and len(res_l.losses) == 6
    assert res_l.privacy_spent == 0.0
    assert res_l.losses == res_s.losses
    assert res_l.uplink_bits == 6 * tp.resolve(pz).bits_per_round(
        pz, tiny_model.param_count())


# ---------------------------------------------------------------------------
# Deprecation shims: old string API == new transport API, bit for bit
# ---------------------------------------------------------------------------

def test_string_shim_bit_identical_trajectories(tiny_model, make_pz,
                                                make_pipeline):
    """fedsim.run(..., variant=, scheme=) warns and reproduces the new
    TransportConfig API bit for bit at fixed seed — both engines."""
    import dataclasses
    pz_new = dataclasses.replace(
        make_pz(rounds=6), transport=TransportConfig("analog", "static"))
    pz_legacy = make_pz(rounds=6, variant="analog", scheme="perfect")
    for engine in ("loop", "scan"):
        res_new = fedsim.run(tiny_model, pz_new, make_pipeline(), rounds=6,
                             engine=engine, chunk_rounds=4)
        with pytest.deprecated_call():
            res_old = fedsim.run(tiny_model, pz_legacy, make_pipeline(),
                                 rounds=6, engine=engine, chunk_rounds=4,
                                 variant="analog", scheme="static")
        assert res_old.losses == res_new.losses, engine
        assert res_old.p_hats == res_new.p_hats, engine
        assert res_old.privacy_spent == res_new.privacy_spent, engine


def test_ota_aggregate_shim_warns_and_matches():
    p = jnp.asarray([1.0, -2.0, 3.0, 0.5, -0.5])
    c = jnp.float32(2.0)
    sigma = jnp.full((5,), 0.3, jnp.float32)
    n0 = jnp.float32(1.0)
    key = jax.random.key(3)
    with pytest.deprecated_call():
        old = ota.aggregate("analog", "solution", p, c, sigma, n0, key)
    ctl = {"c": c, "sigma": sigma, "n0": n0,
           "mask": jnp.ones((5,), jnp.float32)}
    new = tp.AnalogOTA("solution").aggregate(p, ctl, key)
    assert np.asarray(old) == np.asarray(new)
    with pytest.deprecated_call():
        old_sign = ota.aggregate("sign", "perfect", p, c, sigma, n0, key)
    assert float(old_sign) == float(tp.SignOTA("perfect").aggregate(
        p, ctl, key))


def test_perfect_transport_is_noise_free_mean(make_pz):
    pz = make_pz()
    t = tp.get("perfect").from_config(TransportConfig("perfect"), pz)
    ctl = {"mask": jnp.ones((3,), jnp.float32)}
    p = jnp.asarray([1.0, 2.0, 3.0])
    assert float(t.aggregate(p, ctl, jax.random.key(0))) == 2.0
    sched = t.make_schedule(np.ones((4, 3)), pz)
    assert sched.scheme == "perfect" and not t.charges_privacy(sched, pz)


# ---------------------------------------------------------------------------
# DP cost ownership
# ---------------------------------------------------------------------------

def test_round_dp_costs_match_accountant_path(make_pz):
    """Transport-reported per-round costs equal the classic per-round
    charge(c, gamma, m) sequence bit for bit."""
    from repro.core.dp import PrivacyAccountant
    pz = make_pz(scheme="static", rounds=12)
    from repro.channel import RayleighFading
    h = RayleighFading().realize(pz.seed ^ 0xC4A7, 12, pz.n_clients).h
    t = tp.resolve(pz)
    sched = t.make_schedule(h, pz)
    costs = t.round_dp_costs(sched, 0, 12, pz)
    acc = PrivacyAccountant(pz.dp.epsilon, pz.dp.delta)
    for r in range(12):
        acc.charge(float(sched.c[r]), pz.zo.clip_gamma,
                   sched.effective_noise_std(r))
    np.testing.assert_array_equal(acc.history, costs)
    assert acc.spent == sum(float(c) for c in costs)  # same fold order
