"""Hypothesis property tests on the system's invariants.

Skipped (not errored) when hypothesis is absent: the container image does
not ship it; CI installs it via the `test` extra in pyproject.toml.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.channel import RayleighFading
from repro.core import dp, ota, power_control as pc, zo
from repro.kernels import ref
from repro.kernels.seeded_axpy import fmix32

SETTINGS = dict(max_examples=30, deadline=None)


# ---------------------------------------------------------------------------
# DP accountant invariants
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(st.floats(0.05, 50.0), st.floats(1e-4, 0.5))
def test_r_dp_positive_and_monotone(eps, delta):
    r = dp.r_dp(eps, delta)
    assert r > 0
    assert dp.r_dp(eps * 1.5, delta) >= r - 1e-12
    assert dp.r_dp(eps, min(delta * 1.5, 0.9)) >= r - 1e-12


@settings(**SETTINGS)
@given(st.floats(1e-3, 1e3))
def test_c_inverse_is_inverse(y):
    x = dp.c_inverse(y)
    assert x >= 0
    assert abs(dp.c_func(x) - y) <= 1e-6 * max(1.0, y)


# ---------------------------------------------------------------------------
# Power control feasibility over random channel draws
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 8), st.integers(10, 120),
       st.floats(1.0, 1e4), st.floats(0.5, 12.0))
def test_analog_solution_always_feasible(seed, k, rounds, power, eps):
    h = RayleighFading().realize(seed, rounds, k).h
    budget = dp.r_dp(eps, 0.01)
    sched = pc.solve_analog(h, power=power, n0=1.0, gamma=100.0,
                            contraction_a=0.998, epsilon=eps, delta=0.01)
    assert sched.privacy_cost(np.full(rounds, 100.0)) \
        <= budget * (1 + 1e-9)
    tx = pc.transmit_power(sched, h, 100.0, 1)
    assert (tx <= power * (1 + 1e-9)).all()
    assert np.isfinite(sched.c).all() and (sched.c >= 0).all()


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 8), st.integers(10, 120),
       st.floats(1.0, 1e4), st.floats(0.5, 12.0))
def test_sign_solution_always_feasible(seed, k, rounds, power, eps):
    h = RayleighFading().realize(seed, rounds, k).h
    budget = dp.r_dp(eps, 0.01)
    sched = pc.solve_sign(h, power=power, n0=1.0, n_clients=k, e0=0.496,
                          contraction_a_tilde=0.998, epsilon=eps,
                          delta=0.01)
    assert sched.privacy_cost(np.ones(rounds)) <= budget * (1 + 1e-9)
    tx = pc.transmit_power(sched, h, 1.0, 1)
    assert (tx <= power * (1 + 1e-9)).all()


# ---------------------------------------------------------------------------
# OTA aggregation invariants
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(st.lists(st.floats(-50, 50), min_size=2, max_size=8),
       st.integers(0, 1000))
def test_noiseless_ota_is_exact_mean(vals, key_seed):
    p = jnp.asarray(vals, jnp.float32)
    p_hat, _ = ota.analog_ota(p, jnp.float32(1.7), jnp.zeros(len(vals)),
                              jnp.float32(0.0), jax.random.key(key_seed))
    assert abs(float(p_hat) - float(np.mean(vals))) < 1e-3 \
        * max(1.0, abs(np.mean(vals)))


@settings(**SETTINGS)
@given(st.lists(st.floats(-5, 5), min_size=2, max_size=8))
def test_sign_payload_bounded(vals):
    """|p̂| ≤ 1 for a noiseless sign round — 1-bit payloads stay 1-bit."""
    p = jnp.asarray(vals, jnp.float32)
    p_hat, _ = ota.sign_ota(p, jnp.float32(1.0), jnp.zeros(len(vals)),
                            jnp.float32(0.0), jax.random.key(0))
    assert abs(float(p_hat)) <= 1.0 + 1e-6


# ---------------------------------------------------------------------------
# ZO / seeded stream invariants
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(st.integers(0, 2 ** 31 - 1))
def test_fmix32_bijective_samples(x):
    """fmix32 is a bijection: distinct inputs → distinct outputs (spot)."""
    a = int(fmix32(jnp.uint32(x)))
    b = int(fmix32(jnp.uint32((x + 1) & 0xFFFFFFFF)))
    assert a != b


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 1000), st.floats(1e-5, 1e-2))
def test_perturb_restore_roundtrip(seed, mu):
    params = {"w": jnp.ones((64, 8)), "b": jnp.zeros((16,))}
    p1 = zo.perturb(params, seed, mu)
    p2 = zo.perturb(p1, seed, -2 * mu)
    p3 = zo.perturb(p2, seed, mu)
    for k in params:
        np.testing.assert_allclose(np.asarray(p3[k]), np.asarray(params[k]),
                                   atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 4), st.integers(1, 4), st.integers(1, 64))
def test_z_stream_shape_invariant(a, b, c):
    """Same seed, same flat index ⇒ same value regardless of array shape."""
    n = a * b * c
    flat = np.asarray(ref.draw_z_ref((n,), 5))
    shaped = np.asarray(ref.draw_z_ref((a, b, c), 5)).reshape(-1)
    np.testing.assert_array_equal(flat, shaped)


# ---------------------------------------------------------------------------
# Cross-entropy invariants
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(0, 100))
def test_cross_entropy_nonnegative(seed):
    from repro.models import layers as L
    k = jax.random.key(seed)
    logits = jax.random.normal(k, (2, 6, 17))
    targets = jax.random.randint(jax.random.fold_in(k, 1), (2, 6), 0, 17)
    mask = jnp.ones((2, 6))
    nll = L.cross_entropy(logits, targets, mask)
    assert (np.asarray(nll) >= -1e-5).all()
