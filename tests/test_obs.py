"""Telemetry subsystem: neutrality, span invariants, ledger exactness.

The contracts under test, in order of importance:

  1. NEUTRALITY — telemetry off (the default) runs the bit-exact
     historical program on both engines, and telemetry ON is passive:
     attaching a tracer, memory sampler, and MetricsSink never changes a
     loss, a p_hat, or the privacy spend.
  2. EXACTNESS — the span timeline is the single source of truth for
     host stalls (span sums equal the legacy scalars), and the trilemma
     ledger's final row equals RunResult's accounting EXACTLY (one
     accounting, not two).
  3. WATERMARKS — RunResult.compile_stats counts step/executor builds:
     a never-seen config trips the counters, a warm rerun shows all
     zeros (retrace regression pin), and peak_bytes is a real watermark.
  4. ARTIFACTS — the exported Chrome trace + JSONL ledger pass
     tools/check_trace.py, the CI gate, end to end.
"""
import dataclasses
import json
import subprocess
import sys
import time
from pathlib import Path

import pytest

from repro import obs
from repro.core import dp, fedsim
from repro.core import transport as tp

REPO = Path(__file__).resolve().parents[1]


def _run(cfg, pz, make_pipeline, *, rounds, engine="scan", chunk=3, **kw):
    pipe = make_pipeline(vocab=cfg.vocab_size, n_clients=pz.n_clients,
                         batch=2, seq=16)
    return fedsim.run(cfg, pz, pipe, rounds=rounds, engine=engine,
                      chunk_rounds=chunk, **kw)


# ---------------------------------------------------------------------------
# Tracer unit behavior
# ---------------------------------------------------------------------------

def test_tracer_span_nesting_and_exactness():
    tr = obs.Tracer()
    with tr.span("outer", which=1):
        with tr.span("inner"):
            pass
    t0 = time.perf_counter()
    t1 = t0 + 0.25
    tr.add_span("measured", t0, t1, chunk=7)
    tr.instant("mark", chunk=7)
    tr.counter("bytes", 123.0)

    spans = tr.spans()
    assert [s["name"] for s in spans] == ["inner", "outer", "measured"]
    inner, outer, measured = spans
    # context-manager spans nest: inner contained in outer
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-9
    # add_span reports the caller's endpoints verbatim
    assert measured["dur"] == pytest.approx(0.25, abs=0)
    assert measured["args"] == {"chunk": 7}
    assert tr.total_s("measured") == measured["dur"]
    kinds = {e["ph"] for e in tr.events()}
    assert kinds == {"X", "i", "C"}


def test_tracer_export_chrome_schema(tmp_path):
    tr = obs.Tracer()
    with tr.span("work"):
        pass
    tr.instant("kick", chunk=0)
    out = tmp_path / "trace.json"
    tr.export_chrome(str(out), metadata={"prep_stall_s": 0.0})
    doc = json.loads(out.read_text())
    assert set(doc) == {"traceEvents", "displayTimeUnit", "otherData"}
    assert doc["otherData"] == {"prep_stall_s": 0.0}
    phases = {e["ph"] for e in doc["traceEvents"]}
    assert {"M", "X", "i"} <= phases
    for e in doc["traceEvents"]:
        assert {"name", "ph", "pid", "tid"} <= set(e)
        if e["ph"] != "M":
            assert "ts" in e          # µs since the tracer epoch
        if e["ph"] == "X":
            assert e["dur"] >= 0


def test_null_tracer_is_inert(tmp_path):
    nt = obs.NULL_TRACER
    assert not nt.enabled
    with nt.span("anything", x=1):
        nt.add_span("a", 0.0, 1.0)
        nt.instant("b")
        nt.counter("c", 1.0)
    assert nt.events() == []
    out = tmp_path / "never.json"
    nt.export_chrome(str(out))
    assert not out.exists()
    assert obs.Telemetry.off().enabled is False
    assert obs.Telemetry.on().enabled is True


def test_retrace_since_keeps_zero_entries():
    before = obs.retrace.snapshot()
    obs.retrace.bump(obs.retrace.ZO_STEP_BUILD)
    delta = obs.retrace.since(before)
    assert delta[obs.retrace.ZO_STEP_BUILD] == 1
    # zero entries stay present so tests can assert "== 0" directly
    assert delta[obs.retrace.CHUNK_TRACE] == 0
    assert delta[obs.retrace.SCAN_EXEC_BUILD] == 0


# ---------------------------------------------------------------------------
# 1. Neutrality: telemetry never changes the program's numbers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ["loop", "scan"])
def test_telemetry_is_numerically_passive(tiny_model, make_pz,
                                          make_pipeline, tmp_path, engine):
    """Telemetry ON (tracer + sampler + ledger sink) vs the default OFF:
    identical losses, p_hats, and privacy spend, bit for bit."""
    pz = make_pz(scheme="solution", rounds=6)
    ref = _run(tiny_model, pz, make_pipeline, rounds=6, engine=engine)
    sink = obs.MetricsSink(str(tmp_path / "m.jsonl"))
    res = _run(tiny_model, pz, make_pipeline, rounds=6, engine=engine,
               telemetry=obs.Telemetry.on(memory_sample_every=2),
               hooks=[sink])
    assert res.losses == ref.losses
    assert res.p_hats == ref.p_hats
    assert res.privacy_spent == ref.privacy_spent
    # and the observability side really ran
    assert res.peak_bytes > 0
    assert sink.rows_written() == 6


def test_telemetry_off_records_nothing(tiny_model, make_pz, make_pipeline):
    pz = make_pz(scheme="solution", rounds=4)
    res = _run(tiny_model, pz, make_pipeline, rounds=4)
    assert res.peak_bytes == 0            # no sampler attached


# ---------------------------------------------------------------------------
# 2a. Span invariants on a real run
# ---------------------------------------------------------------------------

def test_span_timeline_invariants(tiny_model, make_pz, make_pipeline):
    """9 rounds / chunk 3: prefetch kick for chunk i fires inside chunk
    i-1's driver span, the kicked prep starts at/after its kick, and the
    prep_stall span sum IS RunResult.prep_stall_s."""
    pz = make_pz(scheme="solution", rounds=9)
    tel = obs.Telemetry.on()
    res = _run(tiny_model, pz, make_pipeline, rounds=9, chunk=3,
               telemetry=tel)
    tr = tel.tracer

    chunks = {s["args"]["chunk"]: s for s in tr.spans("chunk")}
    assert sorted(chunks) == [0, 1, 2]
    kicks = {e["args"]["chunk"]: e["ts"] for e in tr.events()
             if e["ph"] == "i" and e["name"] == "prefetch_kick"}
    assert kicks, "overlap on but no prefetch kicks recorded"
    for i, ts in kicks.items():
        prev = chunks[i - 1]
        assert prev["ts"] <= ts <= prev["ts"] + prev["dur"], \
            f"kick {i} fired outside chunk {i - 1}'s span"
    for s in tr.spans("chunk_prep"):
        if s["args"].get("kicked"):
            i = s["args"]["chunk"]
            assert s["ts"] >= kicks[i] - 1e-6

    # exactness: the scalar is the span-derived sum
    assert tr.total_s("prep_stall") == pytest.approx(res.prep_stall_s,
                                                     abs=1e-9)
    # one dispatch span per chunk, nested inside its chunk span
    for s in tr.spans("dispatch"):
        c = chunks[s["args"]["chunk"]]
        assert c["ts"] <= s["ts"]
        assert s["ts"] + s["dur"] <= c["ts"] + c["dur"] + 1e-6


# ---------------------------------------------------------------------------
# 2b. Ledger exactness: one accounting, not two
# ---------------------------------------------------------------------------

def test_ledger_matches_runresult_exactly(tiny_model, make_pz,
                                          make_pipeline, tmp_path):
    pz = make_pz(scheme="solution", rounds=8)
    path = str(tmp_path / "metrics.jsonl")
    tel = obs.Telemetry.on(memory_sample_every=2)
    res = _run(tiny_model, pz, make_pipeline, rounds=8, chunk=3,
               telemetry=tel, hooks=[obs.MetricsSink(path)])

    led = obs.read_ledger(path)
    rows = led["rows"]
    assert led["header"]["schema"] == "trilemma_ledger/v2"
    assert led["header"]["n_clients"] == pz.n_clients
    assert len(rows) == res.steps == 8

    final = rows[-1]
    assert final["bits_cum"] == res.uplink_bits            # exact int
    assert final["dp_spent_cum"] == res.privacy_spent      # bit-exact fold
    assert final["peak_bytes"] == res.peak_bytes
    assert obs.final_row(path) == final

    # per-round loss column is the run's loss trajectory verbatim
    assert [r["loss"] for r in rows] == res.losses
    # cumulative columns never decrease; rounds strictly increase
    for a, b in zip(rows, rows[1:]):
        assert b["round"] == a["round"] + 1
        assert b["bits_cum"] >= a["bits_cum"]
        assert b["dp_spent_cum"] >= a["dp_spent_cum"]
        assert b["eps_cum"] >= a["eps_cum"]
    # bits_round re-sums to bits_cum
    assert sum(r["bits_round"] for r in rows) == final["bits_cum"]


def test_ledger_bits_equal_transport_accounting(tiny_model, make_pz,
                                                make_pipeline, tmp_path):
    """Full participation, no defense: the ledger's uplink column is
    exactly Transport.bits_per_round summed over executed rounds."""
    pz = make_pz(scheme="solution", rounds=6)
    path = str(tmp_path / "m.jsonl")
    res = _run(tiny_model, pz, make_pipeline, rounds=6,
               telemetry=obs.Telemetry.on(), hooks=[obs.MetricsSink(path)])
    transport = tp.resolve(pz)
    d = tiny_model.param_count()
    per_round = transport.bits_per_round(pz, d)
    rows = obs.read_ledger(path)["rows"]
    assert all(r["bits_round"] == per_round for r in rows)
    assert rows[-1]["bits_cum"] == per_round * 6 == res.uplink_bits


def test_privacy_spent_per_round(tiny_model, make_pz, make_pipeline):
    pz = make_pz(scheme="solution", rounds=7)
    res = _run(tiny_model, pz, make_pipeline, rounds=7)
    spend = res.privacy_spent_per_round
    assert spend is not None and len(spend) == res.steps == 7
    assert all(b >= a for a, b in zip(spend, spend[1:]))
    assert spend[-1] == res.privacy_spent
    # the canonical fold reproduces it from the accountant's history
    costs = [spend[0]] + [b - a for a, b in zip(spend, spend[1:])]
    re_fold = dp.cumulative_spend(costs)
    assert re_fold[-1] == pytest.approx(spend[-1])


# ---------------------------------------------------------------------------
# 3. Compile watermarks: cold build trips the counters, warm rerun is zero
# ---------------------------------------------------------------------------

def test_retrace_counts_cold_build_then_zero_warm(tiny_model, make_pz,
                                                  make_pipeline):
    """A never-before-seen config (distinctive mu) must build exactly one
    step + one scan executor + one chunk trace; the identical rerun hits
    every cache and reports ALL ZEROS while staying bitwise identical."""
    pz = make_pz(scheme="solution", rounds=6)
    pz = dataclasses.replace(pz, zo=dataclasses.replace(pz.zo, mu=1.23e-3))
    cold = _run(tiny_model, pz, make_pipeline, rounds=6, chunk=3)
    assert cold.compile_stats["zo_step_build"] == 1
    assert cold.compile_stats["scan_executor_build"] == 1
    assert cold.compile_stats["scan_chunk_trace"] == 1

    warm = _run(tiny_model, pz, make_pipeline, rounds=6, chunk=3)
    assert all(v == 0 for v in warm.compile_stats.values()), \
        f"warm rerun recompiled: {warm.compile_stats}"
    assert warm.losses == cold.losses


def test_memory_watermark_samples(tiny_model, make_pz, make_pipeline):
    pz = make_pz(scheme="solution", rounds=6)
    tel = obs.Telemetry.on(memory_sample_every=2)
    res = _run(tiny_model, pz, make_pipeline, rounds=6, chunk=3,
               telemetry=tel)
    wm = tel.memory
    assert res.peak_bytes == wm.peak_bytes > 0
    # initial sample + >=1 boundary sample + final sample
    assert len(wm.samples) >= 3
    assert max(b for _, b in wm.samples) == wm.peak_bytes
    # sampling surfaced as counter events on the timeline
    counters = [e for e in tel.tracer.events()
                if e["ph"] == "C" and e["name"] == "device_bytes"]
    assert len(counters) == len(wm.samples)


# ---------------------------------------------------------------------------
# 4. The artifacts pass the CI gate end to end
# ---------------------------------------------------------------------------

def test_artifacts_pass_check_trace(tiny_model, make_pz, make_pipeline,
                                    tmp_path):
    pz = make_pz(scheme="solution", rounds=9)
    trace = tmp_path / "trace.json"
    ledger = tmp_path / "metrics.jsonl"
    summary = tmp_path / "run.json"

    tel = obs.Telemetry.on(memory_sample_every=4)
    res = _run(tiny_model, pz, make_pipeline, rounds=9, chunk=3,
               telemetry=tel, hooks=[obs.MetricsSink(str(ledger))])
    tel.tracer.export_chrome(str(trace), metadata={
        "engine": "scan", "overlap": True,
        "prep_stall_s": res.prep_stall_s,
        "ckpt_stall_s": res.ckpt_stall_s,
        "peak_bytes": res.peak_bytes,
        "compile_stats": res.compile_stats})
    summary.write_text(json.dumps({
        "rounds": res.steps, "uplink_bits": res.uplink_bits,
        "privacy_spent": res.privacy_spent,
        "peak_bytes": res.peak_bytes}))

    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_trace.py"),
         str(trace), "--ledger", str(ledger), "--summary", str(summary)],
        capture_output=True, text=True, cwd=REPO)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "check_trace: OK" in proc.stdout


def test_check_trace_rejects_broken_ledger(tiny_model, make_pz,
                                           make_pipeline, tmp_path):
    """The gate actually gates: corrupt the final bits_cum and the
    summary cross-check must fail."""
    pz = make_pz(scheme="solution", rounds=4)
    trace, ledger = tmp_path / "t.json", tmp_path / "m.jsonl"
    tel = obs.Telemetry.on()
    res = _run(tiny_model, pz, make_pipeline, rounds=4, chunk=2,
               telemetry=tel, hooks=[obs.MetricsSink(str(ledger))])
    tel.tracer.export_chrome(str(trace), metadata={
        "prep_stall_s": res.prep_stall_s})
    lines = ledger.read_text().splitlines()
    last = json.loads(lines[-1])
    last["bits_cum"] += 1
    lines[-1] = json.dumps(last)
    ledger.write_text("\n".join(lines) + "\n")
    summary = tmp_path / "s.json"
    summary.write_text(json.dumps({
        "rounds": res.steps, "uplink_bits": res.uplink_bits,
        "privacy_spent": res.privacy_spent,
        "peak_bytes": res.peak_bytes}))
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_trace.py"),
         str(trace), "--ledger", str(ledger), "--summary", str(summary)],
        capture_output=True, text=True, cwd=REPO)
    assert proc.returncode == 1
    assert "bits_cum" in proc.stdout
