"""Device-visible observability: HLO introspection, profiler merge,
health monitor, bench history.

The contracts under test, in order of importance:

  1. NEUTRALITY — `Telemetry(cost=True)` and an attached HealthMonitor
     (warn) are numerically passive on loop and scan, and the HLO
     analysis (which lowers the executor's program once) never bumps a
     retrace counter (`retrace.suspended`), so the CI's exact
     compile-count pins survive.
  2. INTROSPECTION — `obs.hlo` reads the compiled executable's own
     numbers: positive flops/peak on real programs, a collective census
     that parses both literal and iota replica_groups, and byte totals
     that agree with roofline's independent HLO parser.
  3. HEALTH — the three detectors (nonfinite/divergence/plateau) fire on
     rising edges, `abort` stops the run at chunk granularity with
     executed == charged rounds, and the abort lands on RunResult.
  4. CRASH CONSISTENCY — `read_ledger` tolerates exactly one torn
     trailing record (strict=False) and never a torn middle line.
  5. MERGED TIMELINE — a real `ProfilerSession` capture anchors onto the
     tracer epoch and the merged trace passes
     `check_trace.py --require-device-lane`.
  6. HISTORY — bench_history rows validate, and `check_bench --history`
     gates same-hardware regressions while ignoring other hosts.
"""
import json
import math
import subprocess
import sys
from pathlib import Path

import pytest

import jax
import jax.numpy as jnp

from repro import obs
from repro.core import fedsim
from repro.obs import hlo as ohlo
from repro.obs import retrace
from repro.obs.health import HealthAbort, HealthMonitor
from repro.obs.ledger import MetricsSink, read_ledger

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "tools"))

import bench_history  # noqa: E402


def _run(cfg, pz, make_pipeline, *, rounds, engine="scan", chunk=3, **kw):
    pipe = make_pipeline(vocab=cfg.vocab_size, n_clients=pz.n_clients,
                         batch=2, seq=16)
    return fedsim.run(cfg, pz, pipe, rounds=rounds, engine=engine,
                      chunk_rounds=chunk, **kw)


# ---------------------------------------------------------------------------
# 1. Collective census parsing (pure text)
# ---------------------------------------------------------------------------

def test_census_parses_literal_and_iota_groups():
    hlo = """
  %ar = f32[128,4]{1,0} all-reduce(f32[128,4]{1,0} %x), replica_groups={{0,1},{2,3}}, to_apply=%add
  %ag.1 = bf16[256]{0} all-gather(bf16[32]{0} %y), replica_groups=[2,4]<=[8], dimensions={0}
  %rs = f32[16]{0} reduce-scatter-start(f32[64]{0} %z), replica_groups={{0,1,2,3}}, to_apply=%add
"""
    census = ohlo.collective_census(hlo)
    ar = census["all-reduce"]
    assert ar["count"] == 1
    assert ar["bytes"] == 128 * 4 * 4
    assert ar["group_sizes"] == [2, 2]          # literal {{0,1},{2,3}}
    ag = census["all-gather"]
    assert ag["bytes"] == 256 * 2
    assert ag["group_sizes"] == [4, 4]          # iota [2,4]<=[8]: 2 groups of 4
    rs = census["reduce-scatter"]               # -start folds into the base op
    assert rs["count"] == 1
    assert rs["group_sizes"] == [4]


def test_census_ignores_non_collective_text():
    hlo = """
  %d = f32[64,64]{1,0} dot(f32[64,64]{1,0} %a, f32[64,64]{1,0} %b)
  ROOT %t = (f32[64,64]{1,0}) tuple(%d)
  // an all-reduce mentioned in a comment must not count
"""
    assert ohlo.collective_census(hlo) == {}


# ---------------------------------------------------------------------------
# 2. Compiled-executable introspection
# ---------------------------------------------------------------------------

def test_analyze_compiled_reports_real_numbers():
    @jax.jit
    def f(a, b):
        return jnp.tanh(a @ b).sum()

    spec = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    stats = ohlo.analyze_compiled(f.lower(spec, spec).compile())
    assert stats.flops > 0
    assert stats.peak_bytes > 0
    assert stats.argument_bytes >= 2 * 64 * 64 * 4
    assert stats.collectives == {}
    d = stats.to_dict()
    assert d["flops"] == stats.flops
    assert "collective_bytes" in d
    text = ohlo.describe(stats)
    assert "flops" in text and "peak" in text


def test_cost_stats_ride_run_result(tiny_model, make_pz, make_pipeline):
    pz = make_pz(scheme="solution", rounds=4)
    res = _run(tiny_model, pz, make_pipeline, rounds=4, chunk=2,
               telemetry=obs.Telemetry(cost=True))
    cs = res.cost_stats
    assert cs is not None and "error" not in cs
    assert cs["flops"] > 0 and cs["peak_bytes"] > 0
    # single-device program: the census must be empty, not missing
    assert cs["collectives"] == {}


def test_cost_analysis_is_passive_and_retrace_silent(tiny_model, make_pz,
                                                     make_pipeline):
    """The analysis lowers the executor's program a second time; without
    `retrace.suspended` that lowering would re-enter the traced bodies
    and bump the counters the CI pins exactly."""
    pz = make_pz(scheme="solution", rounds=6)
    for engine in ("loop", "scan"):
        _run(tiny_model, pz, make_pipeline, rounds=6,
             engine=engine, chunk=3)            # pay the cold compile
        ref = _run(tiny_model, pz, make_pipeline, rounds=6,
                   engine=engine, chunk=3)
        res = _run(tiny_model, pz, make_pipeline, rounds=6,
                   engine=engine, chunk=3,
                   telemetry=obs.Telemetry(cost=True))
        assert res.losses == ref.losses, engine
        assert res.privacy_spent == ref.privacy_spent, engine
        # warm + warm: both all-zero — the analysis lowering must not
        # re-fire any build/trace counter
        assert all(v == 0 for v in ref.compile_stats.values()), engine
        assert all(v == 0 for v in res.compile_stats.values()), engine
        assert res.cost_stats is not None


def test_suspended_blocks_bump_and_restores():
    before = retrace.snapshot()
    with retrace.suspended():
        retrace.bump("zo_step_build")
        with retrace.suspended():        # reentrant
            retrace.bump("zo_step_build")
        retrace.bump("zo_step_build")
    assert all(v == 0 for v in retrace.since(before).values())
    retrace.bump("zo_step_build")
    assert retrace.since(before)["zo_step_build"] == 1


# ---------------------------------------------------------------------------
# 3. Health monitor
# ---------------------------------------------------------------------------

def test_health_detectors_fire_on_rising_edge():
    hm = HealthMonitor(policy="warn", divergence_factor=10.0,
                       plateau_rounds=2)
    hm.on_start(None)
    hm.on_round(0, {"loss": 1.0})
    hm.on_round(1, {"loss": 50.0})       # divergence: > 10x best
    hm.on_round(2, {"loss": 60.0})       # still firing: no new event
    assert [e["kind"] for e in hm.events] == ["divergence"]
    assert hm.events[0]["round"] == 1
    hm.on_round(3, {"loss": 0.5})        # recovery clears the edge
    hm.on_round(4, {"loss": 0.6})
    hm.on_round(5, {"loss": 0.7})        # 2 rounds without improvement
    kinds = [e["kind"] for e in hm.events]
    assert kinds == ["divergence", "plateau"]
    hm.on_round(6, {"loss": float("nan")})
    assert [e["kind"] for e in hm.events][-1] == "nonfinite"


def test_health_abort_raises_with_round_and_reason():
    hm = HealthMonitor(policy="abort")
    hm.on_start(None)
    hm.on_round(0, {"loss": 2.0})
    with pytest.raises(HealthAbort) as ei:
        hm.on_round(7, {"loss": float("inf")})
    assert ei.value.round == 7
    assert ei.value.reason == "nonfinite"
    with pytest.raises(ValueError):
        HealthMonitor(policy="explode")


def test_health_warn_is_numerically_passive(tiny_model, make_pz,
                                            make_pipeline):
    pz = make_pz(scheme="solution", rounds=6)
    for engine in ("loop", "scan"):
        ref = _run(tiny_model, pz, make_pipeline, rounds=6, engine=engine)
        hm = HealthMonitor(policy="warn")
        res = _run(tiny_model, pz, make_pipeline, rounds=6, engine=engine,
                   hooks=[hm])
        assert res.losses == ref.losses, engine
        assert res.privacy_spent == ref.privacy_spent, engine
        assert res.health_abort_round == -1


def test_health_abort_realized_spend(tiny_model, make_pz, make_pipeline):
    """Abort mid-run: executed rounds == charged rounds, so the spend on
    RunResult is the realized (shorter) ledger, not the planned one."""
    pz = make_pz(scheme="solution", rounds=12)
    full = _run(tiny_model, pz, make_pipeline, rounds=12, chunk=2)
    # fire deterministically at round 4 regardless of the loss curve
    hm = HealthMonitor(policy="abort")
    fired = {}

    def fire_at(t, metrics, _orig=hm.on_round):
        if t >= 4 and not fired:
            fired["t"] = t
            raise HealthAbort(t, "synthetic")
    hm.on_round = fire_at
    res = _run(tiny_model, pz, make_pipeline, rounds=12, chunk=2,
               hooks=[hm])
    assert res.health_abort_round == 4
    assert res.health_abort_reason == "synthetic"
    # round 4's metrics flush after the NEXT chunk is dispatched (the
    # driver pipelines), so charged == executed == 8 of 12 rounds
    assert res.steps < 12
    assert len(res.privacy_spent_per_round) == res.steps
    assert res.privacy_spent < full.privacy_spent
    # per-round is the cumulative fold; its last entry IS the spend
    assert res.privacy_spent == float(res.privacy_spent_per_round[-1])


# ---------------------------------------------------------------------------
# 4. Torn ledger + deleted-buffer watermark
# ---------------------------------------------------------------------------

def _write_ledger(path, n_rows, torn_at=None):
    sink_header = {"schema": MetricsSink.SCHEMA, "arch": "tiny"}
    lines = [json.dumps(sink_header)]
    for i in range(n_rows):
        lines.append(json.dumps({"round": i, "loss": 1.0}))
    if torn_at is not None:
        lines[torn_at] = lines[torn_at][: len(lines[torn_at]) // 2]
    path.write_text("\n".join(lines) + "\n")


def test_read_ledger_tolerates_torn_tail_only(tmp_path):
    p = tmp_path / "m.jsonl"
    _write_ledger(p, 4, torn_at=4)          # last row torn
    with pytest.raises(json.JSONDecodeError):
        read_ledger(str(p))                 # strict default
    led = read_ledger(str(p), strict=False)
    assert led["truncated"] is True
    assert len(led["rows"]) == 3
    _write_ledger(p, 4, torn_at=2)          # torn MIDDLE line: corruption
    with pytest.raises(json.JSONDecodeError):
        read_ledger(str(p), strict=False)
    _write_ledger(p, 4)
    led = read_ledger(str(p), strict=False)
    assert led["truncated"] is False and len(led["rows"]) == 4


def test_live_buffer_bytes_skips_deleted(tiny_model):
    """Donated carry buffers linger in jax.live_arrays() as deleted
    husks; counting them double-charges the watermark (the v3 fix)."""
    from repro.obs.memory import live_buffer_bytes
    a = jnp.ones((128,), jnp.float32)
    b = jnp.ones((256,), jnp.float32)
    total = live_buffer_bytes([a, b])
    f = jax.jit(lambda x: x * 2.0, donate_argnums=(0,))
    c = f(b)                                # b's buffer is now deleted
    jax.block_until_ready(c)
    assert b.is_deleted()
    assert live_buffer_bytes([a, b]) == a.nbytes
    assert total == a.nbytes + 256 * 4


# ---------------------------------------------------------------------------
# 5. Profiler-merged timeline (real capture, CPU)
# ---------------------------------------------------------------------------

def test_profiler_merge_passes_device_lane_gate(tmp_path):
    tracer = obs.Tracer()
    prof = obs.ProfilerSession(logdir=str(tmp_path / "prof"))
    prof.start()
    with tracer.span("chunk", chunk=0):
        with tracer.span("dispatch"):
            x = jnp.ones((256, 256), jnp.float32)
            jax.block_until_ready(jax.jit(lambda a: a @ a)(x))
        with tracer.span("chunk_prep", chunk=1, kicked=False):
            pass
        with tracer.span("prep_stall"):
            pass
        with tracer.span("metrics_flush"):
            pass
    prof.stop()
    events, meta = prof.device_events(tracer.epoch)
    assert meta["events"] > 0
    assert meta["anchor"] is True           # exact clock join, no fallback
    assert all(e.get("pid") != 0 for e in events)
    assert not any(str(e.get("name", "")).startswith("$") for e in events)

    trace = tmp_path / "merged.json"
    tracer.export_chrome(str(trace), metadata={"profile": meta},
                         extra_events=events)
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_trace.py"),
         str(trace), "--require-device-lane"],
        capture_output=True, text=True, cwd=REPO)
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_device_lane_gate_rejects_host_only_trace(tmp_path):
    tracer = obs.Tracer()
    for name in ("chunk", "dispatch", "chunk_prep", "prep_stall",
                 "metrics_flush"):
        with tracer.span(name):
            pass
    trace = tmp_path / "host_only.json"
    tracer.export_chrome(str(trace))
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_trace.py"),
         str(trace), "--require-device-lane"],
        capture_output=True, text=True, cwd=REPO)
    assert proc.returncode == 1
    assert "no device-lane" in proc.stdout


def test_check_trace_reports_torn_ledger_without_failing(
        tiny_model, make_pz, make_pipeline, tmp_path):
    pz = make_pz(scheme="solution", rounds=4)
    trace, ledger = tmp_path / "t.json", tmp_path / "m.jsonl"
    tel = obs.Telemetry.on()
    _run(tiny_model, pz, make_pipeline, rounds=4, chunk=2, telemetry=tel,
         hooks=[obs.MetricsSink(str(ledger))])
    tel.tracer.export_chrome(str(trace))
    raw = ledger.read_bytes()
    ledger.write_bytes(raw[:-20])           # SIGKILL mid-append
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_trace.py"),
         str(trace), "--ledger", str(ledger)],
        capture_output=True, text=True, cwd=REPO)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "torn trailing record" in proc.stdout


# ---------------------------------------------------------------------------
# 6. Bench history: schema + regression gate
# ---------------------------------------------------------------------------

def test_bench_history_row_roundtrip(tmp_path):
    p = tmp_path / "hist.jsonl"
    row = bench_history.append_row(
        str(p), "engine", {"scan_rounds_per_s": 10.0})
    assert row["schema"] == "bench_history/v1"
    assert row["host"]["devices"] >= 1
    rows = bench_history.read_history(str(p))
    assert len(rows) == 1 and rows[0]["kind"] == "engine"
    with pytest.raises(ValueError):
        bench_history.make_row("engine", {"wrong_metric": 1.0})
    with pytest.raises(ValueError):
        bench_history.make_row("nope", {"scan_rounds_per_s": 1.0})


def _hist_row(kind, val, host=None):
    row = bench_history.make_row(
        kind, {bench_history.GATE_METRIC[kind]: val})
    if host:
        row["host"] = host
    return row


def _write_hist(path, rows):
    path.write_text("".join(json.dumps(r) + "\n" for r in rows))


def _check_history(path, *extra):
    return subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_bench.py"),
         str(path), "--history", *extra],
        capture_output=True, text=True, cwd=REPO)


def test_check_bench_history_gates_same_host_regression(tmp_path):
    p = tmp_path / "hist.jsonl"
    _write_hist(p, [_hist_row("engine", 10.0), _hist_row("engine", 9.0)])
    proc = _check_history(p)                # 10% drop: within 30% allowance
    assert proc.returncode == 0, proc.stdout
    _write_hist(p, [_hist_row("engine", 10.0), _hist_row("engine", 5.0)])
    proc = _check_history(p)                # 50% drop: regression
    assert proc.returncode == 1
    assert "regressed" in proc.stdout
    # the same drop on DIFFERENT hardware never gates
    other = {"platform": "tpu", "devices": 8, "machine": "other"}
    _write_hist(p, [_hist_row("engine", 10.0),
                    _hist_row("engine", 5.0, host=other)])
    proc = _check_history(p)
    assert proc.returncode == 0, proc.stdout
    # and a tighter allowance flips the verdict
    _write_hist(p, [_hist_row("engine", 10.0), _hist_row("engine", 9.0)])
    proc = _check_history(p, "--max-regression", "0.05")
    assert proc.returncode == 1


def test_check_bench_history_rejects_bad_rows(tmp_path):
    p = tmp_path / "hist.jsonl"
    bad = _hist_row("engine", 10.0)
    del bad["git_sha"]
    _write_hist(p, [bad])
    assert _check_history(p).returncode == 1
    bad = _hist_row("kernels", 10.0)
    bad["metrics"] = {"fused_duals_per_s": 0.0}
    _write_hist(p, [bad])
    assert _check_history(p).returncode == 1
