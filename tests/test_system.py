"""End-to-end behaviour tests for the pAirZero system."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.configs.base import (ChannelConfig, DPConfig, ModelConfig,
                                PairZeroConfig, PowerControlConfig, ZOConfig)
from repro.channel import RayleighFading
from repro.core import fedsim
from repro.data.pipeline import FederatedPipeline
from repro.data.tasks import TaskSpec

TINY = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64,
                   head_dim=16)


def _pipe(seed=0, seq=24):
    return FederatedPipeline(task="sst2", spec=TaskSpec("sst2", 64, seq),
                             n_clients=5, per_client_batch=8, seed=seed)


def _pz(variant="analog", scheme="perfect", lr=5e-3, n_perturb=4,
        eps=5.0, rounds=600, seed=0):
    return PairZeroConfig(
        variant=variant, n_clients=5, rounds=rounds,
        zo=ZOConfig(mu=1e-3, lr=lr, clip_gamma=5.0, n_perturb=n_perturb),
        channel=ChannelConfig(n0=1.0, power=100.0),
        dp=DPConfig(epsilon=eps, delta=0.01),
        power=PowerControlConfig(scheme=scheme), seed=seed)


def test_zo_federated_finetuning_learns():
    """Paper-faithful ZO (Perfect aggregation) reaches non-trivial accuracy
    on the synthetic SST-2 analogue — the core reproduction claim."""
    res = fedsim.run(TINY, _pz(), _pipe(), rounds=600, eval_every=300,
                     eval_n=256)
    assert res.accuracies[-1] > 0.6
    assert np.mean(res.losses[-20:]) < 0.5 * np.mean(res.losses[:5])


def test_sign_variant_learns():
    res = fedsim.run(TINY, _pz(variant="sign", lr=2e-2), _pipe(),
                     rounds=600, eval_every=600, eval_n=256)
    assert np.mean(res.losses[-20:]) < 0.7 * np.mean(res.losses[:5])


def test_fo_baseline_learns_fast():
    res = fedsim.run(TINY, _pz(variant="fo", lr=3e-3), _pipe(), rounds=120,
                     eval_every=120, eval_n=256)
    assert res.accuracies[-1] > 0.8


def test_dp_solution_respects_budget_exactly():
    """Solution power control spends ≤ budget and (budget-limited regime)
    nearly all of it — privacy is enforced, not wasted."""
    res = fedsim.run(TINY, _pz(scheme="solution", lr=1e-3, eps=5.0,
                               n_perturb=1, rounds=150),
                     _pipe(), rounds=150)
    assert res.privacy_spent <= res.privacy_budget * (1 + 1e-6)
    assert res.privacy_spent > 0.95 * res.privacy_budget


def test_dp_training_stays_finite_under_noise():
    res = fedsim.run(TINY, _pz(scheme="solution", lr=1e-4, eps=5.0,
                               n_perturb=1), _pipe(), rounds=200)
    assert np.isfinite(res.losses).all()


def test_checkpoint_resume_is_bit_identical(tmp_path):
    """Crash/restart mid-run reproduces the uninterrupted trajectory —
    data stream, seed stream, power schedule and DP budget all replay."""
    pz = _pz(scheme="solution", lr=1e-3, n_perturb=1, rounds=60)
    # uninterrupted run
    res_a = fedsim.run(TINY, pz, _pipe(), rounds=60)
    # interrupted run: 30 rounds + checkpoint, then resume to 60
    ck = str(tmp_path / "ck")
    fedsim.run(TINY, pz, _pipe(), rounds=30, checkpoint_dir=ck,
               checkpoint_every=30)
    res_b = fedsim.run(TINY, pz, _pipe(), rounds=60, checkpoint_dir=ck,
                       checkpoint_every=1000)
    assert res_b.resumed_from == 30
    np.testing.assert_allclose(res_a.losses[30:], res_b.losses,
                               rtol=2e-4, atol=2e-4)


def test_communication_payload_is_scalar():
    """The per-round uplink payload is ONE scalar per client (16 bits in
    fp16; 1 bit for Sign) — the paper's Table II claim, on the wire format."""
    pz = _pz(n_perturb=1)
    captured = {}

    def on_round(t, metrics):
        captured["p_clients"] = metrics["p_clients"]

    fedsim.run(TINY, pz, _pipe(), rounds=2, on_round=on_round)
    assert captured["p_clients"].shape == (5,)   # one scalar per client


def test_solution_tracks_perfect_better_than_static():
    """Fig. 3 reproduction in miniature: Solution ≥ Static on final loss.

    Seeded explicitly: the claim holds on average over channel draws, not
    for every draw — seed 3 is a fixed, verified-representative draw (the
    run itself is fully deterministic given the seed)."""
    pipe = _pipe(seed=3)
    common = dict(lr=1e-3, eps=20.0, n_perturb=2, seed=3)
    res_sol = fedsim.run(TINY, _pz(scheme="solution", **common), pipe,
                         rounds=300)
    res_sta = fedsim.run(TINY, _pz(scheme="static", **common), pipe,
                         rounds=300)
    sol = np.mean(res_sol.losses[-30:])
    sta = np.mean(res_sta.losses[-30:])
    assert sol <= sta * 1.05, (sol, sta)


def test_alternate_task_converges():
    """A second task family (markov LM) trains under the same ZO machinery."""
    pipe = FederatedPipeline(task="lm", spec=TaskSpec("lm", 64, 24),
                             n_clients=5, per_client_batch=8, seed=1)
    res = fedsim.run(TINY, _pz(lr=5e-3, rounds=400), pipe, rounds=400)
    # markov-LM entropy floor is high (15% noise); require a clear drop
    assert np.mean(res.losses[-20:]) < 0.95 * np.mean(res.losses[:5])


def test_harder_task_stays_stable():
    """The extraction task (SQuAD analogue) is beyond a 2-layer model at
    T=400, but the ZO trajectory must stay bounded (no divergence)."""
    pipe = FederatedPipeline(task="squad",
                             spec=TaskSpec("squad", 64, 24),
                             n_clients=5, per_client_batch=8, seed=1)
    res = fedsim.run(TINY, _pz(lr=1e-3), pipe, rounds=200)
    assert np.isfinite(res.losses).all()
    assert np.mean(res.losses[-20:]) < 1.2 * np.mean(res.losses[:5])


def test_privacy_guard_halts_overspend():
    """Running past the planned DP horizon must halt transmission, not
    silently overspend the (ε, δ) budget."""
    pz = _pz(scheme="solution", lr=1e-3, n_perturb=1, rounds=50)
    res = fedsim.run(TINY, pz, _pipe(), rounds=120)  # 70 beyond the horizon?
    # horizon = max(50, 120) = 120 → schedule re-solved over 120: no trip.
    assert res.privacy_exhausted_at == -1
    assert res.privacy_spent <= res.privacy_budget * (1 + 1e-6)

    # force a true overspend: static schedule solved for T=50 but run 120
    import numpy as np_
    from repro.channel import RayleighFading
    from repro.core import power_control as pc
    h = RayleighFading().realize(0, 50, 5).h
    sched = pc.static_analog(h, power=100.0, n0=1.0, gamma=5.0,
                             epsilon=5.0, delta=0.01)
    # extend the same per-round gain past its designed horizon
    long_sched = pc.PowerSchedule(
        c=np_.tile(sched.c, 3)[:120],
        sigma=np_.zeros((120, 5)), scheme="static", n0=1.0)
    from repro.core.dp import PrivacyAccountant
    acc = PrivacyAccountant(5.0, 0.01)
    tripped = None
    for t in range(120):
        if acc.would_violate(float(long_sched.c[t]), 5.0,
                             long_sched.effective_noise_std(t)):
            tripped = t
            break
        acc.charge(float(long_sched.c[t]), 5.0,
                   long_sched.effective_noise_std(t))
    assert tripped is not None and 45 <= tripped <= 55
    assert acc.spent <= acc.budget * (1 + 1e-9)
