"""Fused ZO dual forward: seeded-draw identity, trajectory equivalence, memory.

The contract under test (docs/kernels.md "perturbed_matmul"):

  * the z-stream a tagged leaf regenerates (ops.perturbed_z / the in-kernel
    Pallas draw) is BITWISE the unfused stream kernels/ref.py draws for the
    whole leaf — including slices taken by `lax.scan` over stacked layers;
  * the fused dual forward (PairZeroConfig.fused_perturbation) follows the
    same trajectory as the unfused `fresh` mode (its bitwise oracle: both
    perturb directly from w) across transports and engines;
  * with the flag off, nothing fused is ever on the trace — the default
    path is the pre-flag program, bit for bit;
  * the fused dual forward's XLA temp overhead over a plain forward is
    under half the chained walk's (the BENCH_kernels gate, pinned here at
    the benchmark's gate size).

Bitwise matmul comparisons use the zero-weight identity probe (w = 0,
eps = 1, x = I): every output element is one z value passed through the
contraction untouched, so accumulation-order/FMA differences between matmul
impls cannot blur the z-stream comparison.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fedsim, pairzero, zo
from repro.kernels import ops, ref

jax.config.update("jax_enable_x64", False)


def tag_leaf(w, seed=7, eps=1.0, leaf_idx=0):
    """Tag one leaf exactly as zo.tag_perturbed tags it inside a tree."""
    tree = zo.tag_perturbed({"w": w}, seed, eps)
    del leaf_idx
    return tree["w"]


# ---------------------------------------------------------------------------
# seeded draw: bitwise vs the unfused stream
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(64, 64), (128, 48), (16, 256), (48, 80)])
def test_perturbed_z_matches_ref_stream(shape):
    pp = tag_leaf(jnp.zeros(shape, jnp.float32))
    z_ref = ref.draw_z_ref(shape, zo.leaf_seed(7, 0))
    assert np.array_equal(np.asarray(ops.perturbed_z(pp)),
                          np.asarray(z_ref))


@pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
@pytest.mark.parametrize("shape", [(64, 64), (128, 48), (16, 256)])
def test_perturbed_matmul_identity_probe_bitwise(impl, shape):
    """w = 0, eps = 1, x = I ⇒ out rows are raw z values: the in-kernel
    tile generation must reproduce the whole-leaf stream bit for bit."""
    pp = tag_leaf(jnp.zeros(shape, jnp.float32))
    eye = jnp.eye(shape[0], dtype=jnp.float32)
    out = ops.perturbed_matmul(eye, pp, impl=impl)
    z_ref = ref.draw_z_ref(shape, zo.leaf_seed(7, 0))
    assert np.array_equal(np.asarray(out), np.asarray(z_ref)), impl


@pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
def test_perturbed_matmul_random_w_close(impl):
    """With real weights the contraction must match the resolve-then-matmul
    oracle to fp tolerance (accumulation order may differ)."""
    k1, k2 = jax.random.split(jax.random.key(3))
    w = jax.random.normal(k1, (96, 64), jnp.float32)
    x = jax.random.normal(k2, (5, 96), jnp.float32)
    pp = tag_leaf(w, seed=11, eps=1e-3)
    out = ops.perturbed_matmul(x, pp, impl=impl)
    z = ref.draw_z_ref(w.shape, zo.leaf_seed(11, 0))
    oracle = x @ (w + 1e-3 * z)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               atol=1e-5, rtol=1e-6)


def test_scan_slice_continues_the_stream():
    """Slicing a stacked [L, d, f] tag layer-by-layer (what lax.scan does)
    must continue the whole-leaf counter stream bitwise."""
    L, d, f = 3, 8, 32
    w = jnp.zeros((L, d, f), jnp.float32)
    pp = tag_leaf(w, seed=5)
    z_full = ref.draw_z_ref((L, d, f), zo.leaf_seed(5, 0))
    for layer in range(L):
        sl = jax.tree_util.tree_map(lambda c: c[layer], pp)
        assert isinstance(sl, ops.PerturbedParam)
        assert np.array_equal(np.asarray(ops.perturbed_z(sl)),
                              np.asarray(z_full[layer])), layer


def test_perturbed_gather_bitwise_rows():
    """Gathered rows carry the same bits the rows have in the full-table
    stream — drawing z only for the touched rows must not change them."""
    V, D = 40, 32
    w = jax.random.normal(jax.random.key(0), (V, D), jnp.float32)
    pp = tag_leaf(w, seed=9, eps=1e-3)
    tokens = jnp.array([[0, 3, 39, 3], [7, 0, 1, 2]])
    out = ops.perturbed_gather(pp, tokens)
    full = ref.seeded_axpy_ref(w, zo.leaf_seed(9, 0), 1e-3)
    assert np.array_equal(np.asarray(out),
                          np.asarray(jnp.take(full, tokens, axis=0)))


def test_resolve_tagged_tree_equals_perturb(tiny_model):
    """resolve() over a tagged real parameter tree == the unfused axpy
    perturbation, leaf for leaf, bitwise."""
    from repro.models import registry
    params = registry.init_params(jax.random.key(0), tiny_model)
    seed = jnp.uint32(21)
    tagged = zo.tag_perturbed(params, seed, 1e-3)
    resolved = jax.tree_util.tree_map(
        ops.resolve, tagged,
        is_leaf=lambda x: isinstance(x, ops.PerturbedParam))
    oracle = zo.perturb(params, seed, 1e-3, impl="xla")
    for a, b in zip(jax.tree_util.tree_leaves(resolved),
                    jax.tree_util.tree_leaves(oracle)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# dual forward + trajectories
# ---------------------------------------------------------------------------

def _loss_and_batch(cfg, n_clients=3, batch=2, seq=12):
    loss_fn = pairzero.make_loss_fn(cfg)
    tok = jax.random.randint(jax.random.key(1), (n_clients, batch, seq),
                             0, cfg.vocab_size)
    b = {"tokens": tok, "targets": jnp.roll(tok, -1, -1),
         "mask": jnp.ones(tok.shape, jnp.float32)}
    return lambda p: loss_fn(p, b)


def test_fused_dual_forward_bitwise_fresh(tiny_model):
    """The headline contract: fused losses == fresh losses, bit for bit."""
    from repro.models import registry
    params = registry.init_params(jax.random.key(0), tiny_model)
    f = _loss_and_batch(tiny_model)
    seed = jnp.uint32(13)
    lp_fr, lm_fr, _ = jax.jit(
        lambda p: zo.dual_forward(f, p, seed, 1e-3, mode="fresh"))(params)
    lp_fu, lm_fu, _ = jax.jit(
        lambda p: zo.dual_forward(f, p, seed, 1e-3, mode="fused"))(params)
    assert np.array_equal(np.asarray(lp_fr), np.asarray(lp_fu))
    assert np.array_equal(np.asarray(lm_fr), np.asarray(lm_fu))


@pytest.mark.parametrize("variant", ["analog", "sign", "digital"])
@pytest.mark.parametrize("engine", ["loop", "scan"])
def test_fused_trajectory_equals_fresh(tiny_model, make_pz, make_pipeline,
                                       variant, engine):
    """End-to-end: the fused flag follows the fresh trajectory exactly,
    across transports and both executors."""
    pz = make_pz(variant=variant, rounds=6, n_clients=3)
    fresh = dataclasses.replace(
        pz, zo=dataclasses.replace(pz.zo, dual_mode="fresh"))
    fused = dataclasses.replace(pz, fused_perturbation=True)
    kw = dict(rounds=6, engine=engine, chunk_rounds=3)
    r_fresh = fedsim.run(tiny_model, fresh,
                         make_pipeline(n_clients=3, batch=2), **kw)
    r_fused = fedsim.run(tiny_model, fused,
                         make_pipeline(n_clients=3, batch=2), **kw)
    assert r_fused.losses == r_fresh.losses


def test_flag_off_never_traces_fused_path(tiny_model, make_pz,
                                          make_pipeline, monkeypatch):
    """fused_perturbation=False must trace the pre-flag program: the fused
    machinery is never entered, so the default trajectory is untouched."""
    assert make_pz().fused_perturbation is False

    def boom(*a, **k):
        raise AssertionError("fused path entered with the flag off")
    monkeypatch.setattr(zo, "tag_perturbed", boom)
    monkeypatch.setattr(ops, "perturbed_matmul", boom)
    pairzero.make_zo_step.cache_clear()
    try:
        res = fedsim.run(tiny_model, make_pz(rounds=2, n_clients=3),
                         make_pipeline(n_clients=3, batch=2), rounds=2)
    finally:
        pairzero.make_zo_step.cache_clear()
    assert len(res.losses) == 2


def test_fused_rejects_unwired_families(make_pz):
    """Families whose layer stacks have no fused consumers must fail loudly
    at step-build time, not silently fall back."""
    from repro.configs.base import ModelConfig
    cfg = ModelConfig(name="ssm-t", family="ssm", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=4, d_ff=64, vocab_size=64,
                      head_dim=8)
    pz = dataclasses.replace(make_pz(), fused_perturbation=True)
    with pytest.raises(ValueError, match="fused_perturbation"):
        pairzero.make_zo_step(cfg, pz)


# ---------------------------------------------------------------------------
# memory: the BENCH_kernels gate, pinned
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_fused_halves_zo_memory_overhead(opt125m_reduced):
    """XLA temp of the fused dual forward minus a plain forward must be
    under half the chained walk's overhead at the benchmark's gate size
    (the committed BENCH_kernels.json memory gate, asserted from source)."""
    from repro.models import registry
    cfg = opt125m_reduced
    params = registry.init_params(jax.random.key(0), cfg)
    f = _loss_and_batch(cfg, n_clients=2, batch=1, seq=16)
    seed = jnp.uint32(3)

    def temp(fn, *a):
        return jax.jit(fn).lower(*a).compile().memory_analysis() \
            .temp_size_in_bytes

    fwd = temp(lambda p: f(p), params)
    over = {m: temp(lambda p, m=m: zo.dual_forward(f, p, seed, 1e-3,
                                                   mode=m)[:2],
                    params) - fwd
            for m in ("chained", "fused")}
    theta = sum(x.size * x.dtype.itemsize
                for x in jax.tree_util.tree_leaves(params))
    # the fused dual never materializes a theta-sized perturbed tree: its
    # whole ZO overhead stays under one parameter copy
    assert over["fused"] < theta
    assert over["fused"] < 0.5 * over["chained"], over


# ---------------------------------------------------------------------------
# property lane: stream determinism across arbitrary shapes (slow lane)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_perturbed_z_stream_property():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st
    del hypothesis

    @settings(max_examples=25, deadline=None)
    @given(lead=st.integers(1, 8), rest=st.integers(1, 96),
           seed=st.integers(0, 2**32 - 1))
    def prop(lead, rest, seed):
        pp = tag_leaf(jnp.zeros((lead, rest), jnp.float32), seed=seed)
        z_ref = ref.draw_z_ref((lead, rest), zo.leaf_seed(seed, 0))
        z = ops.perturbed_z(pp)
        np.testing.assert_allclose(np.asarray(z), np.asarray(z_ref),
                                   rtol=0, atol=3e-7)
        # slices continue the stream
        sl = jax.tree_util.tree_map(lambda c: c[lead - 1], pp)
        np.testing.assert_allclose(np.asarray(ops.perturbed_z(sl)),
                                   np.asarray(z_ref[lead - 1]),
                                   rtol=0, atol=3e-7)

    prop()
