"""Dry-run machinery smoke test (subprocess: needs its own device count).

The full 40-cell × 2-mesh sweep is the deliverable run separately
(results/dryrun.json); here we prove the machinery end-to-end on a small
fake-device mesh so the test suite stays fast and self-contained.
"""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp

import repro.launch.dryrun as dr
import repro.launch.mesh as mesh_mod

# shrink the production mesh to the 8 fake devices: (data=2, model=4)
def small_mesh(*, multi_pod=False):
    if multi_pod:
        return mesh_mod.make_mesh_compat((2, 2, 2),
                                         ("pod", "data", "model"))
    return mesh_mod.make_mesh_compat((2, 4), ("data", "model"))

dr.make_production_mesh = small_mesh

# shrink the shape cells and configs
from repro.configs import base
small_shapes = {
    "train_4k": base.ShapeConfig("train_4k", 64, 8, "train"),
    "prefill_32k": base.ShapeConfig("prefill_32k", 128, 4, "prefill"),
    "decode_32k": base.ShapeConfig("decode_32k", 128, 8, "decode"),
}
dr.SHAPES_BY_NAME.update(small_shapes)

from repro.models import registry
orig_get = registry.get_arch
registry.get_arch = lambda a: orig_get(a).reduced(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=512, head_dim=16)

results = []
for shape in ("train_4k", "prefill_32k", "decode_32k"):
    for multi in (False, True):
        r = dr.run_cell("yi-6b", shape, multi, "zo",
                        with_roofline=(shape == "train_4k" and not multi))
        results.append({"cell": r["cell"], "status": r["status"],
                        "err": r.get("error", ""),
                        "has_roofline": "roofline" in r})
print("RESULTS" + json.dumps(results))
"""


@pytest.mark.slow
def test_dryrun_machinery_small_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines()
            if l.startswith("RESULTS")][0]
    results = json.loads(line[len("RESULTS"):])
    assert len(results) == 6
    for r in results:
        assert r["status"] == "ok", r
    assert any(r["has_roofline"] for r in results)
