"""Checkpointing: atomic save/restore, corruption detection, retention."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt


@pytest.fixture
def params():
    return {"layer": {"w": jnp.arange(12.0).reshape(3, 4),
                      "b": jnp.ones((4,))},
            "head": jnp.full((2, 2), 7.0)}


def test_save_restore_roundtrip(tmp_path, params):
    path = ckpt.save(str(tmp_path), 42, params,
                     extra={"accountant": {"spent": 0.5}})
    like = jax.tree_util.tree_map(jnp.zeros_like, params)
    restored, step, extra = ckpt.restore(path, like)
    assert step == 42
    assert extra["accountant"]["spent"] == 0.5
    for a, b in zip(jax.tree_util.tree_leaves(restored),
                    jax.tree_util.tree_leaves(params), strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_retention(tmp_path, params):
    for step in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), step, params, keep=3)
    assert ckpt.latest(str(tmp_path)).endswith("step_00000005")
    remaining = sorted(os.listdir(tmp_path))
    assert remaining == ["step_00000003", "step_00000004", "step_00000005"]


def test_corruption_detected(tmp_path, params):
    path = ckpt.save(str(tmp_path), 1, params)
    npz = os.path.join(path, "arrays.npz")
    data = dict(np.load(npz).items())
    first = sorted(data)[0]
    data[first] = data[first] + 1.0          # flip bits
    np.savez(npz, **data)
    like = jax.tree_util.tree_map(jnp.zeros_like, params)
    with pytest.raises(IOError, match="corruption"):
        ckpt.restore(path, like)


def test_shape_mismatch_detected(tmp_path, params):
    path = ckpt.save(str(tmp_path), 1, params)
    bad = {"layer": {"w": jnp.zeros((5, 5)), "b": jnp.zeros((4,))},
           "head": jnp.zeros((2, 2))}
    with pytest.raises(ValueError, match="shape"):
        ckpt.restore(path, bad)


def test_latest_none_when_empty(tmp_path):
    assert ckpt.latest(str(tmp_path)) is None
    assert ckpt.latest(str(tmp_path / "missing")) is None


def test_manifest_is_valid_json(tmp_path, params):
    path = ckpt.save(str(tmp_path), 9, params)
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["step"] == 9
    assert set(manifest["crc32"]) == set(manifest["shapes"])


@pytest.mark.parametrize("double_buffer", [True, False])
def test_async_checkpointer_roundtrip(tmp_path, params, double_buffer):
    import jax.numpy as jnp
    acp = ckpt.AsyncCheckpointer(str(tmp_path), keep=2,
                                 double_buffer=double_buffer)
    for step in (1, 2, 3):
        bumped = jax.tree_util.tree_map(lambda a: a + step, params)
        acp.save(step, bumped, extra={"round": step})
    acp.wait()
    assert ckpt.latest(str(tmp_path)).endswith("step_00000003")
    like = jax.tree_util.tree_map(jnp.zeros_like, params)
    restored, step, extra = ckpt.restore(ckpt.latest(str(tmp_path)), like)
    assert step == 3 and extra["round"] == 3
    np.testing.assert_array_equal(
        np.asarray(restored["head"]), np.asarray(params["head"]) + 3)
    assert sorted(os.listdir(tmp_path)) == ["step_00000002",
                                            "step_00000003"]
    assert acp.stall_s >= 0.0


def test_double_buffered_snapshot_survives_donation(tmp_path, params):
    """The regression the double-buffer exists for: the carry is donated to
    the next chunk IMMEDIATELY after save() returns, long before the writer
    thread materializes the snapshot. The checkpoint must still hold the
    pre-donation values bit for bit."""
    import jax.numpy as jnp
    acp = ckpt.AsyncCheckpointer(str(tmp_path), double_buffer=True)
    acp.save(1, params, extra={})
    # donate the original buffers (what ScanExecutor's chunk dispatch does)
    bump = jax.jit(lambda t: jax.tree_util.tree_map(lambda x: x * 2.0, t),
                   donate_argnums=0)
    bumped = bump(params)
    jax.block_until_ready(bumped)
    acp.wait()
    like = jax.tree_util.tree_map(jnp.zeros_like, bumped)
    restored, step, _ = ckpt.restore(ckpt.latest(str(tmp_path)), like)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(restored["layer"]["w"]),
                                  np.arange(12.0).reshape(3, 4))


def test_async_writer_ioerror_keeps_last_good(tmp_path, params,
                                              monkeypatch):
    """A real IOError on the writer thread (disk full, permissions) is
    retried, then swallowed under the keep-last-good contract — it must
    never propagate into the training thread, and the previous checkpoint
    must survive untouched."""
    acp = ckpt.AsyncCheckpointer(str(tmp_path), write_retries=2)
    acp.save(1, params, extra={})
    acp.wait()

    def broken_save(*a, **kw):
        raise IOError("No space left on device")

    monkeypatch.setattr(ckpt, "save", broken_save)
    acp.save(2, params, extra={})       # returns immediately, no raise
    acp.wait()                          # writer thread swallowed the error
    assert acp.write_failures == 1
    assert acp.retries.get("ckpt_write", 0) == 1     # write_retries - 1
    monkeypatch.undo()
    assert ckpt.latest_valid(str(tmp_path)).endswith("step_00000001")
    like = jax.tree_util.tree_map(jnp.zeros_like, params)
    restored, step, _ = ckpt.restore(ckpt.latest_valid(str(tmp_path)), like)
    assert step == 1


def test_restore_rejects_torn_npz(tmp_path, params):
    """A half-written arrays.npz (manifest intact) must never restore."""
    path = ckpt.save(str(tmp_path), 3, params)
    ckpt.tear_checkpoint(path)
    like = jax.tree_util.tree_map(jnp.zeros_like, params)
    with pytest.raises(Exception):      # BadZipFile/IOError: anything but
        ckpt.restore(path, like)        # a silent half-restore


def test_valid_checkpoint_and_latest_valid_walk(tmp_path, params):
    """latest_valid walks newest-first past any mix of damage: torn npz,
    missing manifest, missing npz — and returns None when nothing valid
    survives."""
    assert ckpt.latest_valid(str(tmp_path / "missing")) is None
    for step in (1, 2, 3, 4):
        ckpt.save(str(tmp_path), step, params, keep=10)
    assert ckpt.valid_checkpoint(str(tmp_path / "step_00000004"))
    ckpt.tear_checkpoint(str(tmp_path / "step_00000004"))
    os.remove(tmp_path / "step_00000003" / "manifest.json")
    os.remove(tmp_path / "step_00000002" / "arrays.npz")
    assert not ckpt.valid_checkpoint(str(tmp_path / "step_00000004"))
    # naive latest() still points at the torn one; the CRC walk recovers
    assert ckpt.latest(str(tmp_path)).endswith("step_00000004")
    assert ckpt.latest_valid(str(tmp_path)).endswith("step_00000001")
    ckpt.tear_checkpoint(str(tmp_path / "step_00000001"))
    assert ckpt.latest_valid(str(tmp_path)) is None


def test_numpy_params_fall_back_to_sync_snapshot(tmp_path):
    """Host-side pytrees (no jax arrays) take the synchronous path even
    with double_buffer on — nothing to copy_to_host_async."""
    host = {"w": np.arange(6.0).reshape(2, 3)}
    acp = ckpt.AsyncCheckpointer(str(tmp_path), double_buffer=True)
    acp.save(5, host, extra={})
    acp.wait()
    restored, step, _ = ckpt.restore(ckpt.latest(str(tmp_path)),
                                     {"w": np.zeros((2, 3))})
    assert step == 5
    np.testing.assert_array_equal(np.asarray(restored["w"]), host["w"])
