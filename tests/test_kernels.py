"""Per-kernel sweeps: Pallas (interpret mode) vs pure-jnp oracles in ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

jax.config.update("jax_enable_x64", False)


# ---------------------------------------------------------------------------
# seeded_axpy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(128,), (300, 70), (8, 16, 33),
                                   (1, 1), (5000,)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_seeded_axpy_matches_ref(shape, dtype):
    w = jax.random.normal(jax.random.key(0), shape, jnp.float32).astype(dtype)
    o_ref = ref.seeded_axpy_ref(w, 42, 0.25)
    o_pl = ops.seeded_axpy(w, 42, 0.25, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(o_pl, np.float32),
                               np.asarray(o_ref, np.float32),
                               atol=2e-2 if dtype == jnp.bfloat16 else 1e-6)


@pytest.mark.parametrize("shape", [(256,), (64, 50)])
def test_seeded_axpy_z_stream_bitwise(shape):
    """The z-stream itself is bitwise identical: kernel == XLA == ref."""
    zeros = jnp.zeros(shape, jnp.float32)
    z_ref = ref.seeded_axpy_ref(zeros, 7, 1.0)
    z_pl = ops.seeded_axpy(zeros, 7, 1.0, impl="pallas_interpret")
    z_xla = ops.seeded_axpy(zeros, 7, 1.0, impl="xla")
    assert np.array_equal(np.asarray(z_ref), np.asarray(z_pl))
    assert np.array_equal(np.asarray(z_ref), np.asarray(z_xla))


def test_seeded_axpy_deterministic_and_seed_sensitive():
    w = jnp.zeros((1000,), jnp.float32)
    a = ops.seeded_axpy(w, 3, 1.0, impl="xla")
    b = ops.seeded_axpy(w, 3, 1.0, impl="xla")
    c = ops.seeded_axpy(w, 4, 1.0, impl="xla")
    assert np.array_equal(np.asarray(a), np.asarray(b))
    assert not np.array_equal(np.asarray(a), np.asarray(c))


def test_seeded_axpy_gaussian_moments():
    z = np.asarray(ops.seeded_axpy(jnp.zeros(200_000), 11, 1.0, impl="xla"))
    assert abs(z.mean()) < 0.01
    assert abs(z.std() - 1.0) < 0.01
    assert abs((z ** 3).mean()) < 0.05         # skewness
    assert abs((z ** 4).mean() - 3.0) < 0.15   # kurtosis


def test_mezo_chain_restores_weights():
    """w → +μz → −2μz → +μz returns w (the MeZO memory trick)."""
    w = jax.random.normal(jax.random.key(1), (400, 30))
    mu = 1e-3
    p1 = ops.seeded_axpy(w, 9, mu, impl="xla")
    p2 = ops.seeded_axpy(p1, 9, -2 * mu, impl="xla")
    p3 = ops.seeded_axpy(p2, 9, mu, impl="xla")
    np.testing.assert_allclose(np.asarray(p3), np.asarray(w), atol=1e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

ATTN_CASES = [
    # (b, hq, hkv, sq, skv, d, causal, window)
    (2, 4, 4, 64, 64, 32, True, None),       # MHA causal
    (2, 8, 2, 64, 64, 32, True, None),       # GQA
    (1, 4, 1, 128, 128, 64, True, None),     # MQA
    (2, 4, 4, 64, 64, 32, False, None),      # bidirectional (encoder)
    (2, 4, 2, 64, 64, 32, True, 16),         # local window
    (1, 4, 2, 1, 64, 32, True, None),        # decode: q = last position
    (2, 4, 4, 48, 96, 32, True, None),       # chunked prefill (sq < skv)
]


@pytest.mark.parametrize("case", ATTN_CASES)
def test_flash_attention_vs_ref(case):
    b, hq, hkv, sq, skv, d, causal, window = case
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, hq, sq, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, hkv, skv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, hkv, skv, d), jnp.float32)
    o_ref = ref.attention_ref(q, k, v, causal=causal, window=window)
    o_pl = ops.attention(q, k, v, causal=causal, window=window,
                         impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(o_pl), np.asarray(o_ref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("case", ATTN_CASES)
def test_xla_chunked_attention_vs_ref(case):
    b, hq, hkv, sq, skv, d, causal, window = case
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (b, hq, sq, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, hkv, skv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, hkv, skv, d), jnp.float32)
    o_ref = ref.attention_ref(q, k, v, causal=causal, window=window)
    o_x = ops.attention(q, k, v, causal=causal, window=window,
                        impl="xla_chunked")
    np.testing.assert_allclose(np.asarray(o_x), np.asarray(o_ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_bf16():
    ks = jax.random.split(jax.random.key(2), 3)
    q = jax.random.normal(ks[0], (1, 4, 64, 32)).astype(jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 2, 64, 32)).astype(jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 2, 64, 32)).astype(jnp.bfloat16)
    o_ref = ref.attention_ref(q, k, v, causal=True)
    o_pl = ops.attention(q, k, v, causal=True, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(o_pl, np.float32),
                               np.asarray(o_ref, np.float32), atol=3e-2)


# ---------------------------------------------------------------------------
# RG-LRU linear recurrence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(1, 32, 16), (3, 64, 48), (2, 128, 256)])
def test_linear_recurrence(shape):
    b, s, d = shape
    ks = jax.random.split(jax.random.key(3), 3)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], shape))
    x = jax.random.normal(ks[1], shape)
    h0 = jax.random.normal(ks[2], (b, d))
    hs_ref, hl_ref = ref.linear_recurrence_ref(a, x, h0)
    for impl in ("xla", "pallas_interpret"):
        hs, hl = ops.linear_recurrence(a, x, h0, impl=impl)
        np.testing.assert_allclose(np.asarray(hs), np.asarray(hs_ref),
                                   atol=2e-5, rtol=2e-4, err_msg=impl)
        np.testing.assert_allclose(np.asarray(hl), np.asarray(hl_ref),
                                   atol=2e-5, rtol=2e-4, err_msg=impl)


# ---------------------------------------------------------------------------
# Mamba-2 SSD
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dims", [(1, 32, 2, 8, 16, 16),
                                  (2, 64, 4, 16, 32, 16),
                                  (1, 128, 2, 64, 128, 32)])
def test_ssd(dims):
    B, S, H, P, N, chunk = dims
    ks = jax.random.split(jax.random.key(4), 5)
    x = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    b = jax.random.normal(ks[3], (B, S, N)) * 0.3
    c = jax.random.normal(ks[4], (B, S, N)) * 0.3
    s0 = jnp.zeros((B, H, P, N))
    y_ref, st_ref = ref.ssd_ref(x, dt, a, b, c, s0)
    for impl in ("xla", "pallas_interpret"):
        y, st = ops.ssd(x, dt, a, b, c, s0, chunk=chunk, impl=impl)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   atol=1e-3, rtol=1e-3, err_msg=impl)
        np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref),
                                   atol=1e-3, rtol=1e-3, err_msg=impl)


def test_ssd_decode_step_matches_scan():
    """Sequential decode steps reproduce the chunked scan outputs."""
    B, S, H, P, N = 1, 16, 2, 8, 16
    ks = jax.random.split(jax.random.key(5), 5)
    x = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    b = jax.random.normal(ks[3], (B, S, N)) * 0.3
    c = jax.random.normal(ks[4], (B, S, N)) * 0.3
    y_ref, st_ref = ref.ssd_ref(x, dt, a, b, c)
    state = jnp.zeros((B, H, P, N))
    ys = []
    for t in range(S):
        y_t, state = ops.ssd_decode_step(state, x[:, t], dt[:, t], a,
                                         b[:, t], c[:, t])
        ys.append(y_t)
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(state), np.asarray(st_ref),
                               atol=1e-4, rtol=1e-4)
