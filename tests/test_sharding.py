"""Sharding rules + roofline machinery unit tests (host-side, no devices)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.roofline import collective_bytes
from repro.models import registry
from repro.runtime import sharding as shd


@pytest.fixture
def mesh():
    # 1-device "production-shaped" mesh: axis names real, sizes 1 — lets the
    # spec logic run on CPU without fake-device flags
    from repro.launch.mesh import make_mesh_compat
    return make_mesh_compat((1, 1), ("data", "model"))


def _spec_for(mesh, tree, leaf_path):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    for path, leaf in flat:
        names = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        if names[-2:] == leaf_path or names[-1:] == leaf_path:
            return shd.param_spec(mesh, path, leaf)
    raise KeyError(leaf_path)


def test_param_specs_follow_roles(mesh):
    cfg = registry.get_arch("yi-6b")
    params = registry.abstract_params(cfg, jnp.bfloat16)
    # col-parallel: [L, d_in, d_out] → (None, fsdp, tp)
    assert _spec_for(mesh, params, ["wq"]) == P(None, ("data",), "model")
    # row-parallel: wo → (None, tp, fsdp)
    assert _spec_for(mesh, params, ["wo"]) == P(None, "model", ("data",))
    # embeddings: (tp on vocab, fsdp on d)
    assert _spec_for(mesh, params, ["embed", "w"]) == P("model", ("data",))
    # norms replicated
    assert _spec_for(mesh, params, ["final_norm", "g"]) == P(None)


def test_moe_down_projection_is_col_parallel(mesh):
    """§Perf iteration 2: we_d must be (E, F→fsdp, D→tp) — a TP-sharded F
    contraction would psum the k·cf× larger pre-combine tensor."""
    cfg = registry.get_arch("deepseek-v2-236b")
    params = registry.abstract_params(cfg, jnp.bfloat16)
    # stacked [L, E, F, D]: last-two dims carry the roles
    assert _spec_for(mesh, params, ["we_d"]) == P(None, None, ("data",),
                                                  "model")
    assert _spec_for(mesh, params, ["we_i"]) == P(None, None, ("data",),
                                                  "model")


def test_divisibility_guard(mesh):
    # vocab 73448 not divisible by 1? always divisible by 1 — use a spec
    # helper directly with a fake axis size via _maybe logic
    assert shd._maybe(mesh, "model", 10) == "model"  # size 1 divides all


def test_batch_sharding_client_axis(mesh):
    batch = {"tokens": jax.ShapeDtypeStruct((4, 8, 16), jnp.int32)}
    s = shd.batch_sharding(mesh, batch)["tokens"]
    assert s.spec == P(("data",), None, None)


def test_cache_sharding_longest_dim(mesh):
    cache = {"k": jax.ShapeDtypeStruct((4, 8, 1024, 2, 64), jnp.bfloat16)}
    s = shd.cache_sharding(mesh, cache)["k"]
    # layer dim None, batch over clients, longest (seq=1024) over model
    assert s.spec == P(None, ("data",), "model", None, None)


def test_hint_noop_outside_context():
    x = jnp.ones((4, 4))
    assert shd.hint(x, "client", "model") is x


def test_hint_applies_in_context(mesh):
    x = jnp.ones((4, 4))
    with shd.hints(mesh):
        y = jax.jit(lambda a: shd.hint(a, "client", "model"))(x)
    assert (y == x).all()


# ---------------------------------------------------------------------------
# HLO collective parser
# ---------------------------------------------------------------------------

def test_collective_bytes_parser():
    hlo = """
  %ag = f32[16,1024]{1,0} all-gather(f32[1,1024]{1,0} %x), dimensions={0}
  %ar = bf16[8,8]{1,0} all-reduce(bf16[8,8]{1,0} %y), to_apply=%add
  %cp = f32[4]{0} collective-permute(f32[4]{0} %z), source_target_pairs={{0,1}}
  %dot = f32[128,128]{1,0} dot(f32[128,64]{1,0} %a, f32[64,128]{1,0} %b)
"""
    total, by_op = collective_bytes(hlo)
    assert by_op["all-gather"] == 16 * 1024 * 4
    assert by_op["all-reduce"] == 8 * 8 * 2
    assert by_op["collective-permute"] == 4 * 4
    assert total == sum(by_op.values())
    assert "dot" not in by_op


def test_collective_bytes_tuple_shapes():
    hlo = ("%f = (f32[2,3]{1,0}, f32[4]{0}) all-reduce(f32[2,3] %a, "
           "f32[4] %b), to_apply=%add")
    total, by_op = collective_bytes(hlo)
    assert total == (2 * 3 + 4) * 4


def test_model_flops_conventions():
    from repro.configs.base import SHAPES_BY_NAME
    from repro.launch.roofline import model_flops
    cfg = registry.get_arch("yi-6b")
    n = registry.count_params(cfg)
    s = SHAPES_BY_NAME["train_4k"]
    assert model_flops(cfg, s) == 6.0 * n * s.global_batch * s.seq_len
    d = SHAPES_BY_NAME["decode_32k"]
    assert model_flops(cfg, d) == 2.0 * n * d.global_batch
