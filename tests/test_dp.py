"""DP accountant: C/C⁻¹, R_dp, budget tracking, checkpoint continuity."""
import math

import pytest

from repro.core import dp


def test_c_inverse_roundtrip():
    for x in (0.01, 0.5, 1.0, 2.0, 3.5):
        y = dp.c_func(x)
        assert abs(dp.c_inverse(y) - x) < 1e-9 * max(1.0, x)


def test_c_inverse_of_large_values():
    # 1/δ for δ=0.01 → C⁻¹(100)
    x = dp.c_inverse(100.0)
    assert abs(dp.c_func(x) - 100.0) < 1e-6 * 100.0


def test_r_dp_monotone_in_epsilon_and_delta():
    base = dp.r_dp(5.0, 0.01)
    assert dp.r_dp(10.0, 0.01) > base
    assert dp.r_dp(5.0, 0.05) > base
    assert base > 0


def test_r_dp_paper_setting():
    """The paper's (ε=5, δ=0.01) budget is finite and small."""
    r = dp.r_dp(5.0, 0.01)
    assert 0.1 < r < 5.0


def test_round_cost_formula():
    # (√2·c·γ/m)² = 2 c² γ² / m²
    assert abs(dp.round_privacy_cost(2.0, 3.0, 4.0)
               - 2 * (2 * 3 / 4) ** 2) < 1e-12


def test_accountant_tracks_and_guards():
    acc = dp.PrivacyAccountant(5.0, 0.01)
    budget = acc.budget
    cost = dp.round_privacy_cost(0.1, 1.0, 1.0)
    n_affordable = int(budget / cost)
    for _ in range(n_affordable):
        acc.charge(0.1, 1.0, 1.0)
    assert acc.spent <= budget + 1e-9
    assert acc.would_violate(0.1, 1.0, 1.0) or acc.remaining < cost


def test_accountant_checkpoint_roundtrip():
    acc = dp.PrivacyAccountant(5.0, 0.01)
    acc.charge(0.5, 2.0, 1.5)
    acc.charge(0.3, 2.0, 1.5)
    restored = dp.PrivacyAccountant.from_state_dict(acc.state_dict())
    assert restored.spent == pytest.approx(acc.spent)
    assert restored.budget == pytest.approx(acc.budget)


def test_invalid_args_raise():
    with pytest.raises(ValueError):
        dp.r_dp(-1.0, 0.01)
    with pytest.raises(ValueError):
        dp.r_dp(5.0, 1.5)
    with pytest.raises(ValueError):
        dp.round_privacy_cost(1.0, 1.0, 0.0)
