"""Power control (Theorems 3 & 4): constraint satisfaction + optimality."""
import numpy as np
import pytest

from repro.channel import RayleighFading
from repro.core import power_control as pc
from repro.core.dp import r_dp

EPS, DELTA = 5.0, 0.01
T, K = 200, 5


@pytest.fixture
def channels():
    return RayleighFading().realize(0, T, K).h


def _check_constraints(sched, h, *, power, n0, gamma, budget, d=1):
    # DP constraint (C1)/(C3)
    spent = sched.privacy_cost(np.full(T, gamma))
    assert spent <= budget * (1 + 1e-9), (spent, budget)
    # power constraint (C2)/(C4)
    tx = pc.transmit_power(sched, h, gamma, d)
    assert (tx <= power * (1 + 1e-9)).all(), tx.max()
    return spent


def test_analog_solution_constraints(channels):
    budget = r_dp(EPS, DELTA)
    sched = pc.solve_analog(channels, power=100.0, n0=1.0, gamma=100.0,
                            contraction_a=0.998, epsilon=EPS, delta=DELTA)
    spent = _check_constraints(sched, channels, power=100.0, n0=1.0,
                               gamma=100.0, budget=budget)
    # budget-limited regime → constraint active (equality)
    assert spent > 0.999 * budget
    assert (sched.sigma == 0).all()             # Theorem 3: σ* = 0


def test_analog_full_power_branch():
    """With a huge budget the power constraint binds instead."""
    h = RayleighFading().realize(1, 10, K).h
    sched = pc.solve_analog(h, power=1e-4, n0=1e6, gamma=100.0,
                            contraction_a=0.998, epsilon=50.0, delta=0.1)
    assert sched.zeta == 0.0                    # condition (28) branch
    cap = np.min(np.sqrt(1e-4) * h / 100.0, axis=1)
    np.testing.assert_allclose(sched.c, cap, rtol=1e-12)


def test_analog_adaptive_term_increases(channels):
    """A^{-t/4} ⇒ later rounds get larger gain (cleaner aggregation)."""
    sched = pc.solve_analog(channels, power=1e9, n0=1.0, gamma=100.0,
                            contraction_a=0.998, epsilon=EPS, delta=DELTA)
    # with a huge power cap the adaptive term is exposed directly
    assert sched.c[-1] > sched.c[0]
    ratio = sched.c[-1] / sched.c[0]
    assert abs(ratio - 0.998 ** (-(T - 1) / 4.0)) < 1e-3 * ratio


def test_sign_solution_constraints(channels):
    budget = r_dp(EPS, DELTA)
    sched = pc.solve_sign(channels, power=100.0, n0=1.0, n_clients=K,
                          e0=0.496, contraction_a_tilde=0.998,
                          epsilon=EPS, delta=DELTA)
    spent = _check_constraints(sched, channels, power=100.0, n0=1.0,
                               gamma=1.0, budget=budget)
    assert spent > 0.99 * budget
    assert (sched.sigma == 0).all()             # Theorem 4: σ* = 0


def test_sign_full_power_branch():
    h = RayleighFading().realize(2, 10, K).h
    sched = pc.solve_sign(h, power=1e-6, n0=1e4, n_clients=K, e0=0.496,
                          contraction_a_tilde=0.998, epsilon=50.0, delta=0.1)
    assert sched.zeta == 0.0
    cap = np.min(np.sqrt(1e-6) * h, axis=1)
    np.testing.assert_allclose(sched.c, cap, rtol=1e-12)


def test_static_spends_budget_evenly(channels):
    budget = r_dp(EPS, DELTA)
    sched = pc.static_analog(channels, power=1e9, n0=1.0, gamma=100.0,
                             epsilon=EPS, delta=DELTA)
    costs = [2 * (sched.c[t] * 100.0 / sched.effective_noise_std(t)) ** 2
             for t in range(T)]
    np.testing.assert_allclose(costs, budget / T, rtol=1e-9)


def test_solution_beats_static_and_reversed_on_bound(channels):
    """The optimization objective Σ A^{-t}(Σσ² + N0/c²) — Theorem 3's
    solution must dominate both ablation baselines."""
    a = 0.998
    kw = dict(power=100.0, n0=1.0, gamma=100.0, epsilon=EPS, delta=DELTA)
    sol = pc.solve_analog(channels, contraction_a=a, **kw)
    sta = pc.static_analog(channels, **kw)
    rev = pc.reversed_analog(channels, contraction_a=a, **kw)

    def bound(s):
        t_idx = np.arange(1, T + 1)
        with np.errstate(divide="ignore"):
            return np.sum(a ** (-t_idx) * (np.sum(s.sigma ** 2, axis=1)
                                           + 1.0 / s.c ** 2))

    assert bound(sol) <= bound(sta) * (1 + 1e-9)
    assert bound(sol) <= bound(rev) * (1 + 1e-9)


def test_make_schedule_dispatch(channels):
    for variant in ("analog", "sign"):
        for scheme in ("solution", "static", "reversed", "perfect"):
            s = pc.make_schedule(variant, scheme, channels, power=100.0,
                                 n0=1.0, gamma=100.0, n_clients=K, e0=0.496,
                                 contraction_a=0.998,
                                 contraction_a_tilde=0.998,
                                 epsilon=EPS, delta=DELTA)
            assert s.c.shape == (T,)
            assert s.sigma.shape == (T, K)
            assert np.isfinite(s.c).all()
