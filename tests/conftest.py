"""Shared fixtures: reduced configs, deterministic pipelines, fixed seeds.

Every fixture is seeded — a test that wants different randomness must ask
for it explicitly (factories take a `seed` argument). Library code only uses
`np.random.default_rng(seed)` / jax keys, so the autouse global seed below is
belt-and-braces for any stray `np.random.*` call in tests themselves.
"""
import numpy as np
import pytest

from repro.configs.base import (ChannelConfig, DPConfig, ModelConfig,
                                PairZeroConfig, PowerControlConfig, ZOConfig)
from repro.data.pipeline import FederatedPipeline
from repro.data.tasks import TaskSpec


@pytest.fixture(autouse=True)
def _fixed_global_seed():
    np.random.seed(0)


@pytest.fixture
def tiny_model() -> ModelConfig:
    """The 2-layer dense model the system tests train on CPU."""
    return ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64,
                       head_dim=16)


@pytest.fixture
def opt125m_reduced() -> ModelConfig:
    """The paper's own architecture, reduced to CPU scale."""
    from repro.models import registry
    return registry.get_arch("opt-125m").reduced()


@pytest.fixture
def make_pipeline():
    """Factory: seeded FederatedPipeline for (vocab, seq, task, seed)."""
    def _make(vocab: int = 64, seq: int = 24, task: str = "sst2",
              seed: int = 0, n_clients: int = 5, batch: int = 8
              ) -> FederatedPipeline:
        return FederatedPipeline(task=task, spec=TaskSpec(task, vocab, seq),
                                 n_clients=n_clients, per_client_batch=batch,
                                 seed=seed)
    return _make


@pytest.fixture
def make_pz():
    """Factory: PairZeroConfig with fixed seed and CPU-scale defaults."""
    def _make(variant: str = "analog", scheme: str = "solution",
              lr: float = 5e-3, n_perturb: int = 1, eps: float = 5.0,
              rounds: int = 8, seed: int = 0, gamma: float = 5.0,
              n_clients: int = 5) -> PairZeroConfig:
        return PairZeroConfig(
            variant=variant, n_clients=n_clients, rounds=rounds,
            zo=ZOConfig(mu=1e-3, lr=lr, clip_gamma=gamma,
                        n_perturb=n_perturb),
            channel=ChannelConfig(n0=1.0, power=100.0),
            dp=DPConfig(epsilon=eps, delta=0.01),
            power=PowerControlConfig(scheme=scheme), seed=seed)
    return _make
