"""Desynchronization modeling: trace determinism, ctl rows, neutrality.

The load-bearing contract: with `desync=None` (or an inert config) every
engine traces the bit-exact historical program — the dsync_* ctl rows are
absent and `desync.stale_payload` is never called. With an active model,
loop and scan stay bitwise identical to each other while the trajectory
genuinely diverges from the clean run.
"""
import dataclasses

import numpy as np
import pytest

from repro.configs.base import DesyncConfig
from repro.core import engine as eng
from repro.core import fedsim, pairzero
from repro.core import power_control as pc
from repro.runtime import desync as ds


# ---------------------------------------------------------------------------
# DesyncModel: validation, determinism, chunk invariance
# ---------------------------------------------------------------------------

def test_model_validation():
    with pytest.raises(ValueError, match="fraction"):
        ds.DesyncModel(fraction=1.5)
    with pytest.raises(ValueError, match="max_lag"):
        ds.DesyncModel(max_lag=0)
    with pytest.raises(ValueError, match="phase_std"):
        ds.DesyncModel(phase_std=-0.1)
    with pytest.raises(ValueError, match="frame_symbols"):
        ds.DesyncModel(frame_symbols=0)


def test_active_property():
    assert not ds.DesyncModel().active
    assert not ds.DesyncModel(max_lag=7, frame_symbols=64).active
    assert ds.DesyncModel(fraction=0.1).active
    assert ds.DesyncModel(phase_std=0.1).active


def test_resolve_inert_config_is_none():
    """An all-zero DesyncConfig must resolve to the historical program."""

    class FakePz:
        desync = DesyncConfig(fraction=0.0, phase_std=0.0)

    assert ds.resolve(FakePz()) is None
    FakePz.desync = None
    assert ds.resolve(FakePz()) is None
    FakePz.desync = DesyncConfig(fraction=0.5)
    assert ds.resolve(FakePz()).fraction == 0.5


def test_sync_trace_chunk_invariant():
    """Per-round seeding: the realization is identical however the round
    range is split (the property resume + scan chunking rely on)."""
    m = ds.DesyncModel(fraction=0.4, max_lag=3, phase_std=0.2, seed=5)
    whole = m.sync_trace(0, 12, 6)
    a = m.sync_trace(0, 7, 6)
    b = m.sync_trace(7, 12, 6)
    for w, x, y in zip(whole, a, b, strict=True):
        np.testing.assert_array_equal(w, np.concatenate([x, y]))


def test_sync_trace_stale_zero_before_lag():
    """Round t can only be stale against an existing round t-d >= 0."""
    m = ds.DesyncModel(fraction=1.0, max_lag=4, seed=0)
    stale, lag, _, _ = m.sync_trace(0, 20, 4)
    for i in range(20):
        if i < lag[i]:
            assert stale[i].sum() == 0.0
    # and staleness does occur once rounds exist to be stale against
    assert stale[10:].sum() > 0


def test_frame_gain_limits():
    # n=1 is the scalar payload: no frame to decohere, gain 1 everywhere
    theta = np.linspace(-1.0, 1.0, 11)
    np.testing.assert_allclose(ds.frame_gain(theta, 1), np.ones(11))
    # theta=0 is perfect sync at any frame length
    assert ds.frame_gain(np.zeros(3), 64) == pytest.approx(1.0)
    # the claim cell: a 64-symbol frame at 0.3 rad has collapsed
    assert ds.frame_gain(np.array([0.3]), 64)[0] < 0.05
    # gain is an attenuation, never again
    assert (ds.frame_gain(theta, 64) <= 1.0 + 1e-12).all()


def test_control_rows_lagged_seed():
    """dsync_seed is zo.round_seed(base, t - d) with the same d the
    sync trace drew (clamped at round 0)."""
    from repro.core import zo
    m = ds.DesyncModel(fraction=0.5, max_lag=3, seed=2)
    rows, stale = ds.control_rows(m, base_seed=11, t0=4, t1=10, n_clients=5)
    _, lag, _, _ = m.sync_trace(4, 10, 5)
    for i, t in enumerate(range(4, 10)):
        expect = np.uint32(zo.round_seed(11, np.uint32(max(t - lag[i], 0))))
        assert rows["dsync_seed"][i] == expect
    np.testing.assert_array_equal(stale, rows["dsync_stale"])


# ---------------------------------------------------------------------------
# ctl rows: only-when-active, shapes, chunk invariance through build_trace
# ---------------------------------------------------------------------------

def _schedule(pz, rounds):
    from repro.channel import RayleighFading
    h = RayleighFading().realize(pz.seed ^ 0xC4A7, rounds, pz.n_clients).h
    return pc.make_schedule(
        "analog", "solution", h, power=100.0, n0=1.0, gamma=5.0,
        n_clients=pz.n_clients, e0=pz.power.e0,
        contraction_a=pz.power.contraction_a,
        contraction_a_tilde=pz.power.contraction_a_tilde,
        epsilon=5.0, delta=0.01)


def test_ctl_rows_only_when_active(make_pz):
    pz = make_pz(rounds=8)
    sched = _schedule(pz, 8)
    off = eng.build_trace(sched, pz, 0, 8)
    for row in ("dsync_seed", "dsync_stale", "dsync_a", "dsync_frame"):
        assert row not in off.ctl
    assert off.host_stale is None

    model = ds.DesyncModel(fraction=0.5, max_lag=2, phase_std=0.2, seed=0)
    on = eng.build_trace(sched, pz, 0, 8, desync=model)
    assert np.asarray(on.ctl["dsync_seed"]).shape == (8,)
    for row in ("dsync_stale", "dsync_a", "dsync_frame"):
        assert np.asarray(on.ctl[row]).shape == (8, pz.n_clients)
    assert on.host_stale.shape == (8, pz.n_clients)
    # the non-dsync rows are untouched by the extra rows
    for key in off.ctl:
        np.testing.assert_array_equal(np.asarray(off.ctl[key]),
                                      np.asarray(on.ctl[key]))


def test_ctl_rows_chunk_invariant(make_pz):
    pz = make_pz(rounds=10)
    sched = _schedule(pz, 10)
    model = ds.DesyncModel(fraction=0.5, max_lag=2, phase_std=0.3, seed=1)
    whole = eng.build_trace(sched, pz, 0, 10, desync=model)
    a = eng.build_trace(sched, pz, 0, 6, desync=model)
    b = eng.build_trace(sched, pz, 6, 10, desync=model)
    for row in ("dsync_seed", "dsync_stale", "dsync_a", "dsync_frame"):
        np.testing.assert_array_equal(
            np.asarray(whole.ctl[row]),
            np.concatenate([np.asarray(a.ctl[row]),
                            np.asarray(b.ctl[row])]))


# ---------------------------------------------------------------------------
# Structural neutrality + engine equivalence (system level)
# ---------------------------------------------------------------------------

def _desynced_pz(make_pz, rounds=6, **kw):
    cfg = DesyncConfig(fraction=0.5, max_lag=2, phase_std=0.2, seed=0)
    return dataclasses.replace(make_pz(rounds=rounds, **kw), desync=cfg)


def test_historical_program_never_touches_desync(tiny_model, make_pz,
                                                 make_pipeline, monkeypatch):
    """Neutrality pin: without an active model the step function must not
    even CALL the desync helpers — the branch is absent from the trace,
    not dynamically disabled."""
    def boom(*a, **kw):
        raise AssertionError("desync helper reached from a clean run")

    monkeypatch.setattr(ds, "stale_payload", boom)
    monkeypatch.setattr(ds, "conventional_ici", boom)
    pairzero.make_zo_step.cache_clear()   # cached steps closed over the real fn
    pairzero.make_fo_step.cache_clear()
    pz = make_pz(rounds=3)
    fedsim.run(tiny_model, pz, make_pipeline(), rounds=3, engine="loop")
    fedsim.run(tiny_model, pz, make_pipeline(), rounds=3, engine="scan",
               chunk_rounds=2)
    fo = make_pz(variant="fo", scheme="perfect", rounds=3)
    fedsim.run(tiny_model, fo, make_pipeline(), rounds=3, engine="loop")


def test_inert_config_bitwise_equals_no_config(tiny_model, make_pz,
                                               make_pipeline):
    """DesyncConfig with every knob at zero == no config, bit for bit."""
    pz = make_pz(rounds=4)
    inert = dataclasses.replace(
        pz, desync=DesyncConfig(fraction=0.0, phase_std=0.0, max_lag=9))
    ref = fedsim.run(tiny_model, pz, make_pipeline(), rounds=4)
    res = fedsim.run(tiny_model, inert, make_pipeline(), rounds=4)
    assert res.losses == ref.losses
    assert res.p_hats == ref.p_hats


def test_desync_run_loop_scan_bitwise(tiny_model, make_pz, make_pipeline):
    """Active desync preserves the loop == scan bitwise contract, and the
    trajectory genuinely differs from the clean run."""
    pz = _desynced_pz(make_pz, rounds=6)
    loop = fedsim.run(tiny_model, pz, make_pipeline(), rounds=6,
                      engine="loop")
    scan = fedsim.run(tiny_model, pz, make_pipeline(), rounds=6,
                      engine="scan", chunk_rounds=4)
    assert scan.losses == loop.losses
    assert scan.p_hats == loop.p_hats
    clean = fedsim.run(tiny_model, make_pz(rounds=6), make_pipeline(),
                       rounds=6, engine="loop")
    assert loop.p_hats != clean.p_hats


def test_desync_fo_loop_scan_close(tiny_model, make_pz, make_pipeline):
    """The conventional-frame path (Dirichlet gain + ICI) runs on both
    engines; FO gets fp-tolerance like the clean FO baseline."""
    pz = _desynced_pz(make_pz, rounds=4, variant="fo", scheme="perfect")
    pz = dataclasses.replace(
        pz, desync=dataclasses.replace(pz.desync, frame_symbols=64))
    loop = fedsim.run(tiny_model, pz, make_pipeline(), rounds=4,
                      engine="loop")
    scan = fedsim.run(tiny_model, pz, make_pipeline(), rounds=4,
                      engine="scan", chunk_rounds=3)
    np.testing.assert_allclose(scan.losses, loop.losses, rtol=1e-5,
                               atol=1e-5)


def test_k_sync_accounting(tiny_model, make_pz, make_pipeline):
    """round_k_sync = surviving clients on the CURRENT round seed: equal to
    k_eff on clean runs, strictly below it on rounds with stale clients."""
    pz = _desynced_pz(make_pz, rounds=8)
    exp = fedsim.Experiment(tiny_model, pz, make_pipeline(), rounds=8,
                            engine="scan", chunk_rounds=3)
    exp.run()
    ks, ke = np.asarray(exp.round_k_sync), np.asarray(exp.round_k_eff)
    assert ks.shape == ke.shape == (8,)
    assert (ks <= ke + 1e-9).all() and (ks >= 0).all()
    stale_rows = np.asarray(exp.desync.sync_trace(0, 8, pz.n_clients)[0])
    expect = ke - stale_rows.sum(axis=1)   # full masks: every client alive
    np.testing.assert_allclose(ks, expect)
    assert (ks < ke).any()                 # the scenario genuinely bites

    clean = fedsim.Experiment(tiny_model, make_pz(rounds=4),
                              make_pipeline(), rounds=4)
    clean.run()
    np.testing.assert_array_equal(clean.round_k_sync, clean.round_k_eff)
